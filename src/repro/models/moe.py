"""Mixture-of-Experts FFN with expert parallelism via ``shard_map``.

Production pattern (arctic's 128 experts are ~940 GB in bf16 — they *must*
shard):

* expert weights shard on the **expert axis over the `model` mesh axis**
  (EP) and on the **hidden axis over the `data` mesh axis** (FSDP); the
  FSDP shards are all-gathered per layer inside the layer scan, so peak
  memory holds one layer's local experts only (~1.7 GB for arctic).
* activations are batch-sharded over `data` and replicated over `model`,
  so *no all-to-all is needed*: each model-rank routes its local copy of
  the tokens to the experts it owns, computes, and the per-rank partial
  outputs combine with one `psum` over `model` — the same collective
  pattern as a tensor-parallel FFN.
* token→expert assignment uses **sort-based dispatch** (argsort by expert
  id + capacity truncation) rather than one-hot dispatch einsums: gathers
  are bytes, not FLOPs, so `cost_analysis` FLOPs stay equal to the
  analytic 6·N_active·D (one-hot dispatch would inflate HLO FLOPs by
  ~T·E·C·d and poison the roofline).

Top-k routing with renormalised softmax gates, per-expert capacity
``C = round_up(T_local · k / E · capacity_factor)``, dropped tokens fall
back to the residual path (standard GShard behaviour).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, axis_size, init_dense, shard_map

__all__ = ["init_moe", "moe_ffn", "local_moe_ffn"]


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 4)
    p = {
        "router": init_dense(keys[0], (d, e), jnp.float32, fan_in=d),
        "w_gate": init_dense(keys[1], (e, d, dff), cfg.pdtype, fan_in=d),
        "w_up": init_dense(keys[2], (e, d, dff), cfg.pdtype, fan_in=d),
        "w_down": init_dense(keys[3], (e, dff, d), cfg.pdtype, fan_in=dff),
    }
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts) + 1
    return max(4, min(c, n_tokens * top_k))


def local_moe_ffn(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,                 # (T_local, d) tokens on this device
    *,
    model_axis: Optional[str] = None,
    fsdp_axes: Optional[Tuple[str, ...]] = None,
) -> jnp.ndarray:
    """Per-device MoE body (called inside shard_map, or standalone when
    both axis names are None for single-device tests)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_ranks = axis_size(model_axis) if model_axis else 1
    assert e % n_ranks == 0, f"{e} experts not divisible over {n_ranks} ranks"
    e_local = e // n_ranks
    cap = _capacity(t, e, k, cfg.capacity_factor)

    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    fsdp_size = 1
    if fsdp_axes:
        for a in fsdp_axes:
            fsdp_size *= axis_size(a)
    if fsdp_size > 1:
        # ZeRO-3: re-assemble this layer's local experts from FSDP shards
        w_gate = jax.lax.all_gather(w_gate, fsdp_axes, axis=2, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp_axes, axis=2, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axes, axis=1, tiled=True)

    # -- routing (computed redundantly on every model-rank; router is tiny)
    logits = (x.astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    my_rank = jax.lax.axis_index(model_axis) if model_axis else 0
    lo = my_rank * e_local

    flat_e = top_e.reshape(-1)                                # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)

    local = (flat_e >= lo) & (flat_e < lo + e_local)
    le = jnp.where(local, flat_e - lo, e_local)               # sentinel bucket

    # -- sort-based dispatch: rank of each assignment within its expert
    order = jnp.argsort(le, stable=True)
    le_s = le[order]
    seg_start = jnp.searchsorted(le_s, jnp.arange(e_local + 1))
    pos_in_e = jnp.arange(t * k) - seg_start[jnp.clip(le_s, 0, e_local)]
    keep = (le_s < e_local) & (pos_in_e < cap)
    slot = jnp.where(keep, le_s * cap + pos_in_e, e_local * cap)

    # -- gather tokens into (E_local, C, d) expert batches
    xe = jnp.zeros((e_local * cap + 1, d), x.dtype).at[slot].set(x[flat_t[order]])
    xe = xe[:-1].reshape(e_local, cap, d)

    # -- expert computation (the only FLOPs-bearing ops)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)                # (E_local, C, d)

    # -- combine: scatter-add weighted expert outputs back to token rows
    y_flat = jnp.concatenate(
        [ye.reshape(e_local * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0
    )
    contrib = y_flat[slot] * (flat_w[order] * keep)[:, None].astype(ye.dtype)
    out = jnp.zeros((t, d), ye.dtype).at[flat_t[order]].add(contrib)

    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out.astype(x.dtype)


def moe_ffn(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,                 # (B, S, d) global (inside pjit)
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
) -> jnp.ndarray:
    """Global MoE FFN: shard_map wrapper around :func:`local_moe_ffn`.

    With ``mesh=None`` runs the local body directly (single device).
    """
    b, s, d = x.shape
    if mesh is None:
        y = local_moe_ffn(cfg, p, x.reshape(b * s, d))
        return y.reshape(b, s, d)

    from jax.sharding import PartitionSpec as P

    data_axes = tuple(data_axes) if data_axes else None
    if data_axes is None:
        fsdp = None          # replicated batch (e.g. B=1 long-context decode)
    else:
        fsdp = data_axes if len(data_axes) > 1 else data_axes[0]  # ZeRO across pods
    in_specs = (
        P(data_axes, None, None),                    # x: batch over data
        {
            "router": P(None, None),
            "w_gate": P(model_axis, None, fsdp),
            "w_up": P(model_axis, None, fsdp),
            "w_down": P(model_axis, fsdp, None),
        },
    )
    out_spec = P(data_axes, None, None)

    def body(x_loc, p_loc):
        bl, sl, dl = x_loc.shape
        y = local_moe_ffn(
            cfg, p_loc, x_loc.reshape(bl * sl, dl),
            model_axis=model_axis, fsdp_axes=data_axes or None,
        )
        return y.reshape(bl, sl, dl)

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
        check_vma=False,
    )(x, p)
