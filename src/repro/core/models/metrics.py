"""Classification metrics — the paper evaluates with F1-macro (§VI-A),
weighting the fulfilled and unfulfilled classes equally."""

from __future__ import annotations

from typing import Dict

import numpy as np

__all__ = ["confusion", "f1_macro", "classification_report"]


def confusion(y_true: np.ndarray, y_pred: np.ndarray) -> np.ndarray:
    """2x2 confusion matrix, rows = true class, cols = predicted class."""
    y_true = np.asarray(y_true).astype(int).ravel()
    y_pred = np.asarray(y_pred).astype(int).ravel()
    cm = np.zeros((2, 2), dtype=np.int64)
    for t in (0, 1):
        for p in (0, 1):
            cm[t, p] = int(np.sum((y_true == t) & (y_pred == p)))
    return cm


def _f1(tp: int, fp: int, fn: int) -> float:
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 0.0


def f1_macro(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Unweighted mean of per-class F1 scores."""
    cm = confusion(y_true, y_pred)
    f1_pos = _f1(cm[1, 1], cm[0, 1], cm[1, 0])
    f1_neg = _f1(cm[0, 0], cm[1, 0], cm[0, 1])
    return 0.5 * (f1_pos + f1_neg)


def classification_report(y_true: np.ndarray, y_pred: np.ndarray) -> Dict[str, float]:
    cm = confusion(y_true, y_pred)
    tp, fp, fn, tn = cm[1, 1], cm[0, 1], cm[1, 0], cm[0, 0]
    return {
        "f1_macro": f1_macro(y_true, y_pred),
        "f1_available": _f1(tp, fp, fn),
        "f1_unavailable": _f1(tn, fn, fp),
        "accuracy": float((tp + tn) / max(1, cm.sum())),
        "support_available": float(tp + fn),
        "support_unavailable": float(tn + fp),
    }
