"""Paper-scale probing campaign: 68 instance types, 15 regions, 24 hours.

Reproduces the §III-B measurement study end to end and prints the
Table-I agreement statistics, the Fig.-3 co-interruption CDF and the
Fig.-5 cost comparison.  (~330k spot requests, well under a second via
the batched fleet engine.)  ``--engine`` picks the collector engine:
``fleet`` (default, batched numpy), ``scalar`` (the paper-faithful
per-pool object path) or ``sharded`` (the mesh-sharded JAX engine) —
same numbers from each, all three share the provider's counter-based
per-pool RNG streams.

Run:  PYTHONPATH=src python examples/probe_campaign.py [--engine fleet]
          [--pools 68] [--hours 24]
"""

import argparse
import time

from repro.core import (
    SimulatedProvider,
    cost_report,
    default_fleet,
    fraction_within,
    proximity_cdf,
    run_campaign,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("fleet", "scalar", "sharded"),
                    default="fleet",
                    help="batched fleet engine (default), per-pool scalar, "
                         "or the mesh-sharded JAX engine")
    ap.add_argument("--pools", type=int, default=68)
    ap.add_argument("--hours", type=float, default=24.0,
                    help="campaign duration (hours)")
    args = ap.parse_args(argv)

    fleet = default_fleet(args.pools, seed=0)
    regions = sorted({c.region for c in fleet})
    provider = SimulatedProvider(fleet, seed=1)
    t0 = time.perf_counter()
    campaign = run_campaign(
        provider, duration=args.hours * 3600.0, engine=args.engine
    )
    elapsed = time.perf_counter() - t0

    print(f"fleet: {len(fleet)} instance types x {len(regions)} regions "
          f"(engine={campaign.engine}, {elapsed:.2f}s wall)")
    print(f"requests submitted: {campaign.api_calls}")
    print(f"probe compute cost: ${campaign.probe_compute_cost:.2f}")

    print("\n== Table I: SnS vs running-instance agreement ==")
    eq = (campaign.s == campaign.running).mean() * 100
    gt = (campaign.running > campaign.s).mean() * 100
    lt = (campaign.running < campaign.s).mean() * 100
    print(f"Actual > SnS: {gt:5.2f}%   Actual = SnS: {eq:5.2f}%   "
          f"Actual < SnS: {lt:4.2f}%")
    print("paper (AWS):  22.31%              77.12%              0.56%")

    print("\n== Fig 3: co-interrupt proximity ==")
    grid, cdf = proximity_cdf(campaign.interruptions, [30, 60, 180, 600])
    for g, v in zip(grid, cdf):
        print(f"  within {int(g):4d}s: {v:.1%}")
    print(f"  (paper: >85% within 1 min, 92.9% within 3 min; "
          f"{len(campaign.interruptions)} events here)")

    print("\n== Fig 5: 24-hour monitoring cost ==")
    rep = cost_report(campaign)
    print(f"  continuous: ${rep.continuous:9.2f}   "
          f"({rep.continuous_over_sns:.1f}x SnS)")
    print(f"  periodic:   ${rep.periodic:9.2f}   "
          f"({rep.periodic_over_sns:.2f}x SnS)")
    print(f"  SnS:        ${rep.sns_total:9.2f}   "
          f"(compute ${rep.sns_compute:.2f} + serverless "
          f"${rep.sns_serverless:.2f})")
    print(f"  paper: 249.5x / 2.5x at 3.33x finer resolution")
    return campaign


if __name__ == "__main__":
    main()
