"""Checkpoint-policy properties: Young–Daly optimality, hazard clamps, panic.

Property tests (hypothesis) for the closed-form interval math in
``repro.fleet.ckpt_policy`` plus one end-to-end statistical check: under
exponential failures, the Young–Daly interval maximises useful work among
scanned fixed intervals when replayed through the goodput engine.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fleet import (
    FixedInterval,
    PolicyTable,
    SnSHazard,
    YoungDaly,
    hazard_tau,
    run_replay_batch,
)


class TestYoungDaly:
    @settings(max_examples=40, deadline=None)
    @given(delta=st.floats(1.0, 600.0), mtbf=st.floats(60.0, 1e6))
    def test_interval_closed_form(self, delta, mtbf):
        pol = YoungDaly(ckpt_cost=delta, mtbf=mtbf)
        assert math.isclose(pol.interval, math.sqrt(2.0 * delta * mtbf),
                            rel_tol=1e-12)

    def test_interval_optimal_under_exponential_failures(self):
        """τ* = sqrt(2δ·MTBF) beats 4× shorter and 4× longer fixed
        intervals on useful work (completed − rolled-back steps) when
        replayed against memoryless preemptions."""
        delta, mtbf, dt = 30.0, 3600.0, 60.0
        tau_star = YoungDaly(ckpt_cost=delta, mtbf=mtbf).interval
        rng = np.random.default_rng(0)
        rows, T = 64, 2000
        avail = ~(rng.random((rows, T)) < dt / mtbf)  # geometric ≈ exponential

        def useful(mult):
            got = run_replay_batch(
                avail, FixedInterval(tau_star * mult), dt=dt, step_time=1.0,
                ckpt_cost=delta, restore_cost=0.0, engine="scan")
            return int(got["steps_completed"].sum() - got["steps_lost"].sum())

        too_eager, opt, too_lazy = useful(0.25), useful(1.0), useful(4.0)
        assert opt > too_eager, (opt, too_eager)
        assert opt > too_lazy, (opt, too_lazy)


class TestSnSHazard:
    @settings(max_examples=40, deadline=None)
    @given(
        p=st.floats(0.0, 1.0),
        delta=st.floats(5.0, 300.0),
        horizon=st.floats(60.0, 3600.0),
    )
    def test_interval_clamped(self, p, delta, horizon):
        pol = SnSHazard(ckpt_cost=delta, horizon=horizon, tau_max=3600.0)
        iv = pol.interval(p)
        assert delta <= iv <= pol.tau_max

    @settings(max_examples=40, deadline=None)
    @given(p=st.floats(0.0, 1.0), delta=st.floats(5.0, 300.0))
    def test_panic_floors_at_two_delta(self, p, delta):
        """Sustained panic must re-write no faster than every 2δ — the
        override collapses τ to exactly 2δ, never below."""
        pol = SnSHazard(ckpt_cost=delta, horizon=900.0, panic_threshold=0.4)
        tau = float(pol.tau(p))
        if 1.0 - p >= pol.panic_threshold:
            assert tau == 2.0 * delta
        else:
            assert tau >= delta

    def test_interval_monotone_in_risk(self):
        pol = SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=1.1)
        ps = np.linspace(0.05, 0.999, 50)
        taus = [pol.interval(p) for p in ps]
        assert all(a <= b + 1e-12 for a, b in zip(taus, taus[1:]))

    def test_should_checkpoint_defaults_to_p_one(self):
        pol = SnSHazard(ckpt_cost=30.0, horizon=900.0, tau_max=1200.0)
        # p=1 → hazard floors at floor_hazard → τ clamps to tau_max
        assert not pol.should_checkpoint(1199.0, 0.0, None)
        assert pol.should_checkpoint(1200.0, 0.0, None)


class TestPolicyTable:
    def test_tau_matches_scalar_policies(self):
        policies = [
            FixedInterval(600.0),
            YoungDaly(ckpt_cost=25.0, mtbf=3000.0),
            SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=0.4),
        ]
        table = PolicyTable.from_policies(policies)
        rng = np.random.default_rng(1)
        p = rng.random((3, 16))
        tau = table.tau(p)
        np.testing.assert_array_equal(tau[0], 600.0)
        np.testing.assert_array_equal(tau[1], policies[1].interval)
        np.testing.assert_array_equal(tau[2], policies[2].tau(p[2]))

    def test_repeat_blocks_are_policy_major(self):
        table = PolicyTable.from_policies(
            [FixedInterval(100.0), FixedInterval(200.0)], repeat=3)
        np.testing.assert_array_equal(
            table.interval, [100.0] * 3 + [200.0] * 3)
        assert table.names == ["FixedInterval"] * 6

    def test_fixed_rows_never_panic(self):
        table = PolicyTable.from_policies(
            [FixedInterval(600.0), SnSHazard(30.0, 900.0, panic_threshold=0.4)])
        panic = table.panic(np.array([0.0, 0.0]))  # certain interrupt
        assert not panic[0] and panic[1]
        assert not table.panic(None).any()

    def test_unsupported_policy_rejected(self):
        with pytest.raises(TypeError, match="unsupported policy"):
            PolicyTable.from_policies([object()])

    @settings(max_examples=25, deadline=None)
    @given(p=st.floats(0.0, 1.0))
    def test_hazard_tau_ufunc_matches_policy(self, p):
        """The shared ufunc and the scalar policy agree bit-for-bit —
        the foundation of cross-engine τ identity."""
        pol = SnSHazard(ckpt_cost=40.0, horizon=600.0, tau_max=2000.0,
                        panic_threshold=0.3)
        via_ufunc = hazard_tau(
            p, ckpt_cost=40.0, horizon=600.0, tau_max=2000.0,
            panic_threshold=0.3, floor_hazard=pol.floor_hazard)
        assert float(via_ufunc) == float(pol.tau(p))
