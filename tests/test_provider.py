"""Simulated provider: admission, reclamation, rate limits, calibration."""

import numpy as np
import pytest

from repro.core import binary_availability
from repro.core.lifecycle import RequestState
from repro.core.provider import (
    PoolConfig,
    RateLimitError,
    SimulatedProvider,
    default_fleet,
)


def make_provider(**kw):
    cfg = PoolConfig(instance_type="t", region="r", base_capacity=30.0)
    return SimulatedProvider([cfg], seed=0, **kw), cfg.pool_id


class TestAdmission:
    def test_accepts_when_capacity_available(self):
        prov, pid = make_provider()
        reqs = prov.submit_spot_request(pid, n=5)
        assert sum(r.state is RequestState.PROVISIONING for r in reqs) >= 4

    def test_concurrent_batch_consumes_headroom(self):
        # 100 concurrent requests against capacity 30 -> ~30 accepted
        prov, pid = make_provider()
        reqs = prov.submit_spot_request(pid, n=100)
        accepted = sum(r.state is RequestState.PROVISIONING for r in reqs)
        assert 20 <= accepted <= 31

    def test_rate_limit(self):
        prov, pid = make_provider(requests_per_minute_per_region=50)
        prov.submit_spot_request(pid, n=50)
        with pytest.raises(RateLimitError):
            prov.submit_spot_request(pid, n=1)
        # budget frees up after the 60 s window
        prov.advance(61.0)
        prov.submit_spot_request(pid, n=10)


class TestLifecycleIntegration:
    def test_uncancelled_requests_reach_running_and_bill(self):
        prov, pid = make_provider()
        reqs = prov.submit_spot_request(pid, n=3)
        prov.advance(120.0)  # provisioning completes
        running = [r for r in reqs if r.state is RequestState.RUNNING]
        assert running, "requests left alone must reach RUNNING"
        assert all(r.billed_seconds(prov.now) > 0 for r in running)

    def test_cancelled_requests_never_bill(self):
        prov, pid = make_provider()
        reqs = prov.submit_spot_request(pid, n=3)
        for r in reqs:
            prov.cancel(r)
        prov.advance(120.0)
        assert all(r.state is RequestState.CANCELLED for r in reqs if r.run_started is None)
        assert all(r.billed_seconds(prov.now) == 0.0 for r in reqs)

    def test_node_pool_maintains_target(self):
        prov, pid = make_provider()
        prov.set_node_pool(pid, 10)
        prov.advance(600.0)
        assert prov.running_count(pid) == 10


class TestCalibration:
    """Statistical properties the paper reports (Table I / Fig 3 bands)."""

    @pytest.fixture(scope="class")
    def campaign(self):
        from repro.core import run_campaign

        fleet = default_fleet(16, seed=1)
        prov = SimulatedProvider(fleet, seed=2)
        return run_campaign(prov, duration=24 * 3600.0)

    def test_agreement_asymmetry(self, campaign):
        # Table I: SnS rarely over-estimates availability
        agree = (campaign.s == campaign.running).mean()
        under = (campaign.running > campaign.s).mean()   # Actual > SnS
        over = (campaign.running < campaign.s).mean()    # Actual < SnS
        assert 0.6 <= agree <= 0.95
        assert under > 5 * over, "conservatism asymmetry lost"

    def test_availability_mostly_full(self, campaign):
        avail = binary_availability(campaign.running, campaign.n)
        assert 0.8 <= avail.mean() <= 0.995

    def test_interruptions_occur(self, campaign):
        assert len(campaign.interruptions) > 20

    def test_probe_cost_is_zero(self, campaign):
        assert campaign.probe_compute_cost == 0.0
        assert campaign.node_pool_cost > 100.0
