"""Bounded-memory property tests — the million-pool-path contract.

A long campaign (≥512 pools × ≥256 cycles, ``retain_records=False``) must
leave the provider's host-side ledgers bounded by the *live fleet*
(O(pools)), never by campaign length (O(pools × cycles)): ledger byte
sizes must be flat across the campaign's second half on all three
engines, and the scalar engine's full object path must fit a fixed
``tracemalloc`` peak budget.
"""

import tracemalloc

import numpy as np
import pytest

from repro.core import CampaignPipelineStream, CampaignStream, SimulatedProvider, default_fleet

POOLS = 512
CYCLES = 256
N_REQ = 2          # scalar-engine runtime knob; bounds don't depend on it
INTERVAL = 180.0
DURATION = CYCLES * INTERVAL

#: ledger budget: bytes per pool, independent of CYCLES — live instances
#: (node_pool_size=10), compaction slack, capacity doubling, cohorts
LEDGER_BUDGET = 64 * 1024 + 8192 * POOLS


def fresh(seed=51):
    return SimulatedProvider(default_fleet(POOLS, seed=seed), seed=seed + 1)


def run_checkpointed(provider, engine, **kw):
    """Drive a campaign cycle-at-a-time, snapshotting ledger bytes."""
    stream = CampaignStream(
        provider, duration=DURATION, interval=INTERVAL, n_requests=N_REQ,
        engine=engine, **kw,
    )
    checkpoints = {}
    for cyc in stream:
        if (cyc.cycle + 1) % 64 == 0:
            checkpoints[cyc.cycle + 1] = stream.provider.ledger_stats()
    return stream, checkpoints


def assert_ledgers_flat(checkpoints):
    sizes = {c: st.nbytes for c, st in sorted(checkpoints.items())}
    mid, end = sizes[CYCLES // 2], sizes[CYCLES]
    # flat across the second half (one capacity doubling of slack), and
    # bounded by pools — a pools×cycles ledger would blow straight past
    assert end <= 2 * mid, sizes
    assert end <= LEDGER_BUDGET, sizes
    st = checkpoints[CYCLES]
    assert st.instance_rows <= 8 * max(st.instance_live, 1), st


class TestLedgersBoundedByPools:
    def test_fleet_engine(self):
        stream, checkpoints = run_checkpointed(fresh(51), "fleet")
        assert_ledgers_flat(checkpoints)
        st = checkpoints[CYCLES]
        # node pools near target (some mid-crunch pools run a deficit)
        assert 0 < st.instance_live <= POOLS * 10
        assert st.probe_rows == 0               # event-driven: no leaks
        assert len(stream.result().interruptions) > 0

    def test_scalar_engine_with_tracemalloc_budget(self):
        provider = fresh(53)
        tracemalloc.start()
        try:
            base, _ = tracemalloc.get_traced_memory()
            stream, checkpoints = run_checkpointed(
                provider, "scalar", retain_records=False
            )
            _, peak = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()
        assert_ledgers_flat(checkpoints)
        # the whole scalar campaign — SpotRequest churn, DataLake,
        # ledgers, output matrices — inside a fixed peak budget
        assert peak - base < 16 * 1024 * 1024, (base, peak)
        lake = stream._collector.lake
        assert len(lake.records) == 0
        assert len(lake) > 0
        # lake buffers: one fixed block + the folded (pool, cycle)
        # aggregate — no per-probe growth (old: 4 lists × len(lake))
        assert lake.nbytes <= 256 * 1024 + 16 * POOLS * 2 * CYCLES
        assert not stream._collector.probe_requests

    def test_sharded_engine_keeps_host_ledgers_empty(self):
        stream, checkpoints = run_checkpointed(fresh(55), "sharded")
        assert_ledgers_flat(checkpoints)
        st = checkpoints[CYCLES]
        # per-instance state is device-resident uid ranges — the host
        # instance/cohort/probe ledgers never gain a row
        assert st.instance_rows == 0
        assert st.cohort_rows == 0
        assert st.probe_rows == 0
        assert len(stream.result().interruptions) > 0


class TestStreamBuffersFlat:
    def test_window_table_ring_is_flat(self):
        pipe = CampaignPipelineStream(
            fresh(57),
            duration=DURATION / 4,      # 64 cycles is plenty for a ring
            interval=INTERVAL,
            n_requests=N_REQ,
            engine="fleet",
            window_minutes=16 * INTERVAL / 60.0,
        )
        sizes = set()
        for view in pipe:
            if view.cycle >= 16:        # past warm-up: ring fully allocated
                sizes.add(pipe.host_buffer_nbytes)
        assert len(sizes) == 1          # exactly flat once the ring wraps
        assert pipe.processor.table.archived > 0
