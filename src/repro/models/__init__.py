from .common import GLOBAL_WINDOW, ModelConfig
from . import api, attention, blocks, encdec, lm, mamba, mlp, moe, sharding

__all__ = [
    "GLOBAL_WINDOW", "ModelConfig",
    "api", "attention", "blocks", "encdec", "lm", "mamba", "mlp", "moe", "sharding",
]
