"""End-to-end elastic training on spot capacity, driven by live SnS hazards.

The complete loop the paper's signals enable, run for real (small model,
CPU-sized by default):

* a **sharded campaign stream** (``CampaignStream(engine="sharded")``
  under a :class:`~repro.core.pipeline.CampaignPipelineStream`) probes the
  spot fleet cycle by cycle; the first ``--pods`` pools host the training
  pods (paper's binary formulation: a pod is up iff all N instances run);
* a :class:`~repro.fleet.GoodputStream` turns each cycle's batched
  predictions into **online checkpoint / panic decisions** for an SnS
  hazard policy and a fixed-interval baseline, simultaneously accounting
  the whole goodput frontier;
* the hazard policy's decisions drive REAL training: an
  :class:`~repro.fleet.ElasticMeshManager` re-meshes on every membership
  change (checkpoint → rebuild mesh through the ``repro.launch.mesh``
  compat helpers → re-shard → re-jit), preemptions roll the job back to
  the last completed checkpoint, and recovered pods scale the data plane
  back up;
* at the end the frontier shows the SnS advantage over the fixed baseline
  on the very trace the job just lived through (the paper's Fig. 9 logic,
  applied to training).

Run:  PYTHONPATH=src python examples/elastic_training.py
      [--hours 12] [--steps 200] [--d-model 128]
"""

import argparse
import os
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SimulatedProvider, default_fleet
from repro.core.pipeline import CampaignPipelineStream
from repro.fleet import (
    ElasticMeshManager,
    FixedInterval,
    GoodputStream,
    SnSHazard,
    reshard,
)
from repro.launch.mesh import data_axes_of, use_mesh
from repro.models import api
from repro.train import (
    OptConfig,
    init_opt_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    synthetic_batch,
)

HAZARD = 1  # row index of the SnS policy in the goodput stream


def heuristic_predictor(feats: np.ndarray) -> np.ndarray:
    """Batched UR → survival heuristic (no fitted model needed): pools
    showing unavailable probe responses are about to lose capacity."""
    return 1.0 - np.clip((feats[:, 1] - 0.05) * 3.0, 0.0, 1.0)


class ElasticTrainer:
    """The data plane: real train steps on whatever mesh the fleet allows.

    Checkpoint → rebuild → restore on every membership change; rollback to
    the last *completed* checkpoint when a mesh-backing pod is preempted.
    """

    def __init__(self, cfg, opt_cfg, mgr, *, batch, seq, ckpt_dir):
        self.cfg, self.opt_cfg, self.mgr = cfg, opt_cfg, mgr
        self.batch, self.seq, self.ckpt_dir = batch, seq, ckpt_dir
        self.params = api.init_params(cfg, seed=0)
        self.opt_state = init_opt_state(self.params)
        self.mesh = None
        self.step_fn = None
        self.members = None        # up-set the current mesh was built from
        self.backing = set()       # pods actually hosting devices
        self.done = 0              # global step (the data-determinism index)
        self.saved = 0             # step of the last completed checkpoint
        self.lost = 0
        self.ckpts = 0
        self.remeshes = 0
        self.losses = []

    def _specs(self, tree):
        from jax.sharding import PartitionSpec as P

        return jax.tree.map(lambda _: P(), tree)

    def _rebuild(self, up):
        """checkpoint-consistent re-mesh: build through the compat helpers
        (never raw ``jax.set_mesh``), re-shard state, re-jit the step."""
        plan = self.mgr.feasible_plan(up)
        if plan is None:
            self.mesh = self.step_fn = None
            self.backing = set()
            return
        self.mesh = plan.build()
        cap = max(1, len(jax.devices()) // (self.mgr.data * self.mgr.model))
        self.backing = set(up[:cap])
        self.params = reshard(self.params, self.mesh, self._specs(self.params))
        self.opt_state = reshard(
            self.opt_state, self.mesh, self._specs(self.opt_state)
        )
        self.step_fn = jax.jit(
            make_train_step(self.cfg, self.opt_cfg, mesh=self.mesh,
                            data_axes=data_axes_of(self.mesh))
        )
        self.remeshes += 1

    def _rollback(self):
        if self.done == self.saved:
            return
        self.lost += self.done - self.saved
        if latest_step(self.ckpt_dir) is not None:
            self.params, self.opt_state, self.done = load_checkpoint(
                self.ckpt_dir, self.params, self.opt_state
            )
        else:
            self.params = api.init_params(self.cfg, seed=0)
            self.opt_state = init_opt_state(self.params)
            self.done = 0

    def checkpoint(self):
        save_checkpoint(self.ckpt_dir, self.done, self.params, self.opt_state)
        self.saved = self.done
        self.ckpts += 1

    def on_cycle(self, view, *, steps: int, budget: int) -> int:
        """React to one goodput-stream cycle; returns steps trained."""
        up = [int(i) for i in np.flatnonzero(view.up)]
        if self.members != set(up):
            if self.backing - set(up):
                self._rollback()          # a mesh-backing pod was preempted
            elif self.mesh is not None and self.done > self.saved:
                self.checkpoint()         # graceful re-mesh: save first
            self._rebuild(up)
            self.members = set(up)
        if self.mesh is None:
            return 0                      # job paused: no pod can host it

        # the hazard policy's online decision, fleet-wide: checkpoint when
        # any surviving pod's row started a write this cycle
        if view.write_started[HAZARD][view.up].any() and self.done > self.saved:
            self.checkpoint()

        k = min(steps, max(0, budget - self.done))
        scale = self.mgr.global_batch_scale(up)
        bsz = max(1, int(round(self.batch * scale)))
        with use_mesh(self.mesh):
            for _ in range(k):
                batch = synthetic_batch(self.cfg, bsz, self.seq, seed=self.done)
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
                self.losses.append(float(metrics["loss"]))
                self.done += 1
        return k


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pools", type=int, default=12)
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--hours", type=float, default=12.0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--steps-per-cycle", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--engine", choices=["fleet", "scalar", "sharded"],
                    default="sharded")
    args = ap.parse_args(argv)

    # -- control plane: sharded campaign stream + live hazard decisions ---
    fleet = default_fleet(args.pools, seed=3)
    provider = SimulatedProvider(fleet, seed=4)
    stream = CampaignPipelineStream(
        provider, predict_fn=heuristic_predictor, window_minutes=240,
        duration=args.hours * 3600.0, engine=args.engine,
    )
    policies = [
        FixedInterval(1800.0),
        SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=0.4),
    ]
    gs = GoodputStream(stream, policies, n_pods=args.pods,
                       names=["fixed_30min", "sns_hazard"])

    # -- data plane: a real LM, elastically re-meshed ----------------------
    cfg = get_config("gemma3-1b").scaled_down(
        d_model=args.d_model, n_layers=args.layers,
        d_ff=args.d_model * 4, vocab_size=2048,
        head_dim=max(16, args.d_model // 8),
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model}); "
          f"fleet: {args.pools} pools / {args.pods} pods "
          f"[engine={args.engine}]")
    mgr = ElasticMeshManager(n_pods=args.pods, data_per_pod=1,
                             model_parallel=1)
    ckpt_root = tempfile.mkdtemp(prefix="elastic_")
    trainer = ElasticTrainer(
        cfg, OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        mgr, batch=args.batch, seq=args.seq,
        ckpt_dir=os.path.join(ckpt_root, "job"),
    )

    paused = trained_cycles = 0
    for view in gs:
        k = trainer.on_cycle(view, steps=args.steps_per_cycle,
                             budget=args.steps)
        trained_cycles += 1 if k else 0
        paused += 1 if trainer.mesh is None else 0
        # keep draining the stream after the step budget: the frontier
        # accounting runs over the full campaign either way

    frontier = gs.frontier()
    print(f"job: {trainer.done} steps done, {trainer.lost} lost, "
          f"{trainer.ckpts} checkpoints, {trainer.remeshes} re-meshes, "
          f"{paused} paused cycles"
          + (f", loss {trainer.losses[0]:.3f} -> {trainer.losses[-1]:.3f}"
             if trainer.losses else ""))
    for name, r in frontier.items():
        print(f"  {name:12s}: goodput {r.goodput:.4f}  "
              f"lost_work {r.lost_work_s:.0f}s  ckpt_overhead "
              f"{r.ckpt_overhead_s:.0f}s  ({r.checkpoints} ckpts)")
    f, s = frontier["fixed_30min"], frontier["sns_hazard"]
    if f.steps_lost > 0:
        print(f"SnS-guided checkpointing cut lost steps by "
              f"{1 - s.steps_lost/max(1, f.steps_lost):.0%} "
              f"vs the fixed-interval baseline")
    shutil.rmtree(ckpt_root, ignore_errors=True)
    return {
        "frontier": frontier,
        "goodput": gs,
        "steps_done": trainer.done,
        "steps_lost": trainer.lost,
        "checkpoints": trainer.ckpts,
        "remeshes": trainer.remeshes,
        "paused_cycles": paused,
        "trained_cycles": trained_cycles,
        "losses": trainer.losses,
    }


if __name__ == "__main__":
    main()
