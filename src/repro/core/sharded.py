"""Mesh-sharded fleet campaign engine — the pool axis across devices.

This is the third campaign engine (after ``scalar`` and ``fleet``, see
:mod:`repro.core.collector`): the whole measure loop — provider dynamics
ticks, node-pool replenishment, reclamation sweeps, and the per-cycle
batched SnS admission — runs as **one** ``shard_map``-ped, jitted device
step per collection cycle, with the stacked ``(pools,)`` state living as
device-sharded arrays on a 1-D ``("pools",)`` mesh.  Every per-pool
operation is elementwise along the pool axis, so the step needs **zero
cross-device communication**: 10^5–10^6-pool fleets split across hosts /
devices under the same ``step_batch`` contract (ROADMAP "sharded campaign
engine" item).

Bit-identity with the fleet engine
----------------------------------

``run_campaign(engine="sharded")`` is **bit-identical** row-for-row to
``engine="fleet"`` (and therefore to ``engine="scalar"``): identical
``S_t`` / ``running_t`` matrices, interruption logs, and cost accounting.
Three properties make that possible:

* **Counter-based RNG** (:mod:`repro.core.rng`): every draw is a pure
  function of ``(seed, pool, counter, site)``.  The SplitMix64 hash is
  pure uint64 integer arithmetic, which JAX reproduces bit-exactly, so
  the device step evaluates the same hash at the same keys as numpy.
* **Exact-arithmetic mirroring**: every floating-point expression in the
  device step copies the numpy engine's operation order; IEEE-754 add /
  mul / div / sqrt / compare are deterministic, and ``jnp.cos`` matches
  numpy bitwise on the probed range.  The one libm routine that does
  *not* match (``log1p``, used by the exponential / Box–Muller variate
  transforms) is handled by precomputing small per-cycle ``log1p`` tables
  on the host with numpy — their keys ``(seed, pool, tick, site)`` are
  known before the step runs, so the tables are inputs, not round-trips.
* **Position-stable keys**: RNG keys depend on the pool's *index*, not on
  how pools are laid out across devices.  Padding the pool axis up to a
  multiple of the mesh size (padded pools get ``target_nodes == 0`` and
  are masked out of every output) is therefore the only sharding-visible
  change — asserted in ``tests/test_sharded_campaign.py``.

Device-resident stepping
------------------------

The per-pool state is committed to the devices once, before the first
step, and then **stays there for the whole campaign**: each jitted step
*donates* the incoming state buffers (``donate_argnums``) and hands back
the updated ones, so a cycle allocates nothing on the steady path and
the host never round-trips the fleet.  Per cycle exactly one transfer
crosses the boundary — the stacked ``(2, pools)`` observation
``[S_t, running_t]``.  Event-granular bookkeeping is *deferred*: the
step's reclamation outputs (``(tick, pool, count, uid-start)``) and any
leaked-probe cohort markers stay on device in a pending queue and are
materialized in bulk — via :func:`repro.core.provider.
reclaim_sweep_delays_batch` and ``InterruptionLog.append_events`` —
only when the interruption log or cost ledgers are actually read
(campaign result, stream checkpoint, ``ledger_stats``), or when the
queue exceeds ``event_flush_entries``.  The flush replays ledger
mutations in the numpy engines' order (probe-cohort rows in cycle order,
sweeps chronologically), so logs and cost sums stay bit-identical.

Slow-terminator probes (``terminator_delay > 0``) ride the same step:
the probe cohort of a cycle is a device-resident ``(pools,)`` slot
(``probe_count`` / ``probe_start``) that settles against the same
provisioning rule as replenishment cohorts; cohorts that outlive the
delay leak into RUNNING exactly as on the fleet engine, and the
host-side leaked-uid ledger (:class:`repro.core.ledger.ProbeLedger`)
is reconstructed at flush time from the settle's uid assignments.

The engine requires ``provisioning_duration <= tick`` (the default: 8 s
vs 60 s), which guarantees at most one in-flight replenishment cohort
and one probe cohort per pool.
"""

from __future__ import annotations

import math
from functools import partial
from typing import List, Optional, Sequence

import numpy as np

from .collector import CampaignResult
from .faults import (
    _TAG_REQUEST_ERROR,
    OUTCOME_DEFERRED,
    OUTCOME_OK,
    OUTCOME_RATE_LIMITED,
    FaultPlan,
)
from .provider import (
    _FLAKE_P,
    _TAG_DEGRADE_BUMP,
    _TAG_DWELL,
    _TAG_NEXT_REGIME,
    _TAG_NOISE_A,
    _TAG_NOISE_B,
    _TAG_RECLAIM_BUMP,
    _TAG_REPLENISH,
    _TAG_SUBMIT,
    _TAG_TARGET,
    CRUNCH,
    STABLE,
    TIGHT,
    PoolConfig,
    SimulatedProvider,
    reclaim_sweep_delays_batch,
)
from .rng import keyed_uniform

__all__ = ["ShardedProvider", "run_sharded_campaign"]


# --------------------------------------------------------------------------
# Device-side twin of repro.core.rng (uint64 SplitMix64 — bit-exact in XLA)
# --------------------------------------------------------------------------

# The hash constants come from rng.py itself — the bit-identity guarantee
# hinges on the device twin and the numpy streams sharing one definition.
from .rng import (  # noqa: E402
    _GOLDEN,
    _INV53,
    _LANE_CTR,
    _LANE_POOL,
    _LANE_TAG,
    _M1,
    _M2,
)

_U64 = np.uint64
_TWO_PI = 2.0 * np.pi


def _dev_mix(x):
    """SplitMix64 finalizer on jnp.uint64 (identical bits to rng._mix)."""
    import jax.numpy as jnp

    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(_M1)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(_M2)
    return x ^ (x >> jnp.uint64(31))


def _dev_u64(x):
    import jax.numpy as jnp

    return jnp.asarray(x, dtype=jnp.int64).astype(jnp.uint64)


def _dev_keyed_uniform(h0, pool, counter, tag):
    """Device twin of :func:`repro.core.rng.keyed_uniform` — uint64 ops
    wrap identically, the final ``* 2^-53`` scaling is exact."""
    import jax.numpy as jnp

    h = _dev_mix(h0 ^ (_dev_u64(pool) * jnp.uint64(_LANE_POOL)))
    h = _dev_mix(h ^ (_dev_u64(counter) * jnp.uint64(_LANE_CTR)))
    h = _dev_mix(h ^ (_dev_u64(tag) * jnp.uint64(_LANE_TAG)))
    return (h >> jnp.uint64(11)).astype(jnp.float64) * _INV53


def _dev_unif_between(lo, hi, u):
    """Device twin of ``keyed_uniform_between`` (same ``lo + (hi-lo)*u``)."""
    return lo + (hi - lo) * u


#: compiled cycle steps, shared across ShardedProvider instances: keyed on
#: (mesh, padded_pools, d_max, n_requests, kind); per-provider scalars
#: (seed hash, provisioning duration, margin decay, replenish delay) are
#: step *inputs*, so back-to-back campaigns never recompile.  ``kind``
#: selects the cycle shape: "scoot" (event-driven probe / plain advance),
#: "hold" (slow-terminator probe: admission leaves the cohort pending),
#: "cancel" (advance by the terminator delay, then cancel what's left).
_STEP_CACHE = {}


# --------------------------------------------------------------------------
# Sharded provider
# --------------------------------------------------------------------------


class ShardedProvider:
    """Device-sharded twin of :class:`~repro.core.provider.SimulatedProvider`
    for campaign workloads.

    Construct from a *fresh* ``SimulatedProvider`` (adopting its fleet,
    seed and control-plane settings) or from a sequence of
    :class:`PoolConfig` plus the same keyword settings.  All per-pool
    state lives in ``(padded_pools,)`` arrays sharded across a 1-D
    ``("pools",)`` mesh (built via the version-compat helpers in
    :mod:`repro.launch.mesh`) and stays device-resident across cycles —
    each jitted ``shard_map`` step donates the previous state buffers;
    one collection cycle is a single device call with a single
    ``(2, pools)`` observation transfer back.

    ``shards`` picks the mesh size (default: all visible devices);
    ``pad_multiple`` additionally pads the pool axis to a multiple of the
    given value, which lets single-device tests exercise the padding +
    masking path the multi-device mesh relies on.
    ``event_flush_entries`` bounds the deferred interruption-event queue
    (device-side ``(tick, pool)`` reclamation outputs) before a forced
    host flush — the knob trades host transfers for queue memory.
    """

    def __init__(
        self,
        pools,
        *,
        shards: Optional[int] = None,
        pad_multiple: Optional[int] = None,
        event_flush_entries: int = 1 << 22,
        **provider_kwargs,
    ):
        if isinstance(pools, SimulatedProvider):
            if provider_kwargs:
                raise ValueError(
                    "pass provider settings either via an existing "
                    "SimulatedProvider or as keyword arguments, not both"
                )
            host = pools
            if host.now != 0.0 or host._tick_count != 0:
                raise ValueError(
                    "ShardedProvider must adopt a fresh SimulatedProvider "
                    "(per-instance ledgers of a mid-flight provider are not "
                    "representable as sharded state)"
                )
        else:
            host = SimulatedProvider(list(pools), **provider_kwargs)
        if host.provisioning_duration > host.tick:
            raise NotImplementedError(
                "sharded engine requires provisioning_duration <= tick "
                f"({host.provisioning_duration} > {host.tick}): it carries "
                "at most one in-flight replenishment cohort per pool"
            )
        self._host = host
        self.tick = host.tick
        self.provisioning_duration = host.provisioning_duration
        self.replenish_delay = host.replenish_delay
        self.now = 0.0
        self.probe_time = 0.0
        self._tick_count = 0
        self._seed = host._seed
        self.n_pools = host.n_pools
        self.event_flush_entries = int(event_flush_entries)
        self._pending: list = []      # deferred device-side event outputs
        self._pending_entries = 0

        import jax

        from ..launch.mesh import make_pool_mesh

        self.shards = int(shards) if shards else len(jax.devices())
        unit = math.lcm(self.shards, int(pad_multiple) if pad_multiple else 1)
        self.padded_pools = ((self.n_pools + unit - 1) // unit) * unit
        self.mesh = make_pool_mesh(self.shards)

        P, Pp = self.n_pools, self.padded_pools

        def pad(a, fill):
            out = np.full(Pp, fill, dtype=np.asarray(a).dtype)
            out[:P] = a
            return out

        dwell = np.empty((Pp, 3), dtype=np.float64)
        dwell[:P] = host._dwell
        dwell[P:] = (8 * 3600.0, 50 * 60.0, 10 * 60.0)
        self._params = {
            "pool_ix": np.arange(Pp, dtype=np.int64),
            "base_capacity": pad(host.base_capacity, 30.0),
            "volatility": pad(host.volatility, 1.0),
            "p_tight_first": pad(host._p_tight_first, 0.85),
            "dwell": dwell,
        }
        # regime_until follows the exact init formula of SimulatedProvider;
        # the first n_pools entries therefore equal host.regime_until bitwise
        from .rng import keyed_exponential

        u0 = keyed_uniform(self._seed, np.arange(Pp), 0, _TAG_DWELL)
        self._state = {
            "capacity": pad(host.capacity, 30.0),
            "regime": np.zeros(Pp, dtype=np.int64),
            "regime_until": keyed_exponential(dwell[:, STABLE], u0),
            "margin": np.zeros(Pp, dtype=np.float64),
            "n_running": np.zeros(Pp, dtype=np.int64),
            "n_provisioning": np.zeros(Pp, dtype=np.int64),
            "target_nodes": np.zeros(Pp, dtype=np.int64),
            "replenish_at": np.full(Pp, math.inf),
            "submit_seq": np.zeros(Pp, dtype=np.int64),
            "head_uid": np.zeros(Pp, dtype=np.int64),
            "next_uid": np.zeros(Pp, dtype=np.int64),
            "cohort_count": np.zeros(Pp, dtype=np.int64),
            "cohort_start": np.zeros(Pp, dtype=np.float64),
            # slow-terminator probe cohort slot (one per pool, like the
            # replenishment cohort): pending count + submission time
            "probe_count": np.zeros(Pp, dtype=np.int64),
            "probe_start": np.zeros(Pp, dtype=np.float64),
        }
        self._started = False
        self._steps = {}  # (n_requests, kind, faults) -> jitted shard_map step
        self._fault_plan: Optional[FaultPlan] = None
        self._last_codes = np.zeros(0, dtype=np.uint8)

    # -- config / bookkeeping passthrough ----------------------------------

    @property
    def pool_ids(self) -> List[str]:
        return self._host.pool_ids

    @property
    def api_calls(self) -> int:
        return self._host.api_calls

    @property
    def fault_api_calls(self) -> int:
        return self._host.fault_api_calls

    @property
    def region_code(self) -> np.ndarray:
        return self._host.region_code

    def rate_budget(self) -> np.ndarray:
        return self._host.rate_budget()

    @property
    def fault_plan(self) -> Optional[FaultPlan]:
        return self._fault_plan

    def set_fault_plan(self, plan: Optional[FaultPlan]) -> None:
        """Attach a deterministic :class:`FaultPlan` (pre-campaign only:
        the plan's seed and error rate are baked into the device hyper
        dict at commit time)."""
        if self._started:
            raise RuntimeError(
                "fault plan must be set before the first device step"
            )
        self._fault_plan = plan
        self._host.set_fault_plan(plan)

    @property
    def interruptions(self):
        """The provider's interruption log — reading it materializes any
        deferred device-side reclamation events first, so snapshots taken
        mid-campaign are exact up to the last completed step."""
        self._flush_events()
        return self._host.interruptions

    def pool_index(self, pool_ids: Sequence[str]) -> np.ndarray:
        return self._host.pool_index(pool_ids)

    def pool_config(self, pool_id: str) -> PoolConfig:
        return self._host.pool_config(pool_id)

    def ledger_stats(self):
        """Host-side ledger footprint (see
        :class:`~repro.core.provider.LedgerStats`), after flushing any
        deferred events.  During a sharded campaign the per-instance state
        lives as ``head_uid``/``next_uid`` uid ranges inside the device
        state, so the host's instance / cohort ledgers stay *empty* — the
        bounded-memory tests assert exactly that; only leaked probes
        (``terminator_delay > 0``) materialize probe-ledger rows."""
        self._flush_events()
        return self._host.ledger_stats()

    def probe_ledger_len(self) -> int:
        """Monotonic probe-ledger cursor (rows ever appended), after
        flushing deferred leak records — 0 for the event-driven
        terminator, which never leaks probes."""
        self._flush_events()
        return self._host.probe_ledger_len()

    def probe_instance_cost(self, now=None, *, since: int = 0, until=None) -> float:
        self._flush_events()
        return self._host.probe_instance_cost(now, since=since, until=until)

    def set_node_pools(self, pool_ids: Sequence[str], n_nodes: int) -> None:
        """Batch ``set_node_pool``: declare ground-truth node pools for
        every listed pool at once (pre-campaign only)."""
        if self._started:
            raise RuntimeError("node pools must be declared before the first step")
        idx = self.pool_index(pool_ids)
        self._state["target_nodes"][idx] = int(n_nodes)
        self._state["replenish_at"][idx] = self.now

    # -- device step construction ------------------------------------------

    def _get_step(self, n: int, kind: str):
        # the cancel step has no admission code, so its compilation is
        # independent of n — collapse the cache key
        n = 0 if kind == "cancel" else int(n)
        # `faults` is a *static* flag: the faults-off compiled step is
        # byte-for-byte today's computation (no error draws, no blackout
        # gate), so chaos support costs the fault-free path nothing
        faults = self._fault_plan is not None
        if (n, kind, faults) in self._steps:
            return self._steps[(n, kind, faults)]
        d_max = max(int(np.asarray(self._state["target_nodes"]).max()), 1)
        key = (self.mesh, self.padded_pools, d_max, n, kind, faults)
        fn = _STEP_CACHE.get(key)
        if fn is None:
            fn = _build_step(self.mesh, d_max, n, kind, faults)
            _STEP_CACHE[key] = fn
        self._steps[(n, kind, faults)] = fn
        return fn

    # -- campaign-facing API ------------------------------------------------

    def advance(self, to_time: float, *, n_hint: int = 1) -> None:
        """Advance the fleet clock (dynamics ticks + fractional settle) in
        one device call — the sharded ``SimulatedProvider.advance``.
        ``n_hint`` lets callers reuse the compiled step of an upcoming
        ``probe_cycle(n=n_hint)`` instead of building a second one."""
        self._run(to_time, None, n_hint, "scoot")

    def probe_cycle(
        self,
        to_time: float,
        pool_idx: np.ndarray,
        n: int,
        terminator_delay: float = 0.0,
        *,
        fault_codes: Optional[np.ndarray] = None,
        attempt: Optional[np.ndarray] = None,
        codes_out: Optional[np.ndarray] = None,
        errors_out: Optional[np.ndarray] = None,
    ):
        """Advance to ``to_time`` and probe ``pool_idx`` with ``n``
        concurrent requests each, all in ``shard_map``-ped steps.

        With ``terminator_delay == 0`` (the event-driven terminator) the
        cycle is one device call; a positive delay runs the fleet
        engine's hold → advance-by-delay → cancel sequence as two calls,
        with the probe cohorts living in the device state between them.
        Probes that finish provisioning within the delay leak into
        RUNNING and are recorded on the host leaked-uid ledger (at the
        next event flush), exactly as on the fleet engine.

        ``fault_codes`` / ``attempt`` / ``codes_out`` / ``errors_out``
        mirror the numpy collectors: whole-call faults are billed
        host-side and excluded from admission; retry-deferred pools are
        dropped from the batch (``OUTCOME_DEFERRED``, no API call).

        Returns ``(S_t, running_t)`` for ``pool_idx`` (host arrays);
        ``self.probe_time`` carries the measurement timestamp (the
        admission time, not the post-delay clock).
        """
        pool_idx = np.asarray(pool_idx, dtype=np.int64)
        P = self.n_pools
        if attempt is None:
            sel_ix = None
            run_idx, fc = pool_idx, fault_codes
        else:
            sel_ix = np.nonzero(np.asarray(attempt, dtype=bool))[0]
            run_idx = pool_idx[sel_ix]
            fc = None if fault_codes is None else fault_codes[sel_ix]

        def unpack(obs):
            obs = np.asarray(obs)
            counts_all, running_all = obs[0, :P], obs[1, :P]
            err_all = obs[2, :P] if obs.shape[0] > 2 else None
            if codes_out is not None:
                if sel_ix is None:
                    codes_out[:] = self._last_codes
                else:
                    codes_out[:] = OUTCOME_DEFERRED
                    codes_out[sel_ix] = self._last_codes
            if errors_out is not None:
                errors_out[:] = 0
                if err_all is not None:
                    if sel_ix is None:
                        errors_out[:] = err_all[pool_idx]
                    else:
                        errors_out[sel_ix] = err_all[run_idx]
            if sel_ix is None:
                s = counts_all[pool_idx]
            else:
                s = np.zeros(len(pool_idx), dtype=np.int64)
                s[sel_ix] = counts_all[run_idx]
            return s, counts_all, running_all

        if terminator_delay <= 0.0:
            obs, _ = self._run(to_time, run_idx, n, "scoot", fault_codes=fc)
            self.probe_time = self.now
            s, _counts, running = unpack(obs)
            return s, running[pool_idx]
        obs_h, _ = self._run(to_time, run_idx, n, "hold", fault_codes=fc)
        self.probe_time = self.now
        s, counts, _running = unpack(obs_h)
        obs_c, puid0 = self._run(
            to_time + float(terminator_delay), None, n, "cancel"
        )
        # leaked cohorts: probes settle at the first provisioning-settle
        # point >= submission + provisioning_duration (same float
        # comparisons the device step just made on the same schedule)
        settle_at = next(
            (s for s in self._last_settles if s - to_time
             >= self.provisioning_duration),
            None,
        )
        nz_idx = run_idx[counts[run_idx] > 0]
        if settle_at is not None and nz_idx.size:
            # puid0 stays an unfetched device array until the flush
            self._pending.append(
                ("probe", settle_at, nz_idx, counts[nz_idx], puid0)
            )
            self._pending_entries += int(nz_idx.size)
        running = np.asarray(obs_c)[1, :P]
        return s, running[pool_idx]

    def _run(
        self,
        to_time: float,
        pool_idx: Optional[np.ndarray],
        n: int,
        kind: str,
        fault_codes: Optional[np.ndarray] = None,
    ):
        if to_time < self.now:
            raise ValueError("time moves forward only")
        Pp = self.padded_pools
        # -- tick schedule: mirror advance()'s accumulate-by-addition loop
        now = self.now
        nows, tick_ids = [], []
        while now + self.tick <= to_time:
            now += self.tick
            self._tick_count += 1
            nows.append(now)
            tick_ids.append(self._tick_count)
        do_frac = to_time > now
        frac_now = to_time if do_frac else -1.0
        if do_frac:
            now = to_time
        n_ticks = len(nows)
        nows_a = np.asarray(nows, dtype=np.float64)
        ticks_a = np.asarray(tick_ids, dtype=np.int64)
        # provisioning-settle points of this call, in order (the probe
        # leak bookkeeping replays them host-side)
        self._last_settles = nows + ([to_time] if do_frac else [])
        # -- host log1p tables for the two exponential/normal draw sites
        if n_ticks:
            pool_row = np.arange(Pp)[None, :]
            l_dwell = np.log1p(
                -keyed_uniform(self._seed, pool_row, ticks_a[:, None], _TAG_DWELL)
            )
            l_noise = np.log1p(
                -keyed_uniform(self._seed, pool_row, ticks_a[:, None], _TAG_NOISE_A)
            )
        else:
            l_dwell = np.zeros((0, Pp))
            l_noise = np.zeros((0, Pp))
        # -- host-side blackout gating of replenishment: same pure window
        # function `_replenish_batch` consults, evaluated at the same
        # tick times, fed to the device step as a (ticks, Pp) mask
        plan = self._fault_plan
        if plan is not None:
            blk = np.zeros((n_ticks, Pp), dtype=bool)
            if plan.blackout is not None and n_ticks:
                blk[:, : self.n_pools] = plan.blackout_mask(
                    nows_a, self._host.region_code
                )
            blk_arg = (blk,)
        else:
            # the faults-off compiled step takes no blackout input at all
            # (trailing optional arg), so the fault substrate adds zero
            # host allocation / transfer / fetch to the fault-free path
            blk_arg = ()
        # -- host-side rate limiting (sequential per-region semantics)
        self._host.now = now  # host clock tracks the device clock
        probe_mask = np.zeros(Pp, dtype=bool)
        do_submit = pool_idx is not None
        if do_submit:
            admitted = self._host._charge_rate_limit_batch(pool_idx, n)
            codes = np.zeros(len(pool_idx), dtype=np.uint8)
            if fault_codes is None:
                live = admitted
            else:
                fault_codes = np.asarray(fault_codes, dtype=np.uint8)
                faulted = fault_codes != OUTCOME_OK
                live = admitted & ~faulted
                self._host.fault_api_calls += int((admitted & faulted).sum()) * n
                codes[faulted] = fault_codes[faulted]
            codes[~admitted] = OUTCOME_RATE_LIMITED  # rate limiting wins
            self._last_codes = codes
            probe_mask[pool_idx[live]] = True

        from jax.experimental import enable_x64

        with enable_x64():
            fn = self._get_step(n, kind)
            if not self._started:
                self._commit_to_devices()
            st, obs, k_rec, uid0, puid0 = fn(
                self._hyper, self._params, self._state, nows_a, ticks_a,
                l_dwell, l_noise, np.float64(frac_now),
                np.bool_(do_frac), probe_mask, np.bool_(do_submit),
                np.float64(now), *blk_arg,
            )
        self._state = st
        self.now = now
        self._host.now = now
        # -- reclamation sweeps stay on device: queue the (tick, pool,
        # count, uid-start) outputs unfetched; timestamps + log rows are
        # materialized in bulk at the next flush
        if n_ticks:
            self._pending.append(("ticks", nows_a, ticks_a, k_rec, uid0))
            self._pending_entries += n_ticks * Pp
            if self._pending_entries >= self.event_flush_entries:
                self._flush_events()
        return obs, puid0

    def _flush_events(self) -> None:
        """Materialize the deferred event queue into the host ledgers.

        Replays the numpy engines' ledger-mutation order: leaked-probe
        cohort rows first, in cycle (append) order — their uids never
        collide with earlier sweeps, because uid streams are strictly
        increasing per pool — then reclamation sweeps chronologically
        ((cycle, tick, pool) ascending), each marking any live leaked
        probes it reclaimed before logging its interruption events.  Same
        rows in the same order means the float cost sums and log
        snapshots are bit-identical to ``engine="fleet"``.
        """
        pending, self._pending = self._pending, []
        self._pending_entries = 0
        if not pending:
            return
        P = self.n_pools
        probe_ledger = self._host._probe_ledger
        for rec in pending:
            if rec[0] != "probe":
                continue
            _, settle_at, pools, counts, puid0 = rec
            pu = np.asarray(puid0)[:P]
            probe_ledger.append_blocks(pools, pu[pools], counts, settle_at)
        log = self._host.interruptions
        for rec in pending:
            if rec[0] != "ticks":
                continue
            _, nows_a, ticks_a, k_rec_d, uid0_d = rec
            k_rec = np.asarray(k_rec_d)[:, :P]
            if not k_rec.any():
                continue
            uid0 = np.asarray(uid0_d)[:, :P]
            ti, pp = np.nonzero(k_rec)  # row-major == (tick, pool) asc
            ks = k_rec[ti, pp]
            delays = reclaim_sweep_delays_batch(
                self._seed, pp, ticks_a[ti], ks
            )
            reps = np.repeat(np.arange(len(ks)), ks)
            within = np.arange(int(ks.sum())) - np.repeat(
                np.cumsum(ks) - ks, ks
            )
            uids = uid0[ti, pp][reps] + within
            times = nows_a[ti][reps] + delays
            if probe_ledger.live_count:
                off = np.concatenate(([0], np.cumsum(ks)))
                for j in range(len(ks)):
                    sl = slice(int(off[j]), int(off[j + 1]))
                    probe_ledger.mark_ended(int(pp[j]), uids[sl], times[sl])
            log.append_events(pp[reps], uids, times)

    def _commit_to_devices(self) -> None:
        """Shard the initial state/params across the mesh once, before the
        first step (avoids an uncommitted->committed retrace later).  From
        here on the state lives on the devices: every step donates these
        buffers and returns their successors."""
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS

        plan = self._fault_plan
        fseed = plan.seed if plan is not None else 0
        with np.errstate(over="ignore"):  # uint64 wraparound is the hash
            h0 = _U64(self._seed & 0xFFFFFFFFFFFFFFFF) * _GOLDEN
            fh0 = _U64(fseed & 0xFFFFFFFFFFFFFFFF) * _GOLDEN
        self._hyper = {
            "h0": h0,
            # fault-plan hash + transient-error rate: always present so
            # the hyper pytree shape is static; DCE'd by the faults-off
            # compiled step
            "fh0": fh0,
            "err_p": np.float64(
                plan.request_error_p if plan is not None else 0.0
            ),
            "pd": np.float64(self.provisioning_duration),
            "decay": np.float64(self._host._margin_decay),
            "replenish_delay": np.float64(self.replenish_delay),
        }
        sharded = NamedSharding(self.mesh, PS("pools"))
        self._params = jax.device_put(self._params, sharded)
        self._state = jax.device_put(self._state, sharded)
        self._started = True

    # -- crash-consistent checkpoints ---------------------------------------

    def state_dict(self) -> dict:
        """Snapshot at a step boundary: deferred events are flushed, the
        device-resident state is fetched to host, and the host provider
        (ledgers, rate windows, RNG counters) is captured — plain numpy
        containers, picklable."""
        self._flush_events()
        return {
            "now": self.now,
            "probe_time": self.probe_time,
            "tick_count": self._tick_count,
            "state": {
                k: np.asarray(v).copy() for k, v in self._state.items()
            },
            "host": self._host.state_dict(),
        }

    def restore(self, sd: dict) -> None:
        """Restore a :meth:`state_dict` snapshot onto a freshly
        constructed, identically configured provider.  The state is
        re-committed to the devices on the next step."""
        self._host.restore(sd["host"])
        self.now = float(sd["now"])
        self.probe_time = float(sd["probe_time"])
        self._tick_count = int(sd["tick_count"])
        self._state = {k: v.copy() for k, v in sd["state"].items()}
        self._pending = []
        self._pending_entries = 0
        self._started = False  # device_put again on the next _run


def _build_step(mesh, d_max: int, n: int, kind: str, faults: bool):
    """Compile the one-cycle device step for ``(mesh, d_max, n, kind)``.

    The returned function is ``jit(shard_map(step), donate_argnums)``
    over the 1-D ``("pools",)`` mesh: a ``lax.scan`` over the cycle's
    dynamics ticks (settle -> regime -> capacity -> margin decay ->
    reclaim -> replenish, mirroring ``SimulatedProvider._step_fleet`` op
    for op), the optional fractional-advance settle, and the cycle tail
    selected by ``kind`` — the batched ``n``-request admission for
    ``"scoot"`` (event-driven probe: state untouched) and ``"hold"``
    (slow terminator: the accepted cohort stays provisioning in the
    per-pool probe slot), or the pending-probe cancellation for
    ``"cancel"``.  The state argument is donated, so a campaign's state
    buffers live on device end to end.  Per-provider scalars arrive via
    the ``hyper`` input dict so one compilation serves every provider
    with the same shapes.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as PS

    from ..models.common import shard_map

    def settle(hyper, st, puid0, now, enabled):
        # provisioning completes after `provisioning_duration`; cohorts
        # still pending then transition to RUNNING (uids at the tail).
        # Replenishment cohorts and held probe cohorts settle under the
        # same rule; when both settle at once the earlier-appended cohort
        # takes the lower uid block (ledger row order — ties go to the
        # replenishment cohort, appended during the tick that precedes
        # the fractional-time probe submission).
        rep_due = enabled & (st["cohort_count"] > 0) & (
            now - st["cohort_start"] >= hyper["pd"]
        )
        pr_due = enabled & (st["probe_count"] > 0) & (
            now - st["probe_start"] >= hyper["pd"]
        )
        k_rep = jnp.where(rep_due, st["cohort_count"], 0)
        k_pr = jnp.where(pr_due, st["probe_count"], 0)
        pr_first = pr_due & (st["probe_start"] < st["cohort_start"])
        puid0 = jnp.where(
            pr_due, st["next_uid"] + jnp.where(pr_first, 0, k_rep), puid0
        )
        k = k_rep + k_pr
        st["n_provisioning"] = st["n_provisioning"] - k
        st["n_running"] = st["n_running"] + k
        st["next_uid"] = st["next_uid"] + k
        st["cohort_count"] = jnp.where(rep_due, 0, st["cohort_count"])
        st["probe_count"] = jnp.where(pr_due, 0, st["probe_count"])
        return st, puid0

    def tick_body(hyper, params, carry, xs):
        if faults:
            now, tick_id, l_dwell, l_noise, blk_t = xs
        else:
            now, tick_id, l_dwell, l_noise = xs
        st, puid0 = carry
        ku = partial(_dev_keyed_uniform, hyper["h0"])
        st = dict(st)
        pool = params["pool_ix"]
        st, puid0 = settle(hyper, st, puid0, now, jnp.bool_(True))
        # -- regime transitions (mirrors _step_fleet line for line) --------
        due = now >= st["regime_until"]
        u = ku(pool, tick_id, _TAG_NEXT_REGIME)
        r = st["regime"]
        new = jnp.where(
            r == STABLE,
            jnp.where(u < params["p_tight_first"], TIGHT, CRUNCH),
            jnp.where(
                r == TIGHT,
                jnp.where(u < 0.75, CRUNCH, STABLE),
                jnp.where(u < 0.6, TIGHT, STABLE),
            ),
        )
        ud = ku(pool, tick_id, _TAG_DWELL)
        mean = jnp.take_along_axis(params["dwell"], new[:, None], axis=1)[:, 0]
        dwell_draw = jnp.where(
            new == STABLE,
            -mean * l_dwell,  # keyed_exponential(mean, ud), host log1p
            _dev_unif_between(0.7 * mean, 1.3 * mean, ud),
        )
        st["regime"] = jnp.where(due, new, r)
        st["regime_until"] = jnp.where(due, now + dwell_draw, st["regime_until"])
        ub = ku(pool, tick_id, _TAG_DEGRADE_BUMP)
        bump = _dev_unif_between(0.15, 0.7, ub) * jnp.maximum(
            st["target_nodes"], 4
        )
        st["margin"] = jnp.where(
            due & (new != STABLE), jnp.maximum(st["margin"], bump), st["margin"]
        )
        # -- capacity mean-reversion to regime target ----------------------
        nmax = jnp.maximum(st["target_nodes"], 1).astype(jnp.float64)
        ut = ku(pool, tick_id, _TAG_TARGET)
        target = jnp.where(
            st["regime"] == STABLE,
            params["base_capacity"],
            jnp.where(
                st["regime"] == TIGHT,
                nmax + _dev_unif_between(0.15 * nmax, 0.6 * nmax, ut),
                _dev_unif_between(0.0, 0.8 * nmax, ut),
            ),
        )
        ubn = ku(pool, tick_id, _TAG_NOISE_B)
        # keyed_normal(vol, ua, ub): sqrt/cos are bitwise-identical in
        # XLA; log1p(-ua) arrives precomputed from the host (l_noise)
        noise = (
            params["volatility"]
            * jnp.sqrt(-2.0 * l_noise)
            * jnp.cos(_TWO_PI * ubn)
        )
        st["capacity"] = jnp.maximum(
            st["capacity"] + (0.35 * (target - st["capacity"]) + noise), 0.0
        )
        # -- admission margin decay ----------------------------------------
        m2 = st["margin"] * hyper["decay"]
        st["margin"] = jnp.where(m2 < 0.05, 0.0, m2)
        # -- reclamation sweeps (FIFO == contiguous uid range) -------------
        overflow = st["n_running"] - st["capacity"].astype(jnp.int64)
        sweep = (overflow > 0) & ((st["regime"] == CRUNCH) | (overflow >= 3))
        k_rec = jnp.where(sweep, jnp.minimum(overflow, st["n_running"]), 0)
        hit = k_rec > 0
        uid0 = st["head_uid"]
        st["head_uid"] = st["head_uid"] + k_rec
        st["n_running"] = st["n_running"] - k_rec
        ubump = ku(pool, tick_id, _TAG_RECLAIM_BUMP)
        rbump = k_rec.astype(jnp.float64) + _dev_unif_between(
            0.4, 1.0, ubump
        ) * jnp.maximum(st["target_nodes"], 4)
        st["margin"] = jnp.where(hit, st["margin"] + rbump, st["margin"])
        st["replenish_at"] = jnp.where(
            hit,
            jnp.maximum(st["replenish_at"], now + hyper["replenish_delay"]),
            st["replenish_at"],
        )
        # -- node-pool replenishment ---------------------------------------
        deficit = st["target_nodes"] - st["n_running"] - st["n_provisioning"]
        mask = (
            (st["target_nodes"] > 0)
            & (now >= st["replenish_at"])
            & (deficit > 0)
        )
        if faults:
            # blackout windows suppress replenishment (host-evaluated
            # mask — same gate `_replenish_batch` applies at this tick)
            mask = mask & ~blk_t
        j = jnp.arange(d_max, dtype=jnp.int64)
        u_rep = ku(pool[:, None], tick_id, _TAG_REPLENISH + j[None, :])
        headroom = (
            st["capacity"]
            - st["n_running"]
            - st["n_provisioning"]
            - st["margin"]
        )
        ok = (
            (j[None, :] < headroom[:, None])
            & (u_rep >= _FLAKE_P)
            & (j[None, :] < deficit[:, None])
        )
        accepts = jnp.where(
            mask, jnp.cumprod(ok.astype(jnp.int64), axis=1).sum(axis=1), 0
        )
        got = accepts > 0
        st["n_provisioning"] = st["n_provisioning"] + jnp.where(mask, accepts, 0)
        st["cohort_count"] = jnp.where(got, accepts, st["cohort_count"])
        st["cohort_start"] = jnp.where(got, now, st["cohort_start"])
        return (st, puid0), (k_rec, uid0)

    def step(
        hyper, params, st, nows, tick_ids, l_dwell, l_noise,
        frac_now, do_frac, probe_mask, do_submit, sub_now, blk=None,
    ):
        puid0 = jnp.full_like(st["next_uid"], -1)
        xs = (nows, tick_ids, l_dwell, l_noise)
        if faults:
            xs = xs + (blk,)
        (st, puid0), (k_rec, uid0) = lax.scan(
            partial(tick_body, hyper, params), (dict(st), puid0), xs,
        )
        st, puid0 = settle(hyper, st, puid0, frac_now, do_frac)
        pool = params["pool_ix"]
        err_counts = jnp.zeros_like(st["n_running"])
        if kind == "cancel":
            # the fleet engine's cancel_cohorts: pending (unsettled)
            # probes stop provisioning; settled ones already leaked
            st["n_provisioning"] = st["n_provisioning"] - st["probe_count"]
            st["probe_count"] = jnp.zeros_like(st["probe_count"])
            counts = jnp.zeros_like(st["n_running"])
        else:
            # -- batched admission (the SnS probe; the scoot leaves state
            # untouched, the hold keeps the cohort provisioning)
            active = probe_mask & do_submit
            seq = st["submit_seq"]
            u = _dev_keyed_uniform(
                hyper["h0"], pool[:, None], seq[:, None],
                _TAG_SUBMIT + jnp.arange(n, dtype=jnp.int64)[None, :],
            )
            okf = u >= _FLAKE_P
            if faults:
                # device twin of FaultPlan.request_errors: same keys
                # (fault seed, pool, submit_seq, error tag + j), so every
                # engine rejects the exact same requests
                u_err = _dev_keyed_uniform(
                    hyper["fh0"], pool[:, None], seq[:, None],
                    _TAG_REQUEST_ERROR
                    + jnp.arange(n, dtype=jnp.int64)[None, :],
                )
                errm = u_err < hyper["err_p"]
                okf = okf & ~errm
                err_counts = jnp.where(
                    active, errm.sum(axis=1).astype(jnp.int64), 0
                )
            headroom = (
                st["capacity"]
                - st["n_running"]
                - st["n_provisioning"]
                - st["margin"]
            )
            acc = okf & ((jnp.cumsum(okf, axis=1) - 1) < headroom[:, None])
            counts = jnp.where(active, acc.sum(axis=1).astype(jnp.int64), 0)
            st["submit_seq"] = jnp.where(active, seq + 1, seq)
            if kind == "hold":
                st["n_provisioning"] = st["n_provisioning"] + counts
                st["probe_count"] = jnp.where(active, counts, st["probe_count"])
                st["probe_start"] = jnp.where(active, sub_now, st["probe_start"])
        # faults-off obs is the pre-chaos 2-row fetch (counts, running);
        # the error row only exists when the plan can produce errors
        obs = jnp.stack(
            [counts, st["n_running"], err_counts] if faults
            else [counts, st["n_running"]]
        )
        return st, obs, k_rec, uid0, puid0

    sharded = PS("pools")
    rep = PS()
    ticks_sharded = PS(None, "pools")
    return jax.jit(
        shard_map(
            step,
            mesh=mesh,
            in_specs=(
                rep, sharded, sharded, rep, rep, ticks_sharded, ticks_sharded,
                rep, rep, sharded, rep, rep,
            ) + ((ticks_sharded,) if faults else ()),
            out_specs=(
                sharded, ticks_sharded, ticks_sharded, ticks_sharded, sharded
            ),
        ),
        donate_argnums=(2,),
    )

# --------------------------------------------------------------------------
# Campaign driver (the engine="sharded" path of run_campaign)
# --------------------------------------------------------------------------


def run_sharded_campaign(
    provider,
    *,
    pool_ids: Optional[Sequence[str]] = None,
    duration: float = 24 * 3600.0,
    interval: float = 180.0,
    n_requests: int = 10,
    node_pool_size: int = 10,
    terminator_delay: float = 0.0,
    on_cycle=None,
    shards: Optional[int] = None,
    pad_multiple: Optional[int] = None,
    fault_plan=None,
    retry_policy=None,
) -> CampaignResult:
    """§III-B campaign on the mesh-sharded engine (see module docstring).

    ``provider`` is either a fresh :class:`SimulatedProvider` (its fleet,
    seed and settings are adopted) or a prebuilt :class:`ShardedProvider`.
    Results are bit-identical to ``run_campaign(engine="fleet")`` on the
    same provider seed.  ``on_cycle`` fires with ``(cycle, time, S_t)``
    after every cycle, exactly like the other engines, so
    ``run_campaign_pipeline`` glue works unchanged.

    Thin driver over ``CampaignStream(engine="sharded")`` — the sharded
    per-cycle logic lives in :meth:`ShardedProvider.probe_cycle` and the
    stream, so batch and streamed campaigns cannot diverge.
    """
    from .collector import CampaignStream  # local: avoid import cycle

    stream = CampaignStream(
        provider,
        pool_ids=pool_ids,
        duration=duration,
        interval=interval,
        n_requests=n_requests,
        node_pool_size=node_pool_size,
        terminator_delay=terminator_delay,
        engine="sharded",
        shards=shards,
        pad_multiple=pad_multiple,
        fault_plan=fault_plan,
        retry_policy=retry_policy,
    )
    for cyc in stream:
        if on_cycle is not None:
            on_cycle(cyc.cycle, cyc.time, cyc.s_t)
    return stream.result()
