"""Public entry point for the flash-attention kernel.

``flash_attention_op`` auto-selects interpret mode off-TPU so the same
call sites work in CPU tests and on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention

__all__ = ["flash_attention_op"]


def flash_attention_op(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 2**30,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    interpret = jax.default_backend() != "tpu"
    return flash_attention(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
