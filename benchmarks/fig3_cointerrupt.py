"""Fig. 3: cumulative distribution of co-interrupt proximity."""

from __future__ import annotations

import numpy as np

from repro.core import proximities, proximity_cdf

from .common import paper_campaign

PAPER = {"within_1min": 0.85, "within_3min": 0.929}  # ">85%", "92.9%"


def run():
    c = paper_campaign()
    grid = [15.0, 30.0, 60.0, 120.0, 180.0, 300.0, 600.0, 1800.0]
    xs, cdf = proximity_cdf(c.interruptions, grid)
    gaps = proximities(c.interruptions)
    return {
        "n_events": int(len(c.interruptions)),
        "n_proximities": int(gaps.size),
        "cdf": {f"{int(x)}s": round(float(v), 3) for x, v in zip(xs, cdf)},
        "within_1min": round(float((gaps <= 60).mean()), 3),
        "within_3min": round(float((gaps <= 180).mean()), 3),
        "paper": PAPER,
    }


if __name__ == "__main__":
    print(run())
