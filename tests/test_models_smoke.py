"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, get_config, shape_applicability
from repro.models import api
from repro.models.lm import block_pattern

ARCHS = sorted(REGISTRY)


def make_batch(cfg, b=2, s=16, seed=0, with_labels=True):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).scaled_down()
    params = api.init_params(cfg, seed=0)
    batch = make_batch(cfg)
    loss = api.train_loss(cfg, params, batch, remat="none")
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # random-init loss should be ~ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).scaled_down()
    params = api.init_params(cfg, seed=0)
    b, s = 2, 16
    batch = make_batch(cfg, b, s, with_labels=False)
    logits, cache = api.prefill(cfg, params, batch, max_seq=s + 4)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = api.decode_step(cfg, params, cache, tok)
    assert logits2.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache2["len"]) == s + 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "jamba-v0.1-52b", "falcon-mamba-7b",
                                  "whisper-large-v3", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_teacher_forcing(arch):
    """decode_step must reproduce full-forward logits exactly (dropless MoE
    capacity removes batch-dependent token dropping for the comparison)."""
    cfg = get_config(arch).scaled_down(capacity_factor=4.0)
    params = api.init_params(cfg, seed=0)
    rng = np.random.default_rng(1)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 2)))
    batch = {"tokens": toks[:, :s]}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    _, cache = api.prefill(cfg, params, batch, max_seq=s + 2)
    dec = []
    for i in range(2):
        lg, cache = api.decode_step(cfg, params, cache, toks[:, s + i])
        dec.append(lg)
    for i in range(1, 3):
        full = dict(batch)
        full["tokens"] = toks[:, : s + i]
        ref, _ = api.prefill(cfg, params, full, max_seq=s + 2)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(dec[i - 1]), atol=2e-4)


class TestBlockPattern:
    def test_dense_period_one(self):
        cfg = get_config("qwen3-8b")
        pattern, repeats = block_pattern(cfg)
        assert len(pattern) == 1 and repeats == cfg.n_layers

    def test_jamba_period_eight(self):
        cfg = get_config("jamba-v0.1-52b")
        pattern, repeats = block_pattern(cfg)
        assert len(pattern) == 8 and repeats == 4
        mixers = [m for m, _, _ in pattern]
        assert mixers.count("attn") == 1 and mixers[4] == "attn"
        assert [moe for _, moe, _ in pattern] == [False, True] * 4

    def test_gemma_windows(self):
        cfg = get_config("gemma3-1b")
        w = cfg.layer_windows()
        assert (w[5::6] > 1e6).all()           # every 6th layer global
        locals_ = np.delete(w, np.arange(5, 26, 6))
        assert (locals_ == 512).all()

    def test_falcon_mamba_attention_free(self):
        cfg = get_config("falcon-mamba-7b")
        pattern, repeats = block_pattern(cfg)
        assert len(pattern) == 1 and repeats == 64
        assert pattern[0][:2] == ("mamba", False)

    def test_gemma_pattern_unrolls_to_26(self):
        # 26 layers with a 5:1 window pattern don't fold (26 % 6 != 0):
        # the stack unrolls, which is what lets local layers take the
        # static banded-attention path
        cfg = get_config("gemma3-1b")
        pattern, repeats = block_pattern(cfg)
        assert len(pattern) * repeats == 26
        windows = [w for _, _, w in pattern for _ in range(repeats)]
        assert sum(1 for w in windows if w == 512) == 22


class TestShapeGrid:
    def test_forty_cells(self):
        cells = [(a, s) for a in ARCHS for s in SHAPES]
        assert len(cells) == 40

    def test_long_500k_applicability(self):
        runnable = {
            a for a in ARCHS
            if shape_applicability(get_config(a), SHAPES["long_500k"])[0]
        }
        assert runnable == {"gemma3-1b", "jamba-v0.1-52b", "falcon-mamba-7b"}

    def test_other_shapes_always_run(self):
        for a in ARCHS:
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                ok, reason = shape_applicability(get_config(a), SHAPES[s])
                assert ok, (a, s, reason)

    def test_param_counts_roughly_match_names(self):
        # analytic param counts should be in the ballpark the names claim
        approx = {
            "qwen3-8b": (6e9, 11e9),
            "starcoder2-15b": (12e9, 18e9),
            "falcon-mamba-7b": (5e9, 9e9),
            "arctic-480b": (3.5e11, 5.5e11),
            "jamba-v0.1-52b": (4e10, 7e10),
            "chameleon-34b": (2.7e10, 4.2e10),
        }
        for name, (lo, hi) in approx.items():
            n = get_config(name).param_count()
            assert lo < n < hi, f"{name}: {n:.2e} not in ({lo:.0e}, {hi:.0e})"
