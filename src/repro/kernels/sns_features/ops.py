"""Public entry points for the SnS feature kernels.

* :func:`sns_features_op` — full-trace replay (whole T resident per tile).
* :func:`sns_features_stream_op` — chunked streaming replay for
  arbitrarily long traces and arbitrary shapes: pads ``T`` up to a
  multiple of ``chunk`` (with fully-fulfilled cycles — causally inert)
  and ``pools`` up to a multiple of ``block_p``, runs the carry-state
  path, and slices back.  Backend selection:

  - ``"pallas"`` — the Pallas kernel (interpret mode off-TPU);
  - ``"jnp"``    — the pure-jnp ``lax.scan`` carry fallback (bit-identical
    to the kernel; the fast path on CPU, where Pallas interpret mode
    costs a Python roundtrip per grid step);
  - ``"auto"``   — Pallas on TPU, jnp scan elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import sns_features, sns_features_stream
from .ref import sns_features_stream_ref

__all__ = ["sns_features_op", "sns_features_stream_op"]


def sns_features_op(s, *, n: int, window_minutes: float, dt_minutes: float,
                    block_p: int = 8):
    w = int(round(window_minutes / dt_minutes))
    interpret = jax.default_backend() != "tpu"
    return sns_features(
        jnp.asarray(s, jnp.int32), n=n, w=w, dt=dt_minutes,
        block_p=block_p, interpret=interpret,
    )


def sns_features_stream_op(
    s,
    *,
    n: int,
    window_minutes: float,
    dt_minutes: float,
    block_p: int = 8,
    chunk: int = 128,
    backend: str = "auto",
):
    w = int(round(window_minutes / dt_minutes))
    s = jnp.asarray(s, jnp.int32)
    pools, t_max = s.shape

    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")

    chunk = min(chunk, t_max)
    pad_t = (-t_max) % chunk
    if pad_t:
        # fully-fulfilled padding cycles never influence earlier outputs
        # (every per-cycle feature is causal in S)
        s = jnp.concatenate(
            [s, jnp.full((pools, pad_t), n, jnp.int32)], axis=1
        )

    if backend == "jnp":
        out = sns_features_stream_ref(s, n, w, dt_minutes, chunk=chunk)
        return out[:, :t_max]

    block_p = min(block_p, pools)
    pad_p = (-pools) % block_p
    if pad_p:
        s = jnp.concatenate(
            [s, jnp.full((pad_p, s.shape[1]), n, jnp.int32)], axis=0
        )
    out = sns_features_stream(
        s, n=n, w=w, dt=dt_minutes, block_p=block_p, chunk=chunk,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:pools, :t_max]
