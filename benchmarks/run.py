"""Benchmark orchestrator — one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only NAME]``

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline
number(s) each benchmark reproduces) followed by a JSON dump per table.

Benches that append to a ``BENCH_*.json`` trajectory log also get a
regression guard: every ``*_per_sec`` rate in the fresh record is
compared against the last committed record, and drops beyond
``DROP_TOLERANCE`` print a ``WARNING`` line (non-fatal — CI containers
are noisy, but silent perf regressions should at least surface in the
logs).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import (
    campaign_throughput,
    fig3_cointerrupt,
    fig5_cost,
    fig6_fidelity,
    fig7_window,
    fig8_horizon,
    fig9_simulation,
    goodput_throughput,
    pipeline_throughput,
    replay_throughput,
    roofline_report,
    serve_throughput,
    table1_agreement,
)

BENCHES = [
    ("table1_agreement", table1_agreement.run,
     lambda r: f"equal%={r['table'][0]['equal_pct']}/{r['table'][1]['equal_pct']}"),
    ("fig3_cointerrupt", fig3_cointerrupt.run,
     lambda r: f"<1min={r['within_1min']} <3min={r['within_3min']}"),
    ("fig5_cost", fig5_cost.run,
     lambda r: f"cont/sns={r['continuous_over_sns']}x periodic/sns={r['periodic_over_sns']}x"),
    ("fig6_fidelity", fig6_fidelity.run,
     lambda r: f"median_r UR={r['UR']['median_r']} SR={r['SR']['median_r']} CUT={r['CUT']['median_r']}"),
    ("fig7_window", fig7_window.run,
     lambda r: f"best={r['best_per_model']}"),
    ("fig8_horizon", fig8_horizon.run,
     lambda r: f"xgb@3min={r['headline']['xgb_full_3min']} xgb@60min={r['headline']['xgb_full_60min']}"),
    ("fig9_simulation", fig9_simulation.run,
     lambda r: f"reduction@3min={r['h=3min']['predict_ar_reduction']} @15min={r['h=15min']['predict_ar_reduction']}"),
    ("roofline_report", roofline_report.run,
     lambda r: f"cells ok={r['ok']} skipped={r['skipped']} errors={r['errors']}"),
    ("pipeline_throughput", pipeline_throughput.run,
     lambda r: (f"numpy={r['speedup']['vectorized_numpy']}x "
                f"kernel={r['speedup']['kernel_replay']}x "
                f"bit_identical={r['kernel_bit_identical_atol0']}")),
    ("campaign_throughput", campaign_throughput.run,
     lambda r: (f"fleet/scalar={r['speedup']}x "
                f"parity={r['parity_identical']}")),
    ("replay_throughput", replay_throughput.run,
     lambda r: (f"scan/numpy={r['speedup_vs_numpy']}x "
                f"scan/loop={r['speedup_vs_python_loop']}x "
                f"parity={r['parity_atol0']} "
                f"fig9_identical={r['fig9_simresults_identical']}")),
    ("serve_throughput", serve_throughput.run,
     lambda r: (f"fleet/scalar={r['speedup']}x "
                f"parity={r['parity_identical']}")),
    ("goodput_throughput", goodput_throughput.run,
     lambda r: (f"scan/loop={r['speedup_vs_python_loop']}x "
                f"parity={r['parity_atol0']} "
                f"hazard_goodput={r['frontier']['sns_hazard']['goodput']}")),
]

#: benches with an append-only trajectory log in the repo root
BENCH_LOGS = {
    "campaign_throughput": "BENCH_campaign.json",
    "replay_throughput": "BENCH_replay.json",
    "serve_throughput": "BENCH_serve.json",
    "goodput_throughput": "BENCH_goodput.json",
}
DROP_TOLERANCE = 0.30   # fractional rate drop vs last committed record


def _last_record(path):
    """Last JSON-lines record of a trajectory log, or None."""
    try:
        lines = [l for l in Path(path).read_text().splitlines() if l.strip()]
        return json.loads(lines[-1]) if lines else None
    except (OSError, json.JSONDecodeError):
        return None


def _rate_leaves(rec, prefix=()):
    """Flatten every ``*_per_sec`` table in a record to {path: rate}."""
    out = {}
    if not isinstance(rec, dict):
        return out
    for k, v in rec.items():
        key = str(k)
        if isinstance(v, dict) and key.endswith("_per_sec"):
            for m, x in v.items():
                if isinstance(x, (int, float)):
                    out[prefix + (key, str(m))] = float(x)
        elif isinstance(v, dict):
            out.update(_rate_leaves(v, prefix + (key,)))
    return out


def check_trajectory(name, fresh, baseline):
    """Non-fatal guard: rate drops > DROP_TOLERANCE vs the last committed
    record come back as WARNING lines (new legs / removed legs are not
    compared — only rates present in both records)."""
    warns = []
    if baseline is None or fresh.get("smoke"):
        return warns
    base = _rate_leaves(baseline)
    now = _rate_leaves(fresh)
    for key, b in sorted(base.items()):
        n = now.get(key)
        if n is not None and b > 0 and n < (1.0 - DROP_TOLERANCE) * b:
            warns.append(
                f"WARNING: {name} {'.'.join(key)} dropped "
                f"{b:.1f} -> {n:.1f} ({n / b:.0%} of last committed record)"
            )
    return warns


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep in fig8 (skips sequence models)")
    args = ap.parse_args()

    results = {}
    print("name,us_per_call,derived")
    for name, fn, derive in BENCHES:
        if args.only and args.only != name:
            continue
        kwargs = {}
        if args.quick and name == "fig8_horizon":
            kwargs = {"seq_models": (), "horizons": (3, 60)}
        # snapshot the trajectory baseline before the bench appends to it
        baseline = (_last_record(BENCH_LOGS[name])
                    if name in BENCH_LOGS else None)
        t0 = time.perf_counter()
        try:
            r = fn(**kwargs)
            us = (time.perf_counter() - t0) * 1e6
            results[name] = r
            print(f"{name},{us:.0f},{derive(r)}", flush=True)
            for warn in check_trajectory(name, r, baseline):
                print(warn, flush=True)
        except Exception as e:  # keep the sweep alive; report at the end
            us = (time.perf_counter() - t0) * 1e6
            results[name] = {"error": str(e)}
            print(f"{name},{us:.0f},ERROR: {e}", flush=True)

    print("\n=== detail ===")
    for name, r in results.items():
        if name == "roofline_report" and "table_single_pod" in r:
            print(f"\n--- {name} (single-pod) ---")
            print(r["table_single_pod"])
            print(f"\n--- {name} (multi-pod) ---")
            print(r["table_multi_pod"])
        else:
            print(f"\n--- {name} ---")
            print(json.dumps(r, indent=1, default=str))


if __name__ == "__main__":
    main()
