"""Point-wise linear predictors: Logistic Regression and linear SVM.

Both operate on a single feature vector per prediction (§VI-A's
"single data point" model group), trained with weighted full-gradient
mini-batch Adam (see ``_train.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ._train import fit_adam

__all__ = ["LogisticRegression", "LinearSVM"]


def _init_linear(n_features: int) -> Dict[str, jnp.ndarray]:
    return {
        "w": jnp.zeros((n_features,), jnp.float32),
        "b": jnp.zeros((), jnp.float32),
    }


def _margin(params, x):
    return x @ params["w"] + params["b"]


@dataclasses.dataclass
class LogisticRegression:
    l2: float = 1e-4
    steps: int = 600
    lr: float = 5e-2
    seed: int = 0
    params: Dict = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LogisticRegression":
        l2 = self.l2

        def loss(params, xb, yb, wb):
            logits = _margin(params, xb)
            ll = wb * (
                jax.nn.softplus(logits) - yb * logits
            )  # weighted binary cross-entropy
            return ll.mean() + l2 * jnp.sum(params["w"] ** 2)

        self.params = fit_adam(
            _init_linear(x.shape[-1]), loss, x, y,
            steps=self.steps, lr=self.lr, seed=self.seed,
        )
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jax.nn.sigmoid(_margin(self.params, jnp.asarray(x))))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)


@dataclasses.dataclass
class LinearSVM:
    """L2-regularised hinge loss; decision threshold at margin 0."""

    c: float = 1.0
    steps: int = 600
    lr: float = 5e-2
    seed: int = 0
    params: Dict = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LinearSVM":
        c = self.c

        def loss(params, xb, yb, wb):
            sign = 2.0 * yb - 1.0
            hinge = jnp.maximum(0.0, 1.0 - sign * _margin(params, xb))
            return c * (wb * hinge).mean() + 0.5 * jnp.sum(params["w"] ** 2)

        self.params = fit_adam(
            _init_linear(x.shape[-1]), loss, x, y,
            steps=self.steps, lr=self.lr, seed=self.seed,
        )
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(_margin(self.params, jnp.asarray(x)))

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        # Platt-free squashing of the margin — monotone, fine for ranking.
        return np.asarray(jax.nn.sigmoid(2.0 * self.decision_function(x)))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.decision_function(x) >= 0.0).astype(np.int32)
