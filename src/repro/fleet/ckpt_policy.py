"""Checkpoint-interval policies for preemptible training.

The paper stops at "prediction enables proactive checkpoint triggering"
(§I); this module operationalises it for the training data plane:

* **FixedInterval** — checkpoint every ``interval`` seconds (baseline).
* **YoungDaly** — the classical optimum ``τ* = sqrt(2·δ·MTBF)`` for
  checkpoint cost δ and a *static* mean time between failures.
* **SnSHazard** — beyond-paper: Young–Daly with a *time-varying* MTBF
  estimated from the SnS interrupt predictor.  The predictor's probability
  that the pool does NOT survive the next horizon ``h`` converts to an
  instantaneous hazard ``λ = -ln(p_survive) / h`` and the interval adapts
  as ``τ(t) = sqrt(2·δ/λ)``, clamped to [δ, τ_max].  Additionally, a
  forecast above ``panic_threshold`` triggers an immediate checkpoint
  (the Predict-AR analogue for training).

All policies answer one question: "given the last checkpoint at time
``t_ckpt`` and the current SnS features, should we checkpoint now?"
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

__all__ = ["FixedInterval", "YoungDaly", "SnSHazard"]


@dataclasses.dataclass
class FixedInterval:
    interval: float                 # seconds

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        return now - t_last_ckpt >= self.interval


@dataclasses.dataclass
class YoungDaly:
    ckpt_cost: float                # δ: seconds to write a checkpoint
    mtbf: float                     # static mean time between failures (s)

    @property
    def interval(self) -> float:
        return math.sqrt(2.0 * self.ckpt_cost * self.mtbf)

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        return now - t_last_ckpt >= self.interval


@dataclasses.dataclass
class SnSHazard:
    """Young–Daly with SnS-predicted time-varying hazard."""

    ckpt_cost: float                # δ (seconds)
    horizon: float                  # predictor horizon (seconds)
    tau_max: float = 3600.0         # interval ceiling when hazard ~ 0
    panic_threshold: float = 0.5    # P(interrupt within horizon) forcing ckpt
    floor_hazard: float = 1e-6

    def interval(self, p_survive: float) -> float:
        p_survive = min(max(p_survive, 1e-6), 1.0 - 1e-9)
        lam = max(-math.log(p_survive) / self.horizon, self.floor_hazard)
        tau = math.sqrt(2.0 * self.ckpt_cost / lam)
        return float(np.clip(tau, self.ckpt_cost, self.tau_max))

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        p = 1.0 if p_survive is None else float(p_survive)
        since = now - t_last_ckpt
        if 1.0 - p >= self.panic_threshold:
            # imminent-interrupt forecast: checkpoint NOW — but under
            # *sustained* panic don't re-write faster than 2δ, or the
            # checkpoint overhead itself destroys goodput
            return since >= 2.0 * self.ckpt_cost
        return since >= self.interval(p)
