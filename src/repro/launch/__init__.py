from .mesh import data_axes_of, make_production_mesh, mesh_axis_sizes

__all__ = ["data_axes_of", "make_production_mesh", "mesh_axis_sizes"]
