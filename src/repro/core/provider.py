"""Simulated cloud provider with spot capacity pools — array-native.

This is the offline stand-in for the AWS/Azure control planes probed in the
paper (no cloud credentials in this environment).  It reproduces the
*structural* properties the paper measures, with dynamics calibrated to the
paper's published statistics:

* **Shared capacity pool per (instance type, AZ)** — all instances of a type
  in an AZ draw from one hidden capacity process ``C_t`` (§IV-A).
* **Regime-switching dynamics** — STABLE / TIGHT / CRUNCH Markov regimes.
  TIGHT tends to precede CRUNCH, so probe-visible degradation *leads*
  interruptions (the paper's §III-B observation that SnS "reflects capacity
  changes that have not yet manifested as actual interruptions").
* **Admission conservatism** — new spot requests are admitted against
  ``C_t`` minus a non-negative *admission margin* that spikes when the
  regime degrades and decays slowly afterwards.  Running instances are only
  reclaimed when ``C_t`` drops below the running count.  This yields the
  Table-I asymmetry: SnS under-counts actual availability far more often
  than it over-counts.
* **Clustered reclamation** — when capacity crunches, reclaimed nodes are
  interrupted within seconds-to-minutes of each other, calibrated to the
  Fig.-3 co-interrupt proximity CDF (>85 % < 1 min, ~93 % < 3 min).
* **Rate limits** — per-region request budgets per minute; the 3-minute
  probe cadence in the paper is the fastest cadence that stays within them.

Architecture (SpotLake-class fleets, 10^4–10^6 pools): all per-pool state —
capacity ``C_t``, regime, admission margin, running / provisioning counts,
dwell clocks — lives in stacked ``(pools,)`` arrays.  One dynamics tick is
:meth:`SimulatedProvider.step_batch`: a constant number of vector ops that
advances every pool at once.  Randomness is *counter-based* per pool
(``repro.core.rng``): every draw is a pure function of
``(seed, pool, counter, draw-site)``, so the batched admission path
(:meth:`submit_spot_requests`) and the scalar object API
(:meth:`submit_spot_request`, which wraps the same array core in
:class:`~repro.core.lifecycle.SpotRequest` views) produce bit-identical
trajectories — the parity anchor for the fleet campaign engine.

Per-*instance* bookkeeping (ground-truth node pools, leaked probes) is
event-driven, not per-tick: instances exist as small FIFO entries touched
only on provisioning-settle / reclaim / terminate, never on the hot path.

The provider is deliberately *interface-first* (`submit_spot_request` /
`cancel` / node-pool maintenance) so the SnS collector code is portable to
a real cloud backend (§VII provider-agnostic claim).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .lifecycle import RequestState, SpotRequest
from .rng import (
    keyed_exponential,
    keyed_normal,
    keyed_uniform,
    keyed_uniform_between,
)

__all__ = [
    "PoolConfig",
    "InterruptionEvent",
    "InterruptionLog",
    "RateLimitError",
    "SimulatedProvider",
    "default_fleet",
    "reclaim_sweep_delays",
]


class RateLimitError(RuntimeError):
    """Raised when a region's API request budget is exhausted."""


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

STABLE, TIGHT, CRUNCH = 0, 1, 2
_REGIME_NAMES = ("stable", "tight", "crunch")

#: transient API flakiness: rare spurious rejections even with headroom
_FLAKE_P = 0.012

# Draw-site tags for the counter-based per-pool RNG streams.  Dynamics
# sites are keyed on the tick counter, admission sites on per-pool
# sequence counters; the tag ranges are disjoint so no key collides.
_TAG_NEXT_REGIME = 1
_TAG_DWELL = 2
_TAG_DEGRADE_BUMP = 3
_TAG_NOISE_A = 4
_TAG_NOISE_B = 5
_TAG_TARGET = 6
_TAG_RECLAIM_BUMP = 7
_TAG_RECLAIM = 1_000          # + 2*i per victim (mixture choice, delay)
_TAG_REPLENISH = 10_000_000   # + attempt index
_TAG_SUBMIT = 20_000_000      # + request index within one submission batch


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static description of one (instance type, AZ) capacity pool."""

    instance_type: str
    region: str
    az: str = "a"
    price_per_hour: float = 1.0          # on-demand-discounted spot price
    base_capacity: float = 30.0          # STABLE-regime mean capacity
    volatility: float = 2.0              # capacity noise std per tick
    # Regime dwell means (seconds).  STABLE >> TIGHT >> CRUNCH.
    dwell_stable: float = 8 * 3600.0
    dwell_tight: float = 50 * 60.0
    dwell_crunch: float = 10 * 60.0
    # Probability that a degradation passes through TIGHT before CRUNCH
    # (gives probes predictive lead time).
    p_tight_first: float = 0.85

    @property
    def pool_id(self) -> str:
        return f"{self.instance_type}/{self.region}/{self.az}"


@dataclasses.dataclass(frozen=True)
class InterruptionEvent:
    pool_id: str
    instance_id: int
    time: float                           # continuous timestamp (seconds)


class InterruptionLog:
    """Struct-of-arrays interruption event log (ROADMAP event-log
    compaction): three growable columns — pool index (int64), instance
    uid (int64), timestamp (float64) — instead of one Python object per
    event, so multi-day 10^5-pool campaigns stay compact and the
    co-interrupt analysis can run columnar.

    The log is a lazy *sequence view* of :class:`InterruptionEvent`:
    ``log[i]`` / ``iter(log)`` materialise events on demand, ``len`` and
    ``==`` (vs another log or an event list) work unchanged, so existing
    consumers (``cointerrupt``, tests, examples) need no changes.
    """

    __slots__ = ("_pool_ids", "_pool", "_uid", "_time", "_n")

    def __init__(self, pool_ids: Sequence[str], _capacity: int = 256):
        self._pool_ids = list(pool_ids)
        self._pool = np.empty(_capacity, dtype=np.int64)
        self._uid = np.empty(_capacity, dtype=np.int64)
        self._time = np.empty(_capacity, dtype=np.float64)
        self._n = 0

    # -- write path (provider-internal) -----------------------------------

    def _grow_to(self, need: int) -> None:
        cap = len(self._pool)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("_pool", "_uid", "_time"):
            old = getattr(self, name)
            new = np.empty(cap, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)

    def append_sweep(self, pool: int, uids, times) -> None:
        """Record one reclamation sweep (k events of one pool) columnar."""
        uids = np.asarray(uids, dtype=np.int64)
        times = np.asarray(times, dtype=np.float64)
        k = len(uids)
        self._grow_to(self._n + k)
        sl = slice(self._n, self._n + k)
        self._pool[sl] = pool
        self._uid[sl] = uids
        self._time[sl] = times
        self._n += k

    # -- columnar read path ------------------------------------------------

    @property
    def columns(self):
        """(pool_idx, uid, time) trimmed column views."""
        n = self._n
        return self._pool[:n], self._uid[:n], self._time[:n]

    @property
    def pool_ids(self) -> List[str]:
        return self._pool_ids

    def snapshot(self) -> "InterruptionLog":
        """A frozen copy (what :class:`CampaignResult` stores)."""
        out = InterruptionLog(self._pool_ids, _capacity=max(self._n, 1))
        pool, uid, time = self.columns
        out.append_sweep(0, uid, time)      # bulk copy, then fix pools
        out._pool[: self._n] = pool
        return out

    # -- lazy InterruptionEvent sequence view ------------------------------

    def __len__(self) -> int:
        return self._n

    def _event(self, i: int) -> InterruptionEvent:
        return InterruptionEvent(
            self._pool_ids[int(self._pool[i])],
            int(self._uid[i]),
            float(self._time[i]),
        )

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._event(j) for j in range(*i.indices(self._n))]
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(i)
        return self._event(i)

    def __iter__(self):
        return (self._event(i) for i in range(self._n))

    def __eq__(self, other) -> bool:
        if isinstance(other, InterruptionLog):
            if self._n != other._n:
                return False
            a, b = self.columns, other.columns
            return (
                bool(np.array_equal(a[1], b[1]))
                and bool(np.array_equal(a[2], b[2]))
                and [self._pool_ids[p] for p in a[0]]
                == [other._pool_ids[p] for p in b[0]]
            )
        if isinstance(other, (list, tuple)):
            return list(self) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"InterruptionLog(n={self._n}, pools={len(self._pool_ids)})"


def reclaim_sweep_delays(seed: int, pool: int, tick: int, k: int) -> np.ndarray:
    """Clustered interruption delays for one reclamation sweep of ``k``
    instances (paper Fig. 3 calibration: a fast exponential for the same
    sweep, a slower uniform tail for follow-up sweeps).

    A pure function of ``(seed, pool, tick, k)`` on the counter-based RNG
    streams — shared by :meth:`SimulatedProvider._reclaim` and the sharded
    engine's host-side interruption-log writer
    (:mod:`repro.core.sharded`), which is what keeps interruption
    timestamps bit-identical across engines.
    """
    i = np.arange(k)
    um = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i)
    ud = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i + 1)
    return np.where(
        (i == 0) | (um < 0.86),
        keyed_exponential(16.0, ud),
        keyed_uniform_between(60.0, 600.0, ud),
    )


@dataclasses.dataclass
class _Instance:
    """One RUNNING instance — FIFO ledger entry, touched only on events."""

    uid: int                  # per-pool instance sequence number
    pool: int                 # pool index
    start: float              # entered RUNNING (billing starts)
    end: Optional[float] = None
    probe: bool = False       # leaked SnS probe (for cost accounting)
    obj: Optional[SpotRequest] = None   # scalar-API view, if any


@dataclasses.dataclass
class _Cohort:
    """Requests accepted together, provisioning since ``start``."""

    pool: int
    start: float
    count: int
    probe: bool = False
    requests: Optional[List[SpotRequest]] = None  # scalar-API views


# --------------------------------------------------------------------------
# Provider
# --------------------------------------------------------------------------


class SimulatedProvider:
    """Discrete-event simulated spot control plane over stacked pool state.

    Time is continuous (seconds); dynamics advance on a fixed tick
    (default 60 s).  Clients call :meth:`advance` to move the clock, then
    interact via the request API — either the scalar object API
    (:meth:`submit_spot_request`, one pool at a time, returning
    :class:`SpotRequest` views) or the batched fleet API
    (:meth:`submit_spot_requests`, every pool in one vector op).  Both sit
    on the same array core and the same counter-based per-pool RNG
    streams, so they are bit-identical.
    """

    def __init__(
        self,
        pools: Sequence[PoolConfig],
        *,
        seed: int = 0,
        tick: float = 60.0,
        provisioning_duration: float = 8.0,
        requests_per_minute_per_region: int = 300,
        replenish_delay: float = 300.0,
        margin_decay_tau: float = 30 * 60.0,
    ):
        self.tick = float(tick)
        self.provisioning_duration = float(provisioning_duration)
        self.rate_limit = int(requests_per_minute_per_region)
        self.replenish_delay = float(replenish_delay)
        self.margin_decay_tau = float(margin_decay_tau)
        self._margin_decay = math.exp(-self.tick / self.margin_decay_tau)
        self._seed = int(seed)
        self.now = 0.0

        self.configs: List[PoolConfig] = list(pools)
        P = len(self.configs)
        self.n_pools = P
        self._pool_index: Dict[str, int] = {
            cfg.pool_id: i for i, cfg in enumerate(self.configs)
        }
        if len(self._pool_index) != P:
            raise ValueError("duplicate pool ids in fleet")
        self._idx = np.arange(P)

        # -- static per-pool config, stacked ------------------------------
        self.base_capacity = np.array([c.base_capacity for c in self.configs])
        self.volatility = np.array([c.volatility for c in self.configs])
        self.price_per_hour = np.array([c.price_per_hour for c in self.configs])
        self._p_tight_first = np.array([c.p_tight_first for c in self.configs])
        self._dwell = np.array(
            [[c.dwell_stable, c.dwell_tight, c.dwell_crunch] for c in self.configs]
        )
        regions = sorted({c.region for c in self.configs})
        self._region_code = np.array(
            [regions.index(c.region) for c in self.configs], dtype=np.int64
        )
        self._region_names = regions

        # -- dynamic per-pool state, stacked ------------------------------
        self.capacity = self.base_capacity.copy()
        self.regime = np.zeros(P, dtype=np.int64)
        self.admission_margin = np.zeros(P)
        self.n_running = np.zeros(P, dtype=np.int64)
        self.n_provisioning = np.zeros(P, dtype=np.int64)
        self.target_nodes = np.zeros(P, dtype=np.int64)
        self.replenish_at = np.full(P, math.inf)
        self._tick_count = 0
        self._submit_seq = np.zeros(P, dtype=np.int64)
        self._instance_seq = np.zeros(P, dtype=np.int64)
        u0 = keyed_uniform(self._seed, self._idx, 0, _TAG_DWELL)
        self.regime_until = keyed_exponential(self._dwell[:, STABLE], u0)

        # -- event-driven per-instance bookkeeping ------------------------
        self._instances: List[Deque[_Instance]] = [deque() for _ in range(P)]
        self._cohorts: List[_Cohort] = []
        self._req_cohort: Dict[int, _Cohort] = {}
        self._probe_instances: List[_Instance] = []
        self.interruptions = InterruptionLog(self.pool_ids)
        self._provision_listeners: List[Callable[[SpotRequest], None]] = []

        # -- per-region rate limiting (sliding 60 s window) ----------------
        self._rate_window: List[Deque[Tuple[float, int]]] = [
            deque() for _ in regions
        ]
        self._rate_sum = np.zeros(len(regions), dtype=np.int64)
        self.api_calls = 0

    # -- public API -------------------------------------------------------

    @property
    def pool_ids(self) -> List[str]:
        return [cfg.pool_id for cfg in self.configs]

    def pool_index(self, pool_ids: Sequence[str]) -> np.ndarray:
        """Map pool ids to stacked-array indices."""
        return np.array([self._pool_index[p] for p in pool_ids], dtype=np.int64)

    def pool_config(self, pool_id: str) -> PoolConfig:
        return self.configs[self._pool_index[pool_id]]

    def on_provisioning(self, callback: Callable[[SpotRequest], None]) -> None:
        """Subscribe to provisioning-started lifecycle events (the hook the
        SnS Request Terminator uses).  Fired by the scalar object API only;
        the batched fleet path models the terminator explicitly."""
        self._provision_listeners.append(callback)

    # -- admission core (shared by both APIs) ------------------------------

    def _accept_mask(self, pool_idx: np.ndarray, n: int) -> np.ndarray:
        """(K, n) accept pattern for one concurrent batch of ``n`` requests
        per pool; consumes one submission sequence number per pool.

        Two-phase concurrency semantics: all ``n`` requests of a pool pass
        the capacity check together, each accepted request consuming one
        unit of headroom — this is what makes the accepted/submitted ratio
        a *graded* estimate of available capacity (§III-A).
        """
        seq = self._submit_seq[pool_idx]
        self._submit_seq[pool_idx] = seq + 1
        u = keyed_uniform(
            self._seed,
            pool_idx[:, None],
            seq[:, None],
            _TAG_SUBMIT + np.arange(n)[None, :],
        )
        ok = u >= _FLAKE_P
        headroom = (
            self.capacity[pool_idx]
            - self.n_running[pool_idx]
            - self.n_provisioning[pool_idx]
            - self.admission_margin[pool_idx]
        )
        # request r is admitted iff it passes the flake draw and the
        # headroom left after the accepts before it is still positive
        return ok & ((np.cumsum(ok, axis=1) - 1) < headroom[:, None])

    def submit_spot_request(self, pool_id: str, *, n: int = 1) -> List[SpotRequest]:
        """Submit ``n`` *concurrent* spot requests (scalar object API).

        Provisioning lifecycle events fire after the whole batch has passed
        the capacity check, so an event-driven canceller cannot free
        capacity mid-batch.  Raises :class:`RateLimitError` when the
        region's request budget is exhausted (nothing is charged).
        """
        p = self._pool_index[pool_id]
        self._charge_rate_limit(int(self._region_code[p]), n)
        accept = self._accept_mask(np.array([p]), n)[0]
        out: List[SpotRequest] = []
        accepted: List[SpotRequest] = []
        k = int(accept.sum())
        cohort = _Cohort(p, self.now, k, probe=True, requests=[]) if k else None
        for r in range(n):
            req = SpotRequest(pool_id=pool_id, submit_time=self.now)
            if accept[r]:
                req.transition(RequestState.PROVISIONING, self.now)
                cohort.requests.append(req)
                self._req_cohort[req.request_id] = cohort
                accepted.append(req)
            else:
                req.transition(RequestState.REJECTED, self.now)
            out.append(req)
        if cohort is not None:
            self._cohorts.append(cohort)
            self.n_provisioning[p] += k
        for req in accepted:
            for cb in self._provision_listeners:
                cb(req)
        return out

    def submit_spot_requests(
        self, pool_idx: np.ndarray, *, n: int = 1, hold: bool = False
    ):
        """Batched admission: ``n`` concurrent requests against *every*
        pool in ``pool_idx`` in one vector op (the fleet probing path).

        Returns the accepted-count vector ``(len(pool_idx),)``.  With the
        default ``hold=False`` the accepted requests are cancelled on
        provisioning acceptance (the event-driven SnS scoot), leaving
        provider state untouched; ``hold=True`` instead leaves them
        provisioning and returns ``(counts, cohorts)`` so the caller can
        :meth:`cancel_cohorts` later (the slow-terminator model).  Pools
        whose region budget is exhausted count 0 (rate-limited cycles
        record total failure, as in the scalar path).
        """
        pool_idx = np.asarray(pool_idx, dtype=np.int64)
        counts = np.zeros(len(pool_idx), dtype=np.int64)
        admitted = self._charge_rate_limit_batch(pool_idx, n)
        cohorts: List[_Cohort] = []
        if admitted.any():
            sub = pool_idx[admitted]
            counts[admitted] = self._accept_mask(sub, n).sum(axis=1)
            if hold:
                for p, k in zip(sub, counts[admitted]):
                    if k > 0:
                        ch = _Cohort(int(p), self.now, int(k), probe=True)
                        cohorts.append(ch)
                        self._cohorts.append(ch)
                self.n_provisioning[sub] += counts[admitted]
        return (counts, cohorts) if hold else counts

    def cancel(self, request: SpotRequest) -> None:
        """Cancel a PROVISIONING request (the scoot)."""
        if request.state is RequestState.PROVISIONING:
            request.transition(RequestState.CANCELLED, self.now)
            cohort = self._req_cohort.pop(request.request_id, None)
            if cohort is not None:
                cohort.count -= 1
                cohort.requests.remove(request)
                self.n_provisioning[cohort.pool] -= 1
        # cancelling REJECTED/terminal requests is a no-op, like real APIs

    def cancel_cohorts(self, cohorts: Sequence[_Cohort]) -> None:
        """Cancel still-provisioning members of held request batches
        (the fleet-path equivalent of flushing delayed per-request
        cancels; cohorts that already settled to RUNNING — marked
        ``count == -1`` by the settle pass — are left alone, like
        cancelling a RUNNING request in the real APIs)."""
        for ch in cohorts:
            if ch.count > 0:
                self.n_provisioning[ch.pool] -= ch.count
                ch.count = 0

    def terminate(self, request: SpotRequest) -> None:
        if request.state is RequestState.RUNNING:
            request.transition(RequestState.TERMINATED, self.now)
            p = self._pool_index[request.pool_id]
            for inst in self._instances[p]:
                if inst.obj is request:
                    inst.end = self.now
                    self._instances[p].remove(inst)
                    self.n_running[p] -= 1
                    break

    def set_node_pool(self, pool_id: str, n_nodes: int) -> None:
        """Declare a ground-truth node pool that tries to keep ``n_nodes``
        running (an autoscaling-group analogue; §III-B's 10-node pools)."""
        p = self._pool_index[pool_id]
        self.target_nodes[p] = int(n_nodes)
        self.replenish_at[p] = self.now  # acquire ASAP

    def running_count(self, pool_id: str) -> int:
        return int(self.n_running[self._pool_index[pool_id]])

    def running_counts(self, pool_idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Stacked running counts (a copy) for the fleet collector."""
        if pool_idx is None:
            return self.n_running.copy()
        return self.n_running[np.asarray(pool_idx, dtype=np.int64)]

    def running_cost(self, pool_id: str, now: Optional[float] = None) -> float:
        """Total compute cost billed so far for RUNNING time in this pool."""
        now = self.now if now is None else now
        p = self._pool_index[pool_id]
        price = self.price_per_hour[p] / 3600.0
        return sum(max(0.0, now - inst.start) * price for inst in self._instances[p])

    def probe_ledger_len(self) -> int:
        """Current length of the leaked-probe ledger (a scope marker for
        per-campaign cost accounting)."""
        return len(self._probe_instances)

    def probe_instance_cost(
        self, now: Optional[float] = None, *, since: int = 0
    ) -> float:
        """Compute dollars billed to probe requests that leaked into
        RUNNING (≈ 0 by design: only a slow terminator leaks).  ``since``
        restricts the sum to ledger entries added after that marker."""
        now = self.now if now is None else now
        total = 0.0
        for inst in self._probe_instances[since:]:
            end = now if inst.end is None else inst.end
            total += max(0.0, end - inst.start) * self.price_per_hour[inst.pool]
        return total / 3600.0

    def advance(self, to_time: float) -> None:
        """Advance simulation clock, stepping the whole fleet each tick."""
        if to_time < self.now:
            raise ValueError("time moves forward only")
        while self.now + self.tick <= to_time:
            self.step_batch()
        # fractional remainder advances the clock without a dynamics step
        if to_time > self.now:
            self.now = to_time
            self._settle_provisioning()

    def step_batch(self, dt: Optional[float] = None) -> None:
        """One dynamics tick for every pool at once (a constant number of
        vector ops over the stacked state, independent of fleet size).

        ``dt`` rescales the step's clock advance and margin decay; the
        regime/capacity increments are calibrated per tick, so dynamics
        are faithful at ``dt == tick`` (the default) and approximate
        otherwise.
        """
        if dt is None:
            dt, decay = self.tick, self._margin_decay
        else:
            dt = float(dt)
            decay = math.exp(-dt / self.margin_decay_tau)
        self.now += dt
        self._tick_count += 1
        self._settle_provisioning()
        self._step_fleet(decay)

    # -- internals ---------------------------------------------------------

    def _step_fleet(self, margin_decay: float) -> None:
        seed, k, idx = self._seed, self._tick_count, self._idx
        # -- regime transitions (due pools only) ---------------------------
        due = self.now >= self.regime_until
        if due.any():
            dp = idx[due]
            u = keyed_uniform(seed, dp, k, _TAG_NEXT_REGIME)
            r = self.regime[dp]
            # STABLE degrades, usually via TIGHT (prediction lead time),
            # rarely straight to CRUNCH (the hard, unpredictable case);
            # TIGHT mostly falls to CRUNCH; CRUNCH mostly recovers via TIGHT.
            new = np.where(
                r == STABLE,
                np.where(u < self._p_tight_first[dp], TIGHT, CRUNCH),
                np.where(
                    r == TIGHT,
                    np.where(u < 0.75, CRUNCH, STABLE),
                    np.where(u < 0.6, TIGHT, STABLE),
                ),
            )
            self.regime[dp] = new
            # Degraded regimes have concentrated dwell times: elapsed time
            # in degradation is informative about time-to-interruption,
            # which is what gives CUT its predictive value (§IV-B).
            ud = keyed_uniform(seed, dp, k, _TAG_DWELL)
            mean = self._dwell[dp, new]
            self.regime_until[dp] = self.now + np.where(
                new == STABLE,
                keyed_exponential(mean, ud),
                keyed_uniform_between(0.7 * mean, 1.3 * mean, ud),
            )
            # Degradation raises the admission margin — new requests start
            # failing *partially* before running instances are reclaimed
            # (paper Fig. 2 lead-time behaviour; Table I's Actual > SnS
            # cases are mostly graded, not blackouts).
            deg = dp[new != STABLE]
            if deg.size:
                ub = keyed_uniform(seed, deg, k, _TAG_DEGRADE_BUMP)
                bump = keyed_uniform_between(0.15, 0.7, ub) * np.maximum(
                    self.target_nodes[deg], 4
                )
                self.admission_margin[deg] = np.maximum(
                    self.admission_margin[deg], bump
                )
        # -- capacity mean-reversion to regime target ----------------------
        nmax = np.maximum(self.target_nodes, 1).astype(np.float64)
        ut = keyed_uniform(seed, idx, k, _TAG_TARGET)
        target = np.where(
            self.regime == STABLE,
            self.base_capacity,
            np.where(
                self.regime == TIGHT,
                # just around the running demand: probes contend with demand
                nmax + keyed_uniform_between(0.15 * nmax, 0.6 * nmax, ut),
                # CRUNCH: below running demand -> forces reclamation
                keyed_uniform_between(0.0, 0.8 * nmax, ut),
            ),
        )
        ua = keyed_uniform(seed, idx, k, _TAG_NOISE_A)
        ub = keyed_uniform(seed, idx, k, _TAG_NOISE_B)
        self.capacity += 0.35 * (target - self.capacity) + keyed_normal(
            self.volatility, ua, ub
        )
        np.maximum(self.capacity, 0.0, out=self.capacity)
        # -- admission margin decays slowly (conservative recovery) --------
        self.admission_margin *= margin_decay
        self.admission_margin[self.admission_margin < 0.05] = 0.0
        # -- reclaim running instances if capacity fell below them ---------
        # Hysteresis: providers reclaim in sweeps, not single-node dribbles;
        # a 1-2 node transient dip outside CRUNCH does not trigger a sweep.
        overflow = self.n_running - self.capacity.astype(np.int64)
        sweep = (overflow > 0) & ((self.regime == CRUNCH) | (overflow >= 3))
        if sweep.any():
            for p in np.nonzero(sweep)[0]:
                self._reclaim(int(p), int(overflow[p]))
        # -- node-pool replenishment ---------------------------------------
        self._replenish_batch()

    def _reclaim(self, p: int, k: int) -> None:
        """Interrupt ``k`` running instances with clustered timestamps.

        Co-interrupt proximity calibration (paper Fig. 3): delays are a
        mixture of a fast exponential (same reclamation sweep, ~88 %) and a
        slower uniform tail (independent follow-up sweeps).  Calibrated to
        >85 % of proximities < 1 min and ≈93 % < 3 min.
        """
        fifo = self._instances[p]
        k = min(k, len(fifo))
        if k == 0:
            return
        tick = self._tick_count
        delay = reclaim_sweep_delays(self._seed, p, tick, k)
        uids = np.empty(k, dtype=np.int64)
        times = self.now + delay[:k]
        for j in range(k):
            inst = fifo.popleft()  # oldest first: sweeps reclaim in order
            t = float(times[j])
            inst.end = t
            if inst.obj is not None:
                inst.obj.transition(RequestState.INTERRUPTED, t)
            uids[j] = inst.uid
        self.interruptions.append_sweep(p, uids, times)
        self.n_running[p] -= k
        # A sweep that actually reclaimed nodes means the pool has zero
        # spare capacity: new admissions black out until the margin decays
        # (this is what keeps post-interruption unavailability episodes
        # alive for tens of minutes, as in the paper's Fig. 2 traces).
        ubump = keyed_uniform(self._seed, p, tick, _TAG_RECLAIM_BUMP)
        self.admission_margin[p] += k + float(
            keyed_uniform_between(0.4, 1.0, ubump)
        ) * max(int(self.target_nodes[p]), 4)
        self.replenish_at[p] = max(
            self.replenish_at[p], self.now + self.replenish_delay
        )

    def _replenish_batch(self) -> None:
        """Node pools try to restore target_nodes (ASG behaviour): retry
        every tick once the post-interruption cooldown has passed, stopping
        at the first failed admission (retry next tick)."""
        deficit = self.target_nodes - self.n_running - self.n_provisioning
        mask = (self.target_nodes > 0) & (self.now >= self.replenish_at) & (deficit > 0)
        if not mask.any():
            return
        mp = self._idx[mask]
        d = deficit[mp]
        dmax = int(d.max())
        j = np.arange(dmax)
        u = keyed_uniform(
            self._seed, mp[:, None], self._tick_count, _TAG_REPLENISH + j[None, :]
        )
        headroom = (
            self.capacity[mp]
            - self.n_running[mp]
            - self.n_provisioning[mp]
            - self.admission_margin[mp]
        )
        # attempt j succeeds while j < headroom (each accept consumes one
        # unit), passes the flake draw, and is within the pool's deficit;
        # the first failure stops the pool's attempts for this tick.
        ok = (j[None, :] < headroom[:, None]) & (u >= _FLAKE_P) & (j[None, :] < d[:, None])
        accepts = np.where(ok.all(axis=1), dmax, np.argmax(~ok, axis=1))
        got = accepts > 0
        for p, c in zip(mp[got], accepts[got]):
            self._cohorts.append(_Cohort(int(p), self.now, int(c)))
        self.n_provisioning[mp] += accepts

    def _settle_provisioning(self) -> None:
        """Provisioning completes after `provisioning_duration`: cohorts
        not cancelled by then transition to RUNNING (and start billing)."""
        if not self._cohorts:
            return
        pending: List[_Cohort] = []
        for ch in self._cohorts:
            if self.now - ch.start < self.provisioning_duration:
                pending.append(ch)
                continue
            if ch.count <= 0:
                continue  # fully cancelled while provisioning
            p, k = ch.pool, ch.count
            ch.count = -1  # settled marker: no longer cancellable
            self.n_provisioning[p] -= k
            self.n_running[p] += k
            uid0 = int(self._instance_seq[p])
            self._instance_seq[p] += k
            objs = ch.requests if ch.requests is not None else []
            for i in range(k):
                obj = objs[i] if i < len(objs) else None
                inst = _Instance(
                    uid=uid0 + i, pool=p, start=self.now, probe=ch.probe, obj=obj
                )
                self._instances[p].append(inst)
                if obj is not None:
                    obj.transition(RequestState.RUNNING, self.now)
                    self._req_cohort.pop(obj.request_id, None)
                if ch.probe:
                    self._probe_instances.append(inst)
        self._cohorts = pending

    # -- rate limiting -----------------------------------------------------

    def _prune_rate_window(self, rc: int) -> None:
        window = self._rate_window[rc]
        cutoff = self.now - 60.0
        while window and window[0][0] <= cutoff:
            _, c = window.popleft()
            self._rate_sum[rc] -= c

    def _charge_rate_limit(self, rc: int, n: int) -> None:
        self._prune_rate_window(rc)
        if self._rate_sum[rc] + n > self.rate_limit:
            raise RateLimitError(
                f"region {self._region_names[rc]}: {int(self._rate_sum[rc]) + n} "
                f"requests in 60 s exceeds limit {self.rate_limit}"
            )
        self._rate_window[rc].append((self.now, n))
        self._rate_sum[rc] += n
        self.api_calls += n

    def _charge_rate_limit_batch(self, pool_idx: np.ndarray, n: int) -> np.ndarray:
        """Sequential-semantics budget check for a batch: per region, the
        first ``floor(budget / n)`` pools (in submission order) are
        admitted, the rest fail without consuming budget — exactly what a
        pool-by-pool loop of :meth:`_charge_rate_limit` yields."""
        admitted = np.zeros(len(pool_idx), dtype=bool)
        codes = self._region_code[pool_idx]
        for rc in np.unique(codes):
            rc = int(rc)
            self._prune_rate_window(rc)
            sel = np.nonzero(codes == rc)[0]
            budget = int(self.rate_limit - self._rate_sum[rc])
            k = min(len(sel), max(0, budget // n))
            if k > 0:
                admitted[sel[:k]] = True
                self._rate_window[rc].append((self.now, k * n))
                self._rate_sum[rc] += k * n
                self.api_calls += k * n
        return admitted


# --------------------------------------------------------------------------
# Fleet construction helpers
# --------------------------------------------------------------------------

_AWS_REGIONS = [
    "us-east-1", "us-west-2", "eu-west-1", "ap-northeast-1", "us-east-2",
    "eu-central-1", "ap-southeast-1", "sa-east-1", "ca-central-1",
    "ap-south-1", "eu-north-1",
]
_AZURE_REGIONS = ["eastus", "westus2", "westeurope", "japaneast"]

_INSTANCE_FAMILIES = [
    ("m5.large", 0.096), ("m5.xlarge", 0.192), ("c5.large", 0.085),
    ("c5.2xlarge", 0.34), ("r5.large", 0.126), ("r5.2xlarge", 0.504),
    ("g4dn.xlarge", 0.526), ("p3.2xlarge", 3.06), ("t3.medium", 0.0416),
    ("i3.large", 0.156), ("m6i.large", 0.096), ("c6i.xlarge", 0.17),
]


def default_fleet(
    n_pools: int = 68,
    *,
    seed: int = 0,
    providers: Tuple[str, ...] = ("aws", "azure"),
) -> List[PoolConfig]:
    """Build a fleet of pool configs shaped like the paper's campaign:
    68 instance types across 15 regions (47 AWS + 21 Azure)."""
    rng = np.random.default_rng(seed)
    n_aws = round(n_pools * 47 / 68) if "azure" in providers else n_pools
    configs: List[PoolConfig] = []
    for i in range(n_pools):
        if "aws" in providers and (i < n_aws or "azure" not in providers):
            region = _AWS_REGIONS[i % len(_AWS_REGIONS)]
            cloud = "aws"
        else:
            region = _AZURE_REGIONS[i % len(_AZURE_REGIONS)]
            cloud = "azure"
        itype, price = _INSTANCE_FAMILIES[i % len(_INSTANCE_FAMILIES)]
        # Azure pools are calmer in Table I (88.7 % vs 77.1 % match):
        stability = 3.0 if cloud == "azure" else 1.0
        configs.append(
            PoolConfig(
                instance_type=f"{cloud}:{itype}:{i}",
                region=region,
                az=chr(ord("a") + int(rng.integers(0, 3))),
                price_per_hour=price * float(rng.uniform(0.8, 1.25)),
                base_capacity=float(rng.uniform(25.0, 45.0)),
                volatility=float(rng.uniform(1.0, 2.5)),
                dwell_stable=float(rng.uniform(4.0, 12.0)) * 3600.0 * stability,
                dwell_tight=float(rng.uniform(30.0, 80.0)) * 60.0,
                dwell_crunch=float(rng.uniform(5.0, 18.0)) * 60.0,
            )
        )
    return configs
