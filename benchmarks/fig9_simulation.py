"""Fig. 9: total lost computation by scheduling strategy (TPC-DS replay).

Paper protocol (§VI-E): pool-level 75/25 train/eval split; XGBoost trained
on SnS features; replay the 99-query TPC-DS profile over each evaluation
pool's 24 h trace; Predict-AR defers new queries when the model forecasts
unavailability.  Paper: −27 % lost computation with the 3-min model, up to
−46 % with the 15-min model, at the cost of added idle time.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    binary_availability,
    build_dataset,
    compute_features,
    fit_predictor,
    run_fleet_strategies,
    tpcds_profile,
)

from .common import paper_campaign

PAPER = {"reduction_3min": 0.27, "reduction_15min": 0.46}


def run(horizons_min=(3, 15), n_permutations=5, engine="auto"):
    c = paper_campaign()
    dt_min = c.interval / 60.0
    durations = tpcds_profile()
    avail = binary_availability(c.running, c.n)
    feats = compute_features(c.s, c.n, 480.0, dt_min)

    out = {}
    for h in horizons_min:
        h_cycles = int(round(h / dt_min))
        ds = build_dataset(
            c, window_minutes=480.0, horizon_minutes=h, split="pool", seed=0
        )
        model = fit_predictor("xgb", ds)
        test_pools = sorted(set(int(p) for p in np.unique(ds.test_pools)))

        # one model call per pool over its whole trace (the batched
        # predictor contract), then every (pool x permutation x strategy)
        # trace replays inside three replay_batch calls — through the
        # scan engine by default (engine="auto")
        predictions = np.stack(
            [
                model.predict(
                    ds.standardizer(feats[pool])
                    if ds.standardizer is not None
                    else feats[pool]
                )
                for pool in test_pools
            ]
        )
        per_pool = run_fleet_strategies(
            avail[test_pools], durations, dt=c.interval,
            predictions=predictions, horizon_cycles=h_cycles,
            n_permutations=n_permutations, seeds=test_pools, engine=engine,
        )
        totals = {s: sum(r.lost_seconds for r in rs) for s, rs in per_pool.items()}
        idle = {s: sum(r.idle_seconds for r in rs) for s, rs in per_pool.items()}

        base = totals["always_run"]
        out[f"h={h}min"] = {
            "eval_pools": len(test_pools),
            "lost_s": {k: round(v, 1) for k, v in totals.items()},
            "idle_s": {k: round(v, 1) for k, v in idle.items()},
            "predict_ar_reduction": round(
                1.0 - totals["predict_ar"] / base, 3
            ) if base > 0 else None,
            "sjf_reduction": round(1.0 - totals["sjf"] / base, 3)
            if base > 0 else None,
        }
    out["paper"] = PAPER
    return out


if __name__ == "__main__":
    print(run())
