"""falcon-mamba-7b — pure Mamba-1, attention-free.

[arXiv:2410.05355; unverified] — 64L d_model=4096 (attn-free) d_ff=0
vocab=65024, ssm_state=16.  Mixer-only layers (no FFN — the SSM block's
in/out projections carry the channel mixing); O(1) decode state makes this
the canonical `long_500k` arch.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,              # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_conv=4,
    expand=2,
    use_rope=False,
    norm="rmsnorm",
    gated_mlp=True,
    source="arXiv:2410.05355; unverified",
)
