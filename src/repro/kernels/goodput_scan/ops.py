"""Public entry point for the fused goodput replay.

``goodput_sweep_op`` takes the normalised fused inputs prepared by
``repro.fleet.runner`` — the host-packed availability/panic flag matrix,
the host-precomputed negative log survival, and the per-policy τ
parameter planes — and replays every pod trace through **all S policy
planes in one pass** on the selected backend.  Host prep stays in the
fleet layer (this package imports neither ``PolicyTable`` nor the policy
objects), so ``kernels`` never depends on ``fleet``.

Backends:

* ``"jnp"``    — the ``lax.scan`` reference (the fast CPU path).
* ``"pallas"`` — the chunked policy-fused Pallas kernel (interpret mode
  off-TPU).  Handles ragged shapes by padding cycles (``flags = 0``
  beyond the real trace, masked inert inside the kernel) and pod rows
  (flags-0 rows never train; sliced off).
* ``"auto"``   — Pallas on TPU (float32 only — Mosaic has no float64),
  scan elsewhere.

Precision tiers: the dtype of ``nlp`` selects the tier.  float64 runs
under a scoped ``enable_x64`` (the atol=0 house contract); float32 runs
the same op sequence in f32 end to end — the bandwidth-lean fast tier.
Counters are int32 in-engine in **both** tiers (identical graphs), cast
to int64 on output; float metrics are returned as float64 (an exact
widening), so the metric dict has one schema per tier.
"""

from __future__ import annotations

import contextlib
from typing import Dict

import numpy as np

__all__ = ["goodput_sweep_op"]

#: fparams plane order shared with ``kernel.py``
_FPARAM_ORDER = ("interval", "ckpt_cost", "horizon", "tau_max", "floor_hazard")


def _x64_if(dtype):
    if np.dtype(dtype) == np.float64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def goodput_sweep_op(
    flags: np.ndarray,   # (P, T) int — bit0 avail, bit(1+s) panic for plane s
    nlp: np.ndarray,     # (P, T) float — host -log(clip(p_survive))
    planes: Dict[str, np.ndarray],  # (S, P): is_hazard + _FPARAM_ORDER
    *,
    dt: float,
    step_time: float,
    ckpt_cost: float,
    restore_cost: float,
    backend: str = "auto",
    block_p: int = 8,
    chunk: int = 128,
) -> Dict[str, np.ndarray]:
    """Fused sweep; returns ``(S, P)`` metric planes (int64 counters,
    float64 seconds — goodput/lost-work derivation stays in the fleet
    layer)."""
    import jax

    fdt = np.dtype(nlp.dtype)
    if fdt not in (np.dtype(np.float32), np.dtype(np.float64)):
        raise ValueError(f"nlp must be float32/float64, got {fdt}")
    if backend == "auto":
        # Mosaic has no float64: f64 contracts stay on the bit-identical
        # scan even on TPU (pass f32 inputs — or request backend="pallas"
        # explicitly — for the native kernel path)
        on_tpu = jax.default_backend() == "tpu"
        backend = "pallas" if on_tpu and fdt != np.float64 else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")

    P, T = np.asarray(flags).shape
    S = planes["is_hazard"].shape[0]
    ft = fdt.type
    flags = np.ascontiguousarray(np.asarray(flags, dtype=np.int32))
    nlp = np.ascontiguousarray(np.asarray(nlp, dtype=fdt))
    fparams = np.stack(
        [np.asarray(planes[k], dtype=fdt) for k in _FPARAM_ORDER], axis=-1
    )                                               # (S, P, 5)
    is_hz = np.asarray(planes["is_hazard"], dtype=bool)
    scal = (ft(dt), ft(step_time), ft(ckpt_cost), ft(restore_cost))

    import jax.numpy as jnp

    if backend == "jnp":
        from .ref import goodput_sweep_ref

        with _x64_if(fdt):
            res = goodput_sweep_ref(
                jnp.asarray(flags.T), jnp.asarray(nlp.T),
                jnp.asarray(np.arange(T, dtype=np.int32)),
                jnp.asarray(is_hz),
                jnp.asarray(fparams[..., 0]), jnp.asarray(fparams[..., 1]),
                jnp.asarray(fparams[..., 2]), jnp.asarray(fparams[..., 3]),
                jnp.asarray(fparams[..., 4]),
                *scal,
            )
            res = {k: np.asarray(v) for k, v in res.items()}
    else:
        from .kernel import goodput_sweep_kernel

        block_p = min(block_p, max(P, 1))
        chunk = min(chunk, max(T, 1))
        pad_p = (-P) % block_p
        pad_t = (-T) % chunk
        fl = np.zeros((P + pad_p, T + pad_t), dtype=np.int32)
        fl[:P, :T] = flags
        nl = np.zeros_like(fl, dtype=fdt)
        nl[:P, :T] = nlp
        hz = np.zeros((S, P + pad_p), dtype=np.int32)
        hz[:, :P] = is_hz
        fp = np.ones((S, P + pad_p, 5), dtype=fdt)   # inert params, no /0
        fp[:, :P] = fparams
        with _x64_if(fdt):
            res = goodput_sweep_kernel(
                jnp.asarray(fl), jnp.asarray(nl), jnp.asarray(hz),
                jnp.asarray(fp),
                jnp.asarray(np.array([scal], dtype=fdt)),
                t_real=T, block_p=block_p, chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
            res = {k: np.asarray(v)[:, :P] for k, v in res.items()}

    return {
        "steps_completed": res["steps_completed"].astype(np.int64),
        "steps_lost": res["steps_lost"].astype(np.int64),
        "checkpoints": res["checkpoints"].astype(np.int64),
        "ckpt_overhead_s": res["ckpt_overhead_s"].astype(np.float64),
        "unavailable_s": res["unavailable_s"].astype(np.float64),
    }
