"""Counter-based per-pool random streams for the campaign engine.

The batched fleet engine and the scalar object API must produce
*bit-identical* trajectories (the PR's parity anchor), which rules out a
shared sequential generator: the scalar path visits pools one at a time
while the fleet path draws for every pool in one vector op, so any RNG
whose output depends on call *order* diverges immediately.

Instead every draw is a pure function of a key::

    u = uniform(seed, pool, counter, tag)        # in [0, 1)

where ``pool`` is the pool index, ``counter`` is a monotone event counter
(the dynamics tick index, or the pool's submission sequence number), and
``tag`` names the draw site (regime transition, capacity noise, the k-th
admission check, ...).  Consumption order is irrelevant — the scalar view
and the batched engine evaluate the same hash at the same keys and get the
same bits.  The hash is SplitMix64 over the mixed-in key lanes, evaluated
elementwise on uint64 numpy arrays so a whole fleet's draws are one
vector op.

Derived variates (exponential, bounded uniform, normal via Box–Muller) are
deterministic float64 transforms of the base uniforms, shared by both
engines.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "keyed_uniform",
    "keyed_exponential",
    "keyed_uniform_between",
    "keyed_normal",
]

_U64 = np.uint64
# SplitMix64 constants + distinct odd multipliers per key lane.
_M1 = _U64(0xBF58476D1CE4E5B9)
_M2 = _U64(0x94D049BB133111EB)
_GOLDEN = _U64(0x9E3779B97F4A7C15)
_LANE_POOL = _U64(0xD6E8FEB86659FD93)
_LANE_CTR = _U64(0xA5CB3B207C7E6B45)
_LANE_TAG = _U64(0x2545F4914F6CDD1D)
_S30, _S27, _S31, _S11 = _U64(30), _U64(27), _U64(31), _U64(11)
_INV53 = np.float64(2.0 ** -53)


def _mix(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, elementwise on uint64."""
    x = (x ^ (x >> _S30)) * _M1
    x = (x ^ (x >> _S27)) * _M2
    return x ^ (x >> _S31)


def _as_u64(x) -> np.ndarray:
    # int64 -> uint64 must wrap, not raise: go through the signed dtype.
    return np.asarray(x, dtype=np.int64).astype(np.uint64)


def keyed_uniform(seed: int, pool, counter, tag) -> np.ndarray:
    """Uniform [0, 1) float64, a pure function of the key.

    ``pool``, ``counter`` and ``tag`` broadcast like numpy operands; the
    result has the broadcast shape (0-d inputs give a 0-d array).
    """
    with np.errstate(over="ignore"):  # uint64 wraparound is the hash
        h = _U64(seed & 0xFFFFFFFFFFFFFFFF) * _GOLDEN
        h = _mix(h ^ (_as_u64(pool) * _LANE_POOL))
        h = _mix(h ^ (_as_u64(counter) * _LANE_CTR))
        h = _mix(h ^ (_as_u64(tag) * _LANE_TAG))
    return (h >> _S11).astype(np.float64) * _INV53


def keyed_exponential(mean, u: np.ndarray) -> np.ndarray:
    """Exponential(mean) from a base uniform (inverse CDF)."""
    return -np.asarray(mean, dtype=np.float64) * np.log1p(-u)


def keyed_uniform_between(lo, hi, u: np.ndarray) -> np.ndarray:
    """Uniform [lo, hi) from a base uniform."""
    lo = np.asarray(lo, dtype=np.float64)
    return lo + (np.asarray(hi, dtype=np.float64) - lo) * u


def keyed_normal(std, u1: np.ndarray, u2: np.ndarray) -> np.ndarray:
    """N(0, std^2) via Box–Muller from two base uniforms."""
    r = np.sqrt(-2.0 * np.log1p(-u1))
    return np.asarray(std, dtype=np.float64) * r * np.cos(2.0 * np.pi * u2)
