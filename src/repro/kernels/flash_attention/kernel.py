"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU adaptation of the FlashAttention algorithm:

* grid = (batch·q_heads, S_q/block_q, S_k/block_k); the kv-block axis is
  innermost, so TPU's sequential grid execution lets the online-softmax
  accumulators (m, l, acc) live in VMEM scratch across kv iterations;
* block shapes are MXU-aligned (block_q × hd and block_k × hd tiles with
  hd a multiple of 128 in the zoo's configs; block sizes default 128);
* GQA is expressed in the *index map*: the kv BlockSpec maps q-head
  ``bh`` to kv-head ``bh // n_rep`` — no materialised head repetition, so
  HBM traffic for K/V stays at the GQA-compressed size;
* causal + sliding-window masking is applied per (q,k) tile from the
  global position grids; fully-masked tiles still execute but contribute
  zeros (the `pl.when` fast-path skip is a possible further optimisation
  and is measured in EXPERIMENTS.md §Perf).

Validated in interpret mode against ``ref.attention_ref`` across
shape/dtype sweeps (``tests/test_kernels_flash.py``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,       # VMEM tiles
    o_ref,                     # output tile (block_q, hd)
    m_scr, l_scr, acc_scr,     # scratch: (block_q,), (block_q,), (block_q, hd)
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[...].astype(jnp.float32)                    # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos > q_pos - window
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_scr[...] + p.sum(axis=1)
    v = v_ref[...].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,     # (B, H, S_q, hd)
    k: jnp.ndarray,     # (B, K, S_k, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 2**30,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, s_q, hd = q.shape
    _, kv, s_k, _ = k.shape
    n_rep = h // kv
    block_q = min(block_q, s_q)
    block_k = min(block_k, s_k)
    assert s_q % block_q == 0 and s_k % block_k == 0
    n_kv_blocks = s_k // block_k

    qf = q.reshape(b * h, s_q, hd)
    kf = k.reshape(b * kv, s_k, hd)
    vf = v.reshape(b * kv, s_k, hd)

    grid = (b * h, s_q // block_q, n_kv_blocks)

    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / math.sqrt(hd),
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv_blocks,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec(
                (None, block_k, hd),
                lambda bh, iq, ik, n_rep=n_rep: (bh // n_rep, ik, 0),
            ),
            pl.BlockSpec(
                (None, block_k, hd),
                lambda bh, iq, ik, n_rep=n_rep: (bh // n_rep, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s_q, hd)
