"""Binary availability labels, horizon shifting, dataset construction."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import binary_availability, build_dataset, horizon_labels


class TestLabels:
    def test_binary_availability(self):
        running = np.array([[10, 9, 10, 0]])
        np.testing.assert_array_equal(
            binary_availability(running, 10), [[1, 0, 1, 0]]
        )

    def test_horizon_zero_is_identity(self):
        a = np.array([1, 0, 1, 1])
        np.testing.assert_array_equal(horizon_labels(a, 0), a)

    def test_horizon_requires_sustained_availability(self):
        #          t:  0  1  2  3  4
        a = np.array([1, 1, 0, 1, 1])
        # h=1: y[t] = a[t+1]
        np.testing.assert_array_equal(horizon_labels(a, 1), [1, 0, 1, 1])
        # h=2: y[t] = min(a[t+1], a[t+2])
        np.testing.assert_array_equal(horizon_labels(a, 2), [0, 0, 1])

    @given(
        a=st.lists(st.integers(0, 1), min_size=5, max_size=60),
        h=st.integers(1, 4),
    )
    @settings(max_examples=40, deadline=None)
    def test_horizon_monotone_in_h(self, a, h):
        """Longer horizons can only flip labels 1 -> 0, never 0 -> 1."""
        arr = np.array(a)
        y1 = horizon_labels(arr, h)
        y2 = horizon_labels(arr, h + 1) if h + 1 < len(a) else None
        if y2 is not None:
            assert (y2 <= y1[: len(y2)]).all()

    def test_horizon_too_long_raises(self):
        with pytest.raises(ValueError):
            horizon_labels(np.ones(5), 5)

    @given(
        t=st.integers(5, 90),
        h=st.integers(1, 24),
        pools=st.integers(1, 4),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_blockmin_matches_stacked_form(self, t, h, pools, seed):
        """The O(T) prefix/suffix block-minimum is bit-identical to the
        old O(h·T) stacked sliding window, including 2-D pool stacks and
        horizons far beyond the block size."""
        from repro.core.labels import _horizon_labels_stacked

        if h >= t:
            return
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, 2, size=(pools, t)).astype(np.int32)
        np.testing.assert_array_equal(
            horizon_labels(arr, h), _horizon_labels_stacked(arr, h)
        )
        np.testing.assert_array_equal(
            horizon_labels(arr[0], h), _horizon_labels_stacked(arr[0], h)
        )

    def test_blockmin_bool_input_with_partial_block(self):
        """bool availability + (T-1) % h != 0 exercises the pad value."""
        from repro.core.labels import _horizon_labels_stacked

        arr = np.array([1, 0, 1, 1, 1, 1, 0], dtype=bool)
        np.testing.assert_array_equal(
            horizon_labels(arr, 4), _horizon_labels_stacked(arr, 4)
        )

    def test_blockmin_matches_stacked_60min_horizon(self):
        """The ROADMAP case: a 60-minute horizon (h=20 at 3-min cycles)
        on a long fleet trace."""
        from repro.core.labels import _horizon_labels_stacked

        rng = np.random.default_rng(1)
        arr = rng.integers(0, 2, size=(8, 960)).astype(np.int32)
        np.testing.assert_array_equal(
            horizon_labels(arr, 20), _horizon_labels_stacked(arr, 20)
        )


class TestDataset:
    def test_point_dataset_shapes(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=60, horizon_minutes=9)
        assert ds.x_train.ndim == 2 and ds.x_train.shape[1] == 3
        assert len(ds.x_train) + len(ds.x_test) > 0
        assert set(np.unique(ds.y_train)) <= {0, 1}
        # 75/25 split
        frac = len(ds.x_train) / (len(ds.x_train) + len(ds.x_test))
        assert 0.74 < frac < 0.76

    def test_sequence_dataset_shapes(self, small_campaign):
        ds = build_dataset(
            small_campaign, window_minutes=60, sequence_length=8
        )
        assert ds.x_train.ndim == 3 and ds.x_train.shape[1:] == (8, 3)

    def test_feature_subset(self, small_campaign):
        ds = build_dataset(small_campaign, feature_set=("SR",))
        assert ds.x_train.shape[1] == 1
        assert ds.feature_names == ("SR",)

    def test_pool_split_is_disjoint(self, small_campaign):
        ds = build_dataset(small_campaign, split="pool", seed=3)
        assert set(np.unique(ds.train_pools)).isdisjoint(np.unique(ds.test_pools))

    def test_standardization(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=60)
        assert abs(ds.x_train.mean()) < 0.2
        assert 0.5 < ds.x_train.std() < 2.0

    def test_sequence_alignment_last_step_equals_point_features(self, small_campaign):
        """The last step of each sequence must be that cycle's features."""
        ds_seq = build_dataset(
            small_campaign, window_minutes=60, sequence_length=4,
            split="pool", seed=7, standardize=False,
        )
        ds_pt = build_dataset(
            small_campaign, window_minutes=60,
            split="pool", seed=7, standardize=False,
        )
        # pool split with same seed -> same pools; sequence dataset drops
        # the first (L-1) cycles of each pool
        pools_seq = np.unique(ds_seq.test_pools)
        pools_pt = np.unique(ds_pt.test_pools)
        np.testing.assert_array_equal(pools_seq, pools_pt)
