"""Chaos smoke — fixed-seed fault-plan matrix, all engines, bit-parity.

The CI tripwire for the fault-injection substrate: runs a small campaign
under a matrix of deterministic fault plans (throttle bursts, blackout
windows, per-request transient errors, provisioning timeouts, and their
composition — with and without the retry/backoff control plane) through
all three collection engines and asserts

* **three-way bit-parity** — scalar ≡ fleet ≡ sharded (atol=0) on
  ``S_t`` / ``running_t`` / outcome codes / per-request error counts /
  interruption logs / cost / ``api_calls`` / ``fault_api_calls``;
* **clean resume** — kill-at-cycle-k + ``state_dict``/``restore`` into a
  fresh stream + drain reproduces the uninterrupted run bit-identically
  on every engine (through pickled checkpoint bytes).

Usage:
    PYTHONPATH=src python benchmarks/chaos_smoke.py [--smoke]
        [--pools 8] [--cycles 20]

``--smoke`` trims the plan matrix to one composite plan per family —
the shape ``make verify`` runs.  Always asserts; prints a JSON summary.
"""

from __future__ import annotations

import argparse
import json
import pickle
import time

import numpy as np

INTERVAL = 180.0


def _plans(smoke: bool):
    from repro.core import BlackoutWindows, FaultPlan, ThrottleBursts

    throttle = ThrottleBursts(p=0.5, epoch=900.0, mean_duration=400.0)
    blackout = BlackoutWindows(p=0.3, epoch=1800.0, mean_duration=600.0)
    composite = FaultPlan(
        seed=11, throttle=throttle, blackout=blackout,
        request_error_p=0.05, timeout_p=0.1,
    )
    if smoke:
        return {"composite": composite}
    return {
        "throttle": FaultPlan(seed=7, throttle=throttle),
        "blackout": FaultPlan(seed=7, blackout=blackout),
        "errors": FaultPlan(seed=7, request_error_p=0.08),
        "timeouts": FaultPlan(seed=7, timeout_p=0.15),
        "composite": composite,
        "composite_alt_seed": FaultPlan(
            seed=23, throttle=throttle, blackout=blackout,
            request_error_p=0.05, timeout_p=0.1,
        ),
    }


def _stream(engine, pools, cycles, plan, retry, seed=3):
    from repro.core import RetryPolicy, SimulatedProvider, default_fleet
    from repro.core.collector import CampaignStream

    prov = SimulatedProvider(default_fleet(pools, seed=seed), seed=seed)
    return CampaignStream(
        prov,
        duration=cycles * INTERVAL,
        interval=INTERVAL,
        engine=engine,
        fault_plan=plan,
        retry_policy=RetryPolicy(seed=5) if retry else None,
    )


def _drain(stream):
    while stream.step() is not None:
        pass
    return stream.result()


def _assert_identical(name, ra, rb):
    np.testing.assert_array_equal(ra.s, rb.s, err_msg=name)
    np.testing.assert_array_equal(ra.running, rb.running, err_msg=name)
    np.testing.assert_array_equal(ra.codes, rb.codes, err_msg=name)
    np.testing.assert_array_equal(ra.errors, rb.errors, err_msg=name)
    assert ra.interruptions == rb.interruptions, name
    assert ra.api_calls == rb.api_calls, name
    assert ra.fault_api_calls == rb.fault_api_calls, name
    assert ra.probe_compute_cost == rb.probe_compute_cost, name
    assert ra.node_pool_cost == rb.node_pool_cost, name


def run(pools: int = 8, cycles: int = 20, smoke: bool = False) -> dict:
    from repro.core import describe_codes

    engines = ("scalar", "fleet", "sharded")
    summary = {}
    for plan_name, plan in _plans(smoke).items():
        for retry in (False, True):
            case = f"{plan_name}{'+retry' if retry else ''}"
            results = {
                e: _drain(_stream(e, pools, cycles, plan, retry))
                for e in engines
            }
            ref = results["fleet"]
            for e in ("scalar", "sharded"):
                _assert_identical(f"{case}: fleet vs {e}", ref, results[e])

            # clean resume on every engine at a mid-campaign boundary
            k = cycles // 2
            for e in engines:
                interrupted = _stream(e, pools, cycles, plan, retry)
                for _ in range(k):
                    interrupted.step()
                blob = pickle.dumps(interrupted.state_dict())
                resumed = _stream(e, pools, cycles, plan, retry)
                resumed.restore(pickle.loads(blob))
                _assert_identical(
                    f"{case}: {e} resume@{k}", ref, _drain(resumed)
                )

            summary[case] = describe_codes(ref.codes)
            summary[case]["fault_api_calls"] = ref.fault_api_calls
    return {
        "pools": pools,
        "cycles": cycles,
        "engines": list(engines),
        "parity_and_resume_identical": True,
        "cases": summary,
        "smoke": smoke,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=8)
    ap.add_argument("--cycles", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="one composite plan instead of the full matrix")
    args = ap.parse_args()
    t0 = time.perf_counter()
    result = run(pools=args.pools, cycles=args.cycles, smoke=args.smoke)
    result["seconds"] = round(time.perf_counter() - t0, 1)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
