"""Binary availability labels, horizon shifting, dataset construction,
and the streaming (label + dataset) forms' bit-identity with the offline
builders."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CampaignPipelineStream,
    DatasetStreamer,
    HorizonLabelStream,
    SimulatedProvider,
    binary_availability,
    build_dataset,
    default_fleet,
    horizon_labels,
)


class TestLabels:
    def test_binary_availability(self):
        running = np.array([[10, 9, 10, 0]])
        np.testing.assert_array_equal(
            binary_availability(running, 10), [[1, 0, 1, 0]]
        )

    def test_horizon_zero_is_identity(self):
        a = np.array([1, 0, 1, 1])
        np.testing.assert_array_equal(horizon_labels(a, 0), a)

    def test_horizon_requires_sustained_availability(self):
        #          t:  0  1  2  3  4
        a = np.array([1, 1, 0, 1, 1])
        # h=1: y[t] = a[t+1]
        np.testing.assert_array_equal(horizon_labels(a, 1), [1, 0, 1, 1])
        # h=2: y[t] = min(a[t+1], a[t+2])
        np.testing.assert_array_equal(horizon_labels(a, 2), [0, 0, 1])

    @given(
        a=st.lists(st.integers(0, 1), min_size=5, max_size=60),
        h=st.integers(1, 4),
    )
    @settings(max_examples=40, deadline=None)
    def test_horizon_monotone_in_h(self, a, h):
        """Longer horizons can only flip labels 1 -> 0, never 0 -> 1."""
        arr = np.array(a)
        y1 = horizon_labels(arr, h)
        y2 = horizon_labels(arr, h + 1) if h + 1 < len(a) else None
        if y2 is not None:
            assert (y2 <= y1[: len(y2)]).all()

    def test_horizon_too_long_raises(self):
        with pytest.raises(ValueError):
            horizon_labels(np.ones(5), 5)

    @given(
        t=st.integers(5, 90),
        h=st.integers(1, 24),
        pools=st.integers(1, 4),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_blockmin_matches_stacked_form(self, t, h, pools, seed):
        """The O(T) prefix/suffix block-minimum is bit-identical to the
        old O(h·T) stacked sliding window, including 2-D pool stacks and
        horizons far beyond the block size."""
        from repro.core.labels import _horizon_labels_stacked

        if h >= t:
            return
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, 2, size=(pools, t)).astype(np.int32)
        np.testing.assert_array_equal(
            horizon_labels(arr, h), _horizon_labels_stacked(arr, h)
        )
        np.testing.assert_array_equal(
            horizon_labels(arr[0], h), _horizon_labels_stacked(arr[0], h)
        )

    def test_blockmin_bool_input_with_partial_block(self):
        """bool availability + (T-1) % h != 0 exercises the pad value."""
        from repro.core.labels import _horizon_labels_stacked

        arr = np.array([1, 0, 1, 1, 1, 1, 0], dtype=bool)
        np.testing.assert_array_equal(
            horizon_labels(arr, 4), _horizon_labels_stacked(arr, 4)
        )

    def test_blockmin_matches_stacked_60min_horizon(self):
        """The ROADMAP case: a 60-minute horizon (h=20 at 3-min cycles)
        on a long fleet trace."""
        from repro.core.labels import _horizon_labels_stacked

        rng = np.random.default_rng(1)
        arr = rng.integers(0, 2, size=(8, 960)).astype(np.int32)
        np.testing.assert_array_equal(
            horizon_labels(arr, 20), _horizon_labels_stacked(arr, 20)
        )


class TestDataset:
    def test_point_dataset_shapes(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=60, horizon_minutes=9)
        assert ds.x_train.ndim == 2 and ds.x_train.shape[1] == 3
        assert len(ds.x_train) + len(ds.x_test) > 0
        assert set(np.unique(ds.y_train)) <= {0, 1}
        # 75/25 split
        frac = len(ds.x_train) / (len(ds.x_train) + len(ds.x_test))
        assert 0.74 < frac < 0.76

    def test_sequence_dataset_shapes(self, small_campaign):
        ds = build_dataset(
            small_campaign, window_minutes=60, sequence_length=8
        )
        assert ds.x_train.ndim == 3 and ds.x_train.shape[1:] == (8, 3)

    def test_feature_subset(self, small_campaign):
        ds = build_dataset(small_campaign, feature_set=("SR",))
        assert ds.x_train.shape[1] == 1
        assert ds.feature_names == ("SR",)

    def test_pool_split_is_disjoint(self, small_campaign):
        ds = build_dataset(small_campaign, split="pool", seed=3)
        assert set(np.unique(ds.train_pools)).isdisjoint(np.unique(ds.test_pools))

    def test_standardization(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=60)
        assert abs(ds.x_train.mean()) < 0.2
        assert 0.5 < ds.x_train.std() < 2.0

    def test_sequence_alignment_last_step_equals_point_features(self, small_campaign):
        """The last step of each sequence must be that cycle's features."""
        ds_seq = build_dataset(
            small_campaign, window_minutes=60, sequence_length=4,
            split="pool", seed=7, standardize=False,
        )
        ds_pt = build_dataset(
            small_campaign, window_minutes=60,
            split="pool", seed=7, standardize=False,
        )
        # pool split with same seed -> same pools; sequence dataset drops
        # the first (L-1) cycles of each pool
        pools_seq = np.unique(ds_seq.test_pools)
        pools_pt = np.unique(ds_pt.test_pools)
        np.testing.assert_array_equal(pools_seq, pools_pt)


class TestHorizonLabelStream:
    @given(
        t=st.integers(2, 60),
        h=st.integers(0, 12),
        pools=st.integers(1, 4),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_streamed_equals_offline(self, t, h, pools, seed):
        """Pushing a trace column by column emits exactly the offline
        horizon_labels matrix, bit for bit."""
        if h >= t:
            return
        rng = np.random.default_rng(seed)
        avail = rng.integers(0, 2, size=(pools, t)).astype(np.int32)
        stream = HorizonLabelStream(h)
        cols = [y for c in range(t) if (y := stream.push(avail[:, c])) is not None]
        assert stream.pushed == t and stream.emitted == t - h == len(cols)
        np.testing.assert_array_equal(
            np.stack(cols, axis=1), horizon_labels(avail, h)
        )

    def test_warmup_emits_nothing(self):
        stream = HorizonLabelStream(3)
        assert [stream.push(np.ones(2, np.int32)) for _ in range(3)] == [None] * 3

    @pytest.mark.parametrize("h", [0, 2])
    def test_column_shape_change_rejected(self, h):
        stream = HorizonLabelStream(h)
        stream.push(np.ones(3, np.int32))
        with pytest.raises(ValueError):
            stream.push(np.ones(4, np.int32))

    def test_negative_horizon_rejected(self):
        with pytest.raises(ValueError):
            HorizonLabelStream(-1)


def _streamed(engine, seed, *, pools=7, hours=4.0, window_minutes=30.0,
              horizons=(0, 2, 10)):
    """Drive a pipeline stream and a DatasetStreamer side by side; return
    (CampaignResult, DatasetStreamer)."""
    provider = SimulatedProvider(default_fleet(pools, seed=seed), seed=seed + 1)
    stream = CampaignPipelineStream(
        provider,
        predict_fn=lambda x: x[:, 0],
        window_minutes=window_minutes,
        duration=hours * 3600.0,
        engine=engine,
    )
    streamer = DatasetStreamer(10, horizons)
    for view in stream:
        streamer.ingest(view)
    return stream.result(), streamer


class TestDatasetStreamer:
    """Streamed (X, y) ≡ offline build_dataset on the final S matrix —
    atol=0, across horizons, engines, splits, and sequence models."""

    #: window_minutes=30 → a 10-cycle ring over an 80-cycle campaign: the
    #: FleetWindowTable evicts 70 cycles while the streamer keeps them all
    WINDOW = 30.0

    @staticmethod
    def assert_dataset_identical(got, want):
        np.testing.assert_array_equal(got.x_train, want.x_train)
        np.testing.assert_array_equal(got.y_train, want.y_train)
        np.testing.assert_array_equal(got.x_test, want.x_test)
        np.testing.assert_array_equal(got.y_test, want.y_test)
        np.testing.assert_array_equal(got.train_pools, want.train_pools)
        np.testing.assert_array_equal(got.test_pools, want.test_pools)
        assert got.feature_names == want.feature_names
        assert got.horizon_cycles == want.horizon_cycles
        if want.standardizer is None:
            assert got.standardizer is None
        else:
            np.testing.assert_array_equal(
                got.standardizer.mean, want.standardizer.mean
            )
            np.testing.assert_array_equal(
                got.standardizer.std, want.standardizer.std
            )

    @pytest.mark.parametrize("engine", ["fleet", "sharded"])
    def test_bit_identical_to_build_dataset(self, engine):
        result, streamer = _streamed(engine, seed=31, window_minutes=self.WINDOW)
        dt = result.interval / 60.0
        assert result.s.shape[1] > 10  # the ring evicted most of the trace
        for h in (0, 2, 10):  # ≥ 2 horizons incl. the degenerate h=0
            got = streamer.dataset(h, seed=3)
            want = build_dataset(
                result, window_minutes=self.WINDOW, horizon_minutes=h * dt,
                seed=3,
            )
            self.assert_dataset_identical(got, want)

    def test_pool_split_and_feature_subset(self):
        result, streamer = _streamed("fleet", seed=37, window_minutes=self.WINDOW)
        dt = result.interval / 60.0
        got = streamer.dataset(
            2, split="pool", feature_set=("SR", "CUT"), seed=9,
            standardize=False,
        )
        want = build_dataset(
            result, window_minutes=self.WINDOW, horizon_minutes=2 * dt,
            split="pool", feature_set=("SR", "CUT"), seed=9,
            standardize=False,
        )
        self.assert_dataset_identical(got, want)

    def test_ragged_start_sequence_dataset(self):
        """sequence_length=L drops the ragged first L-1 cycles — streamed
        trailing windows must equal the offline ones exactly."""
        result, streamer = _streamed("fleet", seed=41, window_minutes=self.WINDOW)
        dt = result.interval / 60.0
        got = streamer.dataset(2, sequence_length=6, seed=5)
        want = build_dataset(
            result, window_minutes=self.WINDOW, horizon_minutes=2 * dt,
            sequence_length=6, seed=5,
        )
        assert got.x_train.ndim == 3 and got.x_train.shape[1:] == (6, 3)
        self.assert_dataset_identical(got, want)

    def test_matrices_alignment(self):
        result, streamer = _streamed("fleet", seed=43, horizons=(3,))
        x, y = streamer.matrices(3)
        t = result.s.shape[1]
        assert x.shape == (7, t - 3, 3) and y.shape == (7, t - 3)
        # features are the streamed (not re-derived) feature rows
        np.testing.assert_array_equal(x, streamer.features()[:, : t - 3])

    def test_out_of_order_and_unknown_horizon_rejected(self):
        streamer = DatasetStreamer(10, (1,))
        streamer.on_cycle(0, np.zeros((2, 3)), np.full(2, 10))
        with pytest.raises(ValueError):
            streamer.on_cycle(2, np.zeros((2, 3)), np.full(2, 10))
        with pytest.raises(ValueError):
            streamer.labels(4)
        with pytest.raises(ValueError):  # h=1 window hasn't closed yet
            streamer.labels(1)
        with pytest.raises(ValueError):
            DatasetStreamer(10, (1, 1))

    def test_streamed_features_survive_ring_eviction(self):
        """The streamer copies each ring-slot view at ingest time; rows the
        window table has long evicted must still be in the dataset."""
        result, streamer = _streamed("fleet", seed=47, window_minutes=self.WINDOW)
        from repro.core import compute_features

        want = compute_features(
            result.s, result.n, self.WINDOW, result.interval / 60.0
        )
        np.testing.assert_array_equal(streamer.features(), want)
