"""SnS availability features — paper §IV-B, Algorithm 1.

Three complementary features derived from the per-cycle SnS success count
``S_t`` (number of accepted probes out of ``N`` concurrent requests):

* ``SR(t)   = S_t / N``                       — instantaneous success rate.
* ``UR(t,w) = (P[t] - P[t-w]) / (w * N)``     — windowed unfulfilled ratio,
  where ``P`` is the running cumulative sum of unfulfilled counts
  ``P[t] = P[t-1] + (N - S_t)``, ``P[0] = 0``.  For ``t < w`` the paper
  uses the partial window ``(P[t] - P[0]) / (t * N)``.
* ``CUT(t)``                                  — contiguous unfulfilled time:
  resets to 0 whenever ``S_t == N`` (or at t==1), otherwise grows by the
  collection interval ``dt``.

Every update is O(1) (Algorithm 1).  Three implementations are provided:

* :class:`FeatureState` / :func:`update` — the incremental streaming form
  used by the online Data Pipeline (pure Python scalars, exact).
* :class:`FleetFeatureState` / :func:`update_batch` — the same O(1) cycle
  update vectorised over a whole fleet of pools: all per-pool state lives
  in stacked ``(pools,)`` / ``(pools, w + 1)`` arrays and one cycle's
  success-count *vector* is ingested with a handful of numpy ops,
  independent of fleet size in Python-interpreter work.  Outputs are
  bit-identical to running :func:`update` per pool.
* :func:`compute_features` — a vectorised batch "replay" over whole traces
  (numpy), used for dataset construction and as the oracle shape for the
  ``kernels/sns_features`` Pallas kernels (full-trace and chunked
  streaming variants).

Cycle indexing follows the paper: cycles are 1-based (``t = 1, 2, ...``)
and the window length in cycles is ``w = W / dt`` with ``W`` in the same
time unit as ``dt``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "FeatureState",
    "init_state",
    "update",
    "FleetFeatureState",
    "init_fleet_state",
    "update_batch",
    "compute_features",
    "FEATURE_NAMES",
]

FEATURE_NAMES = ("SR", "UR", "CUT")


@dataclasses.dataclass
class FeatureState:
    """O(1) streaming state for Algorithm 1.

    ``p_window`` is a ring buffer holding the last ``w + 1`` values of the
    cumulative array ``P`` so that ``P[t - w]`` is available without
    storing the full history (the paper stores the full array; the ring
    buffer is the constant-memory equivalent — identical outputs).
    """

    n: int                       # concurrent requests per measurement point
    w: int                       # window length in collection cycles
    dt: float                    # collection interval (minutes)
    t: int = 0                   # last completed cycle (0 = none yet)
    p_t: int = 0                 # P[t]
    cut: float = 0.0             # CUT_t
    p_window: np.ndarray = None  # ring buffer of P values, len w + 1
    head: int = 0                # ring index of P[t]

    def __post_init__(self):
        if self.p_window is None:
            self.p_window = np.zeros(self.w + 1, dtype=np.int64)


def init_state(n: int, window_minutes: float, dt_minutes: float) -> FeatureState:
    """Create streaming state for ``N`` requests and a ``W``-minute window."""
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if window_minutes <= 0 or dt_minutes <= 0:
        raise ValueError("window and dt must be positive")
    w = int(round(window_minutes / dt_minutes))
    if w < 1:
        raise ValueError(
            f"window {window_minutes} shorter than collection interval {dt_minutes}"
        )
    return FeatureState(n=n, w=w, dt=dt_minutes)


def update(state: FeatureState, s_t: int) -> Tuple[FeatureState, Tuple[float, float, float]]:
    """Algorithm 1: one O(1) incremental update.

    Mutates and returns ``state`` along with ``(SR_t, UR_t, CUT_t)``.
    """
    n, w, dt = state.n, state.w, state.dt
    if not 0 <= s_t <= n:
        raise ValueError(f"S_t={s_t} out of range [0, {n}]")

    state.t += 1
    t = state.t

    # line 3: SR_t <- S_t / N
    sr = s_t / n

    # line 4: P[t] <- P[t-1] + (N - S_t)
    state.p_t += n - s_t
    state.head = (state.head + 1) % (w + 1)
    state.p_window[state.head] = state.p_t

    # lines 5-9: windowed / partial-window UR
    if t >= w:
        # P[t - w] sits w slots behind the head in the ring buffer.
        p_t_minus_w = int(state.p_window[(state.head - w) % (w + 1)])
        ur = (state.p_t - p_t_minus_w) / (w * n)
    else:
        ur = state.p_t / (t * n)  # P[0] == 0

    # lines 10-14: CUT reset / accumulate
    if t == 1 or s_t == n:
        state.cut = 0.0
    else:
        state.cut += dt

    return state, (sr, ur, float(state.cut))


@dataclasses.dataclass
class FleetFeatureState:
    """Stacked Algorithm 1 state for a whole fleet of pools.

    Structure mirrors :class:`FeatureState` with every per-pool scalar
    promoted to a ``(pools,)`` array and the ring buffer to
    ``(pools, w + 1)``.  The cycle counter and ring head stay scalar —
    all pools advance in lock-step, one collection cycle at a time.
    """

    n: int                        # concurrent requests per measurement point
    w: int                        # window length in collection cycles
    dt: float                     # collection interval (minutes)
    pools: int                    # fleet size
    t: int = 0                    # last completed cycle (shared by all pools)
    p_t: np.ndarray = None        # (pools,) int64 — P[t] per pool
    cut: np.ndarray = None        # (pools,) float64 — CUT_t per pool
    p_window: np.ndarray = None   # (pools, w + 1) int64 ring buffer of P
    head: int = 0                 # ring index of P[t]
    staleness: np.ndarray = None  # (pools,) int64 — cycles since valid data
    last_feats: np.ndarray = None  # (pools, 3) — carried-forward features

    def __post_init__(self):
        if self.p_t is None:
            self.p_t = np.zeros(self.pools, dtype=np.int64)
        if self.cut is None:
            self.cut = np.zeros(self.pools, dtype=np.float64)
        if self.p_window is None:
            self.p_window = np.zeros((self.pools, self.w + 1), dtype=np.int64)
        if self.staleness is None:
            self.staleness = np.zeros(self.pools, dtype=np.int64)
        if self.last_feats is None:
            self.last_feats = np.zeros((self.pools, 3), dtype=np.float64)


def init_fleet_state(
    pools: int, n: int, window_minutes: float, dt_minutes: float
) -> FleetFeatureState:
    """Create stacked streaming state for ``pools`` pools (see
    :func:`init_state` for the per-pool parameters)."""
    if pools <= 0:
        raise ValueError(f"pools must be positive, got {pools}")
    proto = init_state(n, window_minutes, dt_minutes)  # validates n/w/dt
    return FleetFeatureState(n=proto.n, w=proto.w, dt=proto.dt, pools=pools)


def update_batch(
    state: FleetFeatureState, s_t: np.ndarray, valid: np.ndarray = None
) -> Tuple[FleetFeatureState, np.ndarray]:
    """Algorithm 1 for one cycle across the whole fleet at once.

    ``s_t`` is the cycle's success-count vector, shape ``(pools,)``.
    Mutates and returns ``state`` along with a ``(pools, 3)`` float64
    feature matrix ordered ``(SR, UR, CUT)`` — bit-identical to applying
    the scalar :func:`update` to each pool independently.  Interpreter
    work per cycle is a constant number of vector ops (no per-pool loop).

    ``valid`` (optional ``(pools,)`` bool) marks which entries of ``s_t``
    are live measurements — the graceful-degradation hook for faulted /
    throttled / retry-deferred collection cycles.  Invalid pools ingest
    nothing: their ``P`` and ``CUT`` state is untouched, their feature
    row is the last valid one carried forward, and ``state.staleness``
    counts the consecutive invalid cycles (0 where valid) so consumers
    (e.g. the serve admission controller) can treat stale pools
    conservatively.  ``valid=None`` is exactly the historical all-valid
    behaviour.
    """
    n, w, dt = state.n, state.w, state.dt
    s_t = np.asarray(s_t)
    if s_t.shape != (state.pools,):
        raise ValueError(f"s_t shape {s_t.shape} != (pools,) = ({state.pools},)")
    if valid is not None:
        valid = np.asarray(valid, dtype=bool)
        if valid.shape != (state.pools,):
            raise ValueError(
                f"valid shape {valid.shape} != (pools,) = ({state.pools},)"
            )
        # masked entries may carry fault sentinels — validate live ones only
        s_t = np.where(valid, s_t, 0)
    ok = (s_t >= 0) & (s_t <= n)  # NaN fails both comparisons
    if not ok.all():
        raise ValueError(f"S_t={s_t[~ok][0]} out of range [0, {n}]")
    s_int = s_t.astype(np.int64)
    if np.any(s_int != s_t):  # fractional counts would silently truncate
        raise ValueError(f"S_t must be integral, got {s_t[s_int != s_t][0]}")
    s_t = s_int

    state.t += 1
    t = state.t

    sr = s_t / n

    if valid is None:
        state.p_t += n - s_t
    else:
        state.p_t += np.where(valid, n - s_t, 0)
    state.head = (state.head + 1) % (w + 1)
    state.p_window[:, state.head] = state.p_t

    if t >= w:
        p_t_minus_w = state.p_window[:, (state.head - w) % (w + 1)]
        ur = (state.p_t - p_t_minus_w) / (w * n)
    else:
        ur = state.p_t / (t * n)  # P[0] == 0

    if t == 1:
        state.cut[:] = 0.0
    elif valid is None:
        state.cut = np.where(s_t == n, 0.0, state.cut + dt)
    else:
        state.cut = np.where(
            valid, np.where(s_t == n, 0.0, state.cut + dt), state.cut
        )

    feats = np.stack([sr, ur, state.cut], axis=-1)
    if valid is None:
        state.staleness[:] = 0
    else:
        feats = np.where(valid[:, None], feats, state.last_feats)
        state.staleness = np.where(valid, 0, state.staleness + 1)
    state.last_feats = feats
    return state, feats


def compute_features(
    s: np.ndarray,
    n: int,
    window_minutes: float,
    dt_minutes: float,
) -> np.ndarray:
    """Vectorised replay of Algorithm 1 over whole traces.

    Args:
      s: success counts, shape ``(T,)`` or ``(pools, T)``, integer in [0, N].
      n: concurrent requests per measurement point.
      window_minutes / dt_minutes: as in :func:`init_state`.

    Returns:
      features with shape ``s.shape + (3,)`` ordered ``(SR, UR, CUT)``,
      bit-identical to streaming :func:`update` applied cycle by cycle.
    """
    s = np.asarray(s)
    squeeze = s.ndim == 1
    if squeeze:
        s = s[None, :]
    if s.ndim != 2:
        raise ValueError(f"s must be 1- or 2-D, got shape {s.shape}")
    pools, t_max = s.shape
    w = int(round(window_minutes / dt_minutes))

    sr = s / n

    # Cumulative unfulfilled counts, P[0] = 0 prepended.
    unfulfilled = n - s
    p = np.concatenate(
        [np.zeros((pools, 1), dtype=np.int64), np.cumsum(unfulfilled, axis=1)], axis=1
    )  # p[:, t] == P[t] for t in [0, T]

    t_idx = np.arange(1, t_max + 1)
    lag = np.maximum(t_idx - w, 0)
    window_len = np.where(t_idx >= w, w, t_idx)
    ur = (p[:, t_idx] - p[:, lag]) / (window_len * n)

    # CUT: distance (in cycles) since the last fully-fulfilled cycle, scaled
    # by dt.  Cycle 1 is forced to 0 per Algorithm 1 line 10.
    full = s == n
    cut = np.empty_like(sr)
    run = np.zeros(pools, dtype=np.int64)
    for t in range(t_max):
        run = np.where(full[:, t] | (t == 0), 0, run + 1)
        cut[:, t] = run * dt_minutes
    # Note: the t==0 forcing matches the streaming code (CUT_1 = 0 always).

    out = np.stack([sr, ur, cut], axis=-1)
    return out[0] if squeeze else out
