"""Docs CI: examples compile, README snippets import, markdown links
resolve.  Keeps the documented entry points from silently rotting."""

import ast
import glob
import os
import py_compile
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXAMPLES = sorted(glob.glob(os.path.join(REPO, "examples", "*.py")))
MARKDOWN = sorted(
    glob.glob(os.path.join(REPO, "*.md"))
    + glob.glob(os.path.join(REPO, "docs", "*.md"))
    + glob.glob(os.path.join(REPO, "benchmarks", "*.md"))
)

# [text](target) markdown links, excluding images; fenced code is stripped
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_PY_BLOCK = re.compile(r"```python\n(.*?)```", re.DOTALL)


@pytest.mark.parametrize(
    "path", EXAMPLES, ids=[os.path.basename(p) for p in EXAMPLES]
)
def test_example_compiles(path):
    py_compile.compile(path, doraise=True)


def _readme_blocks():
    readme = os.path.join(REPO, "README.md")
    with open(readme) as f:
        return _PY_BLOCK.findall(f.read())


def test_readme_has_python_snippets():
    assert _readme_blocks(), "README.md lost its python quickstart snippets"


@pytest.mark.parametrize("i, block", list(enumerate(_readme_blocks())))
def test_readme_snippet_compiles_and_imports(i, block):
    compile(block, f"README.md[snippet {i}]", "exec")
    # execute only the snippet's import statements (AST, so multi-line
    # parenthesized imports count too): renamed/removed symbols must fail
    tree = ast.parse(block)
    imports = ast.Module(
        body=[
            node for node in tree.body
            if isinstance(node, (ast.Import, ast.ImportFrom))
        ],
        type_ignores=[],
    )
    exec(compile(imports, f"README.md[snippet {i} imports]", "exec"), {})


@pytest.mark.parametrize(
    "path", MARKDOWN, ids=[os.path.relpath(p, REPO) for p in MARKDOWN]
)
def test_markdown_relative_links_resolve(path):
    with open(path) as f:
        text = _FENCE.sub("", f.read())
    broken = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(os.path.dirname(path), rel)):
            broken.append(target)
    assert not broken, f"broken relative links in {path}: {broken}"
