"""Dense feed-forward blocks: gated (SwiGLU) and plain GELU MLPs."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense

__all__ = ["init_mlp", "mlp"]


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    dff = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    p = {
        "w_up": init_dense(keys[0], (d, dff), cfg.pdtype, fan_in=d),
        "w_down": init_dense(keys[1], (dff, d), cfg.pdtype, fan_in=dff),
    }
    if cfg.gated_mlp:
        p["w_gate"] = init_dense(keys[2], (d, dff), cfg.pdtype, fan_in=d)
    else:
        p["b_up"] = jnp.zeros((dff,), cfg.pdtype)
        p["b_down"] = jnp.zeros((d,), cfg.pdtype)
    return p


def mlp(cfg: ModelConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.gated_mlp:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]
