"""AdamW with f32 master state over bf16 parameters.

Production mixed-precision scheme: parameters/activations live in bf16,
optimizer moments and the update math in f32.  Global-norm gradient
clipping and a linear-warmup + cosine-decay schedule.  The optimizer
state is a plain pytree, so it checkpoints/reshards exactly like params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "schedule"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # Low-precision moments (distributed-memory trick): bf16 m/v halves the
    # optimizer footprint — what lets arctic-480b + Adam fit v5e-256.
    moments_dtype: str = "float32"
    # Update arithmetic dtype.  f32 is standard; bf16 is the memory-
    # constrained mode for the 480B-class cells: it eliminates the hoisted
    # whole-stack f32 convert buffers XLA:CPU materialises around the
    # update (≈2.3 GiB per expert-stack leaf).  Precision cost documented
    # in EXPERIMENTS.md.
    update_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init_opt_state(params, *, moments_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(moments_dtype)
    zeros = lambda p: jnp.zeros(jnp.shape(p), dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    # accumulate in f32 WITHOUT materialising f32 copies of bf16 leaves
    # (an .astype here costs a full-leaf HBM temp per parameter tensor)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l), dtype=jnp.float32) for l in leaves))


def apply_updates(
    params, grads, state: Dict[str, Any], cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step; returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"]
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1**t
    c2 = 1.0 - cfg.b2**t

    mdt = jnp.dtype(cfg.moments_dtype)
    udt = jnp.dtype(cfg.update_dtype)

    def upd_math(p, g, m, v):
        g = g.astype(udt) * scale.astype(udt)
        m = (cfg.b1 * m.astype(udt) + (1 - cfg.b1) * g)
        v = (cfg.b2 * v.astype(udt) + (1 - cfg.b2) * g * g)
        update = (m / c1) / (jnp.sqrt(v / c2) + jnp.asarray(cfg.eps, udt))
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(udt)
        new_p = (p.astype(udt) - lr.astype(udt) * update).astype(p.dtype)
        return new_p, m.astype(mdt), v.astype(mdt)

    def upd(p, g, m, v):
        # Layer-stacked leaves (leading scan axis) update via lax.map so the
        # f32 temporaries are bounded by ONE layer's slice, not the whole
        # stack — at arctic scale this is ~10 GiB of transient HBM saved.
        if p.ndim >= 3 and 1 < p.shape[0] <= 512:
            return jax.lax.map(lambda a: upd_math(*a), (p, g, m, v))
        return upd_math(p, g, m, v)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        p2, m2, v2 = upd(p, g, m, v)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree.unflatten(tree, new_p),
        {
            "m": jax.tree.unflatten(tree, new_m),
            "v": jax.tree.unflatten(tree, new_v),
            "step": step + 1,
        },
        metrics,
    )
