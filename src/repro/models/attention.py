"""GQA attention: training/prefill path, cross-attention, and a
flash-decode path with sequence-sharded KV caches.

Three execution paths, one parameter layout:

* :func:`attention` — full-sequence attention for train/prefill.  Memory-
  efficient: queries are processed in chunks (Rabe–Staats style) so the
  (S × S) score matrix never materialises — required for the 32k-prefill
  shapes, where full scores would be ~25 GB/device.  Supports causal and
  bidirectional masks, per-layer sliding windows (gemma's 5:1 pattern is a
  per-layer window *scalar*, keeping the layer scan homogeneous), GQA
  (kv-head repetition), QKV bias (qwen1.5), and qk-norm (qwen3/chameleon).
* :func:`cross_attention` — whisper decoder attending to encoder states.
* :func:`decode_attention` — one-token decode against a KV cache whose
  *sequence axis is sharded over the `model` mesh axis* (flash-decoding):
  each shard computes partial (max, sumexp, weighted-V) statistics over its
  cache slice and the results combine with three `psum`s.  This is what
  makes 32k/500k decode fit: an unsharded 32k cache would need 34–51
  GB/device on the MoE/VLM archs.

The chunked inner computation is the natural target for a Pallas flash
kernel on real TPUs; this repo keeps the XLA path only (used for CPU
tests and the dry-run), since the model zoo is a workload generator here,
not a compute hot-spot of the paper.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import GLOBAL_WINDOW, ModelConfig, apply_rope, init_dense, rms_norm, rope_angles

__all__ = [
    "init_attention",
    "attention",
    "cross_attention",
    "flash_decode",
    "decode_project_q",
    "decode_project_kv",
    "update_kv_cache",
]

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> Dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    keys = jax.random.split(key, 4)
    p = {
        "wq": init_dense(keys[0], (d, h, hd), cfg.pdtype, fan_in=d),
        "wk": init_dense(keys[1], (d, k, hd), cfg.pdtype, fan_in=d),
        "wv": init_dense(keys[2], (d, k, hd), cfg.pdtype, fan_in=d),
        "wo": init_dense(keys[3], (h, hd, d), cfg.pdtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((k, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((k, hd), cfg.pdtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), cfg.pdtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.pdtype)
    return p


def _project_qkv(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,
    kv_source: jnp.ndarray,
    positions: Optional[jnp.ndarray],
    kv_positions: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_source, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_source, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        cos_q, sin_q = rope_angles(positions, cfg.hd, cfg.rope_theta)
        cos_k, sin_k = rope_angles(
            positions if kv_positions is None else kv_positions, cfg.hd, cfg.rope_theta
        )
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_k, sin_k)
    return q, k, v


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return x
    b, s, k, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, k, n_rep, hd)).reshape(
        b, s, k * n_rep, hd
    )


def _chunked_scores_softmax(
    q: jnp.ndarray,           # (B, S_q, H, hd)
    k: jnp.ndarray,           # (B, S_k, H, hd)
    v: jnp.ndarray,           # (B, S_k, H, hd)
    *,
    causal: bool,
    window: jnp.ndarray,      # scalar int32 (GLOBAL_WINDOW = unbounded)
    q_offset: int,
    chunk: int,
) -> jnp.ndarray:
    """Memory-efficient attention: scan over query chunks, f32 softmax."""
    b, s_q, h, hd = q.shape
    s_k = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    n_chunks = max(1, s_q // chunk)
    assert s_q % n_chunks == 0, f"S_q={s_q} not divisible into chunks of {chunk}"
    csz = s_q // n_chunks

    kt = k.astype(jnp.bfloat16) if k.dtype == jnp.bfloat16 else k
    k_pos = jnp.arange(s_k)

    def one_chunk(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * csz, csz, axis=1)
        scores = jnp.einsum("bqhk,bshk->bhqs", qc, kt).astype(jnp.float32) * scale
        q_pos = q_offset + i * csz + jnp.arange(csz)
        mask = jnp.ones((csz, s_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        mask &= k_pos[None, :] > q_pos[:, None] - window  # sliding window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqs,bshk->bqhk", probs.astype(v.dtype), v)

    if n_chunks == 1:
        return one_chunk(0)
    # remat the chunk body: without this, backward-through-map saves every
    # chunk's (csz, S_k) probs — i.e. the full S×S matrix in f32 — which is
    # exactly the materialisation chunking exists to avoid
    out = jax.lax.map(
        jax.checkpoint(one_chunk, prevent_cse=False), jnp.arange(n_chunks)
    )   # (C, B, csz, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(b, s_q, h, hd)


def _banded_scores_softmax(
    q: jnp.ndarray,           # (B, S, H, hd)
    k: jnp.ndarray,           # (B, S, H, hd)
    v: jnp.ndarray,
    *,
    window: int,
) -> jnp.ndarray:
    """Sliding-window attention computing only the S×(2W) band.

    For local layers (gemma's 22/26) the full-S path wastes S/W× compute
    and score traffic; here each W-sized q chunk attends to its own chunk
    plus the previous one (causal window ≤ W)."""
    b, s, h, hd = q.shape
    w = int(window)
    scale = 1.0 / math.sqrt(hd)
    n_chunks = s // w
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))

    def one_chunk(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * w, w, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(kp, i * w, 2 * w, axis=1)  # [i*w-w, i*w+w)
        vc = jax.lax.dynamic_slice_in_dim(vp, i * w, 2 * w, axis=1)
        scores = jnp.einsum("bqhk,bshk->bhqs", qc, kc).astype(jnp.float32) * scale
        q_pos = i * w + jnp.arange(w)[:, None]                  # global q rows
        k_pos = (i - 1) * w + jnp.arange(2 * w)[None, :]        # global k cols
        mask = (k_pos >= 0) & (k_pos <= q_pos) & (k_pos > q_pos - w)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqs,bshk->bqhk", probs.astype(vc.dtype), vc)

    out = jax.lax.map(
        jax.checkpoint(one_chunk, prevent_cse=False), jnp.arange(n_chunks)
    )
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def attention(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,
    *,
    window: int = int(GLOBAL_WINDOW),
    positions: Optional[jnp.ndarray] = None,
    causal: Optional[bool] = None,
    q_chunk: int = 1024,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Self-attention for train/prefill.

    Returns (output, (k, v)) — the kv tensors feed cache initialisation in
    the prefill path.  ``window`` is static: local layers (< S) take the
    banded path.  With ``cfg.seq_parallel_attn`` and a mesh whose model
    axis doesn't divide the head count, activations are re-sharded onto
    the sequence axis for the attention block (sequence parallelism)
    instead of replicating the whole attention computation per model rank.
    """
    b, s, _ = x.shape
    window = int(window)
    causal = cfg.causal if causal is None else causal

    seq_par = False
    if mesh is not None and cfg.seq_parallel_attn:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        seq_par = cfg.n_heads % tp != 0 and s % tp == 0
    if seq_par:
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(data_axes) if data_axes else None
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(dp, "model", None))
        )

    if positions is None and cfg.use_rope:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, x, positions, None)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kf, vf = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)

    if causal and window < s and s % window == 0:
        out = _banded_scores_softmax(q, kf, vf, window=window)
    else:
        out = _chunked_scores_softmax(
            q, kf, vf, causal=causal, window=window,
            q_offset=0, chunk=min(q_chunk, s),
        )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if seq_par:
        y = jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P(dp, None, None))
        )
    return y, (k, v)


def cross_attention(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,
    enc: jnp.ndarray,
    *,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Decoder-to-encoder attention (whisper); no mask, no rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    out = _chunked_scores_softmax(
        q, k, v, causal=False, window=jnp.asarray(GLOBAL_WINDOW, jnp.int32),
        q_offset=0, chunk=min(q_chunk, x.shape[1]),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# --------------------------------------------------------------------------
# Decode path: sequence-sharded KV cache + flash-decoding combine
# --------------------------------------------------------------------------

def update_kv_cache(
    k_cache: jnp.ndarray,     # (B, S_max, K, hd)
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,       # (B, 1, K, hd)
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,   # scalar int32 — tokens already in the cache
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write one decode step's K/V at position `cache_len`."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1
    )
    return k_cache, v_cache


def flash_decode(
    q: jnp.ndarray,           # (B, H, hd) — current token's query, RoPE'd
    k_cache: jnp.ndarray,     # (B, S_shard, K, hd) — LOCAL cache shard
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,       # (B, 1, K, hd) — current token's K (RoPE'd)
    v_new: jnp.ndarray,
    cache_len: jnp.ndarray,   # scalar int32: tokens cached INCLUDING new one
    *,
    window: jnp.ndarray = GLOBAL_WINDOW,
    model_axis: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Flash-decoding over a sequence-sharded KV cache.

    Runs inside ``shard_map`` with the cache sharded along its sequence
    axis over ``model_axis`` (or unsharded when ``model_axis=None``).  The
    shard owning position ``cache_len - 1`` writes the new K/V, every shard
    computes partial (max, sumexp, V-weighted) statistics over its slice,
    and the statistics combine with one ``pmax`` + two ``psum``s.

    Returns ``(attn_out (B, H, hd), k_cache, v_cache)``.
    """
    b, h, hd = q.shape
    pos = cache_len - 1  # global position of the token being decoded

    s_shard = k_cache.shape[1]
    shard_idx = jax.lax.axis_index(model_axis) if model_axis else 0
    local_pos = pos - shard_idx * s_shard
    owns = (local_pos >= 0) & (local_pos < s_shard)
    lp = jnp.clip(local_pos, 0, s_shard - 1)
    k_upd = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), lp, axis=1
    )
    v_upd = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), lp, axis=1
    )
    k_cache = jnp.where(owns, k_upd, k_cache)
    v_cache = jnp.where(owns, v_upd, v_cache)

    slot_pos = shard_idx * s_shard + jnp.arange(s_shard)     # global positions
    valid = (slot_pos < cache_len) & (slot_pos > pos - window)

    n_rep = h // k_cache.shape[2]
    kf = _repeat_kv(k_cache, n_rep)   # (B, S_shard, H, hd)
    vf = _repeat_kv(v_cache, n_rep)

    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhk,bshk->bhs", q, kf).astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)

    m_loc = scores.max(axis=-1)                              # (B, H)
    m_safe = jnp.maximum(m_loc, NEG_INF / 2)                 # fully-masked guard
    e = jnp.exp(scores - m_safe[..., None])
    e = jnp.where(valid[None, None, :], e, 0.0)
    l_loc = e.sum(axis=-1)                                   # (B, H)
    o_loc = jnp.einsum("bhs,bshk->bhk", e.astype(vf.dtype), vf).astype(jnp.float32)

    if model_axis is not None:
        m_glob = jax.lax.pmax(m_safe, model_axis)
        corr = jnp.exp(m_safe - m_glob)
        l = jax.lax.psum(l_loc * corr, model_axis)
        o = jax.lax.psum(o_loc * corr[..., None], model_axis)
    else:
        l, o = l_loc, o_loc
    out = o / jnp.maximum(l, 1e-30)[..., None]               # (B, H, hd) f32
    return out, k_cache, v_cache


def decode_project_q(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, cache_len: jnp.ndarray
) -> jnp.ndarray:
    """Project + RoPE the current token's query: (B, 1, d) -> (B, H, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if cfg.use_rope:
        pos = cache_len - 1
        cos, sin = rope_angles(pos[None, None], cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
    return q[:, 0]


def decode_project_kv(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, cache_len: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project the current token's K/V for cache insertion (B, 1, K, hd)."""
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        pos = cache_len - 1
        cos, sin = rope_angles(pos[None, None], cfg.hd, cfg.rope_theta)
        k = apply_rope(k, cos, sin)
    return k, v
