"""Checkpoint-interval policies for preemptible training.

The paper stops at "prediction enables proactive checkpoint triggering"
(§I); this module operationalises it for the training data plane:

* **FixedInterval** — checkpoint every ``interval`` seconds (baseline).
* **YoungDaly** — the classical optimum ``τ* = sqrt(2·δ·MTBF)`` for
  checkpoint cost δ and a *static* mean time between failures.
* **SnSHazard** — beyond-paper: Young–Daly with a *time-varying* MTBF
  estimated from the SnS interrupt predictor.  The predictor's probability
  that the pool does NOT survive the next horizon ``h`` converts to an
  instantaneous hazard ``λ = -ln(p_survive) / h`` and the interval adapts
  as ``τ(t) = sqrt(2·δ/λ)``, clamped to [δ, τ_max].  Additionally, a
  forecast above ``panic_threshold`` triggers an immediate checkpoint
  (the Predict-AR analogue for training) — but under *sustained* panic
  re-writes are floored at ``2δ`` so the checkpoint overhead itself
  cannot destroy goodput.

Every policy reduces to one per-cycle number: the interval ``τ`` that the
replay contract compares against ``now - t_last_ckpt`` (see
``repro.fleet.runner``).  The scalar ``should_checkpoint`` methods and the
stacked :class:`PolicyTable` rows both evaluate τ through the *same*
vectorised ufunc formulas (:func:`hazard_tau`), which is what lets the
fleet engines stay bit-identical (atol=0) to the per-pod scalar replay.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

__all__ = [
    "FixedInterval",
    "YoungDaly",
    "SnSHazard",
    "PolicyTable",
    "hazard_tau",
    "neg_log_survival",
]


def neg_log_survival(p):
    """``-ln(clip(p, 1e-6, 1-1e-9))`` — the transcendental half of the
    hazard formula, evaluated on the host.

    The fused kernel engine (``kernels.goodput_scan``) consumes this as
    input data and re-derives τ in-graph from traced parameters only —
    host log here, IEEE division/sqrt/clip there — which keeps its τ
    bit-identical to the :func:`hazard_tau` ufunc chain.
    """
    p_c = np.clip(np.asarray(p, dtype=np.float64), 1e-6, 1.0 - 1e-9)
    return -np.log(p_c)


def _base_tau(p, ckpt_cost, horizon, tau_max, floor_hazard):
    """The adaptive Young–Daly interval (no panic override), vectorised.

    ``τ(p) = sqrt(2δ / λ)`` with ``λ = max(-ln(clip(p)) / h, floor)``,
    clamped to ``[δ, τ_max]``.  Pure elementwise float64 ufuncs — the one
    formula shared by ``SnSHazard.interval`` and the stacked table rows.
    """
    lam = np.maximum(neg_log_survival(p) / horizon, floor_hazard)
    return np.clip(np.sqrt(2.0 * ckpt_cost / lam), ckpt_cost, tau_max)


def hazard_tau(p, *, ckpt_cost, horizon, tau_max, panic_threshold, floor_hazard):
    """Per-cycle SnSHazard interval including the panic override.

    A forecast ``1 - p >= panic_threshold`` collapses the interval to the
    ``2δ`` re-write floor ("checkpoint now, but never faster than 2δ");
    otherwise the adaptive Young–Daly interval applies.  All arguments
    broadcast elementwise, so the same call serves a scalar policy
    decision and a full ``(rows, cycles)`` table evaluation.
    """
    p = np.asarray(p, dtype=np.float64)
    tau = _base_tau(p, ckpt_cost, horizon, tau_max, floor_hazard)
    return np.where(1.0 - p >= panic_threshold, 2.0 * ckpt_cost, tau)


@dataclasses.dataclass
class FixedInterval:
    interval: float                 # seconds

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        return now - t_last_ckpt >= self.interval


@dataclasses.dataclass
class YoungDaly:
    ckpt_cost: float                # δ: seconds to write a checkpoint
    mtbf: float                     # static mean time between failures (s)

    @property
    def interval(self) -> float:
        return math.sqrt(2.0 * self.ckpt_cost * self.mtbf)

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        return now - t_last_ckpt >= self.interval


@dataclasses.dataclass
class SnSHazard:
    """Young–Daly with SnS-predicted time-varying hazard."""

    ckpt_cost: float                # δ (seconds)
    horizon: float                  # predictor horizon (seconds)
    tau_max: float = 3600.0         # interval ceiling when hazard ~ 0
    panic_threshold: float = 0.5    # P(interrupt within horizon) forcing ckpt
    floor_hazard: float = 1e-6

    def interval(self, p_survive: float) -> float:
        """The adaptive interval before the panic override."""
        return float(
            _base_tau(
                p_survive, self.ckpt_cost, self.horizon, self.tau_max,
                self.floor_hazard,
            )
        )

    def tau(self, p_survive) -> np.ndarray:
        """Per-cycle interval(s) including the panic 2δ floor (vectorised)."""
        return hazard_tau(
            p_survive,
            ckpt_cost=self.ckpt_cost,
            horizon=self.horizon,
            tau_max=self.tau_max,
            panic_threshold=self.panic_threshold,
            floor_hazard=self.floor_hazard,
        )

    def should_checkpoint(self, now, t_last_ckpt, p_survive=None) -> bool:
        p = 1.0 if p_survive is None else float(p_survive)
        return now - t_last_ckpt >= float(self.tau(p))


@dataclasses.dataclass
class PolicyTable:
    """Struct-of-arrays policy rows for the fleet replay engines.

    One row per replay trace; fixed-interval rows (FixedInterval /
    YoungDaly) carry a constant τ, hazard rows (SnSHazard) re-derive τ
    every cycle from the predictor's survival probability through
    :func:`hazard_tau` — ufunc-for-ufunc the same formula the scalar
    policy objects evaluate, so table-driven engines and per-pod scalar
    replays agree bit-identically.
    """

    is_hazard: np.ndarray        # (R,) bool
    interval: np.ndarray         # (R,) f64 — τ for fixed rows (unused on hazard)
    ckpt_cost: np.ndarray        # (R,) f64 — δ for hazard rows
    horizon: np.ndarray          # (R,) f64
    tau_max: np.ndarray          # (R,) f64
    panic_threshold: np.ndarray  # (R,) f64
    floor_hazard: np.ndarray     # (R,) f64
    names: List[str]

    def __len__(self) -> int:
        return self.is_hazard.shape[0]

    @classmethod
    def from_policies(
        cls,
        policies: Sequence,
        *,
        repeat: int = 1,
        names: Optional[Sequence[str]] = None,
    ) -> "PolicyTable":
        """Stack policy objects into rows; ``repeat`` replicates each
        policy over that many consecutive rows (the per-pod block of a
        pods × policies cross product)."""
        is_hz, interval, delta, horizon = [], [], [], []
        tau_max, panic, floor = [], [], []
        row_names = []
        for i, pol in enumerate(policies):
            name = names[i] if names is not None else type(pol).__name__
            if isinstance(pol, SnSHazard):
                is_hz.append(True)
                interval.append(0.0)
                delta.append(pol.ckpt_cost)
                horizon.append(pol.horizon)
                tau_max.append(pol.tau_max)
                panic.append(pol.panic_threshold)
                floor.append(pol.floor_hazard)
            elif isinstance(pol, (FixedInterval, YoungDaly)):
                is_hz.append(False)
                iv = pol.interval  # YoungDaly derives sqrt(2·δ·MTBF)
                interval.append(float(iv))
                delta.append(1.0)       # inert hazard params for fixed rows
                horizon.append(1.0)
                tau_max.append(1.0)
                panic.append(2.0)       # 1 - p can never reach 2
                floor.append(1.0)
            else:
                raise TypeError(f"unsupported policy type {type(pol).__name__}")
            row_names.append(name)
        rep = int(repeat)
        return cls(
            is_hazard=np.repeat(np.asarray(is_hz, dtype=bool), rep),
            interval=np.repeat(np.asarray(interval, dtype=np.float64), rep),
            ckpt_cost=np.repeat(np.asarray(delta, dtype=np.float64), rep),
            horizon=np.repeat(np.asarray(horizon, dtype=np.float64), rep),
            tau_max=np.repeat(np.asarray(tau_max, dtype=np.float64), rep),
            panic_threshold=np.repeat(np.asarray(panic, dtype=np.float64), rep),
            floor_hazard=np.repeat(np.asarray(floor, dtype=np.float64), rep),
            names=[n for n in row_names for _ in range(rep)],
        )

    def _cols(self, ndim: int):
        """Params reshaped to broadcast against a (R, ...) probability array."""
        shape = (-1,) + (1,) * (ndim - 1)
        return (
            self.is_hazard.reshape(shape),
            self.interval.reshape(shape),
            self.ckpt_cost.reshape(shape),
            self.horizon.reshape(shape),
            self.tau_max.reshape(shape),
            self.panic_threshold.reshape(shape),
            self.floor_hazard.reshape(shape),
        )

    def tau(
        self, p: Optional[np.ndarray] = None, cycles: Optional[int] = None
    ) -> np.ndarray:
        """Per-row, per-cycle checkpoint intervals.

        ``p`` is ``(R,)`` or ``(R, T)`` survival probabilities (``None``
        means no predictor — hazard rows fall back to ``p = 1``; pass
        ``cycles`` to shape the fallback ``(R, cycles)``).  Returns τ of
        the same shape, float64.
        """
        if p is None:
            shape = (len(self),) if cycles is None else (len(self), cycles)
            p = np.ones(shape)
        p = np.asarray(p, dtype=np.float64)
        is_hz, interval, delta, horizon, tau_max, panic, floor = self._cols(p.ndim)
        hz = hazard_tau(
            p, ckpt_cost=delta, horizon=horizon, tau_max=tau_max,
            panic_threshold=panic, floor_hazard=floor,
        )
        return np.where(is_hz, hz, interval * np.ones_like(p))

    def engine_planes(self) -> dict:
        """The per-row τ parameter columns the fused kernel engine
        consumes (``kernels.goodput_scan``); the panic threshold is not
        among them — panic is a host predicate packed into the flag bits
        (see :meth:`panic`)."""
        return {
            "is_hazard": self.is_hazard.copy(),
            "interval": self.interval.copy(),
            "ckpt_cost": self.ckpt_cost.copy(),
            "horizon": self.horizon.copy(),
            "tau_max": self.tau_max.copy(),
            "floor_hazard": self.floor_hazard.copy(),
        }

    def panic(self, p: Optional[np.ndarray] = None) -> np.ndarray:
        """Which rows are in the imminent-interrupt (panic) regime."""
        if p is None:
            return np.zeros(len(self), dtype=bool)
        p = np.asarray(p, dtype=np.float64)
        is_hz, _, _, _, _, panic, _ = self._cols(p.ndim)
        return is_hz & (1.0 - p >= panic)
