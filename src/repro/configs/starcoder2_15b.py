"""starcoder2-15b — dense GQA code model.

[arXiv:2402.19173; hf] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.  GQA, RoPE, LayerNorm + plain GELU MLP with biases.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    use_rope=True,
    rope_theta=1e5,
    norm="layernorm",
    gated_mlp=False,
    source="arXiv:2402.19173; hf",
)
