"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()``
must succeed on the production meshes; memory_analysis() proves the
per-device footprint fits, cost_analysis() + the HLO-text roofline feed
EXPERIMENTS.md.

Run ONE cell:      python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
Run the full grid: python -m repro.launch.dryrun --all  [--mesh both] [--out results/dryrun]
(--all spawns one subprocess per cell: isolates compiler memory and makes
the sweep resumable — finished cells are skipped via their JSON files.)
"""

# The placeholder-device flag MUST precede any other import (jax locks the
# device count on first init).  Deliberately NOT set in conftest/pyproject:
# only the dry-run sees 512 fake devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, get_config, shape_applicability
from repro.launch.mesh import (
    data_axes_of, make_production_mesh, mesh_axis_sizes, use_mesh,
)
from repro.launch.roofline import HW, analyze_hlo, roofline_report
from repro.models import api
from repro.models.common import ModelConfig
from repro.models.sharding import make_rules, param_specs
from repro.train import OptConfig, init_opt_state
from repro.train.trainer import make_train_step


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape, mesh):
    """Abstract inputs + their shardings for one cell."""
    dp = data_axes_of(mesh)
    dp_size = int(np.prod([mesh_axis_sizes(mesh)[a] for a in dp]))
    b, s = shape.global_batch, shape.seq_len
    batch_axes = dp if b % dp_size == 0 else None
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = {}
    shardings = {}
    if shape.kind == "train":
        specs = {"tokens": tok, "labels": tok}
        shardings = {k: NamedSharding(mesh, P(batch_axes, None)) for k in specs}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32
            )
            shardings["frames"] = NamedSharding(mesh, P(batch_axes, None, None))
    elif shape.kind == "prefill":
        specs = {"tokens": tok}
        shardings = {"tokens": NamedSharding(mesh, P(batch_axes, None))}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32
            )
            shardings["frames"] = NamedSharding(mesh, P(batch_axes, None, None))
    else:  # decode
        specs = {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}
        shardings = {"token": NamedSharding(mesh, P(batch_axes))}
    return specs, shardings, batch_axes


def _spec_tree_to_shardings(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _cache_spec_tree(cfg, batch_axes):
    """PartitionSpec tree matching api.init_cache's structure."""
    if cfg.family == "encdec":
        return {
            "k": P(None, batch_axes, "model", None, None),
            "v": P(None, batch_axes, "model", None, None),
            "xk": P(None, batch_axes, None, None, None),
            "xv": P(None, batch_axes, None, None, None),
            "len": P(),
        }
    from repro.models.lm import block_pattern

    pattern, _ = block_pattern(cfg)
    entries = []
    for mixer, _moe, _w in pattern:
        if mixer == "attn":
            entries.append({
                "k": P(None, batch_axes, "model", None, None),
                "v": P(None, batch_axes, "model", None, None),
            })
        else:
            entries.append({
                "ssm": P(None, batch_axes, "model", None),
                "conv": P(None, batch_axes, None, "model"),
            })
    return {"layers": entries, "len": P()}


# --------------------------------------------------------------------------
# Cell runner
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, *, hlo_dir=None,
             variant: str = "baseline"):
    import dataclasses as _dc

    cfg = get_config(arch)
    if variant == "optimized":
        # §Perf hillclimb variant: sequence-parallel attention for archs
        # whose head count doesn't divide the model axis
        cfg = _dc.replace(cfg, seq_parallel_attn=True)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicability(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    dp = data_axes_of(mesh)
    rules = make_rules(mesh)

    t0 = time.time()
    params_struct = jax.eval_shape(lambda: api.init_params(cfg, 0))
    pspecs = param_specs(cfg, params_struct, rules)
    pshard = _spec_tree_to_shardings(pspecs, mesh)
    specs, in_shardings, batch_axes = input_specs(cfg, shape, mesh)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": int(n_dev), "kind": shape.kind, "variant": variant,
    }

    # -- per-cell tuning heuristics (recorded in the result) ----------------
    n_params = cfg.param_count()
    # bf16 Adam moments when f32 state would blow the 16 GiB HBM budget
    moments = "bfloat16" if n_params * 10.0 / n_dev > 14 * 2**30 else "float32"
    # q-chunk sized so per-chunk f32 scores stay ~<= 0.5 GiB/device
    dp_size = int(np.prod([mesh_axis_sizes(mesh)[a] for a in dp]))
    tp = mesh_axis_sizes(mesh)["model"]
    heads_sharded = cfg.n_heads % tp == 0
    h_loc = cfg.n_heads // tp if heads_sharded else cfg.n_heads
    result["tuning"] = {"moments_dtype": moments, "heads_sharded": heads_sharded}

    with use_mesh(mesh):
        if shape.kind == "train":
            # memory-aware accumulation: grow grad_accum only until one
            # microbatch's per-device activations fit ~1 GiB (per-micro
            # overheads — FSDP weight gathers, gradient all-reduces —
            # scale LINEARLY with grad_accum, so smaller is faster)
            max_accum = max(1, shape.global_batch // dp_size)
            grad_accum = int(os.environ.get("REPRO_GRAD_ACCUM", "0")) or 1
            while grad_accum == 1 and grad_accum < min(16, max_accum):
                b_loc_t = max(1, shape.global_batch // grad_accum // dp_size)
                act_bytes = b_loc_t * shape.seq_len * cfg.d_model * 2
                if act_bytes <= 1 * 2**30:
                    break
                grad_accum *= 2
            while shape.global_batch % (grad_accum * dp_size):
                grad_accum -= 1
            result["grad_accum"] = grad_accum
            b_loc = max(1, shape.global_batch // grad_accum // dp_size)
            q_chunk = 1024
            while (b_loc * h_loc * q_chunk * shape.seq_len * 4 > 0.5 * 2**30
                   and q_chunk > 128):
                q_chunk //= 2
            result["tuning"]["q_chunk"] = q_chunk
            opt_struct = jax.eval_shape(
                lambda p: init_opt_state(p, moments_dtype=moments), params_struct
            )
            ospecs = {
                "m": pspecs, "v": pspecs, "step": P(),  # moments shard like params
            }
            oshard = _spec_tree_to_shardings(ospecs, mesh)
            accum_dtype = "bfloat16" if moments == "bfloat16" else "float32"
            result["tuning"]["accum_dtype"] = accum_dtype
            opt_cfg = OptConfig(moments_dtype=moments, update_dtype=accum_dtype)
            step_fn = make_train_step(
                cfg, opt_cfg, mesh=mesh,
                data_axes=batch_axes or (), grad_accum=grad_accum,
                remat="full", q_chunk=q_chunk, accum_dtype=accum_dtype,
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, in_shardings),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_struct, opt_struct, specs)
        elif shape.kind == "prefill":
            cache_specs = _cache_spec_tree(cfg, batch_axes)
            cshard = _spec_tree_to_shardings(cache_specs, mesh)

            def prefill_fn(params, batch):
                return api.prefill(
                    cfg, params, batch, mesh=mesh,
                    data_axes=batch_axes or (), max_seq=shape.seq_len,
                )

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(pshard, in_shardings),
                out_shardings=(None, cshard),
            )
            lowered = jitted.lower(params_struct, specs)
        else:  # decode / serve_step
            cache_struct = jax.eval_shape(
                lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            cache_specs = _cache_spec_tree(cfg, batch_axes)
            cshard = _spec_tree_to_shardings(cache_specs, mesh)

            def serve_step(params, cache, token):
                return api.decode_step(
                    cfg, params, cache, token, mesh=mesh,
                    data_axes=batch_axes or (),
                )

            jitted = jax.jit(
                serve_step,
                in_shardings=(pshard, cshard, in_shardings["token"]),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_struct, cache_struct, specs["token"])

        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

    # ---- memory & cost --------------------------------------------------
    ma = compiled.memory_analysis()
    if ma is not None:
        result["memory"] = {
            "argument_gib": round(ma.argument_size_in_bytes / 2**30, 3),
            "output_gib": round(ma.output_size_in_bytes / 2**30, 3),
            "temp_gib": round(ma.temp_size_in_bytes / 2**30, 3),
            "alias_gib": round(ma.alias_size_in_bytes / 2**30, 3),
            "peak_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3,
            ),
        }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict], one per device set
        ca = ca[0] if ca else {}
    result["xla_cost"] = {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "note": "XLA counts while bodies once; see loop-adjusted analysis",
    }

    # ---- loop-adjusted roofline -----------------------------------------
    text = compiled.as_text()
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(
            hlo_dir, f"{arch}__{shape_name}__{mesh_kind}.hlo.txt"
        ), "w") as f:
            f.write(text)
    analysis = analyze_hlo(text, total_devices=n_dev)
    result["analysis"] = {k: float(v) for k, v in analysis.items()}

    model_flops = _model_flops(cfg, shape, n_dev)
    result["model_flops_per_device"] = model_flops
    result["roofline"] = roofline_report(
        analysis, model_flops_per_device=model_flops
    )
    result["status"] = "ok"
    return result


def _model_flops(cfg: ModelConfig, shape, n_dev: int) -> float:
    """Analytic MODEL_FLOPS per device: 6·N·D (dense) / 6·N_active·D (MoE),
    ×1.5 extra backward factor folded into the 6 for training; decode uses
    D = global_batch tokens per step; prefill D = B·S forward-only (2·N·D)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:
        total = 2.0 * n_active * shape.global_batch
    return total / n_dev


# --------------------------------------------------------------------------
# Grid orchestration
# --------------------------------------------------------------------------

def _cell_path(out_dir, arch, shape, mesh_kind):
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default=None, help="dump compiled HLO text")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = [
            (a, s, m)
            for a in sorted(REGISTRY)
            for s in SHAPES
            for m in meshes
        ]
        failed = []
        for arch, shape, mesh_kind in cells:
            path = _cell_path(args.out, arch, shape, mesh_kind)
            if os.path.exists(path) and not args.force:
                print(f"[skip] {arch} {shape} {mesh_kind} (done)")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                "--out", args.out, "--variant", args.variant,
            ]
            if args.hlo_dir:
                cmd += ["--hlo-dir", args.hlo_dir]
            print(f"[run ] {arch} {shape} {mesh_kind}", flush=True)
            rc = subprocess.run(cmd).returncode
            if rc != 0:
                failed.append((arch, shape, mesh_kind))
        print(f"grid done; {len(failed)} failures: {failed}")
        sys.exit(1 if failed else 0)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    try:
        result = run_cell(args.arch, args.shape, args.mesh,
                          hlo_dir=args.hlo_dir, variant=args.variant)
    except Exception as e:
        result = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(_cell_path(args.out, args.arch, args.shape, args.mesh), "w") as f:
        json.dump(result, f, indent=1)
    status = result["status"]
    print(f"[{status}] {args.arch} {args.shape} {args.mesh} "
          + (result.get("reason") or result.get("error") or ""))
    if status == "ok":
        r = result["roofline"]
        print(f"  compute {r['t_compute_s']:.4f}s  memory {r['t_memory_s']:.4f}s  "
              f"collective {r['t_collective_s']:.4f}s  -> {r['bottleneck']}  "
              f"(roofline_frac {r['roofline_fraction']:.3f})")
        if "memory" in result:
            print(f"  peak/device: {result['memory']['peak_gib']} GiB; "
                  f"compile {result['compile_s']}s")
    sys.exit(0 if status in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
