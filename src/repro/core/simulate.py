"""Trace-driven workload simulation — paper §VI-E, Fig. 9.

Replays a 24-hour availability trace (3-minute cycles) against a batch
query workload and compares scheduling strategies:

* **Always Run** — launch the next queued query immediately whenever the
  pool is available and idle (unguided baseline).
* **Shortest Job First** — same, with the queue sorted by ascending
  duration (reduces expected loss per interruption without prediction).
* **Predict-AR** — consults the SnS-trained predictor every collection
  cycle; when it forecasts upcoming unavailability, *defers launching new
  queries* for the prediction-horizon duration while leaving any running
  query undisturbed (the paper's strategy).

Semantics follow the paper: queries proceed only while the pool is fully
available; the running query's progress is lost the moment the pool
becomes unavailable (binary formulation — §IV-A), and the query is retried
later.  Metrics: total lost computation, idle-while-available time, and
makespan.  The experiment repeats each run over random permutations of the
query queue and averages (§VI-E).

The replay contract (scan form)
-------------------------------

Every implementation advances one *closed-form state transition per
cycle* — there is no data-dependent inner drain loop.  Per trace row the
carried state is ``(head, front, running, remaining, progress,
defer_until, lost, idle, completed, makespan)`` and queue consumption is
resolved against the row's *prefix-sum of query durations* ``cum``
(``cum[j] = durations[:j].sum()``, a strict left-to-right ``np.cumsum``
fold shared verbatim by every backend):

* **down cycle** — a running query loses its progress and is re-queued at
  the front with value ``progress + remaining`` (the ``front`` register;
  the duration array itself is never mutated).
* **up cycle** — after the Predict-AR deferral update, budget ``b = dt``:

  - *phase A*: the in-hand item (the running query, or the re-queued
    front when launching is not deferred) advances by ``min(b, x)``;
  - *phase B*: with leftover budget and an undeferred queue, the number
    of whole queries that finish this cycle is the prefix count
    ``k = #{j >= 1 : cum[head+j] <= cum[head] + (b + 1e-9)}`` (a
    searchsorted / windowed count — never an unrolled walk), the budget
    afterwards is ``max(b - (cum[head+k] - cum[head]), 0)``, and at most
    one partial launch carries ``(cum[head+k+1] - cum[head+k]) - b`` of
    remaining work into the next cycle;
  - *phase C*: leftover budget with nothing runnable is idle time, and
    the completion that empties the queue sets ``makespan =
    (c + 1) * dt - b_left``.

All float arithmetic is pinned by this contract (every backend executes
the same IEEE-754 double ops in the same order), which is what makes the
four implementations below **bit-identical row by row**:

* :func:`replay` — the scalar reference: one trace, one strategy, a plain
  Python cycle loop (readable; the semantic spec).
* :func:`replay_batch` — a thin dispatcher over the batched engines:
  ``engine="numpy"`` is the vectorised per-cycle numpy loop (the parity
  oracle and benchmark baseline), ``engine="scan"`` is the
  ``lax.scan`` form (``repro.kernels.replay_scan.ref``, the fast CPU
  path), ``engine="kernel"`` is the chunked Pallas kernel, and
  ``engine="auto"`` picks per backend (Pallas on TPU, scan elsewhere).

:func:`run_strategies` (one trace, permutation-averaged) and
:func:`run_fleet_strategies` (pools × permutations × strategies in one
shot — the §VI-E experiment) are thin drivers over :func:`replay_batch`.
Prediction inputs are per-cycle label *arrays* (one model call for the
whole trace) rather than per-cycle callables — the batched-predictor
contract of the fleet pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "SimResult",
    "replay",
    "replay_batch",
    "replay_sweep",
    "run_strategies",
    "run_fleet_strategies",
]

#: legacy prediction callback: cycle index -> 1 if pool forecast available
PredictorFn = Callable[[int], int]

STRATEGIES = ("always_run", "sjf", "predict_ar")
ENGINES = ("auto", "numpy", "scan", "kernel")
PRECISIONS = ("f64", "f32")

#: completion slack shared by every backend (a query whose remaining work
#: is within EPS of the budget counts as finished this cycle)
EPS = 1e-9


@dataclasses.dataclass
class SimResult:
    strategy: str
    lost_seconds: float
    idle_seconds: float          # pool available but deliberately idle
    completed: int
    total_queries: int
    makespan_seconds: float

    def __add__(self, other: "SimResult") -> "SimResult":
        assert self.strategy == other.strategy
        return SimResult(
            self.strategy,
            self.lost_seconds + other.lost_seconds,
            self.idle_seconds + other.idle_seconds,
            self.completed + other.completed,
            self.total_queries + other.total_queries,
            self.makespan_seconds + other.makespan_seconds,
        )

    def scaled(self, k: float) -> "SimResult":
        return SimResult(
            self.strategy,
            self.lost_seconds * k,
            self.idle_seconds * k,
            int(round(self.completed * k)),
            int(round(self.total_queries * k)),
            self.makespan_seconds * k,
        )


def _predictions_array(
    predictions, predictor: Optional[PredictorFn], t_cycles: int
) -> Optional[np.ndarray]:
    """Normalize the prediction input to a per-cycle label array."""
    if predictions is not None:
        return np.asarray(predictions)
    if predictor is not None:
        return np.array([int(predictor(c)) for c in range(t_cycles)])
    return None


def replay(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    strategy: str = "always_run",
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
) -> SimResult:
    """Replay one trace with one strategy (the scalar contract reference).

    Args:
      avail: (T,) binary pool availability per collection cycle.
      durations: query durations (seconds).
      strategy: "always_run" | "sjf" | "predict_ar".
      predictions: required for predict_ar — (T,) per-cycle predicted
        labels (1 = stays available over the horizon).  ``predictor`` is
        the legacy per-cycle callable form, evaluated over all cycles.
      horizon_cycles: deferral length when the predictor flags risk.
    """
    avail = np.asarray(avail).astype(bool)
    dur = np.asarray(durations, dtype=np.float64)
    if strategy == "sjf":
        dur = np.sort(dur)
    pred = _predictions_array(predictions, predictor, len(avail))
    use_pred = strategy == "predict_ar"
    if use_pred and pred is None:
        raise ValueError("predict_ar requires predictions")

    t_cycles = len(avail)
    q = len(dur)
    cum = np.concatenate([[0.0], np.cumsum(dur)])  # cum[j] = dur[:j].sum()

    head = 0
    front = 0.0                 # re-queued (interrupted) query, if any
    has_front = False
    running = False
    remaining = 0.0
    progress = 0.0
    defer_until = -1
    lost = 0.0
    idle = 0.0
    completed = 0
    makespan = t_cycles * dt

    for c in range(t_cycles):
        if not avail[c]:
            if running:         # running query loses all progress; retry
                lost += progress
                front = progress + remaining
                has_front = True
                running = False
                progress = 0.0
            continue

        deferred = False
        if use_pred:
            if c > defer_until and pred[c] == 0:
                defer_until = c + horizon_cycles
            deferred = c <= defer_until

        b = dt
        # -- phase A: the in-hand item ------------------------------------
        launch_front = (not running) and has_front and not deferred
        if running or launch_front:
            x = remaining if running else front
            step = min(b, x)
            xr = x - step
            progress = (progress + step) if running else step
            b = b - step
            if launch_front:
                has_front = False
            if xr <= EPS:
                completed += 1
                running = False
                progress = 0.0
                if head >= q and not has_front:
                    makespan = min(makespan, (c + 1) * dt - b)
            else:
                remaining = xr
                running = True
        # -- phase B: queue consumption by prefix sums --------------------
        if (not running) and (not deferred) and head < q and b > EPS:
            base = cum[head]
            target = base + (b + EPS)
            k = int(np.searchsorted(cum, target, side="right")) - head - 1
            used = cum[head + k] - base
            b = max(b - used, 0.0)
            completed += k
            head += k
            if k > 0 and head >= q:
                makespan = min(makespan, (c + 1) * dt - b)
            if head < q and b > EPS:
                remaining = (cum[head + 1] - cum[head]) - b
                progress = b
                running = True
                head += 1
                b = 0.0
        # -- phase C: leftover budget is idle time ------------------------
        if not running and b > EPS:
            idle += b

    # a query still running when the trace ends is neither lost nor complete
    return SimResult(
        strategy=strategy,
        lost_seconds=lost,
        idle_seconds=idle,
        completed=completed,
        total_queries=len(dur),
        makespan_seconds=makespan,
    )


def _prepare_batch(avail, durations, strategy, predictions):
    """Shared input normalisation for the batched engines."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    avail = np.atleast_2d(np.asarray(avail)).astype(bool)
    dur = np.atleast_2d(np.asarray(durations, dtype=np.float64))
    B = max(avail.shape[0], dur.shape[0])
    T, Q = avail.shape[1], dur.shape[1]
    avail = np.broadcast_to(avail, (B, T))
    dur = np.array(np.broadcast_to(dur, (B, Q)))
    if strategy == "sjf":
        dur = np.sort(dur, axis=1)
    pred_zero = None
    if strategy == "predict_ar":
        if predictions is None:
            raise ValueError("predict_ar requires predictions")
        pred = np.atleast_2d(np.asarray(predictions))
        pred_zero = np.array(np.broadcast_to(pred == 0, (B, T)))
    cum = np.concatenate([np.zeros((B, 1)), np.cumsum(dur, axis=1)], axis=1)
    return avail, dur, cum, pred_zero


def _replay_batch_numpy(
    avail: np.ndarray,       # (B, T) bool
    dur: np.ndarray,         # (B, Q) f64, launch order (sjf pre-sorted)
    cum: np.ndarray,         # (B, Q+1) f64 prefix sums of dur
    pred_zero,               # (B, T) bool "predictor says unavailable", or None
    *,
    dt: float,
    horizon_cycles: int,
) -> Dict[str, np.ndarray]:
    """The vectorised per-cycle numpy loop — the batch parity oracle.

    One closed-form transition per cycle over stacked row state; the
    prefix count of phase B is a plain comparison count against the
    ``cum`` rows.  Bit-identical to :func:`replay` row by row.

    Dtype-generic: the dtype of ``cum`` drives every float op through
    typed constants (so the f32 tier has a numpy oracle executing the
    same IEEE ops as the f32 scan/kernel paths).  float64 inputs keep
    the historical bit-exact behaviour.
    """
    B, T = avail.shape
    Q = dur.shape[1]
    use_pred = pred_zero is not None
    rows = np.arange(B)
    fd = cum.dtype
    ft = fd.type
    dtc = ft(dt)
    eps = ft(EPS)
    zero = ft(0.0)

    head = np.zeros(B, dtype=np.int64)
    front = np.zeros(B, dtype=fd)
    has_front = np.zeros(B, dtype=bool)
    running = np.zeros(B, dtype=bool)
    remaining = np.zeros(B, dtype=fd)
    progress = np.zeros(B, dtype=fd)
    defer = np.full(B, -1, dtype=np.int64)
    lost = np.zeros(B, dtype=fd)
    idle = np.zeros(B, dtype=fd)
    completed = np.zeros(B, dtype=np.int64)
    makespan = np.full(B, T, dtype=fd) * dtc

    for c in range(T):
        up = avail[:, c]
        drop = ~up & running
        if drop.any():
            lost[drop] += progress[drop]
            front[drop] = progress[drop] + remaining[drop]
            has_front[drop] = True
            running[drop] = False
            progress[drop] = 0.0
        if use_pred:
            trig = up & (c > defer) & pred_zero[:, c]
            defer[trig] = c + horizon_cycles
            deferred = up & (c <= defer)
        else:
            deferred = np.zeros(B, dtype=bool)

        b = np.where(up, dtc, zero)
        mk_edge = ft(c + 1) * dtc
        # -- phase A ------------------------------------------------------
        a_run = up & running
        a_frt = up & ~running & has_front & ~deferred
        has_a = a_run | a_frt
        if has_a.any():
            x = np.where(a_run, remaining, front)
            step = np.where(has_a, np.minimum(b, x), zero)
            xr = x - step
            progress = np.where(a_run, progress + step,
                                np.where(a_frt, step, progress))
            b = b - step
            has_front = has_front & ~a_frt
            fin = has_a & (xr <= eps)
            completed[fin] += 1
            running = has_a & ~fin
            remaining = np.where(has_a & ~fin, xr, remaining)
            progress[fin] = 0.0
            mk_a = fin & (head >= Q) & ~has_front
            if mk_a.any():
                makespan[mk_a] = np.minimum(
                    makespan[mk_a], mk_edge - b[mk_a]
                )
        # -- phase B ------------------------------------------------------
        qb = up & ~running & ~deferred & (head < Q) & (b > eps)
        if qb.any():
            r = rows[qb]
            base = cum[r, head[qb]]
            target = base + (b[qb] + eps)
            k = (cum[r] <= target[:, None]).sum(axis=1) - head[qb] - 1
            used = cum[r, head[qb] + k] - base
            b2 = np.maximum(b[qb] - used, zero)
            completed[qb] += k
            h2 = head[qb] + k
            mk_b = (k > 0) & (h2 >= Q)
            if mk_b.any():
                mrows = r[mk_b]
                makespan[mrows] = np.minimum(
                    makespan[mrows], mk_edge - b2[mk_b]
                )
            part = (h2 < Q) & (b2 > eps)
            if part.any():
                prow = r[part]
                hp = h2[part]
                remaining[prow] = (cum[prow, hp + 1] - cum[prow, hp]) - b2[part]
                progress[prow] = b2[part]
                running[prow] = True
                h2 = h2 + part
            head[qb] = h2
            b[qb] = np.where(part, zero, b2)
        # -- phase C ------------------------------------------------------
        sit = ~running & (b > eps)
        idle[sit] += b[sit]

    return {
        "lost_seconds": lost,
        "idle_seconds": idle,
        "completed": completed,
        "total_queries": np.full(B, Q, dtype=np.int64),
        "makespan_seconds": makespan,
    }


def _cast_precision(cum: np.ndarray, precision: str) -> np.ndarray:
    """Select the precision tier: the dtype of ``cum`` drives every
    engine.  Prefix sums always accumulate in float64 first (shared
    verbatim by every backend), then round once to f32 for the fast tier
    — on 1/32-second-quantised workloads with bounded totals that cast
    is exact, which is what makes the f32 tier reproduce the f64 oracle
    bit for bit there (see ``kernels.replay_scan.ops``)."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r} (want one of {PRECISIONS})"
        )
    return cum.astype(np.float32) if precision == "f32" else cum


def replay_batch(
    avail: np.ndarray,
    durations: np.ndarray,
    *,
    strategy: str = "always_run",
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    horizon_cycles: int = 1,
    engine: str = "auto",
    precision: str = "f64",
    shards=None,
) -> Dict[str, np.ndarray]:
    """Replay a stack of traces with one strategy (thin dispatcher).

    Args:
      avail: (B, T) — or (T,), broadcast — binary availability per trace.
      durations: (B, Q) — or (Q,), broadcast — per-trace query queues in
        launch order (``sjf`` sorts each row internally).
      predictions: (B, T) or (T,) per-cycle labels, required for
        ``predict_ar``.
      strategy: ``"always_run"`` | ``"sjf"`` (shortest-job-first sort of
        each row's queue) | ``"predict_ar"`` (defer new launches while
        the model predicts unavailability).
      engine: which implementation of the replay contract runs the batch
        — all are **bit-identical (atol=0)** to the scalar
        :func:`replay` row by row:

        * ``"numpy"`` — the vectorised per-cycle loop (the parity
          oracle; also taken automatically for degenerate empty-queue /
          empty-trace shapes);
        * ``"scan"`` — the ``lax.scan`` closed form with windowed prefix
          counts, the fast CPU path (float64 runs under a scoped
          ``enable_x64``; auto row-sharded at fleet batch sizes);
        * ``"kernel"`` — the chunked Pallas kernel (native on TPU at
          float32; interpret mode elsewhere);
        * ``"auto"`` (default) — Pallas on TPU for float32 inputs, scan
          everywhere else (float64 contracts stay on the bit-identical
          scan even on TPU).
      precision: ``"f64"`` (default — the atol=0 house contract) or
        ``"f32"`` (the bandwidth-lean fast tier: every engine executes
        the same op sequence in float32; on 1/32-second-quantised
        workloads with bounded totals the f32 results — integer
        decisions *and* float metrics — reproduce the f64 oracle bit
        for bit).
      shards: trace-axis mesh size for the scan backend — ``None`` /
        ``"auto"`` shards across all visible devices (single device:
        plain unsharded scan), an int pins the mesh size.  Ignored by
        the numpy oracle and the Pallas kernel.

    Returns stacked metrics ``{"lost_seconds", "idle_seconds",
    "completed", "total_queries", "makespan_seconds"}``, each of shape
    (B,).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (want one of {ENGINES})")
    avail, dur, cum, pred_zero = _prepare_batch(
        avail, durations, strategy, predictions
    )
    cum = _cast_precision(cum, precision)
    if engine == "numpy" or dur.shape[1] == 0 or avail.shape[1] == 0:
        # degenerate shapes stay on the oracle path (nothing to scan over)
        return _replay_batch_numpy(
            avail, dur, cum, pred_zero, dt=dt, horizon_cycles=horizon_cycles
        )
    from repro.kernels.replay_scan.ops import replay_scan_op

    backend = {"auto": "auto", "scan": "jnp", "kernel": "pallas"}[engine]
    return replay_scan_op(
        avail, dur, cum, pred_zero,
        dt=dt, horizon_cycles=horizon_cycles, backend=backend,
        shards=shards,
    )


def replay_sweep(
    avail: np.ndarray,
    durations: np.ndarray,
    *,
    strategies: Sequence[str] = STRATEGIES,
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    horizon_cycles: int = 1,
    engine: str = "auto",
    precision: str = "f64",
    shards=None,
) -> Dict[str, Dict[str, np.ndarray]]:
    """Replay a stack of traces through *all* strategies in one pass.

    The fused form of S :func:`replay_batch` calls: on the scan and
    kernel engines the carried state gains a strategies plane, so each
    availability column streams from memory once and feeds every
    strategy's transition — the bandwidth-lean path that
    :func:`run_strategies` / :func:`run_fleet_strategies` (fig9) ride.
    Fused results are **bit-identical (atol=0)** to the per-strategy
    calls (the fused body executes the same elementwise ops in the same
    order); the numpy oracle simply loops strategies.

    Same arguments as :func:`replay_batch` plus ``strategies`` (the
    planes to sweep, default all three).  Returns ``{strategy: metric
    dict}`` with the :func:`replay_batch` metrics per strategy.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (want one of {ENGINES})")
    strategies = list(strategies)
    prepped = [
        _prepare_batch(avail, durations, s, predictions) for s in strategies
    ]
    avail_b = prepped[0][0]
    pred_zero = next((p[3] for p in prepped if p[3] is not None), None)
    cums = _cast_precision(
        np.stack([p[2] for p in prepped]), precision
    )
    degenerate = cums.shape[2] == 1 or avail_b.shape[1] == 0
    if engine == "numpy" or degenerate:
        return {
            s: _replay_batch_numpy(
                avail_b, prepped[i][1], cums[i], prepped[i][3],
                dt=dt, horizon_cycles=horizon_cycles,
            )
            for i, s in enumerate(strategies)
        }
    from repro.kernels.replay_scan.ops import replay_sweep_op

    backend = {"auto": "auto", "scan": "jnp", "kernel": "pallas"}[engine]
    use_pred = tuple(s == "predict_ar" for s in strategies)
    results = replay_sweep_op(
        avail_b, cums, pred_zero, use_pred,
        dt=dt, horizon_cycles=horizon_cycles, backend=backend, shards=shards,
    )
    return dict(zip(strategies, results))


def _pool_mean_results(
    strategy: str, batch: Dict[str, np.ndarray], pools: int, n_perm: int
) -> List[SimResult]:
    """Per-pool permutation means via one columnar segment reduction.

    The (pools * n_perm,) metric vectors reduce along the permutation
    axis in a single reshape-sum per metric — no per-pool slicing.
    """
    sums = {k: v.reshape(pools, n_perm).sum(axis=1) for k, v in batch.items()}
    return [
        SimResult(
            strategy=strategy,
            lost_seconds=float(sums["lost_seconds"][p] / n_perm),
            idle_seconds=float(sums["idle_seconds"][p] / n_perm),
            completed=int(round(sums["completed"][p] / n_perm)),
            total_queries=int(round(sums["total_queries"][p] / n_perm)),
            makespan_seconds=float(sums["makespan_seconds"][p] / n_perm),
        )
        for p in range(pools)
    ]


def run_strategies(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
    n_permutations: int = 5,
    seed: int = 0,
    engine: str = "auto",
    precision: str = "f64",
) -> List[SimResult]:
    """Average each strategy over query-order permutations (§VI-E).

    All permutations × strategies replay as a single fused
    :func:`replay_sweep` call instead of a Python loop of scalar
    replays — each trace column is read once for all strategies.
    """
    rng = np.random.default_rng(seed)
    avail = np.asarray(avail)
    durations = np.asarray(durations, dtype=np.float64)
    pred = _predictions_array(predictions, predictor, avail.shape[-1])
    strategies = ["always_run", "sjf"]
    if pred is not None:
        strategies.append("predict_ar")
    perms = np.stack([rng.permutation(durations) for _ in range(n_permutations)])
    sweep = replay_sweep(
        np.broadcast_to(avail, (n_permutations, avail.shape[-1])),
        perms,
        strategies=strategies,
        dt=dt,
        predictions=pred,
        horizon_cycles=horizon_cycles,
        engine=engine,
        precision=precision,
    )
    return [
        _pool_mean_results(s, sweep[s], 1, n_permutations)[0]
        for s in strategies
    ]


def run_fleet_strategies(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    horizon_cycles: int = 1,
    n_permutations: int = 5,
    seeds: Optional[Sequence[int]] = None,
    engine: str = "auto",
    precision: str = "f64",
) -> Dict[str, List[SimResult]]:
    """The §VI-E experiment in one shot: every (pool × permutation ×
    strategy) trace replays inside ONE fused :func:`replay_sweep` call.

    Args:
      avail: (pools, T) per-pool availability traces.
      durations: (Q,) query profile, permuted per pool/permutation.
      predictions: (pools, T) per-pool per-cycle predicted labels;
        enables the ``predict_ar`` strategy.
      seeds: per-pool permutation seeds (defaults to the pool index, the
        historical per-pool convention).
      engine: replay engine, forwarded to :func:`replay_sweep` (the
        default routes through the fused scan path).
      precision: ``"f64"`` (atol=0 contract) or ``"f32"`` (fast tier).

    Returns ``{strategy: [per-pool permutation-averaged SimResult]}``.
    """
    avail = np.asarray(avail)
    if avail.ndim != 2:
        raise ValueError(f"avail must be (pools, T), got {avail.shape}")
    pools, T = avail.shape
    durations = np.asarray(durations, dtype=np.float64)
    if seeds is None:
        seeds = range(pools)
    perm_rows = []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        perm_rows.extend(rng.permutation(durations) for _ in range(n_permutations))
    perms = np.stack(perm_rows)  # (pools * n_permutations, Q)
    big_avail = np.repeat(avail, n_permutations, axis=0)
    strategies = ["always_run", "sjf"]
    big_pred = None
    if predictions is not None:
        big_pred = np.repeat(np.asarray(predictions), n_permutations, axis=0)
        strategies.append("predict_ar")
    sweep = replay_sweep(
        big_avail,
        perms,
        strategies=strategies,
        dt=dt,
        predictions=big_pred,
        horizon_cycles=horizon_cycles,
        engine=engine,
        precision=precision,
    )
    return {
        s: _pool_mean_results(s, sweep[s], pools, n_permutations)
        for s in strategies
    }
