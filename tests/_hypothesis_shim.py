"""Minimal in-repo stand-in for `hypothesis` (property-based testing).

The real `hypothesis` package is the declared test dependency
(``requirements-dev.txt``); this shim exists so the tier-1 suite still
*collects and runs* in hermetic environments where it cannot be installed.
Importing this module (done by ``tests/conftest.py`` only when the real
package is absent) registers ``hypothesis`` and ``hypothesis.strategies``
modules in ``sys.modules`` backed by a tiny deterministic random-sampling
engine:

* ``@given(**strategies)`` draws ``max_examples`` pseudo-random examples
  (seeded per test function, so runs are reproducible) and calls the test
  once per example;
* ``@settings(...)`` records ``max_examples`` (other knobs are accepted and
  ignored — there is no shrinking, database, or deadline enforcement);
* strategies cover what this repo uses: ``integers``, ``floats``,
  ``booleans``, ``just``, ``sampled_from``, ``lists``, ``tuples``.

Failures report the drawn example in the assertion chain but are NOT
shrunk — install real hypothesis for minimal counterexamples.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 30


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self.draw(rng)))

    def filter(self, pred, _tries: int = 1000):
        def draw(rng):
            for _ in range(_tries):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict for shim strategy")
        return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(size)]
    return _Strategy(draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def settings(**kw):
    """Decorator recording settings for ``given``.

    Works in either decorator order (real hypothesis accepts both):
    the settings dict is merged onto whatever it decorates — the raw
    test function (``@given`` above ``@settings``) or the already-built
    given-wrapper (``@settings`` above ``@given``), which reads it at
    call time.
    """
    def deco(fn):
        fn._shim_settings = {**getattr(fn, "_shim_settings", {}), **kw}
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):  # *args carries `self` for methods
            n_examples = getattr(wrapper, "_shim_settings", {}).get(
                "max_examples", _DEFAULT_MAX_EXAMPLES
            )
            rng = random.Random(seed)
            for i in range(n_examples):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"shim-hypothesis falsified {fn.__qualname__} on "
                        f"example {i}: {drawn!r}"
                    ) from e

        # Hide the drawn parameters from pytest's fixture resolution: the
        # wrapper supplies them, so they must not look like fixture requests.
        del wrapper.__wrapped__
        wrapper._shim_settings = getattr(fn, "_shim_settings", {})
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs
        ])
        return wrapper
    return deco


def _install():
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)

    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "lists", "tuples"):
        setattr(st, name, globals()[name])

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install()
