"""Serving decision throughput — admission decisions/sec, scalar vs fleet.

Measures the Predict-AR **decision layer** of the streaming serve path
(`repro.serve`): per collection cycle, every pool must be decided —
admit new requests or defer (§VI-E) — from the cycle's availability
scores.  Two implementations of the same policy:

1. ``scalar`` — one pure-Python Predict-AR controller per pool (the
   pre-vectorisation arithmetic of ``repro.serve.AdmissionController``,
   inlined here so the baseline isn't burdened by that class's modern
   fleet-view delegation), each invoking a per-pool predictor callable:
   O(pools) interpreter work per cycle (the paper-faithful shape, fine
   at 68 pools);
2. ``fleet``  — ONE :class:`~repro.serve.FleetAdmissionController` for
   the whole fleet: the defer clocks live in a ``(pools,)`` array, the
   cycle's scores arrive as the pipeline's batched prediction column,
   and the decision is a constant number of vector ops.

The benchmark *asserts* bit-identical admission matrices between the
two paths before timing anything.

Usage:
    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]
        [--pools 4096] [--cycles 64]

The full run asserts (at 4096 pools on CPU) that the fleet controller
clears >= 20x the per-pool scalar loop in decisions/sec and appends a
perf record to ``BENCH_serve.json``.  ``--smoke`` only checks plumbing +
parity.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

REQUIRED_SPEEDUP = 20.0   # fleet vs per-pool scalar controllers
THRESHOLD = 0.5
HORIZON = 5


def _workload(pools: int, cycles: int, seed: int = 0) -> np.ndarray:
    """(cycles, pools, 3) synthetic SnS feature stream; p_stay := SR."""
    rng = np.random.default_rng(seed)
    feats = rng.random((cycles, pools, 3))
    return feats


class _ScalarPredictAR:
    """The paper-faithful per-pool controller arithmetic, pure Python.

    This is the *pre-vectorisation* implementation (three scalar
    comparisons, no numpy) — the honest baseline for the speedup claim.
    The library's :class:`repro.serve.AdmissionController` is nowadays a
    thin view over the fleet controller (shared defer-clock arithmetic),
    which would make it slower than this and flatter the fleet number;
    its parity with the fleet controller is property-tested in
    ``tests/test_serve_stream.py``, and parity of THIS baseline is
    asserted below before anything is timed.
    """

    __slots__ = ("predictor", "horizon_cycles", "threshold", "_defer_until")

    def __init__(self, predictor, horizon_cycles, threshold):
        self.predictor = predictor
        self.horizon_cycles = horizon_cycles
        self.threshold = threshold
        self._defer_until = -1

    def on_cycle(self, cycle, features):
        if cycle <= self._defer_until:
            return False
        p_stay = float(self.predictor(features))
        if 1.0 - p_stay >= self.threshold:
            self._defer_until = cycle + self.horizon_cycles
            return False
        return True


def run_scalar(feats: np.ndarray) -> tuple[np.ndarray, float]:
    """Per-pool controller objects + per-pool predictor calls."""
    cycles, pools, _ = feats.shape
    predictor = lambda f: float(f[0])  # noqa: E731 — p_stay := SR
    ctls = [
        _ScalarPredictAR(predictor, HORIZON, THRESHOLD) for _ in range(pools)
    ]
    admit = np.zeros((cycles, pools), dtype=bool)
    t0 = time.perf_counter()
    for c in range(cycles):
        f_c = feats[c]
        for p, ctl in enumerate(ctls):
            admit[c, p] = ctl.on_cycle(c, f_c[p])
    return admit, time.perf_counter() - t0


def run_fleet(feats: np.ndarray) -> tuple[np.ndarray, float]:
    """One vectorised controller; scores from one columnar slice/cycle."""
    from repro.serve import FleetAdmissionController

    cycles, pools, _ = feats.shape
    ctl = FleetAdmissionController(
        pools, horizon_cycles=HORIZON, threshold=THRESHOLD
    )
    admit = np.zeros((cycles, pools), dtype=bool)
    t0 = time.perf_counter()
    for c in range(cycles):
        admit[c] = ctl.on_cycle(c, feats[c, :, 0])
    return admit, time.perf_counter() - t0


def run(pools: int = 4096, cycles: int = 64, smoke: bool = False) -> dict:
    if smoke:
        pools, cycles = min(pools, 256), min(cycles, 8)
    sizes = sorted({min(1024, pools), pools})

    per_size = {}
    for p in sizes:
        feats = _workload(p, cycles)
        admit_s, wall_s = run_scalar(feats)
        admit_f, wall_f = run_fleet(feats)
        np.testing.assert_array_equal(admit_s, admit_f)
        decisions = p * cycles
        per_size[p] = {
            "decisions_per_sec": {
                "scalar": round(decisions / wall_s),
                "fleet": round(decisions / wall_f),
            },
            "speedup": round(wall_s / wall_f, 1),
            "defer_fraction": round(1.0 - float(admit_f.mean()), 3),
        }

    result = {
        "cycles": cycles,
        "per_pools": per_size,
        "speedup": per_size[pools]["speedup"],
        "parity_identical": True,  # asserted above for every size
        "smoke": smoke,
    }
    if not smoke:
        assert result["speedup"] >= REQUIRED_SPEEDUP, result
        rec = dict(result, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
        with open(Path.cwd() / "BENCH_serve.json", "a") as f:
            f.write(json.dumps(rec) + "\n")
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; skip the speedup assertion")
    args = ap.parse_args()
    result = run(pools=args.pools, cycles=args.cycles, smoke=args.smoke)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
