# The paper's primary contribution — the SnS control plane:
# probing lifecycle, simulated provider, collector, O(1) feature pipeline,
# availability labels/datasets, predictor zoo, and the trace-driven
# workload simulator.  Sibling subpackages (models/, train/, serve/,
# fleet/) are the data-plane substrates that consume these signals.

from .collector import (
    CampaignCycle,
    CampaignResult,
    CampaignStream,
    DataLake,
    FleetCollector,
    SnSCollector,
    run_campaign,
)
from .cointerrupt import fraction_within, proximities, proximity_cdf
from .cost import CostReport, ServerlessPricing, cost_report
from .dataset import Dataset, DatasetStreamer, build_dataset
from .faults import (
    BILLED_FAULT_CODES,
    OUTCOME_BLACKOUT,
    OUTCOME_CAPACITY,
    OUTCOME_DEFERRED,
    OUTCOME_ERROR,
    OUTCOME_NAMES,
    OUTCOME_OK,
    OUTCOME_RATE_LIMITED,
    OUTCOME_THROTTLED,
    OUTCOME_TIMEOUT,
    BlackoutWindows,
    FaultPlan,
    ThrottleBursts,
    describe_codes,
)
from .features import (
    FEATURE_NAMES,
    FleetFeatureState,
    compute_features,
    init_fleet_state,
    init_state,
    update,
    update_batch,
)
from .labels import HorizonLabelStream, binary_availability, horizon_labels
from .lifecycle import RequestState, SpotRequest
from .pipeline import (
    CampaignPipelineStream,
    DataArchive,
    FeatureProcessor,
    FleetCycleResult,
    FleetFeatureProcessor,
    FleetWindowTable,
    StreamCycleView,
    WindowTable,
    run_campaign_pipeline,
)
from .predictor import (
    MODEL_REGISTRY,
    SEQUENCE_MODELS,
    batched_predict_fn,
    evaluate,
    fit_predictor,
    make_model,
    pointwise_predict_fn,
)
from .ledger import CohortLedger, InstanceLedger, ProbeLedger, RunningInstance
from .retry import RetryController, RetryPolicy, backoff_delays, base_backoff
from .provider import (
    InterruptionEvent,
    InterruptionLog,
    LedgerStats,
    PoolConfig,
    ProbeCostMeter,
    RateLimitError,
    SimulatedProvider,
    default_fleet,
)
from .sharded import ShardedProvider, run_sharded_campaign
from .simulate import (
    SimResult,
    replay,
    replay_batch,
    replay_sweep,
    run_fleet_strategies,
    run_strategies,
)
from .workloads import tpcds_profile

__all__ = [
    "CampaignCycle", "CampaignResult", "CampaignStream",
    "DataLake", "FleetCollector", "SnSCollector", "run_campaign",
    "fraction_within", "proximities", "proximity_cdf",
    "CostReport", "ServerlessPricing", "cost_report",
    "Dataset", "DatasetStreamer", "build_dataset",
    "FaultPlan", "ThrottleBursts", "BlackoutWindows", "describe_codes",
    "OUTCOME_NAMES", "OUTCOME_OK", "OUTCOME_CAPACITY", "OUTCOME_RATE_LIMITED",
    "OUTCOME_THROTTLED", "OUTCOME_ERROR", "OUTCOME_TIMEOUT",
    "OUTCOME_BLACKOUT", "OUTCOME_DEFERRED", "BILLED_FAULT_CODES",
    "RetryPolicy", "RetryController", "base_backoff", "backoff_delays",
    "FEATURE_NAMES", "compute_features", "init_state", "update",
    "FleetFeatureState", "init_fleet_state", "update_batch",
    "HorizonLabelStream", "binary_availability", "horizon_labels",
    "RequestState", "SpotRequest",
    "DataArchive", "FeatureProcessor", "WindowTable",
    "FleetCycleResult", "FleetFeatureProcessor", "FleetWindowTable",
    "CampaignPipelineStream", "StreamCycleView",
    "run_campaign_pipeline",
    "MODEL_REGISTRY", "SEQUENCE_MODELS", "evaluate", "fit_predictor", "make_model",
    "batched_predict_fn", "pointwise_predict_fn",
    "CohortLedger", "InstanceLedger", "ProbeLedger", "RunningInstance",
    "InterruptionEvent", "InterruptionLog", "LedgerStats", "PoolConfig",
    "ProbeCostMeter", "RateLimitError",
    "SimulatedProvider", "default_fleet",
    "ShardedProvider", "run_sharded_campaign",
    "SimResult", "replay", "replay_batch", "replay_sweep", "run_strategies",
    "run_fleet_strategies",
    "tpcds_profile",
]
