"""Chunked Pallas kernel for the fused goodput replay.

Tiles the (pods × cycles) grid as ``(block_p × chunk)`` blocks with the
chunk axis innermost / sequential; the carried ``(S, block_p)`` replay
state — step counters, checkpoint bookkeeping, and the resumable
restore / write registers — lives in VMEM scratch across chunk steps
(the ``replay_scan`` pattern).  The strategies axis of ``replay_scan``
becomes the *policies* axis here: each pod's packed flag / hazard column
is loaded from HBM once per chunk and replayed through every policy
plane.

Per cycle the kernel applies the same closed-form transition as
``ref.goodput_sweep_ref`` op for op — τ re-derived in-kernel from the
resident parameter planes and the cycle's negative-log-survival column,
with every divisor / clip bound a traced operand (see the ``ref`` module
docstring for why that pins bit-identity) — so outputs are bit-identical
in the shared dtype.  On CPU the kernel runs in interpret mode
(parity/testing); float64 state requires x64, so real-TPU use means
float32 inputs.

grid = (P / block_p, T / chunk)   [chunk axis innermost / sequential]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# scratch column layout
_G_OVERHEAD, _G_UNAVAIL, _G_TLAST, _G_RESTORE, _G_WRITE = range(5)
_G_DONE, _G_SINCE, _G_LOST, _G_CKPTS = range(4)

# fparams plane layout (matches ops._FPARAM_ORDER)
_P_INTERVAL, _P_DELTA, _P_HORIZON, _P_TAUMAX, _P_FLOOR = range(5)


def _goodput_kernel(
    flags_ref, nlp_ref, ishz_ref, fparams_ref, scal_ref,
    done_ref, lost_ref, ck_ref, oh_ref, un_ref,
    fstate, istate,
    *,
    chunk: int,
    t_real: int,
):
    ic = pl.program_id(1)
    f = nlp_ref.dtype
    i32 = jnp.int32
    s_pl, bp = ishz_ref.shape
    zero = jnp.zeros((), f)
    two = jnp.asarray(2.0, f)

    # the four cost scalars ride in as a (1, 4) tile: load the tile, then
    # index the *value* (traced operands -> exact IEEE division in-kernel)
    sv = scal_ref[...]
    dt, step_time = sv[0, 0], sv[0, 1]
    ckpt_cost, restore_cost = sv[0, 2], sv[0, 3]

    @pl.when(ic == 0)
    def _init():
        fstate[...] = jnp.zeros_like(fstate)
        istate[...] = jnp.zeros_like(istate)

    flags = flags_ref[...]            # (bp, chunk) int32 — packed avail/panic
    nlp = nlp_ref[...]                # (bp, chunk) f
    is_hz = ishz_ref[...] > 0         # (s_pl, bp)
    fp = fparams_ref[...]             # (s_pl, bp, 5)
    interval = fp[..., _P_INTERVAL]
    delta = fp[..., _P_DELTA]
    horizon = fp[..., _P_HORIZON]
    tau_max = fp[..., _P_TAUMAX]
    floor = fp[..., _P_FLOOR]
    col_iota = jax.lax.broadcasted_iota(i32, (bp, chunk), 1)
    s_iota = jax.lax.broadcasted_iota(i32, (s_pl, bp), 0)

    def cycle(j, st):
        (done, since, lost, ckpts, overhead, unavailable,
         t_last, restore_rem, write_rem) = st
        g = ic * chunk + j
        # padded cycles beyond t_real are inert: neither up nor down
        valid = g < t_real
        fc = jnp.sum(jnp.where(col_iota == j, flags, 0), axis=1)   # (bp,)
        nc = jnp.sum(jnp.where(col_iota == j, nlp, zero), axis=1)  # (bp,)
        up_raw = (fc & 1) > 0
        up = jnp.broadcast_to(up_raw[None, :], (s_pl, bp)) & valid
        down = jnp.broadcast_to(~up_raw[None, :], (s_pl, bp)) & valid
        panic = ((fc[None, :] >> (s_iota + 1)) & 1) > 0
        now = g.astype(f) * dt

        lam = jnp.maximum(nc[None, :] / horizon, floor)
        hz = jnp.clip(jnp.sqrt((two * delta) / lam), delta, tau_max)
        tau_c = jnp.where(is_hz, jnp.where(panic, two * delta, hz), interval)

        lost = lost + jnp.where(down, since, 0)
        since = jnp.where(down, 0, since)
        unavailable = unavailable + jnp.where(down, dt, zero)
        restore_rem = jnp.where(down, restore_cost, restore_rem)
        write_rem = jnp.where(down, zero, write_rem)

        budget = jnp.where(up, dt, zero)
        used = jnp.minimum(budget, restore_rem)
        restore_rem = restore_rem - used
        budget = budget - used
        was_writing = write_rem > zero
        w = jnp.minimum(budget, write_rem)
        write_rem = write_rem - w
        budget = budget - w
        overhead = overhead + w
        done_write = was_writing & (write_rem <= zero)
        ckpts = ckpts + done_write.astype(i32)
        t_last = jnp.where(done_write, now + (dt - budget), t_last)
        since = jnp.where(done_write, 0, since)

        t_c = now + (dt - budget)
        can = up & (budget > zero)
        decide = can & (t_c - t_last >= tau_c)
        start = decide & (since > 0)
        t_last = jnp.where(decide & (since == 0), t_c, t_last)
        w2 = jnp.where(start, jnp.minimum(budget, ckpt_cost), zero)
        budget = budget - w2
        overhead = overhead + w2
        full = start & (w2 >= ckpt_cost)
        write_rem = jnp.where(start & ~full, ckpt_cost - w2, write_rem)
        ckpts = ckpts + full.astype(i32)
        t_last = jnp.where(full, now + (dt - budget), t_last)
        since = jnp.where(full, 0, since)

        steps = jnp.floor(budget / step_time).astype(i32)
        done = done + steps
        since = since + steps
        return (done, since, lost, ckpts, overhead, unavailable,
                t_last, restore_rem, write_rem)

    st = (
        istate[:, :, _G_DONE],
        istate[:, :, _G_SINCE],
        istate[:, :, _G_LOST],
        istate[:, :, _G_CKPTS],
        fstate[:, :, _G_OVERHEAD],
        fstate[:, :, _G_UNAVAIL],
        fstate[:, :, _G_TLAST],
        fstate[:, :, _G_RESTORE],
        fstate[:, :, _G_WRITE],
    )
    st = jax.lax.fori_loop(0, chunk, cycle, st)
    (done, since, lost, ckpts, overhead, unavailable,
     t_last, restore_rem, write_rem) = st

    istate[:, :, _G_DONE] = done
    istate[:, :, _G_SINCE] = since
    istate[:, :, _G_LOST] = lost
    istate[:, :, _G_CKPTS] = ckpts
    fstate[:, :, _G_OVERHEAD] = overhead
    fstate[:, :, _G_UNAVAIL] = unavailable
    fstate[:, :, _G_TLAST] = t_last
    fstate[:, :, _G_RESTORE] = restore_rem
    fstate[:, :, _G_WRITE] = write_rem

    # same out block every chunk step: the final write is the result
    done_ref[...] = done[..., None]
    lost_ref[...] = lost[..., None]
    ck_ref[...] = ckpts[..., None]
    oh_ref[...] = overhead[..., None]
    un_ref[...] = unavailable[..., None]


@functools.partial(
    jax.jit,
    static_argnames=("t_real", "block_p", "chunk", "interpret"),
)
def goodput_sweep_kernel(
    flags: jnp.ndarray,       # (P, Tpad) int32 packed flags (0 beyond t_real)
    nlp: jnp.ndarray,         # (P, Tpad) f negative log survival
    is_hz: jnp.ndarray,       # (S, P) int32
    fparams: jnp.ndarray,     # (S, P, 5) f — interval/δ/horizon/τ_max/floor
    scalars: jnp.ndarray,     # (1, 4) f — dt/step_time/ckpt_cost/restore_cost
    *,
    t_real: int,
    block_p: int = 8,
    chunk: int = 128,
    interpret: bool = False,
):
    """Policy-fused chunked goodput replay; bit-identical to
    ``goodput_sweep_ref``.

    Requires ``P % block_p == 0`` and ``Tpad % chunk == 0`` — use ``ops``
    for the padded general-shape wrapper.
    """
    S, P = is_hz.shape
    t_pad = flags.shape[1]
    block_p = min(block_p, P)
    chunk = min(chunk, t_pad)
    if P % block_p or t_pad % chunk:
        # a bare assert would vanish under -O and leave grid-uncovered
        # output rows silently uninitialized
        raise ValueError(
            f"P={P} / T={t_pad} not divisible by block_p={block_p} / "
            f"chunk={chunk}; use ops.goodput_sweep_op for padding"
        )
    grid = (P // block_p, t_pad // chunk)
    f = nlp.dtype

    kernel = functools.partial(_goodput_kernel, chunk=chunk, t_real=t_real)
    out_shapes = [
        jax.ShapeDtypeStruct((S, P, 1), jnp.int32),  # steps done
        jax.ShapeDtypeStruct((S, P, 1), jnp.int32),  # steps lost
        jax.ShapeDtypeStruct((S, P, 1), jnp.int32),  # checkpoints
        jax.ShapeDtypeStruct((S, P, 1), f),          # overhead
        jax.ShapeDtypeStruct((S, P, 1), f),          # unavailable
    ]
    done, lost, ck, oh, un = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, chunk), lambda i, ic: (i, ic)),
            pl.BlockSpec((block_p, chunk), lambda i, ic: (i, ic)),
            pl.BlockSpec((S, block_p), lambda i, ic: (0, i)),
            pl.BlockSpec((S, block_p, 5), lambda i, ic: (0, i, 0)),
            pl.BlockSpec((1, 4), lambda i, ic: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((S, block_p, 1), lambda i, ic: (0, i, 0))] * 5,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((S, block_p, 5), f),
            pltpu.VMEM((S, block_p, 4), jnp.int32),
        ],
        interpret=interpret,
    )(flags, nlp, is_hz, fparams, scalars)
    return {
        "steps_completed": done[..., 0],
        "steps_lost": lost[..., 0],
        "checkpoints": ck[..., 0],
        "ckpt_overhead_s": oh[..., 0],
        "unavailable_s": un[..., 0],
    }
