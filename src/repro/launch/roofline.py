"""Roofline analysis from compiled HLO (no hardware required).

`compiled.cost_analysis()` on XLA:CPU counts while-loop *bodies once* —
useless for scan-over-layers models where ~all compute sits inside the
layer loop.  This module therefore parses the post-optimization HLO text
into per-computation symbol tables, extracts

* **FLOPs** — ``2 · |result| · K`` for every `dot`/`convolution`, with
  ``K`` looked up from the contracting-dim sizes of the lhs operand;
* **HBM bytes** — Σ (result + operand bytes) over *top-level* (post-
  fusion) instructions: XLA:TPU materialises fusion boundaries to HBM, so
  fusion inputs/outputs are the honest traffic proxy;
* **collective wire bytes** — per collective kind, with ring multipliers:
  all-reduce ``2(n−1)/n·bytes``, all-gather ``(n−1)/n·full``,
  reduce-scatter ``(n−1)·result``, all-to-all ``(n−1)/n``, permute ``1×``;
  group size ``n`` parsed from ``replica_groups`` (both explicit-list and
  iota ``[a,b]<=[N]`` forms);

and multiplies every computation's totals by its **loop multiplicity**,
derived from each `while` op's ``known_trip_count`` backend config
(product over nested loops; call/fusion subcomputations inherit their
callers' multiplicity).

Hardware constants (TPU v5e-class, from the assignment):
197 TFLOP/s bf16 per chip · 819 GB/s HBM · 50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "analyze_hlo", "roofline_report"]

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([\w\-]+)\(([^;]*)$"
)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s+->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s+((?:\([^)]*\))|(?:[\w\[\],]+))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes_and_elems(type_str: str) -> Tuple[int, int]:
    """Total bytes and element count of a (possibly tuple) HLO type."""
    total_b, total_e = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_b += elems * _DTYPE_BYTES[dtype]
        total_e += elems
    return total_b, total_e


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str            # raw text after the opcode's '('
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]       # value name -> type string


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        # strip /*index=N*/-style tuple comments: their '=' breaks parsing
        line = _COMMENT_RE.sub("", line)
        header = _COMP_HEADER_RE.match(line)
        if header and line.rstrip().endswith("{"):
            current = Computation(header.group(1), [], {})
            comps[current.name] = current
            for pname, ptype in _PARAM_RE.findall(header.group(2)):
                current.symbols[pname] = ptype
            continue
        if current is None:
            continue
        if line.startswith("}"):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operands = %refs before any attribute section
        args = rest.split("),")[0]
        operands = _OPERAND_RE.findall(args)
        instr = Instr(name, type_str.strip(), op, rest, operands)
        current.instrs.append(instr)
        current.symbols[name] = instr.type_str
    return comps


def _multiplicities(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Computation execution counts: loops multiply, calls inherit."""
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # breadth-first over call edges (while/call/fusion/conditional)
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            callees: List[Tuple[str, float]] = []
            if ins.op == "while":
                trip = 1.0
                t = _TRIP_RE.search(ins.rest)
                if t:
                    trip = float(t.group(1))
                for key in ("body", "condition"):
                    m = re.search(key + r"=%?([\w\.\-]+)", ins.rest)
                    if m:
                        callees.append((m.group(1), trip))
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.rest):
                    callees.append((m.group(1), 1.0))
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                if m:
                    for name in _OPERAND_RE.findall(m.group(1)):
                        callees.append((name, 1.0))
            for callee, k in callees:
                mult[callee] += mult[cname] * k
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return dict(mult)


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_b, out_e = _type_bytes_and_elems(ins.type_str)
    lhs = ins.operands[0] if ins.operands else None
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if lhs and lhs in comp.symbols and mc and mc.group(1):
        dims_m = _SHAPE_RE.search(comp.symbols[lhs])
        if dims_m and dims_m.group(2):
            dims = [int(d) for d in dims_m.group(2).split(",")]
            for ci in mc.group(1).split(","):
                idx = int(ci)
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_e * k


# ops whose results/operands plausibly cross HBM at fusion boundaries
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "reduce", "sort", "gather", "scatter",
    "transpose", "concatenate", "pad", "slice", "reverse", "select-and-scatter",
    "cholesky", "triangular-solve", "reduce-window", "bitcast-convert",
} | set(_COLLECTIVES)


def _group_size(ins: Instr, total_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(ins.rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(ins.rest)
    if m:
        return int(m.group(2))
    return total_devices


def _collective_wire_bytes(kind: str, result_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return float(n - 1) * result_bytes    # operand = n × result
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    return result_bytes                        # collective-permute


def analyze_hlo(text: str, *, total_devices: int) -> Dict[str, float]:
    """Loop-adjusted per-device FLOPs / HBM bytes / collective wire bytes."""
    comps = parse_module(text)
    entry = _entry_name(comps, text)
    mult = _multiplicities(comps, entry)

    def _callee_root(ins: Instr) -> Optional[Instr]:
        m2 = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
        if m2 and m2.group(1) in comps:
            callee = comps[m2.group(1)]
            if callee.instrs:
                return callee.instrs[-1]
        return None

    def _mem_bytes(comp: Computation, ins: Instr) -> float:
        """HBM traffic model per instruction.

        In-place ops must NOT be charged their full buffer:
        * dynamic-update-slice writes only the update slice (scan stacking
          — the dominant op in scanned models);
        * dynamic-slice reads only the slice it produces.
        Fusions are resolved through their root: a dus-rooted fusion is an
        in-place scatter into the big aliased operand."""
        out_b, _ = _type_bytes_and_elems(ins.type_str)
        op = ins.op
        root = _callee_root(ins) if op == "fusion" else None
        if op == "fusion" and root is not None and \
                root.op in ("dynamic-update-slice", "dynamic-slice"):
            op = root.op
        if op == "dynamic-slice":
            return 2.0 * out_b
        if op == "dynamic-update-slice":
            # traffic = read + write of the update slice (+ tiny indices);
            # the big buffer operand is aliased in place
            small = sum(
                _type_bytes_and_elems(comp.symbols[o])[0]
                for o in ins.operands
                if o in comp.symbols
                and _type_bytes_and_elems(comp.symbols[o])[0] < out_b
            )
            return 2.0 * small if small > 0 else out_b / 4.0
        in_b = 0.0
        for o in ins.operands:
            if o in comp.symbols:
                in_b += _type_bytes_and_elems(comp.symbols[o])[0]
        return out_b + in_b

    flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = 0.0
    coll_by_kind: Dict[str, float] = defaultdict(float)
    coll_count = 0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(comp, ins)
            if ins.op in _MEM_OPS:
                hbm_bytes += m * _mem_bytes(comp, ins)
            base = ins.op.split("-start")[0]
            if base in _COLLECTIVES:
                if ins.op.endswith("-done"):
                    continue  # counted at -start
                out_b, _ = _type_bytes_and_elems(ins.type_str)
                n = _group_size(ins, total_devices)
                wire = _collective_wire_bytes(base, out_b, n)
                coll_bytes += m * wire
                coll_by_kind[base] += m * wire
                coll_count += int(m)

    out = {
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll_bytes,
        "collective_count": coll_count,
    }
    out.update({f"coll_{k}": v for k, v in coll_by_kind.items()})
    return out


def roofline_report(
    analysis: Dict[str, float],
    *,
    model_flops_per_device: float,
    hw: Dict[str, float] = HW,
) -> Dict[str, float]:
    """The three roofline terms (seconds) + bottleneck + usefulness ratio."""
    t_compute = analysis["flops_per_device"] / hw["peak_flops"]
    t_memory = analysis["hbm_bytes_per_device"] / hw["hbm_bw"]
    t_coll = analysis["collective_bytes_per_device"] / hw["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    useful = (
        model_flops_per_device / analysis["flops_per_device"]
        if analysis["flops_per_device"] > 0 else 0.0
    )
    mfu = (
        model_flops_per_device / hw["peak_flops"] / step_time
        if step_time > 0 else 0.0
    )
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_ratio": useful,
        "roofline_fraction": mfu,   # model-useful-FLOPs utilisation bound
    }
