"""Histogram gradient-boosted trees and random forests in pure JAX.

The paper's best availability predictors are XGBoost and Random Forest
(§VI-D).  Neither library is available offline, and the project rule is to
build every substrate natively — so this module implements both on a shared
vectorised histogram-tree grower:

* features are quantile-binned to ``n_bins`` integer codes;
* trees grow level-wise to a fixed depth: per level, a (node × feature ×
  bin) gradient/hessian histogram is built with one ``segment_sum``, split
  gain is the standard second-order formula ``GL²/(HL+λ) + GR²/(HR+λ) −
  G²/(H+λ)``, and sample→node assignment advances with one gather;
* **GBDT mode** (``GradientBoostedTrees``): Newton boosting on the logistic
  loss, exactly XGBoost's formulation (g = p − y, h = p(1−p), shrinkage,
  row subsampling, per-tree feature subsampling);
* **RF mode** (``RandomForest``): each tree fits the labels directly with
  squared loss on a Poisson(1) bootstrap, predictions averaged.

Everything after binning is jit-compiled; per-round work is O(N·F) with no
data-dependent shapes, so the whole ensemble trains as one ``lax.scan``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GradientBoostedTrees", "RandomForest"]


# --------------------------------------------------------------------------
# Binning
# --------------------------------------------------------------------------

def quantile_edges(x: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature quantile bin edges, shape (F, n_bins - 1)."""
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(x, qs, axis=0).T  # (F, n_bins - 1)
    # strictly increasing edges keep searchsorted well-defined
    edges += np.arange(edges.shape[1])[None, :] * 1e-9
    return edges.astype(np.float32)


def bin_data(x: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Digitise features to integer codes in [0, n_bins-1]; (N, F) int32."""
    def one(col, e):
        return jnp.searchsorted(e, col, side="right")
    return jax.vmap(one, in_axes=(1, 0), out_axes=1)(x, edges).astype(jnp.int32)


# --------------------------------------------------------------------------
# Tree growing (shared by GBDT / RF)
# --------------------------------------------------------------------------

def _grow_tree(
    xb: jnp.ndarray,        # (N, F) int32 binned features
    g: jnp.ndarray,         # (N,) gradients
    h: jnp.ndarray,         # (N,) hessians
    feat_mask: jnp.ndarray, # (F,) float 0/1 feature subsample mask
    *,
    depth: int,
    n_bins: int,
    lam: float,
    min_child_weight: float,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Level-wise growth to fixed `depth`.

    Returns (split_feat, split_bin) of shape (depth, 2**(depth-1)) —
    padded per level — and leaf values of shape (2**depth,).
    """
    n, f = xb.shape
    max_nodes = 2 ** (depth - 1)
    node = jnp.zeros((n,), jnp.int32)
    split_feat = jnp.zeros((depth, max_nodes), jnp.int32)
    split_bin = jnp.zeros((depth, max_nodes), jnp.int32)

    feat_ids = jnp.arange(f, dtype=jnp.int32)[None, :]  # (1, F)

    for level in range(depth):
        n_nodes = 2**level
        # -- histograms: one segment_sum over N*F flattened (node,f,bin) ids
        ids = (node[:, None] * f + feat_ids) * n_bins + xb  # (N, F)
        seg = n_nodes * f * n_bins
        hist_g = jax.ops.segment_sum(
            jnp.broadcast_to(g[:, None], (n, f)).ravel(), ids.ravel(), seg
        ).reshape(n_nodes, f, n_bins)
        hist_h = jax.ops.segment_sum(
            jnp.broadcast_to(h[:, None], (n, f)).ravel(), ids.ravel(), seg
        ).reshape(n_nodes, f, n_bins)

        gl = jnp.cumsum(hist_g, axis=-1)[..., :-1]        # split "bin <= b"
        hl = jnp.cumsum(hist_h, axis=-1)[..., :-1]
        gt = hist_g.sum(-1, keepdims=True)
        ht = hist_h.sum(-1, keepdims=True)
        gr, hr = gt - gl, ht - hl

        gain = gl**2 / (hl + lam) + gr**2 / (hr + lam) - gt**2 / (ht + lam)
        ok = (hl >= min_child_weight) & (hr >= min_child_weight)
        gain = jnp.where(ok, gain, -jnp.inf)
        gain = jnp.where(feat_mask[None, :, None] > 0, gain, -jnp.inf)

        flat = gain.reshape(n_nodes, -1)                   # (nodes, F*(B-1))
        best = jnp.argmax(flat, axis=-1)
        best_f = (best // (n_bins - 1)).astype(jnp.int32)
        best_b = (best % (n_bins - 1)).astype(jnp.int32)
        # nodes with no valid split: degenerate split keeps samples together
        no_split = ~jnp.isfinite(jnp.max(flat, axis=-1))
        best_f = jnp.where(no_split, 0, best_f)
        # bin codes are <= n_bins - 1, so "fv > n_bins - 1" routes all left
        best_b = jnp.where(no_split, n_bins - 1, best_b)

        split_feat = split_feat.at[level, :n_nodes].set(best_f)
        split_bin = split_bin.at[level, :n_nodes].set(best_b)

        fv = jnp.take_along_axis(xb, best_f[node][:, None], axis=1)[:, 0]
        node = node * 2 + (fv > best_b[node]).astype(jnp.int32)

    leaf_g = jax.ops.segment_sum(g, node, 2**depth)
    leaf_h = jax.ops.segment_sum(h, node, 2**depth)
    leaf = -leaf_g / (leaf_h + lam)
    return split_feat, split_bin, leaf


def _tree_predict(
    xb: jnp.ndarray, split_feat: jnp.ndarray, split_bin: jnp.ndarray, leaf: jnp.ndarray
) -> jnp.ndarray:
    """Route (N, F) binned samples through one tree; returns (N,) values."""
    n = xb.shape[0]
    node = jnp.zeros((n,), jnp.int32)
    depth = split_feat.shape[0]
    for level in range(depth):
        f = split_feat[level][node]
        b = split_bin[level][node]
        fv = jnp.take_along_axis(xb, f[:, None], axis=1)[:, 0]
        node = node * 2 + (fv > b).astype(jnp.int32)
    return leaf[node]


# --------------------------------------------------------------------------
# Boosted ensemble
# --------------------------------------------------------------------------

@partial(
    jax.jit,
    static_argnames=(
        "n_rounds", "depth", "n_bins", "lam", "min_child_weight",
        "learning_rate", "subsample", "colsample", "mode",
    ),
)
def _fit_ensemble(
    xb, y, w, key, *, n_rounds, depth, n_bins, lam, min_child_weight,
    learning_rate, subsample, colsample, mode,
):
    n, f = xb.shape
    y = y.astype(jnp.float32)

    pos = jnp.clip((w * y).sum() / w.sum(), 1e-6, 1 - 1e-6)
    f0 = jnp.log(pos / (1 - pos)) if mode == "gbdt" else 0.0
    margin0 = jnp.full((n,), f0, jnp.float32)

    def round_fn(carry, key_r):
        margin = carry
        k1, k2 = jax.random.split(key_r)
        if mode == "gbdt":
            p = jax.nn.sigmoid(margin)
            g = (p - y) * w
            h = jnp.maximum(p * (1 - p), 1e-6) * w
            row_w = (
                jax.random.bernoulli(k1, subsample, (n,)).astype(jnp.float32)
                if subsample < 1.0 else jnp.ones((n,))
            )
        else:  # rf: squared loss around 0 -> leaf = weighted mean of y
            g = -(y * w)
            h = w
            row_w = jax.random.poisson(k1, 1.0, (n,)).astype(jnp.float32)
        g, h = g * row_w, h * row_w
        feat_mask = (
            jax.random.bernoulli(k2, colsample, (f,)).astype(jnp.float32)
            if colsample < 1.0 else jnp.ones((f,))
        )
        # guarantee at least one active feature
        feat_mask = jnp.where(feat_mask.sum() == 0, jnp.ones((f,)), feat_mask)
        sf, sb, leaf = _grow_tree(
            xb, g, h, feat_mask,
            depth=depth, n_bins=n_bins, lam=lam,
            min_child_weight=min_child_weight,
        )
        pred = _tree_predict(xb, sf, sb, leaf)
        margin = margin + (learning_rate * pred if mode == "gbdt" else 0.0)
        return margin, (sf, sb, leaf)

    keys = jax.random.split(key, n_rounds)
    _, trees = jax.lax.scan(round_fn, margin0, keys)
    return f0, trees


@partial(jax.jit, static_argnames=("mode", "learning_rate"))
def _predict_ensemble(xb, f0, trees, *, mode, learning_rate):
    sf, sb, leaf = trees

    def one(carry, tree):
        sfi, sbi, leafi = tree
        return carry + _tree_predict(xb, sfi, sbi, leafi), None

    total, _ = jax.lax.scan(one, jnp.zeros((xb.shape[0],)), (sf, sb, leaf))
    if mode == "gbdt":
        return jax.nn.sigmoid(f0 + learning_rate * total)
    return total / sf.shape[0]  # rf: mean leaf value == P(y=1)


@dataclasses.dataclass
class _TreeEnsemble:
    mode: str = "gbdt"
    n_rounds: int = 60
    depth: int = 4
    n_bins: int = 64
    lam: float = 1.0
    min_child_weight: float = 1.0
    learning_rate: float = 0.2
    subsample: float = 1.0
    colsample: float = 1.0
    seed: int = 0
    class_weight: bool = True
    # fitted state
    edges: np.ndarray = None
    f0: float = None
    trees: Tuple = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "_TreeEnsemble":
        x = np.asarray(x, np.float32)
        y = np.asarray(y)
        self.edges = quantile_edges(x, self.n_bins)
        xb = bin_data(jnp.asarray(x), jnp.asarray(self.edges))
        if self.class_weight:
            from ._train import class_weights
            w = jnp.asarray(class_weights(y))
        else:
            w = jnp.ones((len(y),), jnp.float32)
        self.f0, self.trees = _fit_ensemble(
            xb, jnp.asarray(y), w, jax.random.PRNGKey(self.seed),
            n_rounds=self.n_rounds, depth=self.depth, n_bins=self.n_bins,
            lam=self.lam, min_child_weight=self.min_child_weight,
            learning_rate=self.learning_rate, subsample=self.subsample,
            colsample=self.colsample, mode=self.mode,
        )
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        xb = bin_data(jnp.asarray(np.asarray(x, np.float32)), jnp.asarray(self.edges))
        return np.asarray(
            _predict_ensemble(
                xb, self.f0, self.trees,
                mode=self.mode, learning_rate=self.learning_rate,
            )
        )

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)


@dataclasses.dataclass
class GradientBoostedTrees(_TreeEnsemble):
    """XGBoost-style second-order boosting (the paper's primary model)."""

    mode: str = "gbdt"
    subsample: float = 0.8


@dataclasses.dataclass
class RandomForest(_TreeEnsemble):
    """Bootstrap-aggregated histogram trees."""

    mode: str = "rf"
    n_rounds: int = 50
    depth: int = 5
    colsample: float = 0.8
    learning_rate: float = 1.0
