"""Algorithm 1 (SR/UR/CUT) — unit, equivalence, and hypothesis properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.features import (
    compute_features,
    init_fleet_state,
    init_state,
    update,
    update_batch,
)


def stream(s_seq, n, window, dt):
    state = init_state(n, window, dt)
    rows = []
    for s_t in s_seq:
        state, feats = update(state, s_t)
        rows.append(feats)
    return np.asarray(rows)


class TestAlgorithm1:
    def test_sr_is_ratio(self):
        out = stream([10, 5, 0], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 0], [1.0, 0.5, 0.0])

    def test_ur_partial_window(self):
        # paper lines 7-8: before the window fills, divide by t*N
        out = stream([5, 5], n=10, window=30, dt=3)  # w = 10 cycles
        np.testing.assert_allclose(out[:, 1], [0.5, 0.5])

    def test_ur_full_window_slides(self):
        # w=2: UR over the last 2 cycles only
        out = stream([0, 0, 10, 10], n=10, window=6, dt=3)
        np.testing.assert_allclose(out[:, 1], [1.0, 1.0, 0.5, 0.0])

    def test_cut_resets_on_full_fulfilment(self):
        out = stream([10, 4, 4, 10, 4], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 2], [0.0, 3.0, 6.0, 0.0, 3.0])

    def test_cut_zero_at_first_cycle_even_if_unfulfilled(self):
        # Algorithm 1 line 10: t == 1 forces CUT = 0
        out = stream([0, 0], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 2], [0.0, 3.0])

    def test_rejects_out_of_range(self):
        state = init_state(10, 30, 3)
        with pytest.raises(ValueError):
            update(state, 11)
        with pytest.raises(ValueError):
            update(state, -1)


class TestBatchEquivalence:
    @given(
        s=st.lists(st.integers(0, 10), min_size=1, max_size=200),
        w_cycles=st.integers(1, 50),
    )
    @settings(max_examples=60, deadline=None)
    def test_batch_matches_streaming(self, s, w_cycles):
        dt = 3.0
        batch = compute_features(np.array(s), 10, w_cycles * dt, dt)
        streamed = stream(s, 10, w_cycles * dt, dt)
        np.testing.assert_allclose(batch, streamed, atol=1e-12)

    def test_multi_pool_shape(self):
        s = np.random.default_rng(0).integers(0, 11, size=(7, 50))
        out = compute_features(s, 10, 30, 3)
        assert out.shape == (7, 50, 3)
        # each pool independently equals its own streaming result
        for p in range(7):
            np.testing.assert_allclose(out[p], stream(s[p], 10, 30, 3))


class TestProperties:
    @given(s=st.lists(st.integers(0, 10), min_size=2, max_size=120))
    @settings(max_examples=40, deadline=None)
    def test_ranges(self, s):
        out = compute_features(np.array(s), 10, 30, 3)
        sr, ur, cut = out[:, 0], out[:, 1], out[:, 2]
        assert ((0 <= sr) & (sr <= 1)).all()
        assert ((0 <= ur) & (ur <= 1)).all()
        assert (cut >= 0).all()
        # CUT is bounded by elapsed time
        assert (cut <= np.arange(len(s)) * 3.0 + 1e-9).all()

    @given(s=st.lists(st.integers(0, 10), min_size=2, max_size=120))
    @settings(max_examples=40, deadline=None)
    def test_all_success_trace_is_flat_zero(self, s):
        full = np.full(len(s), 10)
        out = compute_features(full, 10, 30, 3)
        np.testing.assert_allclose(out[:, 0], 1.0)
        np.testing.assert_allclose(out[:, 1], 0.0)
        np.testing.assert_allclose(out[:, 2], 0.0)

    @given(
        s=st.lists(st.integers(0, 10), min_size=12, max_size=120),
        w=st.integers(2, 10),
    )
    @settings(max_examples=40, deadline=None)
    def test_ur_is_window_mean_of_failure_rate(self, s, w):
        # UR over a full window must equal the mean per-cycle failure ratio
        arr = np.array(s)
        out = compute_features(arr, 10, w * 3.0, 3.0)
        fail = 1.0 - arr / 10.0
        for t in range(w - 1, len(arr)):
            expected = fail[t - w + 1 : t + 1].mean()
            np.testing.assert_allclose(out[t, 1], expected, atol=1e-12)


class TestFleetBatchUpdate:
    """update_batch ≡ per-pool scalar update — bit-identical, cycle by cycle."""

    @given(
        pools=st.integers(1, 9),
        t_max=st.integers(1, 80),
        w_cycles=st.integers(1, 20),
        n=st.integers(1, 12),
    )
    @settings(max_examples=40, deadline=None)
    def test_bit_identical_to_scalar_update(self, pools, t_max, w_cycles, n):
        rng = np.random.default_rng(pools * 1000 + t_max)
        s = rng.integers(0, n + 1, size=(pools, t_max))
        dt = 3.0
        fleet = init_fleet_state(pools, n, w_cycles * dt, dt)
        scalar = [init_state(n, w_cycles * dt, dt) for _ in range(pools)]
        for t in range(t_max):
            fleet, batch_rows = update_batch(fleet, s[:, t])
            for p in range(pools):
                scalar[p], row = update(scalar[p], int(s[p, t]))
                assert batch_rows[p].tolist() == list(row)

    @given(
        pools=st.integers(1, 8),
        t_max=st.integers(1, 60),
        w_cycles=st.integers(1, 15),
    )
    @settings(max_examples=30, deadline=None)
    def test_matches_batch_replay(self, pools, t_max, w_cycles):
        n, dt = 10, 3.0
        rng = np.random.default_rng(pools + 31 * t_max)
        s = rng.integers(0, n + 1, size=(pools, t_max))
        state = init_fleet_state(pools, n, w_cycles * dt, dt)
        streamed = np.empty((pools, t_max, 3))
        for t in range(t_max):
            state, streamed[:, t] = update_batch(state, s[:, t])
        replay = compute_features(s, n, w_cycles * dt, dt)
        np.testing.assert_array_equal(streamed, replay)

    def test_rejects_bad_shape_and_range(self):
        state = init_fleet_state(3, 10, 30, 3)
        with pytest.raises(ValueError):
            update_batch(state, np.array([1, 2]))          # wrong fleet size
        with pytest.raises(ValueError):
            update_batch(state, np.array([1, 2, 11]))      # S_t > N
        with pytest.raises(ValueError):
            update_batch(state, np.array([1, -1, 3]))      # S_t < 0
        with pytest.raises(ValueError):
            update_batch(state, np.array([1.0, np.nan, 3.0]))  # collector gap
        with pytest.raises(ValueError):
            init_fleet_state(0, 10, 30, 3)                 # empty fleet
        assert state.t == 0  # rejected cycles never touch the state
