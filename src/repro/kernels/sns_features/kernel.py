"""Batched SnS feature-replay Pallas kernel (Algorithm 1 at fleet scale).

The paper's Data Pipeline updates SR/UR/CUT per pool in O(1); at
SpotLake-class collection scale (instance types × regions × AZs ≈ 10⁴
pools) the natural TPU formulation is a *batched replay*: one fused kernel
recomputes all three features for a (pool-block × T) tile entirely in
VMEM — one HBM read of the success counts, one write per feature, no
intermediate cumulative arrays in HBM.

Per pool-block tile:
* ``SR`` — elementwise scale;
* ``UR`` — prefix-sum of unfulfilled counts along T, then a shifted
  difference (the paper's cumulative-array trick, vectorised);
* ``CUT`` — running max of the last fully-fulfilled index (a `cummax`
  replaces the sequential reset-counter recurrence, an associative-scan
  rewrite of Algorithm 1 lines 10-14).

grid = (pools / block_p,);  block = (block_p, T) in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _features_kernel(s_ref, sr_ref, ur_ref, cut_ref, *, n: int, w: int, dt: float):
    s = s_ref[...].astype(jnp.float32)                       # (bp, T)
    bp, t_max = s.shape

    sr_ref[...] = s / n

    unful = n - s
    p = jnp.cumsum(unful, axis=1)                            # P[t], t >= 1
    lagged = jnp.pad(p, ((0, 0), (w, 0)))[:, :t_max]         # P[t - w] (P<=0 -> 0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bp, t_max), 1) + 1
    wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
    ur_ref[...] = (p - lagged) / (wlen * n)

    idx = jax.lax.broadcasted_iota(jnp.int32, (bp, t_max), 1)
    full = (s == n) | (idx == 0)
    last_full = jax.lax.cummax(jnp.where(full, idx, -1), axis=1)
    cut_ref[...] = (idx - last_full).astype(jnp.float32) * dt


@functools.partial(jax.jit, static_argnames=("n", "w", "dt", "block_p", "interpret"))
def sns_features(
    s: jnp.ndarray,        # (pools, T) int32
    *,
    n: int,
    w: int,
    dt: float,
    block_p: int = 8,
    interpret: bool = False,
):
    pools, t_max = s.shape
    block_p = min(block_p, pools)
    assert pools % block_p == 0
    grid = (pools // block_p,)

    kernel = functools.partial(_features_kernel, n=n, w=w, dt=dt)
    out_shape = jax.ShapeDtypeStruct((pools, t_max), jnp.float32)
    sr, ur, cut = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_p, t_max), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_p, t_max), lambda i: (i, 0))] * 3,
        out_shape=[out_shape] * 3,
        interpret=interpret,
    )(s)
    return jnp.stack([sr, ur, cut], axis=-1)
