"""Pallas kernels vs pure-jnp oracles — interpret-mode allclose sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.features import compute_features
from repro.kernels.sns_features.kernel import sns_features, sns_features_stream
from repro.kernels.sns_features.ops import sns_features_stream_op
from repro.kernels.sns_features.ref import sns_features_ref, sns_features_stream_ref

RNG = np.random.default_rng(0)


class TestSnSFeatures:
    @pytest.mark.parametrize("pools,t,w", [(8, 64, 10), (16, 128, 32), (4, 480, 160)])
    def test_matches_ref_and_core(self, pools, t, w):
        s = jnp.asarray(RNG.integers(0, 11, size=(pools, t)), jnp.int32)
        out = sns_features(s, n=10, w=w, dt=3.0, block_p=4, interpret=True)
        ref = sns_features_ref(s, 10, w, 3.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        # and both must match the production numpy pipeline (Algorithm 1)
        core = compute_features(np.asarray(s), 10, w * 3.0, 3.0)
        np.testing.assert_allclose(np.asarray(out), core, atol=1e-5)

    def test_block_size_independence(self):
        s = jnp.asarray(RNG.integers(0, 11, size=(16, 96)), jnp.int32)
        o1 = sns_features(s, n=10, w=8, dt=3.0, block_p=2, interpret=True)
        o2 = sns_features(s, n=10, w=8, dt=3.0, block_p=16, interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))


class TestSnSFeaturesStream:
    """Chunked streaming kernel — carry state across time-chunks must be
    invisible: bit-identical to the full-trace kernel / jnp carry-scan,
    and equal to the float64 numpy replay of Algorithm 1."""

    @pytest.mark.parametrize(
        "pools,t,w,chunk",
        [
            (8, 64, 10, 16),    # w < chunk
            (8, 128, 32, 16),   # w > chunk (tail spans multiple carries)
            (4, 480, 160, 96),  # paper-scale window
            (8, 96, 8, 96),     # single chunk == full trace
            (8, 40, 50, 8),     # whole trace inside the partial window
        ],
    )
    def test_stream_kernel_bit_identical_to_full(self, pools, t, w, chunk):
        s = jnp.asarray(RNG.integers(0, 11, size=(pools, t)), jnp.int32)
        full = sns_features(s, n=10, w=w, dt=3.0, block_p=4, interpret=True)
        strm = sns_features_stream(
            s, n=10, w=w, dt=3.0, block_p=4, chunk=chunk, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(strm), np.asarray(full))
        ref = sns_features_stream_ref(s, 10, w, 3.0, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(strm), np.asarray(ref))

    def test_cut_reset_exactly_at_chunk_boundary(self):
        """A fully-fulfilled cycle landing on the first column of a chunk
        must reset CUT through the carry, not stale state."""
        n, w, chunk = 10, 4, 8
        s = np.zeros((2, 32), np.int64)
        s[0, 8] = n    # reset at a chunk boundary
        s[1, 15] = n   # reset at the last column of a chunk
        out = sns_features_stream_op(
            s, n=n, window_minutes=w * 3.0, dt_minutes=3.0, chunk=chunk,
            backend="jnp",
        )
        core = compute_features(s, n, w * 3.0, 3.0)
        np.testing.assert_array_equal(np.asarray(out), core.astype(np.float32))
        assert float(out[0, 8, 2]) == 0.0 and float(out[1, 15, 2]) == 0.0

    def test_ragged_t_and_pools_padding(self):
        """ops wrapper: T % chunk != 0 and pools % block_p != 0."""
        s = RNG.integers(0, 11, size=(5, 101))
        core = compute_features(s, 10, 21.0, 3.0)
        for backend in ("jnp", "pallas"):
            out = sns_features_stream_op(
                s, n=10, window_minutes=21.0, dt_minutes=3.0,
                block_p=4, chunk=16, backend=backend,
            )
            assert out.shape == (5, 101, 3)
            np.testing.assert_allclose(np.asarray(out), core, atol=1e-6)

    def test_bit_identical_atol0_to_compute_features(self):
        """Acceptance: with exactly-representable params (N and window
        power-of-two, dt = 3.0) the f32 streaming kernel equals the f64
        numpy replay bit-for-bit after the cast — atol=0, both backends."""
        n, w = 8, 16
        s = RNG.integers(0, n + 1, size=(8, 200))
        core = compute_features(s, n, w * 3.0, 3.0).astype(np.float32)
        for backend in ("jnp", "pallas"):
            out = sns_features_stream_op(
                s, n=n, window_minutes=w * 3.0, dt_minutes=3.0,
                chunk=48, backend=backend,
            )
            np.testing.assert_array_equal(np.asarray(out), core)

    @given(
        pools=st.integers(1, 6),
        t_max=st.integers(1, 70),
        w_cycles=st.integers(1, 20),
        n=st.integers(1, 12),
        chunk=st.integers(1, 80),
        dt=st.sampled_from([0.5, 1.0, 3.0]),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_stream_equals_algorithm1(
        self, pools, t_max, w_cycles, n, chunk, dt
    ):
        """Random (n, w, dt, T, chunk_T) incl. T % chunk != 0 and t < w:
        jnp carry-scan ≡ Pallas chunked kernel (bit-identical) ≡ float64
        replay (f32 round-off)."""
        rng = np.random.default_rng(pools * 7919 + t_max * 13 + chunk)
        s = rng.integers(0, n + 1, size=(pools, t_max))
        kw = dict(
            n=n, window_minutes=w_cycles * dt, dt_minutes=dt, chunk=chunk,
            block_p=4,
        )
        out_jnp = sns_features_stream_op(s, backend="jnp", **kw)
        out_pl = sns_features_stream_op(s, backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(out_pl), np.asarray(out_jnp))
        core = compute_features(s, n, w_cycles * dt, dt)
        np.testing.assert_allclose(np.asarray(out_jnp), core, atol=1e-5)
