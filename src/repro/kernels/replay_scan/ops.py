"""Public entry points for the scan-form lock-step replay.

``replay_sweep_op`` is the fused multi-strategy form: it takes the
normalised batch inputs prepared by ``repro.core.simulate`` — shared
broadcast availability, the per-strategy stacked prefix sums ``cums``
``(S, B, Q+1)``, and the "predicted unavailable" mask — and replays every
trace row through **all S strategies in one pass** on the selected
backend (each availability column is loaded once and broadcast through
the ``(S, B)`` state planes).  ``replay_scan_op`` is the single-strategy
wrapper (``S == 1``) used by ``replay_batch``.

Backends:

* ``"jnp"``    — the ``lax.scan`` reference (the fast CPU path).  Rows
  are embarrassingly parallel, so with more than one visible device the
  batch axis is ``shard_map``-ped over a 1-D ``("traces",)`` mesh
  (``repro.launch.mesh.make_trace_mesh``) — one jitted device call, zero
  cross-device collectives, bit-identical to the unsharded scan by
  construction (rows are padded up to a shard multiple with inert
  all-unavailable rows and sliced off).
* ``"pallas"`` — the chunked strategy-fused Pallas kernel (interpret
  mode off-TPU).  Handles ragged shapes by padding cycles (``avail = 0``
  beyond the real trace, masked inert inside the kernel) and rows
  (sliced off).
* ``"auto"``   — Pallas on TPU, scan elsewhere.

Precision tiers: the dtype of ``cum`` / ``cums`` selects the tier.
float64 inputs run under a scoped ``enable_x64`` context (so importing
this module never flips global JAX precision) — the atol=0 house
contract.  float32 inputs run the same op sequence in f32 end to end —
the bandwidth-lean fast tier (``precision="f32"`` upstream); on
1/32-second-quantised workloads with bounded magnitudes every f32
quantity is exactly representable, so even the f32 tier reproduces the
f64 oracle bit for bit (asserted in ``benchmarks/replay_throughput`` and
``tests/test_replay_scan``).
"""

from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

import numpy as np

__all__ = ["replay_scan_op", "replay_sweep_op"]

#: jitted shard_map sweeps, keyed on (shards, use_pred, window, unroll) —
#: shapes and the queue length are traced, so one entry serves every
#: workload on the same mesh
_MESH_CACHE = {}


def _x64_if(dtype):
    if np.dtype(dtype) == np.float64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def _mesh_sweep(n_shards: int, use_pred: tuple, window: int, unroll: int):
    """The trace-sharded sweep: ``jit(shard_map(replay_sweep_ref))`` over
    a 1-D ``("traces",)`` mesh, built once per (shards, static-config)."""
    key = (n_shards, use_pred, window, unroll)
    fn = _MESH_CACHE.get(key)
    if fn is None:
        import jax
        from jax.sharding import PartitionSpec as PS

        from ...launch.mesh import make_trace_mesh
        from ...models.common import shard_map
        from .ref import replay_sweep_ref

        mesh = make_trace_mesh(n_shards)

        def run(avail_t, predz_t, cums_pad, dt, horizon_cycles, q):
            return replay_sweep_ref(
                avail_t, predz_t, cums_pad, dt, horizon_cycles,
                q=q, use_pred=use_pred, window=window, unroll=unroll,
            )

        traces = PS(None, "traces")
        fn = jax.jit(
            shard_map(
                run,
                mesh=mesh,
                in_specs=(
                    traces, traces, PS(None, "traces", None),
                    PS(), PS(), PS(),
                ),
                out_specs=traces,
            )
        )
        _MESH_CACHE[key] = fn
    return fn


def replay_sweep_op(
    avail: np.ndarray,            # (B, T) bool — shared by every strategy
    cums: np.ndarray,             # (S, B, Q+1) float prefix sums per strategy
    pred_zero: Optional[np.ndarray],  # (B, T) bool or None
    use_pred,                     # (S,) per-strategy Predict-AR flags
    *,
    dt: float,
    horizon_cycles: int,
    backend: str = "auto",
    block_b: int = 8,
    chunk: int = 128,
    window: int = 8,
    unroll: int = 1,
    shards=None,
) -> List[Dict[str, np.ndarray]]:
    """Fused sweep; returns one ``replay_batch`` metric dict per strategy.

    ``shards`` controls the trace-axis mesh on the scan backend:
    ``None`` / ``"auto"`` shards across all visible devices (single
    device: plain unsharded scan), an int pins the mesh size (must not
    exceed the visible device count).
    """
    import jax

    if backend == "auto":
        # the Mosaic kernel has no float64 support: f64 contracts stay on
        # the bit-identical scan even on TPU (pass f32 inputs — or request
        # backend="pallas" explicitly — for the native kernel path)
        on_tpu = jax.default_backend() == "tpu"
        f64 = np.dtype(cums.dtype) == np.float64
        backend = "pallas" if on_tpu and not f64 else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")

    avail = np.asarray(avail, dtype=bool)
    B, T = avail.shape
    S, Q = cums.shape[0], cums.shape[2] - 1
    use_pred = tuple(bool(u) for u in use_pred)
    if len(use_pred) != S:
        raise ValueError(f"{len(use_pred)} use_pred flags for {S} planes")
    any_pred = pred_zero is not None and any(use_pred)
    predz = (
        np.asarray(pred_zero, dtype=bool)
        if any_pred
        else np.zeros((B, T), dtype=bool)
    )
    if any(use_pred) and pred_zero is None:
        raise ValueError("use_pred flags set but pred_zero is None")

    if backend == "jnp":
        import jax.numpy as jnp

        from .ref import replay_sweep_ref

        pad = np.full((S, B, window + 1), np.inf, dtype=cums.dtype)
        cums_pad = np.concatenate([cums, pad], axis=2)
        n_dev = len(jax.devices())
        if shards in (None, "auto"):
            n_shards = min(n_dev, B) if n_dev > 1 else 1
        else:
            n_shards = int(shards)
            if n_shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            if n_shards > n_dev:
                raise ValueError(
                    f"shards={n_shards} exceeds the {n_dev} visible "
                    "device(s) — the trace mesh is one shard per device"
                )
            n_shards = min(n_shards, B)
        with _x64_if(cums.dtype):
            if n_shards == 1:
                res = replay_sweep_ref(
                    jnp.asarray(avail.T), jnp.asarray(predz.T),
                    jnp.asarray(cums_pad), dt, horizon_cycles,
                    q=Q, use_pred=use_pred, window=window, unroll=unroll,
                )
                res = {k: np.asarray(v) for k, v in res.items()}
            else:
                # pad the trace axis up to a shard multiple with inert
                # rows (never available -> the scan body never acts on
                # them), then slice the padding back off
                pad_b = (-B) % n_shards
                if pad_b:
                    avail = np.concatenate(
                        [avail, np.zeros((pad_b, T), dtype=bool)]
                    )
                    predz = np.concatenate(
                        [predz, np.zeros((pad_b, T), dtype=bool)]
                    )
                    cums_pad = np.concatenate(
                        [cums_pad,
                         np.full((S, pad_b, cums_pad.shape[2]), np.inf,
                                 dtype=cums_pad.dtype)], axis=1
                    )
                fn = _mesh_sweep(n_shards, use_pred, window, unroll)
                res = fn(
                    jnp.asarray(avail.T), jnp.asarray(predz.T),
                    jnp.asarray(cums_pad), dt, horizon_cycles, Q,
                )
                res = {k: np.asarray(v)[:, :B] for k, v in res.items()}
    else:
        import jax.numpy as jnp

        from .kernel import replay_sweep_kernel

        block_b = min(block_b, B)
        chunk = min(chunk, T)
        pad_b = (-B) % block_b
        pad_t = (-T) % chunk
        av = np.zeros((B + pad_b, T + pad_t), dtype=np.int32)
        av[:B, :T] = avail
        pz = np.zeros_like(av)
        pz[:B, :T] = predz
        cm = np.zeros((S, B + pad_b, Q + 1), dtype=cums.dtype)
        cm[:, :B] = cums
        with _x64_if(cums.dtype):
            res = replay_sweep_kernel(
                jnp.asarray(av),
                jnp.asarray(pz),
                jnp.asarray(cm),
                dt=dt,
                horizon_cycles=horizon_cycles,
                t_real=T,
                use_pred=use_pred,
                block_b=block_b,
                chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
            res = {k: np.asarray(v)[:, :B] for k, v in res.items()}

    return [
        {
            "lost_seconds": res["lost_seconds"][s],
            "idle_seconds": res["idle_seconds"][s],
            "completed": res["completed"][s].astype(np.int64),
            "total_queries": np.full(B, Q, dtype=np.int64),
            "makespan_seconds": res["makespan_seconds"][s],
        }
        for s in range(S)
    ]


def replay_scan_op(
    avail: np.ndarray,            # (B, T) bool
    dur: np.ndarray,              # (B, Q) float, launch order
    cum: np.ndarray,              # (B, Q+1) float prefix sums of dur
    pred_zero: Optional[np.ndarray],  # (B, T) bool or None
    *,
    dt: float,
    horizon_cycles: int,
    backend: str = "auto",
    block_b: int = 8,
    chunk: int = 128,
    window: int = 8,
    unroll: int = 1,
    shards=None,
) -> Dict[str, np.ndarray]:
    """Single-strategy replay (the ``S == 1`` plane of the fused sweep);
    returns the ``replay_batch`` metric dict."""
    use_pred = pred_zero is not None
    (res,) = replay_sweep_op(
        avail, np.asarray(cum)[None], pred_zero, (use_pred,),
        dt=dt, horizon_cycles=horizon_cycles, backend=backend,
        block_b=block_b, chunk=chunk, window=window, unroll=unroll,
        shards=shards,
    )
    return res
