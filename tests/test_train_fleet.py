"""Training runtime: optimizer, train step, checkpointing, fleet policies."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.fleet import (
    FixedInterval,
    SnSHazard,
    YoungDaly,
    run_replay,
    traces_from_campaign,
)
from repro.models import api
from repro.train import (
    OptConfig,
    init_opt_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    schedule,
    synthetic_batch,
)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen3-8b").scaled_down()
    params = api.init_params(cfg, seed=0)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    opt_state = init_opt_state(params)
    return cfg, params, opt_cfg, opt_state


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        assert float(schedule(cfg, jnp.asarray(0))) < 0.2
        peak = float(schedule(cfg, jnp.asarray(10)))
        assert peak > 0.9
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)

    def test_loss_decreases(self, tiny_setup):
        cfg, params, opt_cfg, opt_state = tiny_setup
        step = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
        batch = synthetic_batch(cfg, batch=4, seq=32, seed=0)
        losses = []
        for _ in range(8):
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses

    def test_grad_accum_matches_full_batch(self, tiny_setup):
        cfg, params, opt_cfg, _ = tiny_setup
        batch = synthetic_batch(cfg, batch=4, seq=16, seed=1)
        s1 = make_train_step(cfg, opt_cfg, grad_accum=1, remat="none")
        s2 = make_train_step(cfg, opt_cfg, grad_accum=2, remat="none")
        p1, _, m1 = s1(params, init_opt_state(params), batch)
        p2, _, m2 = s2(params, init_opt_state(params), batch)
        np.testing.assert_allclose(
            float(m1["loss"]), float(m2["loss"]), rtol=1e-5
        )
        l1, l2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5
            )

    def test_remat_matches_no_remat(self, tiny_setup):
        cfg, params, opt_cfg, _ = tiny_setup
        batch = synthetic_batch(cfg, batch=2, seq=16, seed=2)
        m_no = make_train_step(cfg, opt_cfg, remat="none")(
            params, init_opt_state(params), batch
        )[2]
        m_full = make_train_step(cfg, opt_cfg, remat="full")(
            params, init_opt_state(params), batch
        )[2]
        np.testing.assert_allclose(
            float(m_no["loss"]), float(m_full["loss"]), rtol=1e-5
        )


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self, tiny_setup, tmp_path):
        cfg, params, opt_cfg, opt_state = tiny_setup
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 3, params, opt_state)
        save_checkpoint(d, 7, params, opt_state)
        assert latest_step(d) == 7
        p2, o2, step = load_checkpoint(d, params, opt_state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_retention(self, tiny_setup, tmp_path):
        cfg, params, _, _ = tiny_setup
        d = str(tmp_path / "ckpt")
        for s in range(6):
            save_checkpoint(d, s, params, keep=2)
        from repro.train import list_steps
        assert list_steps(d) == [4, 5]

    def test_corruption_detected(self, tiny_setup, tmp_path):
        cfg, params, _, _ = tiny_setup
        d = str(tmp_path / "ckpt")
        path = save_checkpoint(d, 1, params)
        # flip bytes in the arrays file
        arr_file = os.path.join(path, "arrays.npz")
        data = bytearray(open(arr_file, "rb").read())
        data[200] ^= 0xFF
        open(arr_file, "wb").write(bytes(data))
        with pytest.raises(Exception):
            load_checkpoint(d, params)

    def test_resume_training_equivalence(self, tiny_setup, tmp_path):
        """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
        cfg, params, opt_cfg, _ = tiny_setup
        d = str(tmp_path / "ckpt")
        step = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
        batches = [synthetic_batch(cfg, 2, 16, seed=i) for i in range(4)]

        p, o = params, init_opt_state(params)
        for b in batches:
            p, o, m = step(p, o, b)
        straight = float(m["loss"])

        p, o = params, init_opt_state(params)
        for b in batches[:2]:
            p, o, _ = step(p, o, b)
        save_checkpoint(d, 2, p, o)
        p2, o2, _ = load_checkpoint(d, p, o)
        for b in batches[2:]:
            p2, o2, m2 = step(p2, o2, b)
        np.testing.assert_allclose(straight, float(m2["loss"]), rtol=1e-4)


class TestFleetPolicies:
    def test_young_daly_interval(self):
        yd = YoungDaly(ckpt_cost=30.0, mtbf=3600.0)
        assert yd.interval == pytest.approx((2 * 30 * 3600) ** 0.5)

    def test_hazard_interval_monotone_in_risk(self):
        pol = SnSHazard(ckpt_cost=30.0, horizon=900.0)
        assert pol.interval(0.999) > pol.interval(0.9) > pol.interval(0.5)

    def test_panic_forces_checkpoint(self):
        pol = SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=0.4)
        # panic overrides the (long) adaptive interval...
        assert pol.should_checkpoint(100.0, 0.0, p_survive=0.5)
        assert not pol.should_checkpoint(100.0, 0.0, p_survive=0.99)
        # ...but sustained panic cannot re-write faster than 2*delta
        assert not pol.should_checkpoint(59.0, 0.0, p_survive=0.5)

    def test_replay_hazard_beats_fixed(self, small_campaign):
        """SnS-guided checkpointing should lose less work than a sparse
        fixed interval on preemption-heavy traces (paper's core claim,
        applied to training)."""
        traces = traces_from_campaign(small_campaign, window_minutes=120)
        # oracle-ish predictor: availability over the next 5 cycles
        results = {}
        # calibrated heuristic predictor: healthy pools (UR <= 5%) map to
        # p_survive ~ 1 (hazard floor -> sparse checkpoints); degradation
        # ramps the hazard up quickly
        def pred(f):
            return 1.0 - min(1.0, max(0.0, (f[1] - 0.05) * 3.0))

        for name, policy, pred in [
            ("fixed_30min", FixedInterval(1800.0), None),
            (
                "sns_hazard",
                SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=0.35),
                pred,
            ),
        ]:
            tot_lost, tot_done = 0, 0
            for tr in traces:
                r = run_replay(
                    tr, policy=policy, predictor=pred, policy_name=name,
                    step_time=2.0, ckpt_cost=30.0,
                )
                tot_lost += r.steps_lost
                tot_done += r.steps_completed
            results[name] = (tot_lost, tot_done)
        lost_fixed, done_fixed = results["fixed_30min"]
        lost_sns, done_sns = results["sns_hazard"]
        assert lost_sns < lost_fixed, results
        # and the adaptive policy shouldn't pay for it with big throughput loss
        assert done_sns > 0.85 * done_fixed, results


class TestServe:
    def test_generate_shapes(self):
        from repro.serve import generate

        cfg = get_config("gemma3-1b").scaled_down()
        params = api.init_params(cfg, seed=0)
        batch = {"tokens": jnp.asarray(np.arange(24).reshape(2, 12) % cfg.vocab_size)}
        out = generate(cfg, params, batch, max_new_tokens=4)
        assert out.shape == (2, 4)
        assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

    def test_admission_controller_defers(self):
        from repro.serve import AdmissionController

        ctl = AdmissionController(
            predictor=lambda f: float(f[0]), horizon_cycles=3, threshold=0.5
        )
        assert ctl.on_cycle(0, np.array([0.9, 0, 0]))      # healthy
        assert not ctl.on_cycle(1, np.array([0.2, 0, 0]))  # risky -> defer
        assert not ctl.on_cycle(2, np.array([0.9, 0, 0]))  # still deferred
        assert not ctl.on_cycle(4, np.array([0.9, 0, 0]))
        assert ctl.on_cycle(5, np.array([0.9, 0, 0]))      # deferral over

    def test_migration_planner(self):
        from repro.serve import plan_migration

        feats = {"a": np.array([0.1]), "b": np.array([0.9]), "c": np.array([0.5])}
        pred = lambda f: float(f[0])
        assert plan_migration(feats, pred, current="a") == "b"
        assert plan_migration(feats, pred, current="b") is None
