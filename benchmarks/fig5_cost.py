"""Fig. 5: 24-hour monitoring cost — continuous vs periodic probing vs SnS."""

from __future__ import annotations

from repro.core import cost_report

from .common import paper_campaign

PAPER = {"continuous_over_sns": 249.5, "periodic_over_sns": 2.5,
         "resolution_ratio": 600.0 / 180.0}


def run():
    c = paper_campaign()
    rep = cost_report(c)
    return {
        "sns_compute_usd": round(rep.sns_compute, 4),
        "sns_serverless_usd": round(rep.sns_serverless, 2),
        "continuous_usd": round(rep.continuous, 2),
        "periodic_usd": round(rep.periodic, 2),
        "continuous_over_sns": round(rep.continuous_over_sns, 1),
        "periodic_over_sns": round(rep.periodic_over_sns, 2),
        "resolution_ratio": rep.resolution_ratio,
        "paper": PAPER,
        "note": (
            "probe compute cost is exactly $0 (requests cancelled during "
            "provisioning); deviation from the paper's 249.5x reflects "
            "their unpublished serverless deployment profile"
        ),
    }


if __name__ == "__main__":
    print(run())
