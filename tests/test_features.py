"""Algorithm 1 (SR/UR/CUT) — unit, equivalence, and hypothesis properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.features import compute_features, init_state, update


def stream(s_seq, n, window, dt):
    state = init_state(n, window, dt)
    rows = []
    for s_t in s_seq:
        state, feats = update(state, s_t)
        rows.append(feats)
    return np.asarray(rows)


class TestAlgorithm1:
    def test_sr_is_ratio(self):
        out = stream([10, 5, 0], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 0], [1.0, 0.5, 0.0])

    def test_ur_partial_window(self):
        # paper lines 7-8: before the window fills, divide by t*N
        out = stream([5, 5], n=10, window=30, dt=3)  # w = 10 cycles
        np.testing.assert_allclose(out[:, 1], [0.5, 0.5])

    def test_ur_full_window_slides(self):
        # w=2: UR over the last 2 cycles only
        out = stream([0, 0, 10, 10], n=10, window=6, dt=3)
        np.testing.assert_allclose(out[:, 1], [1.0, 1.0, 0.5, 0.0])

    def test_cut_resets_on_full_fulfilment(self):
        out = stream([10, 4, 4, 10, 4], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 2], [0.0, 3.0, 6.0, 0.0, 3.0])

    def test_cut_zero_at_first_cycle_even_if_unfulfilled(self):
        # Algorithm 1 line 10: t == 1 forces CUT = 0
        out = stream([0, 0], n=10, window=30, dt=3)
        np.testing.assert_allclose(out[:, 2], [0.0, 3.0])

    def test_rejects_out_of_range(self):
        state = init_state(10, 30, 3)
        with pytest.raises(ValueError):
            update(state, 11)
        with pytest.raises(ValueError):
            update(state, -1)


class TestBatchEquivalence:
    @given(
        s=st.lists(st.integers(0, 10), min_size=1, max_size=200),
        w_cycles=st.integers(1, 50),
    )
    @settings(max_examples=60, deadline=None)
    def test_batch_matches_streaming(self, s, w_cycles):
        dt = 3.0
        batch = compute_features(np.array(s), 10, w_cycles * dt, dt)
        streamed = stream(s, 10, w_cycles * dt, dt)
        np.testing.assert_allclose(batch, streamed, atol=1e-12)

    def test_multi_pool_shape(self):
        s = np.random.default_rng(0).integers(0, 11, size=(7, 50))
        out = compute_features(s, 10, 30, 3)
        assert out.shape == (7, 50, 3)
        # each pool independently equals its own streaming result
        for p in range(7):
            np.testing.assert_allclose(out[p], stream(s[p], 10, 30, 3))


class TestProperties:
    @given(s=st.lists(st.integers(0, 10), min_size=2, max_size=120))
    @settings(max_examples=40, deadline=None)
    def test_ranges(self, s):
        out = compute_features(np.array(s), 10, 30, 3)
        sr, ur, cut = out[:, 0], out[:, 1], out[:, 2]
        assert ((0 <= sr) & (sr <= 1)).all()
        assert ((0 <= ur) & (ur <= 1)).all()
        assert (cut >= 0).all()
        # CUT is bounded by elapsed time
        assert (cut <= np.arange(len(s)) * 3.0 + 1e-9).all()

    @given(s=st.lists(st.integers(0, 10), min_size=2, max_size=120))
    @settings(max_examples=40, deadline=None)
    def test_all_success_trace_is_flat_zero(self, s):
        full = np.full(len(s), 10)
        out = compute_features(full, 10, 30, 3)
        np.testing.assert_allclose(out[:, 0], 1.0)
        np.testing.assert_allclose(out[:, 1], 0.0)
        np.testing.assert_allclose(out[:, 2], 0.0)

    @given(
        s=st.lists(st.integers(0, 10), min_size=12, max_size=120),
        w=st.integers(2, 10),
    )
    @settings(max_examples=40, deadline=None)
    def test_ur_is_window_mean_of_failure_rate(self, s, w):
        # UR over a full window must equal the mean per-cycle failure ratio
        arr = np.array(s)
        out = compute_features(arr, 10, w * 3.0, 3.0)
        fail = 1.0 - arr / 10.0
        for t in range(w - 1, len(arr)):
            expected = fail[t - w + 1 : t + 1].mean()
            np.testing.assert_allclose(out[t, 1], expected, atol=1e-12)
