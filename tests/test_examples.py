"""Smoke-run the documented example entry points (tiny shapes) so the
quickstart paths in README.md cannot silently rot."""

import importlib.util
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_example(name):
    path = os.path.join(REPO, "examples", f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("engine", ["fleet", "sharded"])
def test_probe_campaign_smoke(engine, capsys):
    mod = load_example("probe_campaign")
    campaign = mod.main(["--pools", "6", "--hours", "2", "--engine", engine])
    assert campaign.engine == engine
    assert campaign.s.shape == (6, 40)
    out = capsys.readouterr().out
    assert "Table I" in out and "probe compute cost" in out


def test_quickstart_smoke(capsys):
    mod = load_example("quickstart")
    mod.main(pools=6, hours=6.0, train_steps=1)
    out = capsys.readouterr().out
    assert "probed 6 pools" in out
    assert "F1-macro" in out
    assert "step 0: loss" in out


@pytest.mark.parametrize("engine", ["fleet", "sharded"])
def test_serve_spot_smoke(engine, capsys):
    """The streaming serve path end to end at tiny shapes; the fleet run
    keeps the LM data plane, the sharded run is control-plane only."""
    mod = load_example("serve_spot")
    argv = ["--pools", "6", "--train-hours", "2", "--serve-hours", "1",
            "--engine", engine]
    if engine == "sharded":
        argv.append("--no-lm")
    out_dict = mod.main(argv)
    n_cycles = out_dict["result"].s.shape[1]
    assert out_dict["result"].engine == engine
    assert out_dict["served"] + out_dict["deferred"] == 2 * n_cycles
    x, y = out_dict["streamer"].matrices(5)
    assert x.shape == (6, n_cycles - 5, 3) and y.shape == (6, n_cycles - 5)
    out = capsys.readouterr().out
    assert "served" in out and "streamed dataset" in out
