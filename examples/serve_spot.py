"""Streaming serving on spot pools: cycle-at-a-time fleet admission.

The measure → featurize → predict → **decide** loop of the paper, run
online: a `CampaignPipelineStream` drives the collection campaign one
cycle at a time (any engine — fleet, scalar, or mesh-sharded), each cycle
yielding fleet-wide `(S_t, features, probs)` views; a
`FleetAdmissionController` applies Predict-AR (§VI-E) to the probability
column in one vector op — pools forecast to degrade defer NEW requests
(drain-friendly) while in-flight decodes finish undisturbed — and
`plan_migration_batch` picks the healthiest migration target from the
same scores.  A `DatasetStreamer` rides the same stream, growing
multi-horizon training data live: the loop from live campaign back to
training data, with no offline trace replay.

Run:  PYTHONPATH=src python examples/serve_spot.py
          [--pools 8] [--engine fleet|scalar|sharded] [--no-lm]
"""

import argparse

import numpy as np

from repro.core import (
    CampaignPipelineStream,
    DatasetStreamer,
    SimulatedProvider,
    batched_predict_fn,
    build_dataset,
    default_fleet,
    fit_predictor,
    run_campaign,
)
from repro.serve import FleetAdmissionController, plan_migration_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pools", type=int, default=8)
    ap.add_argument("--train-hours", type=float, default=12.0,
                    help="offline campaign used to fit the predictor")
    ap.add_argument("--serve-hours", type=float, default=5.0,
                    help="streamed serving window")
    ap.add_argument("--engine", choices=("fleet", "scalar", "sharded"),
                    default="fleet")
    ap.add_argument("--model", default="xgb")
    ap.add_argument("--window-minutes", type=float, default=240.0)
    ap.add_argument("--horizon-minutes", type=float, default=15.0)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--batch", type=int, default=2,
                    help="requests per admitted cycle")
    ap.add_argument("--no-lm", action="store_true",
                    help="control-plane only: skip the LM data plane")
    args = ap.parse_args(argv)

    # -- control plane: fit the SnS predictor on an offline campaign ------
    fleet = default_fleet(args.pools, seed=5)
    campaign = run_campaign(
        SimulatedProvider(fleet, seed=6), duration=args.train_hours * 3600.0
    )
    ds = build_dataset(campaign, window_minutes=args.window_minutes,
                       horizon_minutes=args.horizon_minutes)
    model = fit_predictor(args.model, ds)
    std = ds.standardizer
    raw = batched_predict_fn(model)
    p_stay = (lambda x: raw(std(x))) if std is not None else raw
    horizon_cycles = max(1, int(round(args.horizon_minutes * 60.0
                                      / campaign.interval)))

    # -- data plane: a small serving model --------------------------------
    if not args.no_lm:
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.models import api
        from repro.serve import generate

        cfg = get_config("qwen3-8b").scaled_down()
        params = api.init_params(cfg, seed=0)
    rng = np.random.default_rng(0)

    # -- streaming serve loop: ONE predict + ONE decide op per cycle ------
    stream = CampaignPipelineStream(
        SimulatedProvider(fleet, seed=7),     # live campaign, unseen seed
        predict_fn=p_stay,
        window_minutes=args.window_minutes,
        duration=args.serve_hours * 3600.0,
        engine=args.engine,
    )
    ctl = FleetAdmissionController(
        args.pools, horizon_cycles=horizon_cycles, threshold=args.threshold
    )
    streamer = DatasetStreamer(campaign.n, tuple(sorted({1, horizon_cycles})))
    current = 0                               # pool currently serving
    served = deferred = migrations = 0
    for view in stream:
        streamer.ingest(view)                 # grow training data live
        admit = ctl.on_cycle(view.cycle, view.probs)
        if admit[current]:
            if not args.no_lm:
                prompts = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (args.batch, 12)),
                    jnp.int32,
                )
                out = generate(cfg, params, {"tokens": prompts},
                               max_new_tokens=4)
                assert out.shape == (args.batch, 4)
            served += args.batch
        else:
            deferred += args.batch
            # degraded: migrate to the healthiest pool by live scores
            target = plan_migration_batch(view.probs, current)
            if target is not None:
                current = target
                migrations += 1

    result = stream.result()
    print(f"served {served} requests, deferred {deferred}, "
          f"{migrations} pool migrations (engine={result.engine})")
    x, y = streamer.matrices(horizon_cycles)
    print(f"streamed dataset: X{x.shape} y{y.shape} at h={horizon_cycles} "
          f"cycles ({int(y.sum())} positive labels)")
    return {
        "served": served,
        "deferred": deferred,
        "migrations": migrations,
        "result": result,
        "streamer": streamer,
    }


if __name__ == "__main__":
    main()
