"""jamba-v0.1-52b — hybrid Mamba + attention with MoE.

[arXiv:2403.19887; hf] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2.  Mamba:attention 7:1 interleave (one attention
layer per 8, offset 4) and MoE on every other layer (offset 1); no
positional embeddings (attention is NoPE).  The layer stack runs as a scan
over 4 super-blocks of 8 structurally distinct positions.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    d_conv=4,
    expand=2,
    use_rope=False,         # jamba uses no positional encoding
    norm="rmsnorm",
    gated_mlp=True,
    source="arXiv:2403.19887; hf",
)
