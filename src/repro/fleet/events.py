"""Fleet events: provider availability traces → pod up/down event streams.

The data plane trains on TPU pods that are spot capacity: one capacity
pool per pod (a pod slice = the paper's "node pool", where any lost host
kills the slice — the binary availability formulation maps exactly).  This
module converts per-pool binary availability traces into the pod
preemption/restore events the elastic runner consumes, plus SnS feature
streams for the hazard-adaptive checkpoint policy.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collector import CampaignResult
from repro.core.features import compute_features
from repro.core.labels import binary_availability

__all__ = ["PodEvent", "PodTrace", "traces_from_campaign"]


@dataclasses.dataclass(frozen=True)
class PodEvent:
    time: float
    pod_id: int
    up: bool


@dataclasses.dataclass
class PodTrace:
    """One pod's availability over a campaign, with its SnS features."""

    pod_id: int
    pool_id: str
    times: np.ndarray        # (T,) seconds
    available: np.ndarray    # (T,) {0,1} — ground truth (running == N)
    features: np.ndarray     # (T, 3) — SR/UR/CUT from SnS probes
    dt: float                # collection interval (seconds)

    def events(self) -> List[PodEvent]:
        out = []
        prev = True  # pods assumed up at t=0; first down edge emits an event
        for t, a in zip(self.times, self.available.astype(bool)):
            if a != prev:
                out.append(PodEvent(float(t), self.pod_id, bool(a)))
                prev = a
        return out


def traces_from_campaign(
    result: CampaignResult,
    *,
    n_pods: Optional[int] = None,
    window_minutes: float = 480.0,
) -> List[PodTrace]:
    """Map the first `n_pods` pools of a campaign onto pods.

    Pools are sliced to ``n_pods`` *before* featurization — per-pool
    features are row-independent (Algorithm 1 runs per pool), so
    featurizing only the kept rows is identical to featurizing the whole
    campaign and slicing after, at a fraction of the work.
    """
    n_pods = n_pods if n_pods is not None else len(result.pool_ids)
    n_pods = min(n_pods, len(result.pool_ids))
    avail = binary_availability(result.running[:n_pods], result.n)
    feats = compute_features(
        result.s[:n_pods], result.n, window_minutes, result.interval / 60.0
    )
    out = []
    for pod in range(n_pods):
        out.append(
            PodTrace(
                pod_id=pod,
                pool_id=result.pool_ids[pod],
                times=result.times,
                available=avail[pod],
                features=feats[pod],
                dt=result.interval,
            )
        )
    return out
