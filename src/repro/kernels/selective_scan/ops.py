"""Public entry point for the selective-scan kernel (auto-interpret off-TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import selective_scan

__all__ = ["selective_scan_op"]


def selective_scan_op(x, dt, a, b, c, h0, *, block_d: int = 512, chunk: int = 128):
    interpret = jax.default_backend() != "tpu"
    return selective_scan(
        x, dt, a, b, c, h0, block_d=block_d, chunk=chunk, interpret=interpret
    )
