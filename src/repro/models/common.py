"""Model configuration and shared building blocks for the LM zoo.

One :class:`ModelConfig` describes every assigned architecture family:
dense GQA transformers (with QKV bias / qk-norm / sliding-window variants),
MoE transformers (with optional dense residual), Mamba-1 SSMs, hybrid
Mamba+attention stacks (Jamba), and encoder–decoder stacks (Whisper).

Design notes
------------
* All decoder stacks scan over layers (`lax.scan` with stacked parameters)
  so the traced HLO is one layer body — essential for compile times on the
  512-device dry-run.  Per-layer heterogeneity that only changes *scalars*
  (e.g. gemma's 5:1 local:global attention window) is expressed as a
  per-layer array scanned alongside the parameters; heterogeneity that
  changes *structure* (Jamba's mamba-vs-attention interleave) is expressed
  as a repeating block pattern (outer scan over super-blocks, inner
  unrolled positions).
* Parameters are plain nested-dict pytrees.  Logical sharding axes are
  attached by path-pattern rules in :mod:`.sharding`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes it at the top level with a ``check_vma`` flag; older
    releases only have ``jax.experimental.shard_map.shard_map`` where the
    same knob is spelled ``check_rep``.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(axis_name) -> int:
    """Static size of a mapped axis (``jax.lax.axis_size`` across versions;
    older JAX exposes it as ``jax.core.axis_frame``)."""
    native = getattr(jax.lax, "axis_size", None)
    if native is not None:
        return native(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return getattr(frame, "size", frame)

__all__ = [
    "ModelConfig",
    "rms_norm",
    "layer_norm",
    "make_norm_params",
    "apply_rope",
    "rope_angles",
    "sincos_positions",
    "init_dense",
    "GLOBAL_WINDOW",
]

# Sentinel window meaning "global attention" in per-layer window arrays.
GLOBAL_WINDOW = np.int32(2**30)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (values straight from the assignment)."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # local window size (tokens)
    global_every: Optional[int] = None     # every k-th layer is global
    causal: bool = True

    # MoE options
    n_experts: int = 0
    top_k: int = 2
    moe_every: int = 1              # MoE FFN on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    residual_d_ff: Optional[int] = None
    capacity_factor: float = 1.25

    # mamba / hybrid options
    attn_every: int = 0             # jamba: one attn layer per `attn_every`
    attn_offset: int = 0
    ssm_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500         # stubbed conv-frontend frame count

    # norm / activation / embeddings
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    gated_mlp: bool = True          # SwiGLU-style (False -> GELU MLP)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # -- beyond-paper performance variants (§Perf hillclimbs) ----------
    # Sequence-parallel attention: when n_heads doesn't divide the model
    # axis (whisper 20H, arctic 56H, gemma 4H), shard the *sequence*
    # instead of heads for the attention block — removes the 16× compute/
    # memory replication the divisibility fallback otherwise costs.
    seq_parallel_attn: bool = False

    # notes carried into DESIGN/EXPERIMENTS tables
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window sizes (GLOBAL_WINDOW = full)."""
        if self.sliding_window is None:
            return np.full(self.n_layers, GLOBAL_WINDOW, dtype=np.int32)
        w = np.full(self.n_layers, np.int32(self.sliding_window), dtype=np.int32)
        if self.global_every:
            # gemma3 pattern: every k-th layer (1-indexed) is global
            idx = np.arange(self.n_layers)
            w[(idx + 1) % self.global_every == 0] = GLOBAL_WINDOW
        return w

    def is_attn_layer(self, idx: int) -> bool:
        """hybrid stacks: which layers are attention (vs mamba)."""
        if self.family == "ssm":
            return False
        if self.attn_every:
            return idx % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return idx % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline terms)."""
        d, dff, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        h, k = self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * k) + h * hd * d
        mlp_dense = d * dff * (3 if self.gated_mlp else 2)
        moe = self.n_experts * d * dff * (3 if self.gated_mlp else 2)
        if self.dense_residual:
            rdff = self.residual_d_ff or dff
            moe += d * rdff * 3
        mamba = (
            d * self.d_inner * 2                       # in_proj
            + self.d_inner * self.d_conv               # conv
            + self.d_inner * (self.ssm_state * 2 + 2)  # x_proj(B,C,dt) approx
            + self.d_inner * self.ssm_state            # A
            + self.d_inner * 2                         # D, dt bias
            + self.d_inner * d                         # out_proj
        )
        total = v * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            if self.family == "ssm" or (self.attn_every and not self.is_attn_layer(i)):
                total += mamba
            else:
                total += attn
            if self.family == "ssm":
                continue  # mamba block includes its mixer; no separate FFN
            if self.attn_every and not self.is_attn_layer(i) and self.family == "hybrid":
                pass  # jamba: every layer still has an FFN after the mixer
            total += moe if self.is_moe_layer(i) else mlp_dense
        total += self.encoder_layers * (attn + mlp_dense + d * dff)  # enc + cross-attn approx
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        per_expert = d * dff * (3 if self.gated_mlp else 2)
        inactive = (self.n_experts - self.top_k) * per_expert
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return int(self.param_count() - n_moe_layers * inactive)

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        base = dict(
            name=self.name + "-smoke",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)),
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            sliding_window=8 if self.sliding_window else None,
            global_every=3 if self.global_every else None,
            attn_every=4 if self.attn_every else 0,
            attn_offset=min(self.attn_offset, 1),
            moe_every=self.moe_every,
            moe_offset=self.moe_offset,
            param_dtype="float32",
            activation_dtype="float32",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def make_norm_params(cfg: ModelConfig, shape_tail: Tuple[int, ...]) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros(shape_tail, cfg.pdtype)}
    return {
        "scale": jnp.ones(shape_tail, cfg.pdtype),
        "bias": jnp.zeros(shape_tail, cfg.pdtype),
    }


def apply_norm(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


# --------------------------------------------------------------------------
# Positions
# --------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, hd: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for RoPE; positions (...,) -> (..., hd/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(seq: int, d: int) -> jnp.ndarray:
    """Sinusoidal absolute positions (whisper-style stub), (seq, d) f32."""
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / (10000 ** (2 * i / d))
    return jnp.asarray(
        np.concatenate([np.sin(angle), np.cos(angle)], axis=-1), jnp.float32
    )


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init_dense(key, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = (1.0 / max(1, fan_in)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)
