"""Deterministic fault-injection substrate for the campaign control plane.

Real spot-probing campaigns run against a flaky control plane: API
throttle bursts, transient request errors, provisioning timeouts, and
zone-wide blackout windows.  This module models those fault processes as
pure functions of ``(fault_seed, region/pool, time/cycle)`` using the
same counter-based SplitMix64 streams as the provider itself
(:mod:`repro.core.rng`), so the scalar, fleet, and sharded engines all
inject *identical* faults and stay bit-identical (atol=0) to each other.

Fault taxonomy / outcome codes
------------------------------

Every pool-cycle of a campaign resolves to exactly one outcome code:

====================  ===  =========================================
code                  val  meaning
====================  ===  =========================================
``OUTCOME_OK``          0  probe submitted, counts are live data
``OUTCOME_CAPACITY``    1  (reserved) rejected on capacity — folded
                           into the success *count*, not a call fault
``OUTCOME_RATE_LIMITED``2  provider rate limiter refused the call
                           (no API charge, existing semantics)
``OUTCOME_THROTTLED``   3  region-wide API throttle burst (API billed)
``OUTCOME_ERROR``       4  (reserved for per-request transient errors;
                           surfaced via the ``errors`` matrix)
``OUTCOME_TIMEOUT``     5  provisioning/API timeout (API billed)
``OUTCOME_BLACKOUT``    6  AZ blackout window (API billed)
``OUTCOME_DEFERRED``    7  retry/breaker control plane skipped the
                           call (no API charge)
====================  ===  =========================================

Whole-call faults (throttle / timeout / blackout) are evaluated
host-side once per cycle via :meth:`FaultPlan.call_codes`; per-request
transient errors are drawn inside the provider's admission mask (and
its device twin) from the same ``(fault_seed, pool, submit_seq)``
stream.  Blackout windows additionally gate background replenishment
via :meth:`FaultPlan.blackout_mask`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .rng import keyed_exponential, keyed_uniform

# Outcome codes (uint8).  Keep stable: they are persisted in DataLake
# blocks and campaign ``codes`` matrices.
OUTCOME_OK = 0
OUTCOME_CAPACITY = 1
OUTCOME_RATE_LIMITED = 2
OUTCOME_THROTTLED = 3
OUTCOME_ERROR = 4
OUTCOME_TIMEOUT = 5
OUTCOME_BLACKOUT = 6
OUTCOME_DEFERRED = 7

OUTCOME_NAMES = (
    "ok",
    "capacity",
    "rate_limited",
    "throttled",
    "error",
    "timeout",
    "blackout",
    "deferred",
)

#: Codes that bill an API call even though no requests were submitted.
BILLED_FAULT_CODES = (OUTCOME_THROTTLED, OUTCOME_TIMEOUT, OUTCOME_BLACKOUT)

# RNG tags — disjoint from every provider tag (provider.py stays below
# 30_000_000).  All draws use the *plan's* seed, never the provider's,
# so fault streams can never collide with capacity/noise streams.
_TAG_THROTTLE_GATE = 30_000_000
_TAG_THROTTLE_START = 30_000_001
_TAG_THROTTLE_DUR = 30_000_002
_TAG_BLACKOUT_GATE = 30_000_010
_TAG_BLACKOUT_START = 30_000_011
_TAG_BLACKOUT_DUR = 30_000_012
_TAG_TIMEOUT = 30_000_020
#: Base tag for per-request transient-error draws: request ``j`` of a
#: submission batch draws at ``_TAG_REQUEST_ERROR + j``.  Mirrored on
#: the sharded device step — keep in sync with ``core.sharded``.
_TAG_REQUEST_ERROR = 31_000_000


@dataclass(frozen=True)
class ThrottleBursts:
    """Region-wide API throttle bursts.

    Time is cut into fixed epochs; each (region, epoch) draws one gate
    ``u < p``.  A gated epoch contains a single burst starting at a
    uniform offset with an exponential duration capped at the epoch
    length, so a burst never spans more than two epochs and activity at
    time ``t`` only needs epochs ``k`` and ``k - 1``.
    """

    p: float = 0.05
    epoch: float = 3600.0
    mean_duration: float = 300.0


@dataclass(frozen=True)
class BlackoutWindows:
    """AZ/region blackout windows — same epoch process, wider and rarer.

    During a blackout the control plane rejects whole calls *and* the
    provider's background replenishment is suppressed for pools in the
    region (see ``SimulatedProvider.set_fault_plan``).
    """

    p: float = 0.01
    epoch: float = 6 * 3600.0
    mean_duration: float = 1800.0


@dataclass(frozen=True)
class FaultPlan:
    """Composable deterministic fault processes for one campaign.

    All processes are pure functions of ``seed`` — two engines given
    the same plan see bit-identical faults.  ``request_error_p`` and
    ``timeout_p`` are per-request / per-pool-cycle Bernoulli rates;
    ``throttle`` / ``blackout`` are region-level window processes.
    """

    seed: int = 0
    throttle: Optional[ThrottleBursts] = None
    blackout: Optional[BlackoutWindows] = None
    request_error_p: float = 0.0
    timeout_p: float = 0.0

    def __post_init__(self) -> None:
        if not 0.0 <= float(self.request_error_p) < 1.0:
            raise ValueError("request_error_p must be in [0, 1)")
        if not 0.0 <= float(self.timeout_p) < 1.0:
            raise ValueError("timeout_p must be in [0, 1)")

    # -- region window processes -------------------------------------

    def _window_active(self, spec, region_codes, times, tags):
        """Bool activity matrix ``(len(times), n_regions)`` for a window spec."""
        tag_gate, tag_start, tag_dur = tags
        t = np.asarray(times, dtype=np.float64).reshape(-1, 1, 1)
        rc = np.asarray(region_codes, dtype=np.int64).reshape(1, -1, 1)
        k = np.floor(t / spec.epoch).astype(np.int64)
        kk = np.concatenate([k - 1, k], axis=2)  # (T, R, 2)
        u_gate = keyed_uniform(self.seed, rc, kk, tag_gate)
        u_start = keyed_uniform(self.seed, rc, kk, tag_start)
        u_dur = keyed_uniform(self.seed, rc, kk, tag_dur)
        start = kk * spec.epoch + u_start * spec.epoch
        dur = np.minimum(keyed_exponential(spec.mean_duration, u_dur), spec.epoch)
        active = (u_gate < spec.p) & (start <= t) & (t < start + dur)
        return active.any(axis=2)

    def throttled_regions(self, region_codes, times):
        """``(T, R)`` bool — which regions are throttle-bursting at ``times``."""
        if self.throttle is None:
            return np.zeros(
                (np.size(times), np.size(region_codes)), dtype=bool
            )
        return self._window_active(
            self.throttle,
            region_codes,
            times,
            (_TAG_THROTTLE_GATE, _TAG_THROTTLE_START, _TAG_THROTTLE_DUR),
        )

    def blacked_out_regions(self, region_codes, times):
        """``(T, R)`` bool — which regions are blacked out at ``times``."""
        if self.blackout is None:
            return np.zeros(
                (np.size(times), np.size(region_codes)), dtype=bool
            )
        return self._window_active(
            self.blackout,
            region_codes,
            times,
            (_TAG_BLACKOUT_GATE, _TAG_BLACKOUT_START, _TAG_BLACKOUT_DUR),
        )

    # -- per-cycle whole-call evaluation -----------------------------

    def call_codes(self, now, cycle, pool_idx, region_code):
        """Whole-call outcome codes for one probe cycle.

        Parameters
        ----------
        now : float
            Provider wall-clock at submission time.
        cycle : int
            Campaign cycle index (the timeout draw's counter).
        pool_idx : (P,) int array
            Pool indices being probed this cycle.
        region_code : (n_pools,) int array
            The provider's pool → region-code map.

        Returns
        -------
        (P,) uint8 array of ``OUTCOME_*`` codes; ``OUTCOME_OK`` where no
        whole-call fault fires.  Severity order (strongest wins):
        blackout > throttle > timeout.
        """
        pool_idx = np.asarray(pool_idx, dtype=np.int64)
        codes = np.zeros(pool_idx.shape[0], dtype=np.uint8)
        if self.timeout_p > 0.0:
            u = keyed_uniform(self.seed, pool_idx, int(cycle), _TAG_TIMEOUT)
            codes[u < self.timeout_p] = OUTCOME_TIMEOUT
        rc = np.asarray(region_code, dtype=np.int64)
        uniq = np.unique(rc[pool_idx])
        if self.throttle is not None:
            hot = self.throttled_regions(uniq, [float(now)])[0]
            hot_regions = uniq[hot]
            if hot_regions.size:
                codes[np.isin(rc[pool_idx], hot_regions)] = OUTCOME_THROTTLED
        if self.blackout is not None:
            dark = self.blacked_out_regions(uniq, [float(now)])[0]
            dark_regions = uniq[dark]
            if dark_regions.size:
                codes[np.isin(rc[pool_idx], dark_regions)] = OUTCOME_BLACKOUT
        return codes

    def blackout_mask(self, times, region_code):
        """``(T, n_pools)`` bool — pools whose replenishment is suppressed.

        Evaluated host-side for the tick times of a provider advance and
        fed to both the numpy ``_replenish_batch`` gate and the sharded
        device step, so all engines suppress the exact same ticks.
        """
        rc = np.asarray(region_code, dtype=np.int64)
        times = np.asarray(times, dtype=np.float64).reshape(-1)
        if self.blackout is None or times.size == 0:
            return np.zeros((times.size, rc.size), dtype=bool)
        uniq, inv = np.unique(rc, return_inverse=True)
        dark = self.blacked_out_regions(uniq, times)  # (T, R)
        return dark[:, inv]

    # -- per-request transient errors --------------------------------

    def request_errors(self, pool_idx, seq, n):
        """``(P, n)`` bool — transient per-request errors for one batch.

        Drawn from ``(seed, pool, submit_seq)`` exactly like the
        provider's flake draws, so every engine sees identical errors
        regardless of which pools it batches together.
        """
        if self.request_error_p <= 0.0:
            return np.zeros((np.size(pool_idx), n), dtype=bool)
        pool_idx = np.asarray(pool_idx, dtype=np.int64)
        seq = np.asarray(seq, dtype=np.int64)
        u = keyed_uniform(
            self.seed,
            pool_idx[:, None],
            seq[:, None],
            _TAG_REQUEST_ERROR + np.arange(n)[None, :],
        )
        return u < self.request_error_p


def describe_codes(codes) -> dict:
    """Histogram of outcome codes as ``{name: count}`` (diagnostics)."""
    codes = np.asarray(codes, dtype=np.uint8).reshape(-1)
    counts = np.bincount(codes, minlength=len(OUTCOME_NAMES))
    return {
        name: int(counts[i])
        for i, name in enumerate(OUTCOME_NAMES)
        if counts[i]
    }


__all__ = [
    "OUTCOME_OK",
    "OUTCOME_CAPACITY",
    "OUTCOME_RATE_LIMITED",
    "OUTCOME_THROTTLED",
    "OUTCOME_ERROR",
    "OUTCOME_TIMEOUT",
    "OUTCOME_BLACKOUT",
    "OUTCOME_DEFERRED",
    "OUTCOME_NAMES",
    "BILLED_FAULT_CODES",
    "ThrottleBursts",
    "BlackoutWindows",
    "FaultPlan",
    "describe_codes",
]
