"""Monitoring-cost model — paper §VI-B, Fig. 5.

Compares three ways to observe spot availability over a campaign:

* **Continuous monitoring** — keep the node pools running; cost is the
  spot-price integral of the running instances (dominant term by far).
* **Periodic probing (Wu et al.)** — briefly launch instances every 10
  minutes.  The paper cannot reproduce the per-launch billing mitigation
  and adopts the reported 100× reduction over continuous *as-is*; we do
  the same.
* **SnS** — probes never reach RUNNING, so instance cost ≈ 0; the cost is
  serverless collector invocations + request/log storage.

Serverless constants default to public AWS list prices; the collector
deployment profile (memory × duration) follows the §V architecture: one
requester Lambda invocation per probe request, one invoker trigger and one
terminator invocation per pool-cycle.  The headline numbers in the paper:
SnS is 249.5× cheaper than continuous and 2.5× cheaper than periodic
probing, at 3.33× finer temporal resolution (3 min vs 10 min).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .collector import CampaignResult
from .provider import LedgerStats, ProbeCostMeter  # noqa: F401  (re-export)

__all__ = [
    "ServerlessPricing",
    "CostReport",
    "ProbeCostMeter",
    "cost_report",
]


@dataclasses.dataclass(frozen=True)
class ServerlessPricing:
    """Public list prices (USD, us-east-1, 2024)."""

    lambda_per_invocation: float = 0.20 / 1e6
    lambda_per_gb_second: float = 1.66667e-5
    eventbridge_per_event: float = 1.00 / 1e6
    s3_per_put: float = 0.005 / 1e3
    dynamodb_per_write: float = 1.25 / 1e6
    cloudwatch_per_gb_ingested: float = 0.50

    # Collector deployment profile (§V): per-request requester Lambda,
    # per-pool-cycle terminator, per-cycle invoker.
    requester_gb: float = 1.769
    requester_seconds: float = 3.0
    terminator_gb: float = 0.512
    terminator_seconds: float = 0.5
    log_bytes_per_record: float = 2048.0


@dataclasses.dataclass(frozen=True)
class CostReport:
    sns_compute: float          # $ billed to probe instances (≈ 0)
    sns_serverless: float       # $ collector invocations + storage
    continuous: float           # $ running the node pools
    periodic: float             # $ Wu et al. estimate (continuous / 100)
    resolution_ratio: float     # SnS cadence vs periodic probing cadence
    #: host-side ledger footprint at report time (set when a provider is
    #: passed to :func:`cost_report`) — the near-zero *dollar* cost claim
    #: and the bounded *memory* cost of collecting it, side by side
    host_ledger: Optional[LedgerStats] = None
    #: API calls that hit an injected fault (throttle/timeout/blackout)
    #: instead of returning a capacity verdict.  Faulted calls still bill
    #: — they are INCLUDED in the ``api_calls`` the serverless total is
    #: built from; this field breaks out how much of the spend bought no
    #: signal (chaos campaigns only; 0 on fault-free runs).
    fault_api_calls: int = 0

    @property
    def sns_total(self) -> float:
        return self.sns_compute + self.sns_serverless

    @property
    def continuous_over_sns(self) -> float:
        return self.continuous / self.sns_total

    @property
    def periodic_over_sns(self) -> float:
        return self.periodic / self.sns_total


def cost_report(
    result: CampaignResult,
    *,
    pricing: ServerlessPricing = ServerlessPricing(),
    periodic_reduction: float = 100.0,
    periodic_interval: float = 600.0,
    provider=None,
) -> CostReport:
    """Itemized cost comparison for one campaign (Fig. 5).

    Everything derives from the campaign's count matrices and counters —
    no per-record iteration: ``api_calls`` is the exact number of probe
    requests submitted (rate-limited cycles submit fewer than
    ``pools × cycles × N``).

    Pass the campaign's ``provider`` (any engine) to also attach its
    host-side :class:`~repro.core.provider.LedgerStats` as
    ``host_ledger`` — the memory half of the "near-zero collection cost"
    claim.
    """
    pools, cycles = result.s.shape
    n_requests = result.n
    pool_cycles = pools * cycles
    records = int(result.api_calls)

    invocations = (
        records              # parallel spot requester: one Lambda per request
        + pool_cycles        # request terminator (event-driven, per pool-cycle)
        + cycles             # request invoker trigger
    )
    gb_seconds = (
        records * pricing.requester_gb * pricing.requester_seconds
        + pool_cycles * pricing.terminator_gb * pricing.terminator_seconds
    )
    serverless = (
        invocations * pricing.lambda_per_invocation
        + gb_seconds * pricing.lambda_per_gb_second
        + cycles * pricing.eventbridge_per_event
        + records * pricing.s3_per_put
        + records * pricing.dynamodb_per_write
        + records * pricing.log_bytes_per_record / 1e9
        * pricing.cloudwatch_per_gb_ingested
    )

    continuous = result.node_pool_cost
    periodic = continuous / periodic_reduction
    return CostReport(
        sns_compute=result.probe_compute_cost,
        sns_serverless=serverless,
        continuous=continuous,
        periodic=periodic,
        resolution_ratio=periodic_interval / result.interval,
        host_ledger=provider.ledger_stats() if provider is not None else None,
        fault_api_calls=int(getattr(result, "fault_api_calls", 0)),
    )
