"""Distribution-layer correctness + dry-run smoke.

These run in subprocesses so the main test process keeps its single real
CPU device (the dry-run needs 512 placeholder devices; the numerics test
needs 4).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, extra_env=None, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.update(extra_env or {})
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )


class TestShardedNumerics:
    """Sharded execution must equal single-device execution bit-for-band."""

    def test_moe_and_decode_match_unsharded(self):
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_explicit_mesh, use_mesh
        from repro.models import api

        cfg = get_config("phi3.5-moe-42b-a6.6b").scaled_down(capacity_factor=4.0)
        params = api.init_params(cfg, seed=0)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))
        batch = {"tokens": toks, "labels": toks}

        loss_ref = api.train_loss(cfg, params, batch, remat="none")

        mesh = make_explicit_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh):
            loss_sh = jax.jit(
                lambda p, b: api.train_loss(cfg, p, b, mesh=mesh,
                                            data_axes=("data",), remat="none")
            )(params, batch)
        np.testing.assert_allclose(float(loss_ref), float(loss_sh), rtol=2e-3)

        # decode path: sequence-sharded cache + flash-decoding psums
        lg_ref, cache_ref = api.prefill(cfg, params, {"tokens": toks}, max_seq=20)
        lg1_ref, _ = api.decode_step(cfg, params, cache_ref,
                                     jnp.argmax(lg_ref, -1).astype(jnp.int32))
        with use_mesh(mesh):
            lg_sh, cache_sh = jax.jit(
                lambda p, t: api.prefill(cfg, p, {"tokens": t}, mesh=mesh,
                                         data_axes=("data",), max_seq=20)
            )(params, toks)
            lg1_sh, _ = jax.jit(
                lambda p, c, t: api.decode_step(cfg, p, c, t, mesh=mesh,
                                                data_axes=("data",))
            )(params, cache_sh, jnp.argmax(lg_sh, -1).astype(jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_sh),
                                   atol=3e-3)
        np.testing.assert_allclose(np.asarray(lg1_ref), np.asarray(lg1_sh),
                                   atol=3e-3)
        print("SHARDED_OK")
        """
        r = run_py(code)
        assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr

    def test_seq_parallel_attention_matches(self):
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_explicit_mesh, use_mesh
        from repro.models import api

        # 3 heads % 2 != 0 -> the seq-parallel path engages on a (2,2) mesh
        cfg = get_config("qwen3-8b").scaled_down(n_heads=3, n_kv_heads=1,
                                                 head_dim=16)
        params = api.init_params(cfg, seed=0)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)))
        batch = {"tokens": toks, "labels": toks}
        loss_ref = api.train_loss(cfg, params, batch, remat="none")

        cfg_sp = dataclasses.replace(cfg, seq_parallel_attn=True)
        mesh = make_explicit_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh):
            loss_sp = jax.jit(
                lambda p, b: api.train_loss(cfg_sp, p, b, mesh=mesh,
                                            data_axes=("data",), remat="none")
            )(params, batch)
        np.testing.assert_allclose(float(loss_ref), float(loss_sp), rtol=2e-3)
        print("SEQPAR_OK")
        """
        r = run_py(code)
        assert "SEQPAR_OK" in r.stdout, r.stdout + r.stderr


class TestDryRunSmoke:
    """One real dry-run cell end-to-end (512 placeholder devices)."""

    def test_decode_cell_compiles_and_reports(self, tmp_path):
        out = str(tmp_path)
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "falcon-mamba-7b", "--shape", "decode_32k",
             "--mesh", "single", "--out", out],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": SRC}, timeout=900,
        )
        assert r.returncode == 0, r.stdout + r.stderr
        cell = json.load(
            open(os.path.join(out, "falcon-mamba-7b__decode_32k__single.json"))
        )
        assert cell["status"] == "ok"
        assert cell["devices"] == 256
        assert cell["roofline"]["bottleneck"] in ("memory", "collective", "compute")
        assert cell["memory"]["peak_gib"] < 16.0  # fits v5e HBM

    def test_skip_rule_applies(self, tmp_path):
        out = str(tmp_path)
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "qwen3-8b", "--shape", "long_500k",
             "--mesh", "single", "--out", out],
            capture_output=True, text=True,
            env={**os.environ, "PYTHONPATH": SRC}, timeout=300,
        )
        assert r.returncode == 0
        cell = json.load(
            open(os.path.join(out, "qwen3-8b__long_500k__single.json"))
        )
        assert cell["status"] == "skipped"
        assert "full-attention" in cell["reason"]


class TestRooflineParser:
    def test_flops_exact_on_reference_scan(self):
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_explicit_mesh, use_mesh
        from repro.launch.roofline import analyze_hlo

        mesh = make_explicit_mesh((2, 4), ("data", "model"))
        D, L, B = 128, 8, 32

        def f(ws, x):
            def body(h, w):
                h = jnp.tanh(h @ w)
                return jax.lax.with_sharding_constraint(
                    h, NamedSharding(mesh, P("data", "model"))), None
            return jax.lax.scan(body, x, ws)[0].sum()

        with use_mesh(mesh):
            comp = jax.jit(f).lower(
                jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                jax.ShapeDtypeStruct((B, D), jnp.float32),
            ).compile()
        a = analyze_hlo(comp.as_text(), total_devices=8)
        expected = 2 * B * D * D * L / 8   # per-device
        assert abs(a["flops_per_device"] - expected) / expected < 0.02, a
        assert a["collective_bytes_per_device"] > 0
        print("ROOFLINE_OK", a["flops_per_device"], expected)
        """
        r = run_py(code)
        assert "ROOFLINE_OK" in r.stdout, r.stdout + r.stderr
