"""Workload profiles for the trace-driven simulation — paper §VI-E.

The paper profiles all 99 TPC-DS queries (scale factor 300) on a ten-node
Spark cluster, yielding per-query execution times from 0.5 s to 661.5 s and
a total of ≈206 minutes.  The actual Spark cluster is out of scope here;
we regenerate a deterministic synthetic profile matching those published
statistics exactly (min, max, count, total), drawn from a log-normal shape
typical of decision-support query mixes.
"""

from __future__ import annotations

import numpy as np

__all__ = ["tpcds_profile", "TPCDS_TOTAL_SECONDS"]

TPCDS_N_QUERIES = 99
TPCDS_MIN_SECONDS = 0.5
TPCDS_MAX_SECONDS = 661.5
TPCDS_TOTAL_SECONDS = 206.0 * 60.0  # ≈206 minutes


def tpcds_profile(seed: int = 0) -> np.ndarray:
    """99 query durations (seconds) with min 0.5, max 661.5, sum 12,360.

    The published statistics hold *exactly* for every seed: the residual
    is redistributed additively over the values that still have slack and
    the result re-clipped until both the clip bounds and the total
    converge (a multiplicative rescale applied after clipping can leave
    the sum off by up to a second and push the final iterate back outside
    the bounds).
    """
    rng = np.random.default_rng(seed)
    d = rng.lognormal(mean=3.6, sigma=1.3, size=TPCDS_N_QUERIES)
    d = np.sort(d)
    # pin the extremes, then adjust the interior to hit the exact total
    d[0], d[-1] = TPCDS_MIN_SECONDS, TPCDS_MAX_SECONDS
    interior = np.clip(d[1:-1], TPCDS_MIN_SECONDS, TPCDS_MAX_SECONDS)
    target_interior = TPCDS_TOTAL_SECONDS - TPCDS_MIN_SECONDS - TPCDS_MAX_SECONDS
    for _ in range(200):
        residual = target_interior - interior.sum()
        if abs(residual) < 1e-9:
            break
        # spread the residual over values with slack in its direction,
        # then re-clip; the clipped-off mass shrinks every round
        free = interior < TPCDS_MAX_SECONDS if residual > 0 else interior > TPCDS_MIN_SECONDS
        if not free.any():
            raise RuntimeError("tpcds_profile cannot absorb residual")
        interior[free] += residual / free.sum()
        np.clip(interior, TPCDS_MIN_SECONDS, TPCDS_MAX_SECONDS, out=interior)
    d[1:-1] = interior
    out = rng.permutation(d)
    assert abs(out.sum() - TPCDS_TOTAL_SECONDS) < 1e-6, out.sum()
    return out
