"""Train-step builder: microbatch gradient accumulation + remat + sharding.

``make_train_step`` returns a jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)``.  The global batch splits into
``grad_accum`` microbatches scanned sequentially — this bounds both
activation memory and the materialised logits (vocab 152k–262k at 1M
tokens would otherwise need hundreds of GB), and is the production
pattern that overlaps per-microbatch backward compute with the gradient
reductions XLA schedules at scan boundaries.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.common import ModelConfig

from .optim import OptConfig, apply_updates

__all__ = ["make_train_step", "make_eval_step", "synthetic_batch"]


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """(B, ...) -> (n, B/n, ...) along the leading batch axis."""

    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by grad_accum {n}"
        return x.reshape((n, b // n) + x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    *,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    grad_accum: int = 1,
    remat: str = "dots",
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
    accum_dtype: str = "float32",
):
    def loss_fn(params, micro):
        return api.train_loss(
            cfg, params, micro,
            mesh=mesh, data_axes=data_axes, remat=remat,
            q_chunk=q_chunk, mamba_chunk=mamba_chunk,
        )

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micros = _split_microbatches(batch, grad_accum)
            adt = jnp.dtype(accum_dtype)

            def accum(carry, micro):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, micro)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(adt), grads_acc, grads
                )
                return (loss_acc + loss, grads_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(jnp.shape(p), adt), params
            )
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zero), micros)
            loss = loss / grad_accum
            # stay in accum dtype: the optimizer casts per-layer-slice, so a
            # full-tree f32 copy never materialises
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(
    cfg: ModelConfig,
    *,
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
):
    def eval_step(params, batch):
        return api.train_loss(
            cfg, params, batch,
            mesh=mesh, data_axes=data_axes, remat="none",
            q_chunk=q_chunk, mamba_chunk=mamba_chunk,
        )

    return eval_step


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> Dict:
    """Deterministic synthetic LM batch (markov-ish token stream)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab_size, jnp.int32)
    out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    return out
