"""Fig. 8: F1-macro by prediction horizon × feature combination.

The paper's headline: RF/XGBoost with SR+UR+CUT start >0.90 at a 3-minute
horizon and hold ≈0.85 at 60 minutes; SR alone is a strong baseline; for
LR/SVM extra features don't help.
"""

from __future__ import annotations

from repro.core import build_dataset, evaluate, fit_predictor

from .common import paper_campaign

HORIZONS_MIN = (3, 15, 30, 60)
FEATURE_SETS = {
    "SR": ("SR",),
    "SR+UR": ("SR", "UR"),
    "SR+CUT": ("SR", "CUT"),
    "SR+UR+CUT": ("SR", "UR", "CUT"),
}
POINT_MODELS = ("lr", "svm", "rf", "xgb")
SEQ_MODELS = ("lstm", "transformer")
WINDOW_MIN = 480.0
SEQ_LEN = 20                      # trailing cycles for sequence models


def run(horizons=HORIZONS_MIN, point_models=POINT_MODELS,
        seq_models=SEQ_MODELS, feature_sets=None):
    feature_sets = feature_sets or FEATURE_SETS
    c = paper_campaign()
    out = {}
    for h in horizons:
        row = {}
        for fs_name, fs in feature_sets.items():
            ds = build_dataset(
                c, window_minutes=WINDOW_MIN, horizon_minutes=h,
                feature_set=fs, seed=0,
            )
            for m in point_models:
                model = fit_predictor(m, ds)
                row[f"{m}[{fs_name}]"] = round(evaluate(model, ds)["f1_macro"], 3)
        if seq_models:
            ds_seq = build_dataset(
                c, window_minutes=WINDOW_MIN, horizon_minutes=h,
                sequence_length=SEQ_LEN, seed=0,
            )
            for m in seq_models:
                model = fit_predictor(m, ds_seq, steps=300)
                row[f"{m}[seq]"] = round(evaluate(model, ds_seq)["f1_macro"], 3)
        out[f"h={h}min"] = row
    headline = {
        "xgb_full_3min": out[f"h={horizons[0]}min"].get("xgb[SR+UR+CUT]"),
        "xgb_full_60min": out.get("h=60min", {}).get("xgb[SR+UR+CUT]"),
        "paper": "≥0.90 at 3 min, ≈0.85 at 60 min (RF/XGB + SR+UR+CUT)",
    }
    return {"f1_by_horizon": out, "headline": headline}


if __name__ == "__main__":
    print(run())
