"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads results/dryrun/*.json (produced by ``repro.launch.dryrun --all``)
and renders the per-(arch × shape × mesh) table: three roofline terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, memory fit.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def render_table(cells, mesh: str = "single") -> str:
    rows = []
    header = (
        f"{'arch':<22} {'shape':<12} {'t_comp':>8} {'t_mem':>8} {'t_coll':>8} "
        f"{'bound':<10} {'useful':>7} {'roofl%':>7} {'GiB/dev':>8} {'status'}"
    )
    rows.append(header)
    rows.append("-" * len(header))
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            rows.append(
                f"{c['arch']:<22} {c['shape']:<12} {'—':>8} {'—':>8} {'—':>8} "
                f"{'—':<10} {'—':>7} {'—':>7} {'—':>8} skipped"
            )
            continue
        if c["status"] != "ok":
            rows.append(
                f"{c['arch']:<22} {c['shape']:<12} {'—':>8} {'—':>8} {'—':>8} "
                f"{'—':<10} {'—':>7} {'—':>7} {'—':>8} ERROR"
            )
            continue
        r = c["roofline"]
        mem = c.get("memory", {}).get("peak_gib", float("nan"))
        rows.append(
            f"{c['arch']:<22} {c['shape']:<12} "
            f"{r['t_compute_s']:>8.3f} {r['t_memory_s']:>8.3f} "
            f"{r['t_collective_s']:>8.3f} {r['bottleneck']:<10} "
            f"{r['model_flops_ratio']:>7.3f} "
            f"{100*r['roofline_fraction']:>6.2f}% {mem:>8.2f} ok"
        )
    return "\n".join(rows)


def run():
    cells = load_cells()
    ok = sum(1 for c in cells if c["status"] == "ok")
    skipped = sum(1 for c in cells if c["status"] == "skipped")
    err = sum(1 for c in cells if c["status"] not in ("ok", "skipped"))
    return {
        "cells_total": len(cells), "ok": ok, "skipped": skipped, "errors": err,
        "table_single_pod": render_table(cells, "single"),
        "table_multi_pod": render_table(cells, "multi"),
    }


if __name__ == "__main__":
    out = run()
    print(f"cells={out['cells_total']} ok={out['ok']} "
          f"skipped={out['skipped']} errors={out['errors']}")
    print(out["table_single_pod"])
