from .ckpt_policy import (
    FixedInterval,
    PolicyTable,
    SnSHazard,
    YoungDaly,
    hazard_tau,
    neg_log_survival,
)
from .elastic import ElasticMeshManager, MeshPlan, reshard
from .events import PodEvent, PodTrace, traces_from_campaign
from .runner import (
    GoodputCycleView,
    GoodputStream,
    ReplayResult,
    run_goodput_frontier,
    run_replay,
    run_replay_batch,
    run_replay_fleet,
)

__all__ = [
    "FixedInterval", "SnSHazard", "YoungDaly", "PolicyTable", "hazard_tau",
    "neg_log_survival",
    "ElasticMeshManager", "MeshPlan", "reshard",
    "PodEvent", "PodTrace", "traces_from_campaign",
    "ReplayResult", "run_replay", "run_replay_batch", "run_replay_fleet",
    "run_goodput_frontier",
    "GoodputCycleView", "GoodputStream",
]
