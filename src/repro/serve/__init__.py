from .engine import (
    AdmissionController,
    FleetAdmissionController,
    generate,
    plan_migration,
    plan_migration_batch,
)

__all__ = [
    "AdmissionController",
    "FleetAdmissionController",
    "generate",
    "plan_migration",
    "plan_migration_batch",
]
