"""Binary availability labels — paper §IV-A.

The co-interruption analysis (Fig. 3) shows that once one node of a pool is
interrupted, the rest follow within minutes; predicting the exact surviving
count has limited value.  The paper therefore adopts a *binary* notion:
at each measurement point, is the full set of ``N`` requested instances
fulfilled or not?

Labels come from the *actual running instance* trace; features come from
the SnS probe trace.  For a prediction horizon ``h`` cycles, the target at
cycle ``t`` is whether the pool maintains its current scale over the whole
of ``(t, t + h]`` (§V Interrupt Predictor: "whether the target instance
node pool will maintain its current scale over a specified future
horizon").  ``h = 0`` degenerates to current-availability modeling (§VI-D
Fig. 7).
"""

from __future__ import annotations

import numpy as np

__all__ = ["binary_availability", "horizon_labels", "HorizonLabelStream"]


def binary_availability(running: np.ndarray, n: int) -> np.ndarray:
    """1 where all ``n`` requested instances are running, else 0.

    Args:
      running: running-instance counts, shape ``(T,)`` or ``(pools, T)``.
      n: requested pool size.
    """
    running = np.asarray(running)
    return (running >= n).astype(np.int32)


def _pad_max(dtype):
    """A value no window minimum can take — the block-padding neutral."""
    if np.issubdtype(dtype, np.floating):
        return np.inf
    if np.issubdtype(dtype, np.bool_):
        return True
    return np.iinfo(dtype).max


def horizon_labels(avail: np.ndarray, horizon_cycles: int) -> np.ndarray:
    """Availability sustained over the next ``horizon_cycles`` cycles.

    Args:
      avail: binary availability, shape ``(..., T)``.
      horizon_cycles: ``h >= 0``.  ``h == 0`` returns ``avail`` unchanged.

    Returns:
      labels of shape ``(..., T - h)``: ``y[..., t] = min(avail[..., t+1 :
      t+h+1])`` for ``h > 0`` — 1 iff the pool stays fully available
      through the horizon.

    The sliding future-minimum runs in O(T) independent of ``h`` (the
    prefix/suffix block-minimum decomposition: every window of length
    ``h`` spans at most two ``h``-blocks, so its minimum is
    ``min(suffix-min of the left block, prefix-min of the right block)``)
    instead of stacking ``h`` shifted copies — 60-minute horizons on long
    fleet traces no longer allocate ``h × T`` intermediates.
    """
    avail = np.asarray(avail)
    h = int(horizon_cycles)
    if h < 0:
        raise ValueError("horizon must be >= 0")
    if h == 0:
        return avail.copy()
    t_total = avail.shape[-1]
    if h >= t_total:
        raise ValueError(f"horizon {h} >= trace length {t_total}")
    x = avail[..., 1:]                       # windows cover (t, t + h]
    n = x.shape[-1]
    n_out = t_total - h                      # = n - h + 1 windows
    if h == 1:
        return x.copy()
    n_blocks = -(-n // h)
    pad = n_blocks * h - n
    if pad:
        fill = np.full(x.shape[:-1] + (pad,), _pad_max(x.dtype), dtype=x.dtype)
        x = np.concatenate([x, fill], axis=-1)
    blocks = x.reshape(x.shape[:-1] + (n_blocks, h))
    prefix = np.minimum.accumulate(blocks, axis=-1)
    suffix = np.minimum.accumulate(blocks[..., ::-1], axis=-1)[..., ::-1]
    prefix = prefix.reshape(x.shape)
    suffix = suffix.reshape(x.shape)
    # window [t, t+h-1]: suffix-min of its head block piece + prefix-min of
    # its tail block piece
    return np.minimum(suffix[..., :n_out], prefix[..., h - 1 : h - 1 + n_out])


class HorizonLabelStream:
    """Streaming form of :func:`horizon_labels` — one horizon, O(h) memory.

    Push availability columns cycle by cycle (shape ``(pools,)`` — or any
    shape, as long as it is the same every cycle); each push returns the
    label column whose future window just closed, or ``None`` while that
    window is still open.  After ``T`` pushes exactly ``T - h`` columns
    have been emitted, and stacking them reproduces
    ``horizon_labels(avail, h)`` **bit-identically**: the emitted column
    at push ``t`` is ``y[t - h] = min(avail[t-h+1 : t+1])``, computed over
    a ``(h, pools)`` ring of the last ``h`` columns — the campaign trace
    itself is never materialized.

    The int/bool minimum is exact, so streamed labels equal the offline
    block-minimum form at atol=0 (``tests/test_labels_dataset.py``).
    """

    def __init__(self, horizon_cycles: int):
        h = int(horizon_cycles)
        if h < 0:
            raise ValueError("horizon must be >= 0")
        self.h = h
        self.pushed = 0         # columns ingested so far (= T)
        self.emitted = 0        # label columns returned so far (= T - h)
        self._ring = None       # (h, *column_shape) ring of trailing avail
        self._shape = None      # column shape pinned by the first push

    def push(self, avail_t: np.ndarray):
        """Ingest cycle ``t``'s availability column; return ``y[t - h]``
        once it exists (``None`` during the first ``h`` pushes)."""
        a = np.asarray(avail_t)
        if self._shape is None:
            self._shape = a.shape
        elif a.shape != self._shape:
            raise ValueError(
                f"column shape {a.shape} != first push {self._shape}"
            )
        t = self.pushed
        self.pushed += 1
        if self.h == 0:
            self.emitted += 1
            return a.copy()
        if self._ring is None:
            self._ring = np.empty((self.h,) + a.shape, dtype=a.dtype)
        self._ring[t % self.h] = a
        if t < self.h:
            return None  # the window (t-h, t] reaches before the trace start
        # after pushing cycle t the ring holds avail[t-h+1 : t+1] — exactly
        # the future window of cycle t - h
        self.emitted += 1
        return self._ring.min(axis=0)


def _horizon_labels_stacked(avail: np.ndarray, horizon_cycles: int) -> np.ndarray:
    """O(h·T) stacked-copy form — kept as the regression oracle for
    :func:`horizon_labels` (bit-identical output)."""
    avail = np.asarray(avail)
    h = int(horizon_cycles)
    if h < 0:
        raise ValueError("horizon must be >= 0")
    if h == 0:
        return avail.copy()
    t_total = avail.shape[-1]
    if h >= t_total:
        raise ValueError(f"horizon {h} >= trace length {t_total}")
    stacked = np.stack([avail[..., 1 + k : t_total - h + 1 + k] for k in range(h)], 0)
    return stacked.min(axis=0)
