"""Public entry point for the scan-form lock-step replay.

``replay_scan_op`` takes the normalised batch inputs prepared by
``repro.core.simulate.replay_batch`` (broadcast availability, launch-order
durations, their prefix sums, and the "predicted unavailable" mask) and
runs the closed-form replay on the selected backend:

* ``"jnp"``    — the ``lax.scan`` reference (the fast CPU path).  Rows
  are embarrassingly parallel, so with more than one visible device the
  batch axis is ``shard_map``-ped over a 1-D ``("traces",)`` mesh
  (``repro.launch.mesh.make_trace_mesh``) — one jitted device call, zero
  cross-device collectives, bit-identical to the unsharded scan by
  construction (rows are padded up to a shard multiple with inert
  all-unavailable rows and sliced off).
* ``"pallas"`` — the chunked Pallas kernel (interpret mode off-TPU).
  Handles ragged shapes by padding cycles (``avail = 0`` beyond the real
  trace, masked inert inside the kernel) and rows (sliced off).
* ``"auto"``   — Pallas on TPU, scan elsewhere.

float64 inputs run under a scoped ``enable_x64`` context, so importing
this module never flips global JAX precision.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

import numpy as np

__all__ = ["replay_scan_op"]

#: jitted shard_map scans, keyed on (shards, use_pred, window, unroll) —
#: shapes and the queue length are traced, so one entry serves every
#: workload on the same mesh
_MESH_CACHE = {}


def _x64_if(dtype):
    if np.dtype(dtype) == np.float64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def _mesh_scan(n_shards: int, use_pred: bool, window: int, unroll: int):
    """The trace-sharded scan: ``jit(shard_map(replay_scan_ref))`` over a
    1-D ``("traces",)`` mesh, built once per (shards, static-config)."""
    key = (n_shards, use_pred, window, unroll)
    fn = _MESH_CACHE.get(key)
    if fn is None:
        import jax
        from jax.sharding import PartitionSpec as PS

        from ...launch.mesh import make_trace_mesh
        from ...models.common import shard_map
        from .ref import replay_scan_ref

        mesh = make_trace_mesh(n_shards)

        def run(avail_t, predz_t, cum_pad, dt, horizon_cycles, q):
            return replay_scan_ref(
                avail_t, predz_t, cum_pad, dt, horizon_cycles,
                q=q, use_pred=use_pred, window=window, unroll=unroll,
            )

        traces = PS("traces")
        fn = jax.jit(
            shard_map(
                run,
                mesh=mesh,
                in_specs=(
                    PS(None, "traces"), PS(None, "traces"), traces,
                    PS(), PS(), PS(),
                ),
                out_specs=traces,
            )
        )
        _MESH_CACHE[key] = fn
    return fn


def replay_scan_op(
    avail: np.ndarray,            # (B, T) bool
    dur: np.ndarray,              # (B, Q) float, launch order
    cum: np.ndarray,              # (B, Q+1) float prefix sums of dur
    pred_zero: Optional[np.ndarray],  # (B, T) bool or None
    *,
    dt: float,
    horizon_cycles: int,
    backend: str = "auto",
    block_b: int = 8,
    chunk: int = 128,
    window: int = 16,
    unroll: int = 1,
    shards=None,
) -> Dict[str, np.ndarray]:
    """Scan-form replay; returns the ``replay_batch`` metric dict.

    ``shards`` controls the trace-axis mesh on the scan backend:
    ``None`` / ``"auto"`` shards across all visible devices (single
    device: plain unsharded scan), an int pins the mesh size (must not
    exceed the visible device count).
    """
    import jax

    if backend == "auto":
        # the Mosaic kernel has no float64 support: f64 contracts stay on
        # the bit-identical scan even on TPU (pass f32 inputs — or request
        # backend="pallas" explicitly — for the native kernel path)
        on_tpu = jax.default_backend() == "tpu"
        f64 = np.dtype(cum.dtype) == np.float64
        backend = "pallas" if on_tpu and not f64 else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")

    avail = np.asarray(avail, dtype=bool)
    B, T = avail.shape
    Q = cum.shape[1] - 1
    use_pred = pred_zero is not None
    predz = (
        np.asarray(pred_zero, dtype=bool)
        if use_pred
        else np.zeros((B, T), dtype=bool)
    )

    if backend == "jnp":
        import jax.numpy as jnp

        from .ref import replay_scan_ref

        pad = np.full((B, window + 1), np.inf, dtype=cum.dtype)
        cum_pad = np.concatenate([cum, pad], axis=1)
        n_dev = len(jax.devices())
        if shards in (None, "auto"):
            n_shards = min(n_dev, B) if n_dev > 1 else 1
        else:
            n_shards = int(shards)
            if n_shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            if n_shards > n_dev:
                raise ValueError(
                    f"shards={n_shards} exceeds the {n_dev} visible "
                    "device(s) — the trace mesh is one shard per device"
                )
            n_shards = min(n_shards, B)
        with _x64_if(cum.dtype):
            if n_shards == 1:
                res = replay_scan_ref(
                    jnp.asarray(avail.T), jnp.asarray(predz.T),
                    jnp.asarray(cum_pad), dt, horizon_cycles,
                    q=Q, use_pred=use_pred, window=window, unroll=unroll,
                )
                res = {k: np.asarray(v) for k, v in res.items()}
            else:
                # pad the trace axis up to a shard multiple with inert
                # rows (never available -> the scan body never acts on
                # them), then slice the padding back off
                pad_b = (-B) % n_shards
                if pad_b:
                    avail = np.concatenate(
                        [avail, np.zeros((pad_b, T), dtype=bool)]
                    )
                    predz = np.concatenate(
                        [predz, np.zeros((pad_b, T), dtype=bool)]
                    )
                    cum_pad = np.concatenate(
                        [cum_pad,
                         np.full((pad_b, cum_pad.shape[1]), np.inf,
                                 dtype=cum_pad.dtype)]
                    )
                fn = _mesh_scan(n_shards, use_pred, window, unroll)
                res = fn(
                    jnp.asarray(avail.T), jnp.asarray(predz.T),
                    jnp.asarray(cum_pad), dt, horizon_cycles, Q,
                )
                res = {k: np.asarray(v)[:B] for k, v in res.items()}
    else:
        import jax.numpy as jnp

        from .kernel import replay_scan_kernel

        block_b = min(block_b, B)
        chunk = min(chunk, T)
        pad_b = (-B) % block_b
        pad_t = (-T) % chunk
        av = np.zeros((B + pad_b, T + pad_t), dtype=np.int32)
        av[:B, :T] = avail
        pz = np.zeros_like(av)
        pz[:B, :T] = predz
        cm = np.zeros((B + pad_b, Q + 1), dtype=cum.dtype)
        cm[:B] = cum
        with _x64_if(cum.dtype):
            res = replay_scan_kernel(
                jnp.asarray(av),
                jnp.asarray(pz),
                jnp.asarray(cm),
                dt=dt,
                horizon_cycles=horizon_cycles,
                t_real=T,
                use_pred=use_pred,
                block_b=block_b,
                chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
            res = {k: np.asarray(v)[:B] for k, v in res.items()}

    return {
        "lost_seconds": res["lost_seconds"],
        "idle_seconds": res["idle_seconds"],
        "completed": res["completed"].astype(np.int64),
        "total_queries": np.full(B, Q, dtype=np.int64),
        "makespan_seconds": res["makespan_seconds"],
    }
