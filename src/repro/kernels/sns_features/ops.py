"""Public entry point for the SnS feature kernel (auto-interpret off-TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import sns_features

__all__ = ["sns_features_op"]


def sns_features_op(s, *, n: int, window_minutes: float, dt_minutes: float,
                    block_p: int = 8):
    w = int(round(window_minutes / dt_minutes))
    interpret = jax.default_backend() != "tpu"
    return sns_features(
        jnp.asarray(s, jnp.int32), n=n, w=w, dt=dt_minutes,
        block_p=block_p, interpret=interpret,
    )
