"""Logical→mesh sharding rules for the LM zoo.

The production mesh is ``(data=16, model=16)`` per pod, with an optional
leading ``pod`` axis (pure data parallelism across pods).  The scheme is
the standard 2-D layout:

* **TP** — attention heads / FFN hidden / expert axis shard over ``model``;
* **FSDP** — the remaining large parameter axis (usually ``d_model``)
  shards over ``data`` (ZeRO-3; XLA all-gathers per layer inside the
  scan);
* **DP** — batch shards over ``(pod, data)``; gradients all-reduce over
  both.

Rules are *divisibility-aware*: an axis is only mapped to a mesh axis that
divides it evenly (e.g. whisper's 20 heads and arctic's 56 heads cannot
shard 16 ways — attention falls back to replicated heads there, an honest
cost that shows up in the roofline and motivates the sequence-parallel
hillclimb in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .common import ModelConfig

__all__ = ["ShardingRules", "make_rules", "param_specs", "param_shardings"]


class ShardingRules:
    """Resolves named logical axes to mesh axes with divisibility checks."""

    def __init__(self, mesh_axes: Dict[str, int], *, tp_axis="model"):
        self.sizes = dict(mesh_axes)
        self.tp = tp_axis if tp_axis in self.sizes else None
        dp = [a for a in ("pod", "data") if a in self.sizes]
        self.dp: Tuple[str, ...] = tuple(dp) if dp else ()
        # FSDP spans ALL data-parallel axes (pods included): ZeRO-3 across
        # pods is what keeps arctic's 480B params + f32 Adam state under
        # the per-chip HBM budget.
        self.fsdp: Optional[Tuple[str, ...]] = self.dp or None

    def tp_if(self, dim: int) -> Optional[str]:
        if self.tp and dim % self.sizes[self.tp] == 0:
            return self.tp
        return None

    def fsdp_if(self, dim: int):
        if not self.fsdp:
            return None
        total = 1
        for a in self.fsdp:
            total *= self.sizes[a]
        if dim % total == 0:
            return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
        # fall back to the largest single axis that divides
        for a in self.fsdp:
            if dim % self.sizes[a] == 0:
                return a
        return None


def make_rules(mesh: Optional[Mesh]) -> ShardingRules:
    if mesh is None:
        return ShardingRules({})
    return ShardingRules({name: size for name, size in zip(mesh.axis_names, mesh.devices.shape)})


def _leaf_spec(cfg: ModelConfig, r: ShardingRules, path: Tuple[str, ...], shape) -> P:
    """Spec for one parameter leaf.  `path` is the nested-dict key path
    WITHOUT the stacked-layer prefix; stacked leading axes get None."""
    name = path[-1]
    d, v = cfg.d_model, cfg.vocab_size
    # -- embeddings / head ------------------------------------------------
    if name == "embedding":
        return P(r.tp_if(v), r.fsdp_if(d))
    if name == "lm_head":
        return P(r.fsdp_if(d), r.tp_if(v))
    if name in ("pos_embedding",):
        return P(None, None)
    # -- attention ---------------------------------------------------------
    if name == "wq":
        return P(r.fsdp_if(d), r.tp_if(cfg.n_heads), None)
    if name in ("wk", "wv"):
        return P(r.fsdp_if(d), r.tp_if(cfg.n_kv_heads), None)
    if name == "wo":
        return P(r.tp_if(cfg.n_heads), None, r.fsdp_if(d))
    if name == "bq":
        return P(r.tp_if(cfg.n_heads), None)
    if name in ("bk", "bv"):
        return P(r.tp_if(cfg.n_kv_heads), None)
    # -- dense mlp -----------------------------------------------------------
    if name in ("w_gate", "w_up") and len(shape) == 2:
        return P(r.fsdp_if(shape[0]), r.tp_if(shape[1]))
    if name == "w_down" and len(shape) == 2:
        return P(r.tp_if(shape[0]), r.fsdp_if(shape[1]))
    if name in ("b_up",):
        return P(r.tp_if(shape[0]))
    # -- moe (must match moe_ffn shard_map in_specs) -------------------------
    if name == "router":
        return P(None, None)
    if name in ("w_gate", "w_up") and len(shape) == 3:   # (E, d, dff)
        return P(r.tp_if(cfg.n_experts), None, r.fsdp_if(shape[2]))
    if name == "w_down" and len(shape) == 3:             # (E, dff, d)
        return P(r.tp_if(cfg.n_experts), r.fsdp_if(shape[1]), None)
    # -- mamba ----------------------------------------------------------------
    din = cfg.d_inner
    if name == "in_proj":
        return P(r.fsdp_if(d), r.tp_if(2 * din))
    if name == "conv_w":
        return P(None, r.tp_if(din))
    if name in ("conv_b", "d_skip", "dt_bias"):
        return P(r.tp_if(din))
    if name == "x_proj":
        return P(r.tp_if(din), None)
    if name == "dt_proj":
        return P(None, r.tp_if(din))
    if name == "a_log":
        return P(r.tp_if(din), None)
    if name == "out_proj":
        return P(r.tp_if(din), r.fsdp_if(d))
    # -- norms / everything else: replicated ------------------------------------
    return P(*([None] * len(shape)))


def param_specs(cfg: ModelConfig, params, rules: ShardingRules, *, stacked_prefixes=("layers", "enc_layers", "dec_layers")):
    """PartitionSpec pytree matching `params`.

    Leaves under a stacked-layers subtree get a leading ``None`` for the
    layer axis; leaf rules are keyed by the final dict key.
    """

    def walk(tree, path, stacked):
        if isinstance(tree, dict):
            return {
                k: walk(v, path + (k,), stacked or k in stacked_prefixes)
                for k, v in tree.items()
            }
        if isinstance(tree, (list, tuple)):
            out = [walk(v, path, stacked) for v in tree]
            return type(tree)(out)
        shape = np.shape(tree)
        if stacked:
            inner = _leaf_spec(cfg, rules, path, shape[1:])
            return P(None, *inner)
        return _leaf_spec(cfg, rules, path, shape)

    return walk(params, (), False)


def param_shardings(cfg: ModelConfig, params, mesh: Mesh):
    rules = make_rules(mesh)
    specs = param_specs(cfg, params, rules)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
