"""Goodput engine: replay contract parity, carried writes, live streaming.

The load-bearing guarantee under test is the house bit-identity invariant:
the scalar reference :func:`repro.fleet.run_replay`, the vectorised numpy
engine, the ``lax.scan`` engine, and the fused policy-planes kernel engine
of :func:`repro.fleet.run_replay_batch` must agree **exactly** (atol=0)
row for row across pods × policies × seeds — and the online
:class:`repro.fleet.GoodputStream` must reproduce the offline batch replay
of the same campaign bit for bit.  The kernel engine's float32 fast tier
must reproduce every integer decision of the f64 oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SimulatedProvider, default_fleet
from repro.core.features import compute_features
from repro.core.pipeline import CampaignPipelineStream
from repro.fleet import (
    FixedInterval,
    GoodputStream,
    PodTrace,
    PolicyTable,
    SnSHazard,
    YoungDaly,
    run_goodput_frontier,
    run_replay,
    run_replay_batch,
    run_replay_fleet,
)

DT = 180.0


def _trace(avail, dt=DT):
    avail = np.asarray(avail)
    T = len(avail)
    return PodTrace(
        pod_id=0,
        pool_id="pool-0",
        times=np.arange(T, dtype=np.float64) * dt,
        available=avail.astype(np.int8),
        features=np.zeros((T, 3)),
        dt=dt,
    )


def _policies():
    return [
        FixedInterval(600.0),
        YoungDaly(ckpt_cost=25.0, mtbf=3000.0),
        SnSHazard(ckpt_cost=200.0, horizon=900.0, panic_threshold=0.4),
        SnSHazard(ckpt_cost=25.0, horizon=900.0),
    ]


def _rand_fleet(seed, pods=6, cycles=80):
    rng = np.random.default_rng(seed)
    avail = rng.random((pods, cycles)) > 0.18
    p = rng.random((pods, cycles))
    return avail, p


def _scalar_reference(avail, p, policies, **kw):
    """Per-row scalar replays stacked policy-major, like the batch engines."""
    out = {}
    rows = []
    for pol in policies:
        for r in range(avail.shape[0]):
            rows.append(
                run_replay(_trace(avail[r], dt=kw["dt"]), policy=pol,
                           step_time=kw["step_time"], ckpt_cost=kw["ckpt_cost"],
                           restore_cost=kw["restore_cost"],
                           p_survive=None if p is None else p[r])
            )
    out["steps_completed"] = np.array([r.steps_completed for r in rows])
    out["steps_lost"] = np.array([r.steps_lost for r in rows])
    out["checkpoints"] = np.array([r.checkpoints for r in rows])
    out["ckpt_overhead_s"] = np.array([r.ckpt_overhead_s for r in rows])
    out["unavailable_s"] = np.array([r.unavailable_s for r in rows])
    return out


class TestEngineParity:
    @pytest.mark.parametrize("ckpt_cost", [30.0, 200.0])  # 200 > dt exercises carry
    def test_four_engines_bit_identical(self, ckpt_cost):
        avail, p = _rand_fleet(seed=7)
        policies = _policies()
        kw = dict(dt=DT, step_time=2.0, ckpt_cost=ckpt_cost, restore_cost=60.0)
        ref = _scalar_reference(avail, p, policies, **kw)
        table = PolicyTable.from_policies(policies, repeat=avail.shape[0])
        big_avail = np.tile(avail, (len(policies), 1))
        big_p = np.tile(p, (len(policies), 1))
        for engine in ("numpy", "scan", "kernel"):
            got = run_replay_batch(big_avail, table, p_survive=big_p,
                                   engine=engine, **kw)
            for key, want in ref.items():
                np.testing.assert_array_equal(got[key], want, err_msg=f"{engine}:{key}")

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        dt=st.sampled_from([60.0, 180.0, 300.0]),
        step_time=st.sampled_from([1.0, 2.0, 7.0]),
        ckpt_cost=st.sampled_from([10.0, 30.0, 250.0]),
    )
    def test_parity_property(self, seed, dt, step_time, ckpt_cost):
        avail, p = _rand_fleet(seed, pods=3, cycles=40)
        pol = SnSHazard(ckpt_cost=ckpt_cost, horizon=900.0, panic_threshold=0.35)
        kw = dict(dt=dt, step_time=step_time, ckpt_cost=ckpt_cost, restore_cost=45.0)
        ref = _scalar_reference(avail, p, [pol], **kw)
        for engine in ("numpy", "scan", "kernel"):
            got = run_replay_batch(avail, pol, p_survive=p, engine=engine, **kw)
            for key, want in ref.items():
                np.testing.assert_array_equal(got[key], want, err_msg=f"{engine}:{key}")

    def test_no_predictor_matches_p_one(self):
        avail, _ = _rand_fleet(seed=3, pods=4)
        pol = SnSHazard(ckpt_cost=30.0, horizon=900.0)
        a = run_replay_batch(avail, pol, engine="numpy")
        b = run_replay_batch(avail, pol, p_survive=np.ones_like(avail, dtype=float),
                             engine="numpy")
        for key in a:
            np.testing.assert_array_equal(a[key], b[key])

    def test_fleet_fused_planes_match_tiled_batch(self):
        """run_replay_fleet's kernel path shares each pod's hazard row
        across all policy planes; its policy-major rows must equal the
        numpy batch over explicitly tiled rows, atol=0."""
        avail, p = _rand_fleet(seed=19, pods=5, cycles=70)
        policies = _policies()
        kw = dict(dt=DT, step_time=2.0, ckpt_cost=30.0, restore_cost=60.0)
        want = run_replay_batch(
            np.tile(avail, (len(policies), 1)),
            PolicyTable.from_policies(policies, repeat=avail.shape[0]),
            p_survive=np.tile(p, (len(policies), 1)), engine="numpy", **kw)
        got = run_replay_fleet(avail, policies, p_survive=p,
                               engine="kernel", **kw)
        for key in want:
            np.testing.assert_array_equal(got[key], want[key], err_msg=key)

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engine"):
            run_replay_batch(np.ones((1, 4), bool), FixedInterval(600.0),
                             engine="pallas")

    def test_f32_rejected_outside_kernel_engine(self):
        with pytest.raises(ValueError, match="precision"):
            run_replay_batch(np.ones((1, 4), bool), FixedInterval(600.0),
                             engine="numpy", precision="f32")

    def test_policy_row_mismatch_rejected(self):
        with pytest.raises(ValueError, match="rows"):
            run_replay_batch(np.ones((3, 4), bool),
                             PolicyTable.from_policies([FixedInterval(600.0)],
                                                       repeat=2))


class TestF32FastTier:
    """The kernel engine's float32 tier vs the f64 oracle.

    Every timed quantity in these workloads is a dyadic rational (dt,
    step_time, δ, restore cost), so clocks and budgets are exact in both
    tiers; τ itself is transcendental but the compared time gaps sit
    ≫ 1 f32 ulp away from it, so every integer decision — and here every
    float metric — must come out identical."""

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        step_time=st.sampled_from([1.0, 2.0, 7.0]),
        ckpt_cost=st.sampled_from([10.0, 30.0, 250.0]),  # 250 > dt: carry
    )
    def test_property_decisions_identical(self, seed, step_time, ckpt_cost):
        avail, p = _rand_fleet(seed, pods=4, cycles=50)
        policies = _policies()
        table = PolicyTable.from_policies(policies, repeat=avail.shape[0])
        big_avail = np.tile(avail, (len(policies), 1))
        big_p = np.tile(p, (len(policies), 1))
        kw = dict(dt=DT, step_time=step_time, ckpt_cost=ckpt_cost,
                  restore_cost=45.0, engine="kernel")
        f64 = run_replay_batch(big_avail, table, p_survive=big_p, **kw)
        f32 = run_replay_batch(big_avail, table, p_survive=big_p,
                               precision="f32", **kw)
        for key in ("steps_completed", "steps_lost", "checkpoints"):
            np.testing.assert_array_equal(f64[key], f32[key], err_msg=key)
        for key in ("ckpt_overhead_s", "unavailable_s", "lost_work_s",
                    "goodput"):
            np.testing.assert_array_equal(f64[key], f32[key], err_msg=key)

    def test_fleet_f32_decisions_identical(self):
        avail, p = _rand_fleet(seed=23, pods=6, cycles=90)
        policies = _policies()
        kw = dict(dt=DT, step_time=2.0, ckpt_cost=30.0, restore_cost=60.0,
                  engine="kernel")
        f64 = run_replay_fleet(avail, policies, p_survive=p, **kw)
        f32 = run_replay_fleet(avail, policies, p_survive=p,
                               precision="f32", **kw)
        for key in ("steps_completed", "steps_lost", "checkpoints"):
            np.testing.assert_array_equal(f64[key], f32[key], err_msg=key)


class TestCarriedWrites:
    """Satellite regression: ckpt_cost > dt must carry across cycles."""

    def test_hand_computed_carry(self):
        # dt=100, δ=150 (> dt), step_time=10, always up, FixedInterval(50):
        # c0: no ckpt due (t_c=0) → 10 steps.
        # c1: write starts, pays 100 of 150, carries write_rem=50 → 0 steps.
        # c2: carry drains (50s) → ckpt #1 completes at t=250, 5 steps.
        # c3: next write starts, carries again → 0 steps.
        res = run_replay(_trace([1, 1, 1, 1], dt=100.0),
                         policy=FixedInterval(50.0),
                         step_time=10.0, ckpt_cost=150.0, restore_cost=0.0)
        assert res.steps_completed == 15
        assert res.checkpoints == 1
        assert res.ckpt_overhead_s == 250.0
        assert res.steps_lost == 0

    def test_aborted_write_protects_nothing(self):
        # The write straddling c1/c2 is killed by the c2 preemption: the
        # 100 s already paid stays paid, the ckpt never lands, and every
        # step since t=0 is lost.
        res = run_replay(_trace([1, 1, 0, 1], dt=100.0),
                         policy=FixedInterval(50.0),
                         step_time=10.0, ckpt_cost=150.0, restore_cost=0.0)
        assert res.checkpoints == 0
        assert res.steps_lost == 10
        assert res.ckpt_overhead_s >= 100.0

    def test_completed_write_protects_steps(self):
        # Same trace, cheap checkpoint: the c1 write completes in-cycle,
        # so only the steps after it are exposed to the c2 preemption.
        res = run_replay(_trace([1, 1, 0, 1], dt=100.0),
                         policy=FixedInterval(50.0),
                         step_time=10.0, ckpt_cost=20.0, restore_cost=0.0)
        assert res.checkpoints >= 1
        assert res.steps_lost < 10


class TestInvariants:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_goodput_bounded_and_time_conserved(self, seed):
        avail, p = _rand_fleet(seed, pods=5, cycles=60)
        got = run_replay_batch(avail, _policies()[0], p_survive=p, engine="numpy")
        assert np.all(got["goodput"] >= 0.0) and np.all(got["goodput"] <= 1.0)
        # Up-time budget: training + ckpt overhead can never exceed the
        # available seconds; total wall time is conserved per row.
        T = avail.shape[1]
        up_s = T * DT - got["unavailable_s"]
        spent = got["steps_completed"] * 2.0 + got["ckpt_overhead_s"]
        assert np.all(spent <= up_s + 1e-9)
        np.testing.assert_allclose(got["unavailable_s"],
                                   (~avail).sum(axis=1) * DT)

    def test_never_available_trace(self):
        avail = np.zeros((2, 50), dtype=bool)
        got = run_replay_batch(
            avail, [FixedInterval(600.0), SnSHazard(30.0, 900.0)], engine="numpy")
        assert np.all(got["checkpoints"] == 0)
        assert np.all(got["steps_completed"] == 0)
        assert np.all(got["goodput"] == 0.0)
        np.testing.assert_array_equal(got["unavailable_s"], 50 * DT)

    def test_always_available_loses_nothing(self):
        avail = np.ones((1, 50), dtype=bool)
        got = run_replay_batch(avail, FixedInterval(600.0), engine="scan")
        assert got["steps_lost"][0] == 0
        assert got["goodput"][0] == 1.0

    def test_frontier_aggregates_match_batch(self):
        avail, p = _rand_fleet(seed=11, pods=4, cycles=60)
        pols = _policies()
        names = ["fixed", "yd", "hazard-big", "hazard"]
        front = run_goodput_frontier(avail, pols, p_survive=p, names=names,
                                     engine="numpy")
        assert set(front) == set(names)
        batch = run_replay_batch(
            np.tile(avail, (len(pols), 1)),
            PolicyTable.from_policies(pols, repeat=4, names=names),
            p_survive=np.tile(p, (len(pols), 1)), engine="numpy")
        for i, n in enumerate(names):
            rows = slice(i * 4, (i + 1) * 4)
            assert front[n].steps_completed == int(batch["steps_completed"][rows].sum())
            assert front[n].checkpoints == int(batch["checkpoints"][rows].sum())


def _make_stream(pools=8, duration=6 * 3600.0):
    fleet = default_fleet(pools, seed=1)
    provider = SimulatedProvider(fleet, seed=2)

    def predict(feats):  # heuristic: high UR → likely interrupt
        return 1.0 - np.clip((feats[:, 1] - 0.05) * 3.0, 0.0, 1.0)

    return CampaignPipelineStream(provider, predict_fn=predict,
                                  window_minutes=120, duration=duration)


class TestGoodputStream:
    N_PODS = 5

    def test_streamed_equals_batch(self):
        policies = [FixedInterval(1800.0),
                    SnSHazard(ckpt_cost=30.0, horizon=900.0, panic_threshold=0.35)]
        gs = GoodputStream(_make_stream(), policies, n_pods=self.N_PODS)
        n_views = sum(1 for _ in gs)
        streamed = gs.result()

        # Offline: drain the finished campaign, recompute the exact same
        # per-cycle probabilities, and batch-replay.
        result = gs.stream.result()
        feats = compute_features(result.s, result.n, 120, result.interval / 60.0)
        p = np.stack(
            [1.0 - np.clip((feats[:, c, 1] - 0.05) * 3.0, 0.0, 1.0)
             for c in range(result.s.shape[1])], axis=1)
        avail = (result.running >= result.n)[: self.N_PODS]
        big_avail = np.tile(avail, (len(policies), 1))
        table = PolicyTable.from_policies(policies, repeat=self.N_PODS)
        big_p = np.tile(p[: self.N_PODS], (len(policies), 1))
        assert n_views == avail.shape[1]
        for engine in ("numpy", "kernel"):
            batch = run_replay_batch(big_avail, table, p_survive=big_p,
                                     dt=result.interval, engine=engine)
            for key in batch:
                np.testing.assert_array_equal(streamed[key], batch[key],
                                              err_msg=f"{engine}:{key}")

    def test_cycle_view_shapes(self):
        policies = [FixedInterval(600.0), SnSHazard(30.0, 900.0)]
        gs = GoodputStream(_make_stream(duration=3600.0), policies,
                           n_pods=self.N_PODS)
        view = gs.step()
        assert view.up.shape == (self.N_PODS,)
        for arr in (view.write_started, view.ckpt_completed, view.panic, view.steps):
            assert arr.shape == (len(policies), self.N_PODS)
        # Fixed rows never panic regardless of forecasts.
        assert not view.panic[0].any()

    def test_kill_and_restore_bit_identical(self):
        policies = [FixedInterval(900.0), SnSHazard(30.0, 900.0)]
        g1 = GoodputStream(_make_stream(), policies, n_pods=self.N_PODS)
        for _ in range(40):
            g1.step()
        snap = g1.state_dict()

        g2 = GoodputStream(_make_stream(), policies, n_pods=self.N_PODS)
        g2.restore(snap)
        assert g2.cycles_run == 40
        for _ in iter(g1.step, None):
            pass
        for _ in iter(g2.step, None):
            pass
        r1, r2 = g1.result(), g2.result()
        for key in r1:
            np.testing.assert_array_equal(r1[key], r2[key], err_msg=key)
        f1, f2 = g1.frontier(), g2.frontier()
        assert {n: r.steps_completed for n, r in f1.items()} == \
               {n: r.steps_completed for n, r in f2.items()}
