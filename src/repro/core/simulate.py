"""Trace-driven workload simulation — paper §VI-E, Fig. 9.

Replays a 24-hour availability trace (3-minute cycles) against a batch
query workload and compares scheduling strategies:

* **Always Run** — launch the next queued query immediately whenever the
  pool is available and idle (unguided baseline).
* **Shortest Job First** — same, with the queue sorted by ascending
  duration (reduces expected loss per interruption without prediction).
* **Predict-AR** — consults the SnS-trained predictor every collection
  cycle; when it forecasts upcoming unavailability, *defers launching new
  queries* for the prediction-horizon duration while leaving any running
  query undisturbed (the paper's strategy).

Semantics follow the paper: queries proceed only while the pool is fully
available; the running query's progress is lost the moment the pool
becomes unavailable (binary formulation — §IV-A), and the query is retried
later.  Metrics: total lost computation, idle-while-available time, and
makespan.  The experiment repeats each run over random permutations of the
query queue and averages (§VI-E).

Two implementations share these semantics exactly:

* :func:`replay` — the scalar reference: one trace, one strategy, a plain
  Python event loop (readable, and the parity oracle for the batch path).
* :func:`replay_batch` — the fleet-scale path: a ``(B, T)`` stack of
  traces advances in lock-step with all per-trace state (queue head,
  running query, deferral clock, metrics) in stacked arrays, so thousands
  of (pool × permutation) traces replay in one call.  Results are
  bit-identical to :func:`replay` row by row.

:func:`run_strategies` (one trace, permutation-averaged) and
:func:`run_fleet_strategies` (pools × permutations × strategies in one
shot — the §VI-E experiment) are thin drivers over :func:`replay_batch`.
Prediction inputs are per-cycle label *arrays* (one model call for the
whole trace) rather than per-cycle callables — the batched-predictor
contract of the fleet pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

__all__ = [
    "SimResult",
    "replay",
    "replay_batch",
    "run_strategies",
    "run_fleet_strategies",
]

#: legacy prediction callback: cycle index -> 1 if pool forecast available
PredictorFn = Callable[[int], int]

STRATEGIES = ("always_run", "sjf", "predict_ar")


@dataclasses.dataclass
class SimResult:
    strategy: str
    lost_seconds: float
    idle_seconds: float          # pool available but deliberately idle
    completed: int
    total_queries: int
    makespan_seconds: float

    def __add__(self, other: "SimResult") -> "SimResult":
        assert self.strategy == other.strategy
        return SimResult(
            self.strategy,
            self.lost_seconds + other.lost_seconds,
            self.idle_seconds + other.idle_seconds,
            self.completed + other.completed,
            self.total_queries + other.total_queries,
            self.makespan_seconds + other.makespan_seconds,
        )

    def scaled(self, k: float) -> "SimResult":
        return SimResult(
            self.strategy,
            self.lost_seconds * k,
            self.idle_seconds * k,
            int(round(self.completed * k)),
            int(round(self.total_queries * k)),
            self.makespan_seconds * k,
        )


def _predictions_array(
    predictions, predictor: Optional[PredictorFn], t_cycles: int
) -> Optional[np.ndarray]:
    """Normalize the prediction input to a per-cycle label array."""
    if predictions is not None:
        return np.asarray(predictions)
    if predictor is not None:
        return np.array([int(predictor(c)) for c in range(t_cycles)])
    return None


def replay(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    strategy: str = "always_run",
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
) -> SimResult:
    """Replay one trace with one strategy (scalar reference).

    Args:
      avail: (T,) binary pool availability per collection cycle.
      durations: query durations (seconds).
      strategy: "always_run" | "sjf" | "predict_ar".
      predictions: required for predict_ar — (T,) per-cycle predicted
        labels (1 = stays available over the horizon).  ``predictor`` is
        the legacy per-cycle callable form, evaluated over all cycles.
      horizon_cycles: deferral length when the predictor flags risk.
    """
    avail = np.asarray(avail).astype(bool)
    queue: List[float] = list(durations)
    if strategy == "sjf":
        queue.sort()
    pred = _predictions_array(predictions, predictor, len(avail))
    if strategy == "predict_ar" and pred is None:
        raise ValueError("predict_ar requires predictions")

    t_cycles = len(avail)
    lost = 0.0
    idle = 0.0
    completed = 0
    makespan = t_cycles * dt
    remaining: Optional[float] = None    # remaining work of running query
    progress = 0.0                        # work done on the running query
    defer_until_cycle = -1

    for c in range(t_cycles):
        if not avail[c]:
            # pool down for this cycle: running query loses all progress
            if remaining is not None:
                lost += progress
                queue.insert(0, progress + remaining)  # retry full query
                remaining, progress = None, 0.0
            continue

        if strategy == "predict_ar" and c > defer_until_cycle:
            if pred[c] == 0:  # forecast: will NOT stay available
                defer_until_cycle = c + horizon_cycles

        budget = dt
        while budget > 1e-9:
            if remaining is None:
                deferred = strategy == "predict_ar" and c <= defer_until_cycle
                if not queue or deferred:
                    idle += budget
                    break
                remaining, progress = queue.pop(0), 0.0
            step = min(budget, remaining)
            remaining -= step
            progress += step
            budget -= step
            if remaining <= 1e-9:
                completed += 1
                remaining, progress = None, 0.0
                if not queue:
                    makespan = min(makespan, (c + 1) * dt - budget)

    # a query still running when the trace ends is neither lost nor complete
    return SimResult(
        strategy=strategy,
        lost_seconds=lost,
        idle_seconds=idle,
        completed=completed,
        total_queries=len(durations),
        makespan_seconds=makespan,
    )


def replay_batch(
    avail: np.ndarray,
    durations: np.ndarray,
    *,
    strategy: str = "always_run",
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    horizon_cycles: int = 1,
) -> Dict[str, np.ndarray]:
    """Replay a stack of traces with one strategy, all rows in lock-step.

    Args:
      avail: (B, T) — or (T,), broadcast — binary availability per trace.
      durations: (B, Q) — or (Q,), broadcast — per-trace query queues in
        launch order (``sjf`` sorts each row internally).
      predictions: (B, T) or (T,) per-cycle labels, required for
        ``predict_ar``.

    Returns stacked metrics, bit-identical to calling :func:`replay` per
    row: ``{"lost_seconds", "idle_seconds", "completed", "total_queries",
    "makespan_seconds"}``, each of shape (B,).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    avail = np.atleast_2d(np.asarray(avail).astype(bool))
    dur = np.atleast_2d(np.asarray(durations, dtype=np.float64))
    B = max(avail.shape[0], dur.shape[0])
    T, Q = avail.shape[1], dur.shape[1]
    avail = np.broadcast_to(avail, (B, T))
    # owned copy: interrupted queries write their duration back to the queue
    dur = np.array(np.broadcast_to(dur, (B, Q)))
    if strategy == "sjf":
        dur = np.sort(dur, axis=1)
    pred = None
    if strategy == "predict_ar":
        if predictions is None:
            raise ValueError("predict_ar requires predictions")
        pred = np.atleast_2d(np.asarray(predictions))
        pred = np.broadcast_to(pred, (B, T))

    head = np.zeros(B, dtype=np.int64)          # next queue slot to launch
    running = np.zeros(B, dtype=bool)
    remaining = np.zeros(B)
    progress = np.zeros(B)
    defer_until = np.full(B, -1, dtype=np.int64)
    lost = np.zeros(B)
    idle = np.zeros(B)
    completed = np.zeros(B, dtype=np.int64)
    makespan = np.full(B, T * dt, dtype=np.float64)
    rows = np.arange(B)

    for c in range(T):
        up = avail[:, c]
        # pool down: the running query loses all progress and is re-queued
        # at the front (progress + remaining == its full duration)
        drop = ~up & running
        if drop.any():
            lost[drop] += progress[drop]
            head[drop] -= 1
            dur[rows[drop], head[drop]] = progress[drop] + remaining[drop]
            running[drop] = False
            progress[drop] = 0.0
        if pred is not None:
            trig = up & (c > defer_until) & (pred[:, c] == 0)
            defer_until[trig] = c + horizon_cycles
        budget = np.where(up, dt, 0.0)
        while True:
            act = budget > 1e-9
            if not act.any():
                break
            # rows with no running query: launch the next one, or idle out
            need = act & ~running
            if need.any():
                blocked = head >= Q
                if pred is not None:
                    blocked = blocked | (c <= defer_until)
                sit = need & blocked
                idle[sit] += budget[sit]
                budget[sit] = 0.0
                pop = need & ~blocked
                if pop.any():
                    remaining[pop] = dur[rows[pop], head[pop]]
                    head[pop] += 1
                    progress[pop] = 0.0
                    running[pop] = True
            # advance the running queries by min(budget, remaining)
            go = (budget > 1e-9) & running
            if not go.any():
                break  # every live row idled out this cycle
            step = np.where(go, np.minimum(budget, remaining), 0.0)
            remaining -= step
            progress = progress + np.where(go, step, 0.0)
            budget -= step
            fin = go & (remaining <= 1e-9)
            if fin.any():
                completed[fin] += 1
                running[fin] = False
                progress[fin] = 0.0
                last = fin & (head >= Q)
                if last.any():
                    makespan[last] = np.minimum(
                        makespan[last], (c + 1) * dt - budget[last]
                    )

    return {
        "lost_seconds": lost,
        "idle_seconds": idle,
        "completed": completed,
        "total_queries": np.full(B, Q, dtype=np.int64),
        "makespan_seconds": makespan,
    }


def _results_from_batch(
    strategy: str, batch: Dict[str, np.ndarray]
) -> List[SimResult]:
    return [
        SimResult(
            strategy=strategy,
            lost_seconds=float(batch["lost_seconds"][b]),
            idle_seconds=float(batch["idle_seconds"][b]),
            completed=int(batch["completed"][b]),
            total_queries=int(batch["total_queries"][b]),
            makespan_seconds=float(batch["makespan_seconds"][b]),
        )
        for b in range(len(batch["lost_seconds"]))
    ]


def _mean_result(strategy: str, batch: Dict[str, np.ndarray]) -> SimResult:
    return SimResult(
        strategy=strategy,
        lost_seconds=float(batch["lost_seconds"].sum() / len(batch["lost_seconds"])),
        idle_seconds=float(batch["idle_seconds"].sum() / len(batch["idle_seconds"])),
        completed=int(round(batch["completed"].sum() / len(batch["completed"]))),
        total_queries=int(
            round(batch["total_queries"].sum() / len(batch["total_queries"]))
        ),
        makespan_seconds=float(
            batch["makespan_seconds"].sum() / len(batch["makespan_seconds"])
        ),
    )


def run_strategies(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
    n_permutations: int = 5,
    seed: int = 0,
) -> List[SimResult]:
    """Average each strategy over query-order permutations (§VI-E).

    All permutations of one strategy replay as a single
    :func:`replay_batch` call instead of a Python loop of scalar replays.
    """
    rng = np.random.default_rng(seed)
    avail = np.asarray(avail)
    durations = np.asarray(durations, dtype=np.float64)
    pred = _predictions_array(predictions, predictor, avail.shape[-1])
    strategies = ["always_run", "sjf"]
    if pred is not None:
        strategies.append("predict_ar")
    perms = np.stack([rng.permutation(durations) for _ in range(n_permutations)])
    out = []
    for s in strategies:
        batch = replay_batch(
            np.broadcast_to(avail, (n_permutations, avail.shape[-1])),
            perms,
            strategy=s,
            dt=dt,
            predictions=pred,
            horizon_cycles=horizon_cycles,
        )
        out.append(_mean_result(s, batch))
    return out


def run_fleet_strategies(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    dt: float = 180.0,
    predictions: Optional[np.ndarray] = None,
    horizon_cycles: int = 1,
    n_permutations: int = 5,
    seeds: Optional[Sequence[int]] = None,
) -> Dict[str, List[SimResult]]:
    """The §VI-E experiment in one shot: every (pool × permutation ×
    strategy) trace replays inside three :func:`replay_batch` calls.

    Args:
      avail: (pools, T) per-pool availability traces.
      durations: (Q,) query profile, permuted per pool/permutation.
      predictions: (pools, T) per-pool per-cycle predicted labels;
        enables the ``predict_ar`` strategy.
      seeds: per-pool permutation seeds (defaults to the pool index, the
        historical per-pool convention).

    Returns ``{strategy: [per-pool permutation-averaged SimResult]}``.
    """
    avail = np.asarray(avail)
    if avail.ndim != 2:
        raise ValueError(f"avail must be (pools, T), got {avail.shape}")
    pools, T = avail.shape
    durations = np.asarray(durations, dtype=np.float64)
    if seeds is None:
        seeds = range(pools)
    perm_rows = []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        perm_rows.extend(rng.permutation(durations) for _ in range(n_permutations))
    perms = np.stack(perm_rows)  # (pools * n_permutations, Q)
    big_avail = np.repeat(avail, n_permutations, axis=0)
    strategies = ["always_run", "sjf"]
    big_pred = None
    if predictions is not None:
        big_pred = np.repeat(np.asarray(predictions), n_permutations, axis=0)
        strategies.append("predict_ar")
    out: Dict[str, List[SimResult]] = {}
    for s in strategies:
        batch = replay_batch(
            big_avail,
            perms,
            strategy=s,
            dt=dt,
            predictions=big_pred,
            horizon_cycles=horizon_cycles,
        )
        per_pool = []
        for p in range(pools):
            sl = slice(p * n_permutations, (p + 1) * n_permutations)
            per_pool.append(
                _mean_result(s, {k: v[sl] for k, v in batch.items()})
            )
        out[s] = per_pool
    return out
