"""Shape grid + config registry scaffolding.

Every architecture is exercised against its own four input shapes
(assignment grid).  ``train_*`` lowers ``train_step``; ``prefill_*``
lowers the prefill step; ``decode_*`` / ``long_*`` lower ``serve_step``
(one new token against a ``seq_len`` cache).  ``long_500k`` requires
sub-quadratic attention and only applies to SSM / hybrid / local-attention
archs (skips are explicit and documented, never silent).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig

__all__ = ["InputShape", "SHAPES", "shape_applicability"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def shape_applicability(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason).  The only assignment-sanctioned skip is
    ``long_500k`` for pure full-attention archs."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or (cfg.sliding_window is not None)
        )
        if not sub_quadratic:
            return False, (
                "long_500k skipped: pure full-attention arch (no sub-"
                "quadratic path); per assignment rule, run only for "
                "SSM/hybrid/local-attention"
            )
    if cfg.family == "encdec" and shape.name == "long_500k":
        return False, "long_500k skipped: enc-dec with full attention"
    return True, ""
