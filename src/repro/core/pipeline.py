"""Data Pipeline — paper §V, Fig. 4 (middle module).

Connects the Data Lake to the Interrupt Predictor:

* **WindowTable** — per-pool streaming feature state (the ring buffer of
  cumulative counts) plus the most recent feature rows and attached
  predictions.
* **FeatureProcessor** — consumes new per-cycle success counts and updates
  features *incrementally in O(1)* per pool (Algorithm 1); records that
  fall out of the window are moved to the **DataArchive**.
* Predictions from the attached predictor are written back onto the window
  rows (§V: "attaches the prediction result to the corresponding input
  record and stores it in the Window Table").

The O(1) claim is tested by counting state-update work per cycle
(``tests/test_pipeline.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .features import FeatureState, init_state, update

__all__ = ["WindowRow", "WindowTable", "DataArchive", "FeatureProcessor"]

PredictFn = Callable[[np.ndarray], float]


@dataclasses.dataclass
class WindowRow:
    cycle: int
    time: float
    s_t: int
    features: Tuple[float, float, float]
    prediction: Optional[float] = None


class DataArchive:
    """Cold storage for rows evicted from the window table."""

    def __init__(self):
        self._rows: Dict[str, List[WindowRow]] = {}

    def archive(self, pool_id: str, row: WindowRow) -> None:
        self._rows.setdefault(pool_id, []).append(row)

    def rows(self, pool_id: str) -> List[WindowRow]:
        return self._rows.get(pool_id, [])

    def __len__(self) -> int:
        return sum(len(v) for v in self._rows.values())


class WindowTable:
    """Recent rows + feature state per pool; bounded by the window length."""

    def __init__(self, archive: Optional[DataArchive] = None):
        self.rows: Dict[str, Deque[WindowRow]] = {}
        self.state: Dict[str, FeatureState] = {}
        self.archive = archive or DataArchive()

    def append(self, pool_id: str, row: WindowRow, max_rows: int) -> None:
        dq = self.rows.setdefault(pool_id, deque())
        dq.append(row)
        while len(dq) > max_rows:
            self.archive.archive(pool_id, dq.popleft())

    def latest(self, pool_id: str) -> Optional[WindowRow]:
        dq = self.rows.get(pool_id)
        return dq[-1] if dq else None


class FeatureProcessor:
    """Incremental feature computation + prediction fan-out (§V)."""

    def __init__(
        self,
        pool_ids: Sequence[str],
        *,
        n_requests: int = 10,
        window_minutes: float = 480.0,
        dt_minutes: float = 3.0,
        predict_fn: Optional[PredictFn] = None,
    ):
        self.pool_ids = list(pool_ids)
        self.n = n_requests
        self.dt_minutes = dt_minutes
        self.window_cycles = int(round(window_minutes / dt_minutes))
        self.table = WindowTable()
        self.predict_fn = predict_fn
        for pid in self.pool_ids:
            self.table.state[pid] = init_state(n_requests, window_minutes, dt_minutes)
        # instrumentation for the O(1)-per-update test
        self.update_ops = 0

    def on_cycle(self, cycle: int, time: float, s: Sequence[int]) -> Dict[str, WindowRow]:
        """Ingest one collection cycle's success counts for all pools."""
        if len(s) != len(self.pool_ids):
            raise ValueError("per-pool success counts length mismatch")
        out: Dict[str, WindowRow] = {}
        for pid, s_t in zip(self.pool_ids, s):
            state = self.table.state[pid]
            state, feats = update(state, int(s_t))
            self.update_ops += 1  # one O(1) state update per pool per cycle
            row = WindowRow(cycle=cycle, time=time, s_t=int(s_t), features=feats)
            if self.predict_fn is not None:
                row.prediction = float(self.predict_fn(np.asarray(feats)))
            self.table.append(pid, row, max_rows=self.window_cycles)
            out[pid] = row
        return out

    def feature_matrix(self, pool_id: str) -> np.ndarray:
        """(rows, 3) matrix of in-window features for one pool."""
        return np.asarray([r.features for r in self.table.rows.get(pool_id, [])])
