"""Unified Interrupt Predictor API — paper §V (right module) + §VI-A zoo.

Six model families, matching the paper's comparison set:

==============  ==========================  ====================
name            class                        input
==============  ==========================  ====================
``lr``          LogisticRegression           single data point
``svm``         LinearSVM                    single data point
``rf``          RandomForest                 single data point
``xgb``         GradientBoostedTrees         single data point
``lstm``        LSTM                         trailing sequence
``transformer`` TransformerClassifier        trailing sequence
``mlp``         MLP (extra, not in paper)    single data point
==============  ==========================  ====================

``fit_predictor`` trains on a :class:`~repro.core.dataset.Dataset`;
``evaluate`` reports F1-macro and per-class scores.

For the Data Pipeline's online serving path two adapters wrap a fitted
model into the pipeline's calling conventions:

* :func:`pointwise_predict_fn` — one feature vector -> one score, for the
  per-pool :class:`~repro.core.pipeline.FeatureProcessor` loop;
* :func:`batched_predict_fn` — one ``(pools, features)`` matrix -> one
  ``(pools,)`` score vector in a single ``predict_proba`` call, for
  :class:`~repro.core.pipeline.FleetFeatureProcessor` (every point-wise
  model's ``predict_proba`` is natively batched — lr/svm/mlp are one
  jitted matmul, rf/xgb route the whole batch through the tree ensemble
  at once); sequence models get the fleet's trailing-window tensor
  ``(pools, L, features)`` instead.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from .dataset import Dataset
from .models.linear import LinearSVM, LogisticRegression
from .models.lstm import LSTM
from .models.metrics import classification_report, f1_macro
from .models.mlp import MLP
from .models.transformer import TransformerClassifier
from .models.trees import GradientBoostedTrees, RandomForest

__all__ = [
    "MODEL_REGISTRY",
    "SEQUENCE_MODELS",
    "make_model",
    "fit_predictor",
    "evaluate",
    "pointwise_predict_fn",
    "batched_predict_fn",
]

MODEL_REGISTRY = {
    "lr": LogisticRegression,
    "svm": LinearSVM,
    "rf": RandomForest,
    "xgb": GradientBoostedTrees,
    "mlp": MLP,
    "lstm": LSTM,
    "transformer": TransformerClassifier,
}

#: models that consume (N, L, F) sequences instead of (N, F) points
SEQUENCE_MODELS = frozenset({"lstm", "transformer"})


def make_model(name: str, **hparams):
    try:
        cls = MODEL_REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown model {name!r}; choose from {sorted(MODEL_REGISTRY)}")
    return cls(**hparams)


def fit_predictor(name: str, dataset: Dataset, **hparams):
    """Train one predictor on the dataset's train split."""
    model = make_model(name, **hparams)
    wants_seq = name in SEQUENCE_MODELS
    has_seq = dataset.x_train.ndim == 3
    if wants_seq and not has_seq:
        raise ValueError(f"{name} needs sequence_length in build_dataset")
    x = dataset.x_train if wants_seq or not has_seq else dataset.x_train[:, -1, :]
    return model.fit(x, dataset.y_train)


def _is_sequence_model(model) -> bool:
    return isinstance(model, (LSTM, TransformerClassifier))


def pointwise_predict_fn(model) -> Callable[[np.ndarray], float]:
    """Adapt a fitted point-wise model to ``FeatureProcessor``'s per-pool
    ``PredictFn`` (one (features,) vector -> one probability)."""
    if _is_sequence_model(model):
        raise ValueError(
            "sequence models need trailing windows; FeatureProcessor's "
            "per-point PredictFn cannot feed them"
        )

    def fn(feats: np.ndarray) -> float:
        x = np.asarray(feats, np.float32)[None, :]
        return float(np.asarray(model.predict_proba(x)).reshape(1)[0])

    return fn


def batched_predict_fn(model) -> Callable[[np.ndarray], np.ndarray]:
    """Adapt a fitted model to ``FleetFeatureProcessor``'s ``BatchPredictFn``
    — ONE vectorised ``predict_proba`` call per cycle for the whole fleet.

    Point-wise models receive the cycle's ``(pools, features)`` matrix;
    sequence models the trailing-window tensor ``(pools, L, features)``
    (attach via ``FleetFeatureProcessor(..., sequence_length=L)``, which
    feeds ``FleetWindowTable.trailing`` once L cycles of history exist).
    Scores agree with the per-pool adapter to float32 round-off.
    """

    def fn(x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        expected_ndim = 3 if _is_sequence_model(model) else 2
        if x.ndim != expected_ndim:
            raise ValueError(
                f"{type(model).__name__} expects a {expected_ndim}-D batch, "
                f"got shape {x.shape}"
            )
        return np.asarray(model.predict_proba(x)).reshape(len(x))

    return fn


def evaluate(model, dataset: Dataset) -> Dict[str, float]:
    """F1-macro & friends on the dataset's test split."""
    wants_seq = _is_sequence_model(model)
    has_seq = dataset.x_test.ndim == 3
    x = dataset.x_test if wants_seq or not has_seq else dataset.x_test[:, -1, :]
    y_pred = model.predict(x)
    return classification_report(dataset.y_test, y_pred)
