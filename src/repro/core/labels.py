"""Binary availability labels — paper §IV-A.

The co-interruption analysis (Fig. 3) shows that once one node of a pool is
interrupted, the rest follow within minutes; predicting the exact surviving
count has limited value.  The paper therefore adopts a *binary* notion:
at each measurement point, is the full set of ``N`` requested instances
fulfilled or not?

Labels come from the *actual running instance* trace; features come from
the SnS probe trace.  For a prediction horizon ``h`` cycles, the target at
cycle ``t`` is whether the pool maintains its current scale over the whole
of ``(t, t + h]`` (§V Interrupt Predictor: "whether the target instance
node pool will maintain its current scale over a specified future
horizon").  ``h = 0`` degenerates to current-availability modeling (§VI-D
Fig. 7).
"""

from __future__ import annotations

import numpy as np

__all__ = ["binary_availability", "horizon_labels"]


def binary_availability(running: np.ndarray, n: int) -> np.ndarray:
    """1 where all ``n`` requested instances are running, else 0.

    Args:
      running: running-instance counts, shape ``(T,)`` or ``(pools, T)``.
      n: requested pool size.
    """
    running = np.asarray(running)
    return (running >= n).astype(np.int32)


def horizon_labels(avail: np.ndarray, horizon_cycles: int) -> np.ndarray:
    """Availability sustained over the next ``horizon_cycles`` cycles.

    Args:
      avail: binary availability, shape ``(..., T)``.
      horizon_cycles: ``h >= 0``.  ``h == 0`` returns ``avail`` unchanged.

    Returns:
      labels of shape ``(..., T - h)``: ``y[..., t] = min(avail[..., t+1 :
      t+h+1])`` for ``h > 0`` — 1 iff the pool stays fully available
      through the horizon.
    """
    avail = np.asarray(avail)
    h = int(horizon_cycles)
    if h < 0:
        raise ValueError("horizon must be >= 0")
    if h == 0:
        return avail.copy()
    t_total = avail.shape[-1]
    if h >= t_total:
        raise ValueError(f"horizon {h} >= trace length {t_total}")
    # sliding min over the future window (t+1 .. t+h]
    stacked = np.stack([avail[..., 1 + k : t_total - h + 1 + k] for k in range(h)], 0)
    return stacked.min(axis=0)
