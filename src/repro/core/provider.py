"""Simulated cloud provider with spot capacity pools — array-native.

This is the offline stand-in for the AWS/Azure control planes probed in the
paper (no cloud credentials in this environment).  It reproduces the
*structural* properties the paper measures, with dynamics calibrated to the
paper's published statistics:

* **Shared capacity pool per (instance type, AZ)** — all instances of a type
  in an AZ draw from one hidden capacity process ``C_t`` (§IV-A).
* **Regime-switching dynamics** — STABLE / TIGHT / CRUNCH Markov regimes.
  TIGHT tends to precede CRUNCH, so probe-visible degradation *leads*
  interruptions (the paper's §III-B observation that SnS "reflects capacity
  changes that have not yet manifested as actual interruptions").
* **Admission conservatism** — new spot requests are admitted against
  ``C_t`` minus a non-negative *admission margin* that spikes when the
  regime degrades and decays slowly afterwards.  Running instances are only
  reclaimed when ``C_t`` drops below the running count.  This yields the
  Table-I asymmetry: SnS under-counts actual availability far more often
  than it over-counts.
* **Clustered reclamation** — when capacity crunches, reclaimed nodes are
  interrupted within seconds-to-minutes of each other, calibrated to the
  Fig.-3 co-interrupt proximity CDF (>85 % < 1 min, ~93 % < 3 min).
* **Rate limits** — per-region request budgets per minute; the 3-minute
  probe cadence in the paper is the fastest cadence that stays within them.

Architecture (SpotLake-class fleets, 10^4–10^6 pools): all per-pool state —
capacity ``C_t``, regime, admission margin, running / provisioning counts,
dwell clocks — lives in stacked ``(pools,)`` arrays.  One dynamics tick is
:meth:`SimulatedProvider.step_batch`: a constant number of vector ops that
advances every pool at once.  Randomness is *counter-based* per pool
(``repro.core.rng``): every draw is a pure function of
``(seed, pool, counter, draw-site)``, so the batched admission path
(:meth:`submit_spot_requests`) and the scalar object API
(:meth:`submit_spot_request`, which wraps the same array core in
:class:`~repro.core.lifecycle.SpotRequest` views) produce bit-identical
trajectories — the parity anchor for the fleet campaign engine.

Per-*instance* bookkeeping (ground-truth node pools, leaked probes) is
event-driven, not per-tick, and columnar: instances, provisioning
cohorts, and leaked probes live in struct-of-arrays ledgers
(:mod:`repro.core.ledger`) touched only on provisioning-settle / reclaim
/ terminate — never on the hot path, never one Python object per
instance.  FIFO reclamation is a per-pool ``head_uid`` advance (the same
uid-range contract the sharded engine keeps on device), cost reads are
vectorized column scans, and campaign-scoped probe accounting uses
monotonic ledger cursors (:class:`ProbeCostMeter`), so host memory stays
bounded by the live fleet on multi-day 10^5–10^6-pool campaigns.

The provider is deliberately *interface-first* (`submit_spot_request` /
`cancel` / node-pool maintenance) so the SnS collector code is portable to
a real cloud backend (§VII provider-agnostic claim).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .faults import (
    OUTCOME_OK,
    OUTCOME_RATE_LIMITED,
    FaultPlan,
)
from .ledger import (
    CohortBatch,
    CohortLedger,
    InstanceLedger,
    ProbeLedger,
    RunningInstance,
    grouped_uid0,
)
from .lifecycle import RequestState, SpotRequest
from .rng import (
    keyed_exponential,
    keyed_normal,
    keyed_uniform,
    keyed_uniform_between,
)

__all__ = [
    "PoolConfig",
    "InterruptionEvent",
    "InterruptionLog",
    "LedgerStats",
    "ProbeCostMeter",
    "RateLimitError",
    "SimulatedProvider",
    "default_fleet",
    "reclaim_sweep_delays",
    "reclaim_sweep_delays_batch",
]


class RateLimitError(RuntimeError):
    """Raised when a region's API request budget is exhausted."""


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

STABLE, TIGHT, CRUNCH = 0, 1, 2
_REGIME_NAMES = ("stable", "tight", "crunch")

#: transient API flakiness: rare spurious rejections even with headroom
_FLAKE_P = 0.012

# Draw-site tags for the counter-based per-pool RNG streams.  Dynamics
# sites are keyed on the tick counter, admission sites on per-pool
# sequence counters; the tag ranges are disjoint so no key collides.
_TAG_NEXT_REGIME = 1
_TAG_DWELL = 2
_TAG_DEGRADE_BUMP = 3
_TAG_NOISE_A = 4
_TAG_NOISE_B = 5
_TAG_TARGET = 6
_TAG_RECLAIM_BUMP = 7
_TAG_RECLAIM = 1_000          # + 2*i per victim (mixture choice, delay)
_TAG_REPLENISH = 10_000_000   # + attempt index
_TAG_SUBMIT = 20_000_000      # + request index within one submission batch


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static description of one (instance type, AZ) capacity pool."""

    instance_type: str
    region: str
    az: str = "a"
    price_per_hour: float = 1.0          # on-demand-discounted spot price
    base_capacity: float = 30.0          # STABLE-regime mean capacity
    volatility: float = 2.0              # capacity noise std per tick
    # Regime dwell means (seconds).  STABLE >> TIGHT >> CRUNCH.
    dwell_stable: float = 8 * 3600.0
    dwell_tight: float = 50 * 60.0
    dwell_crunch: float = 10 * 60.0
    # Probability that a degradation passes through TIGHT before CRUNCH
    # (gives probes predictive lead time).
    p_tight_first: float = 0.85

    @property
    def pool_id(self) -> str:
        return f"{self.instance_type}/{self.region}/{self.az}"


@dataclasses.dataclass(frozen=True)
class InterruptionEvent:
    pool_id: str
    instance_id: int
    time: float                           # continuous timestamp (seconds)


class InterruptionLog:
    """Struct-of-arrays interruption event log (ROADMAP event-log
    compaction): three growable columns — pool index (int64), instance
    uid (int64), timestamp (float64) — instead of one Python object per
    event, so multi-day 10^5-pool campaigns stay compact and the
    co-interrupt analysis can run columnar.

    The log is a lazy *sequence view* of :class:`InterruptionEvent`:
    ``log[i]`` / ``iter(log)`` materialise events on demand, ``len`` and
    ``==`` (vs another log or an event list) work unchanged, so existing
    consumers (``cointerrupt``, tests, examples) need no changes.
    """

    __slots__ = ("_pool_ids", "_pool", "_uid", "_time", "_n")

    def __init__(self, pool_ids: Sequence[str], _capacity: int = 256):
        self._pool_ids = list(pool_ids)
        self._pool = np.empty(_capacity, dtype=np.int64)
        self._uid = np.empty(_capacity, dtype=np.int64)
        self._time = np.empty(_capacity, dtype=np.float64)
        self._n = 0

    # -- write path (provider-internal) -----------------------------------

    def _grow_to(self, need: int) -> None:
        cap = len(self._pool)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("_pool", "_uid", "_time"):
            old = getattr(self, name)
            new = np.empty(cap, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)

    def append_sweep(self, pool: int, uids, times) -> None:
        """Record one reclamation sweep (k events of one pool) columnar."""
        uids = np.asarray(uids, dtype=np.int64)
        times = np.asarray(times, dtype=np.float64)
        k = len(uids)
        self._grow_to(self._n + k)
        sl = slice(self._n, self._n + k)
        self._pool[sl] = pool
        self._uid[sl] = uids
        self._time[sl] = times
        self._n += k

    def append_events(self, pools, uids, times) -> None:
        """Bulk append of many sweeps' events at once (``pools`` aligned
        per event) — the sharded engine's deferred-flush path, equivalent
        to the :meth:`append_sweep` calls the numpy engines make."""
        pools = np.asarray(pools, dtype=np.int64)
        uids = np.asarray(uids, dtype=np.int64)
        times = np.asarray(times, dtype=np.float64)
        k = len(uids)
        self._grow_to(self._n + k)
        sl = slice(self._n, self._n + k)
        self._pool[sl] = pools
        self._uid[sl] = uids
        self._time[sl] = times
        self._n += k

    # -- columnar read path ------------------------------------------------

    @property
    def columns(self):
        """(pool_idx, uid, time) trimmed column views."""
        n = self._n
        return self._pool[:n], self._uid[:n], self._time[:n]

    @property
    def pool_ids(self) -> List[str]:
        return self._pool_ids

    def snapshot(self) -> "InterruptionLog":
        """A frozen copy (what :class:`CampaignResult` stores)."""
        out = InterruptionLog(self._pool_ids, _capacity=max(self._n, 1))
        pool, uid, time = self.columns
        out.append_sweep(0, uid, time)      # bulk copy, then fix pools
        out._pool[: self._n] = pool
        return out

    # -- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        pool, uid, time = self.columns
        return {"pool": pool.copy(), "uid": uid.copy(), "time": time.copy()}

    def restore(self, sd: dict) -> None:
        n = len(sd["uid"])
        self._grow_to(n)
        self._n = n
        self._pool[:n] = sd["pool"]
        self._uid[:n] = sd["uid"]
        self._time[:n] = sd["time"]

    # -- lazy InterruptionEvent sequence view ------------------------------

    def __len__(self) -> int:
        return self._n

    def _event(self, i: int) -> InterruptionEvent:
        return InterruptionEvent(
            self._pool_ids[int(self._pool[i])],
            int(self._uid[i]),
            float(self._time[i]),
        )

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._event(j) for j in range(*i.indices(self._n))]
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(i)
        return self._event(i)

    def __iter__(self):
        return (self._event(i) for i in range(self._n))

    def __eq__(self, other) -> bool:
        if isinstance(other, InterruptionLog):
            if self._n != other._n:
                return False
            a, b = self.columns, other.columns
            return (
                bool(np.array_equal(a[1], b[1]))
                and bool(np.array_equal(a[2], b[2]))
                and [self._pool_ids[p] for p in a[0]]
                == [other._pool_ids[p] for p in b[0]]
            )
        if isinstance(other, (list, tuple)):
            return list(self) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return f"InterruptionLog(n={self._n}, pools={len(self._pool_ids)})"


def reclaim_sweep_delays(seed: int, pool: int, tick: int, k: int) -> np.ndarray:
    """Clustered interruption delays for one reclamation sweep of ``k``
    instances (paper Fig. 3 calibration: a fast exponential for the same
    sweep, a slower uniform tail for follow-up sweeps).

    A pure function of ``(seed, pool, tick, k)`` on the counter-based RNG
    streams — shared by :meth:`SimulatedProvider._reclaim` and the sharded
    engine's host-side interruption-log writer
    (:mod:`repro.core.sharded`), which is what keeps interruption
    timestamps bit-identical across engines.
    """
    i = np.arange(k)
    um = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i)
    ud = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i + 1)
    return np.where(
        (i == 0) | (um < 0.86),
        keyed_exponential(16.0, ud),
        keyed_uniform_between(60.0, 600.0, ud),
    )


def reclaim_sweep_delays_batch(seed: int, pools, ticks, ks) -> np.ndarray:
    """Vectorized :func:`reclaim_sweep_delays` over many sweeps at once.

    ``pools``/``ticks``/``ks`` are aligned per-sweep arrays; the result is
    the flat concatenation of ``reclaim_sweep_delays(seed, p, t, k)`` for
    each sweep, bit-identical to the per-sweep calls (``keyed_uniform`` is
    elementwise in its key columns).  The sharded engine's deferred event
    flush uses this to materialize a whole campaign's interruption
    timestamps in one pass.
    """
    pools = np.asarray(pools, dtype=np.int64)
    ticks = np.asarray(ticks, dtype=np.int64)
    ks = np.asarray(ks, dtype=np.int64)
    total = int(ks.sum())
    if total == 0:
        return np.zeros(0, dtype=np.float64)
    reps = np.repeat(np.arange(len(ks)), ks)
    i = np.arange(total) - np.repeat(np.cumsum(ks) - ks, ks)
    pool = pools[reps]
    tick = ticks[reps]
    um = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i)
    ud = keyed_uniform(seed, pool, tick, _TAG_RECLAIM + 2 * i + 1)
    return np.where(
        (i == 0) | (um < 0.86),
        keyed_exponential(16.0, ud),
        keyed_uniform_between(60.0, 600.0, ud),
    )


class _Cohort:
    """Scalar-API view of one pending cohort — a thin handle over a
    :class:`~repro.core.ledger.CohortLedger` row (the row itself is pure
    columns; only the scalar object path ever creates one of these, so
    the fleet hot path stays object-free)."""

    __slots__ = ("_ledger", "cid", "pool", "probe", "requests", "_final")

    def __init__(
        self,
        ledger: CohortLedger,
        cid: int,
        pool: int,
        probe: bool,
        requests: List[SpotRequest],
    ):
        self._ledger = ledger
        self.cid = cid
        self.pool = pool
        self.probe = probe
        self.requests = requests
        self._final: Optional[int] = None  # -1 settled / 0 cancelled-out

    @property
    def count(self) -> int:
        """Pending member count; ``-1`` once settled to RUNNING (matching
        the historical settled marker), ``0`` once fully cancelled."""
        if self._final is not None:
            return self._final
        c = self._ledger.peek_count(self.cid)
        return -1 if c is None else c

    def cancel_one(self, request: SpotRequest) -> None:
        self.requests.remove(request)
        self._ledger.dec_count(self.cid)


@dataclasses.dataclass(frozen=True)
class LedgerStats:
    """Snapshot of the provider's host-side ledger footprint.

    The bounded-memory contract for long campaigns: with the event-driven
    terminator, every field here is bounded by the *live* fleet (pools ×
    node-pool size), not by campaign length — only the interruption log
    (a campaign output) grows with events.
    """

    instance_rows: int         # ledger rows (live + not-yet-compacted dead)
    instance_live: int         # currently RUNNING instances
    cohort_rows: int           # in-flight provisioning cohorts
    probe_rows: int            # leaked-probe ledger cursor (rows ever)
    probe_live: int            # leaked probes still billing
    interruption_events: int
    nbytes: int                # instance + cohort + probe column bytes


# --------------------------------------------------------------------------
# Provider
# --------------------------------------------------------------------------


class SimulatedProvider:
    """Discrete-event simulated spot control plane over stacked pool state.

    Time is continuous (seconds); dynamics advance on a fixed tick
    (default 60 s).  Clients call :meth:`advance` to move the clock, then
    interact via the request API — either the scalar object API
    (:meth:`submit_spot_request`, one pool at a time, returning
    :class:`SpotRequest` views) or the batched fleet API
    (:meth:`submit_spot_requests`, every pool in one vector op).  Both sit
    on the same array core and the same counter-based per-pool RNG
    streams, so they are bit-identical.
    """

    def __init__(
        self,
        pools: Sequence[PoolConfig],
        *,
        seed: int = 0,
        tick: float = 60.0,
        provisioning_duration: float = 8.0,
        requests_per_minute_per_region: int = 300,
        replenish_delay: float = 300.0,
        margin_decay_tau: float = 30 * 60.0,
    ):
        self.tick = float(tick)
        self.provisioning_duration = float(provisioning_duration)
        self.rate_limit = int(requests_per_minute_per_region)
        self.replenish_delay = float(replenish_delay)
        self.margin_decay_tau = float(margin_decay_tau)
        self._margin_decay = math.exp(-self.tick / self.margin_decay_tau)
        self._seed = int(seed)
        self.now = 0.0

        self.configs: List[PoolConfig] = list(pools)
        P = len(self.configs)
        self.n_pools = P
        self._pool_index: Dict[str, int] = {
            cfg.pool_id: i for i, cfg in enumerate(self.configs)
        }
        if len(self._pool_index) != P:
            raise ValueError("duplicate pool ids in fleet")
        self._idx = np.arange(P)

        # -- static per-pool config, stacked ------------------------------
        self.base_capacity = np.array([c.base_capacity for c in self.configs])
        self.volatility = np.array([c.volatility for c in self.configs])
        self.price_per_hour = np.array([c.price_per_hour for c in self.configs])
        self._p_tight_first = np.array([c.p_tight_first for c in self.configs])
        self._dwell = np.array(
            [[c.dwell_stable, c.dwell_tight, c.dwell_crunch] for c in self.configs]
        )
        regions = sorted({c.region for c in self.configs})
        self._region_code = np.array(
            [regions.index(c.region) for c in self.configs], dtype=np.int64
        )
        self._region_names = regions

        # -- dynamic per-pool state, stacked ------------------------------
        self.capacity = self.base_capacity.copy()
        self.regime = np.zeros(P, dtype=np.int64)
        self.admission_margin = np.zeros(P)
        self.n_running = np.zeros(P, dtype=np.int64)
        self.n_provisioning = np.zeros(P, dtype=np.int64)
        self.target_nodes = np.zeros(P, dtype=np.int64)
        self.replenish_at = np.full(P, math.inf)
        self._tick_count = 0
        self._submit_seq = np.zeros(P, dtype=np.int64)
        self._instance_seq = np.zeros(P, dtype=np.int64)
        u0 = keyed_uniform(self._seed, self._idx, 0, _TAG_DWELL)
        self.regime_until = keyed_exponential(self._dwell[:, STABLE], u0)

        # -- event-driven per-instance bookkeeping (columnar ledgers) ------
        self._ledger = InstanceLedger(P)
        self._cohort_ledger = CohortLedger()
        self._probe_ledger = ProbeLedger()
        # scalar-object API side tables — empty unless SpotRequest views
        # exist, so the fleet hot path never touches them
        self._cohort_handles: Dict[int, _Cohort] = {}
        self._req_cohort: Dict[int, _Cohort] = {}
        self._uid_objs: Dict[Tuple[int, int], SpotRequest] = {}
        self._obj_uids: Dict[int, Tuple[int, int]] = {}
        self.interruptions = InterruptionLog(self.pool_ids)
        self._provision_listeners: List[Callable[[SpotRequest], None]] = []

        # -- per-region rate limiting (sliding 60 s window) ----------------
        self._rate_window: List[Deque[Tuple[float, int]]] = [
            deque() for _ in regions
        ]
        self._rate_sum = np.zeros(len(regions), dtype=np.int64)
        self.api_calls = 0
        #: API calls billed to whole-call control-plane faults (throttle /
        #: timeout / blackout cycles still charge the caller).  A subset of
        #: :attr:`api_calls`, surfaced separately in ``cost_report``.
        self.fault_api_calls = 0
        self._fault_plan: Optional[FaultPlan] = None
        # per-call scratch: transient-error pattern of the last scalar
        # submission batch (the scalar collector reads it for outcome codes)
        self.last_request_errors = np.zeros(0, dtype=bool)

    # -- public API -------------------------------------------------------

    @property
    def pool_ids(self) -> List[str]:
        return [cfg.pool_id for cfg in self.configs]

    def pool_index(self, pool_ids: Sequence[str]) -> np.ndarray:
        """Map pool ids to stacked-array indices."""
        return np.array([self._pool_index[p] for p in pool_ids], dtype=np.int64)

    def pool_config(self, pool_id: str) -> PoolConfig:
        return self.configs[self._pool_index[pool_id]]

    def on_provisioning(self, callback: Callable[[SpotRequest], None]) -> None:
        """Subscribe to provisioning-started lifecycle events (the hook the
        SnS Request Terminator uses).  Fired by the scalar object API only;
        the batched fleet path models the terminator explicitly."""
        self._provision_listeners.append(callback)

    # -- fault injection ---------------------------------------------------

    @property
    def fault_plan(self) -> Optional[FaultPlan]:
        return self._fault_plan

    def set_fault_plan(self, plan: Optional[FaultPlan]) -> None:
        """Attach (or clear) a deterministic :class:`FaultPlan`.

        Per-request transient errors are drawn inside the admission mask
        from ``(plan.seed, pool, submit_seq)``; blackout windows suppress
        node-pool replenishment; whole-call faults are evaluated by the
        collection layer via :meth:`FaultPlan.call_codes` and billed
        through :meth:`charge_api_fault` / ``submit_spot_requests``'s
        ``fault_codes``.  With ``plan=None`` (the default) every code
        path is bit-identical to the fault-free provider.
        """
        self._fault_plan = plan

    @property
    def region_code(self) -> np.ndarray:
        """(pools,) int64 region codes (read-only view for fault/retry)."""
        return self._region_code

    @property
    def n_regions(self) -> int:
        return len(self._region_names)

    def rate_budget(self) -> np.ndarray:
        """(regions,) remaining request budget in the sliding 60 s window.

        The same numbers ``_charge_rate_limit_batch`` enforces — the
        retry control plane's token bucket pre-gates attempts against
        this so the limiter itself never has to refuse a call.
        """
        out = np.empty(len(self._region_names), dtype=np.int64)
        for rc in range(len(self._region_names)):
            self._prune_rate_window(rc)
            out[rc] = self.rate_limit - self._rate_sum[rc]
        return out

    def charge_api_fault(self, pool_id: str, *, n: int = 1) -> bool:
        """Bill one whole-call faulted probe (scalar path).

        A throttled/timed-out/blacked-out call still consumes rate
        budget and bills API calls — it just never reaches admission.
        Returns ``False`` (charging nothing) when the region budget is
        exhausted, mirroring the batch path where rate-limiting wins
        over the fault code.
        """
        rc = int(self._region_code[self._pool_index[pool_id]])
        self._prune_rate_window(rc)
        if self._rate_sum[rc] + n > self.rate_limit:
            return False
        self._rate_window[rc].append((self.now, n))
        self._rate_sum[rc] += n
        self.api_calls += n
        self.fault_api_calls += n
        return True

    # -- admission core (shared by both APIs) ------------------------------

    def _accept_mask(
        self, pool_idx: np.ndarray, n: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(K, n) ``(accept, errored)`` patterns for one concurrent batch
        of ``n`` requests per pool; consumes one submission sequence
        number per pool.

        Two-phase concurrency semantics: all ``n`` requests of a pool pass
        the capacity check together, each accepted request consuming one
        unit of headroom — this is what makes the accepted/submitted ratio
        a *graded* estimate of available capacity (§III-A).
        """
        seq = self._submit_seq[pool_idx]
        self._submit_seq[pool_idx] = seq + 1
        u = keyed_uniform(
            self._seed,
            pool_idx[:, None],
            seq[:, None],
            _TAG_SUBMIT + np.arange(n)[None, :],
        )
        plan = self._fault_plan
        if plan is not None and plan.request_error_p > 0.0:
            # injected transient request errors: drawn from the *plan's*
            # stream keyed on the same (pool, submit_seq), so every engine
            # sees identical errors; errored requests fail outright and
            # never consume admission headroom
            err = plan.request_errors(pool_idx, seq, n)
        else:
            err = np.zeros((len(pool_idx), n), dtype=bool)
        ok = (u >= _FLAKE_P) & ~err
        headroom = (
            self.capacity[pool_idx]
            - self.n_running[pool_idx]
            - self.n_provisioning[pool_idx]
            - self.admission_margin[pool_idx]
        )
        # request r is admitted iff it passes the flake draw and the
        # headroom left after the accepts before it is still positive
        return ok & ((np.cumsum(ok, axis=1) - 1) < headroom[:, None]), err

    def submit_spot_request(
        self, pool_id: str, *, n: int = 1, strict: bool = True
    ) -> List[SpotRequest]:
        """Submit ``n`` *concurrent* spot requests (scalar object API).

        Provisioning lifecycle events fire after the whole batch has passed
        the capacity check, so an event-driven canceller cannot free
        capacity mid-batch.  When the region's request budget is exhausted
        nothing is charged and, with ``strict=True`` (the historical
        behaviour), :class:`RateLimitError` is raised; ``strict=False``
        instead returns ``[]`` — the admit-what-fits semantics of the
        batched fleet path, where a rate-limited pool simply counts 0.
        Transient-error injection (``FaultPlan.request_error_p``) surfaces
        per request in :attr:`last_request_errors`.
        """
        p = self._pool_index[pool_id]
        rc = int(self._region_code[p])
        if not strict:
            self._prune_rate_window(rc)
            if self._rate_sum[rc] + n > self.rate_limit:
                self.last_request_errors = np.zeros(0, dtype=bool)
                return []
        self._charge_rate_limit(rc, n)
        accept, err = self._accept_mask(np.array([p]), n)
        accept = accept[0]
        self.last_request_errors = err[0].copy()
        out: List[SpotRequest] = []
        accepted: List[SpotRequest] = []
        k = int(accept.sum())
        cohort = None
        if k:
            cid = self._cohort_ledger.append(p, self.now, k, probe=True)
            cohort = _Cohort(self._cohort_ledger, cid, p, True, [])
            self._cohort_handles[cid] = cohort
        for r in range(n):
            req = SpotRequest(pool_id=pool_id, submit_time=self.now)
            if accept[r]:
                req.transition(RequestState.PROVISIONING, self.now)
                cohort.requests.append(req)
                self._req_cohort[req.request_id] = cohort
                accepted.append(req)
            else:
                req.transition(RequestState.REJECTED, self.now)
            out.append(req)
        if cohort is not None:
            self.n_provisioning[p] += k
        for req in accepted:
            for cb in self._provision_listeners:
                cb(req)
        return out

    def submit_spot_requests(
        self,
        pool_idx: np.ndarray,
        *,
        n: int = 1,
        hold: bool = False,
        fault_codes: Optional[np.ndarray] = None,
        codes_out: Optional[np.ndarray] = None,
        errors_out: Optional[np.ndarray] = None,
    ):
        """Batched admission: ``n`` concurrent requests against *every*
        pool in ``pool_idx`` in one vector op (the fleet probing path).

        Returns the accepted-count vector ``(len(pool_idx),)``.  With the
        default ``hold=False`` the accepted requests are cancelled on
        provisioning acceptance (the event-driven SnS scoot), leaving
        provider state untouched; ``hold=True`` instead leaves them
        provisioning and returns ``(counts, cohorts)`` — an opaque
        :class:`~repro.core.ledger.CohortBatch` handle — so the caller
        can :meth:`cancel_cohorts` later (the slow-terminator model).
        Pools whose region budget is exhausted count 0 (rate-limited
        cycles record total failure, as in the scalar path).

        Fault hooks: ``fault_codes`` (per-pool ``OUTCOME_*`` codes from
        :meth:`FaultPlan.call_codes`) marks pools whose call fails whole
        — they are still rate-charged and billed (``fault_api_calls``)
        but never reach admission and do not consume a submission
        sequence number.  ``codes_out`` / ``errors_out`` are optional
        preallocated per-pool outputs for the resolved outcome codes and
        injected-transient-error counts.
        """
        pool_idx = np.asarray(pool_idx, dtype=np.int64)
        counts = np.zeros(len(pool_idx), dtype=np.int64)
        admitted = self._charge_rate_limit_batch(pool_idx, n)
        if fault_codes is None:
            faulted = None
            live = admitted
        else:
            fault_codes = np.asarray(fault_codes, dtype=np.uint8)
            faulted = fault_codes != OUTCOME_OK
            live = admitted & ~faulted
            self.fault_api_calls += int((admitted & faulted).sum()) * n
        if codes_out is not None:
            codes_out[:] = OUTCOME_OK
            if faulted is not None:
                codes_out[faulted] = fault_codes[faulted]
            codes_out[~admitted] = OUTCOME_RATE_LIMITED
        ids = np.empty(0, dtype=np.int64)
        if live.any():
            sub = pool_idx[live]
            accept, err = self._accept_mask(sub, n)
            counts[live] = accept.sum(axis=1)
            if errors_out is not None:
                errors_out[live] = err.sum(axis=1)
            if hold:
                ca = counts[live]
                nz = ca > 0
                ids = self._cohort_ledger.append_batch(
                    sub[nz], self.now, ca[nz], probe=True
                )
                self.n_provisioning[sub] += ca
        return (counts, CohortBatch(ids)) if hold else counts

    def cancel(self, request: SpotRequest) -> None:
        """Cancel a PROVISIONING request (the scoot)."""
        if request.state is RequestState.PROVISIONING:
            request.transition(RequestState.CANCELLED, self.now)
            cohort = self._req_cohort.pop(request.request_id, None)
            if cohort is not None:
                cohort.cancel_one(request)
                self.n_provisioning[cohort.pool] -= 1
        # cancelling REJECTED/terminal requests is a no-op, like real APIs

    def cancel_cohorts(self, cohorts) -> None:
        """Cancel still-provisioning members of held request batches
        (the fleet-path equivalent of flushing delayed per-request
        cancels): one vectorized ledger op.  Accepts the
        :class:`~repro.core.ledger.CohortBatch` returned by
        ``submit_spot_requests(hold=True)`` or a sequence of scalar-API
        cohort handles.  Cohorts that already settled to RUNNING are
        left alone, like cancelling a RUNNING request in the real APIs."""
        if isinstance(cohorts, CohortBatch):
            ids = cohorts.ids
        else:
            ids = np.array([ch.cid for ch in cohorts], dtype=np.int64)
        pools, counts = self._cohort_ledger.cancel_ids(ids)
        if pools.size:
            np.add.at(self.n_provisioning, pools, -counts)

    def terminate(self, request: SpotRequest) -> None:
        if request.state is RequestState.RUNNING:
            request.transition(RequestState.TERMINATED, self.now)
            loc = self._obj_uids.pop(request.request_id, None)
            if loc is not None:
                p, uid = loc
                self._uid_objs.pop(loc, None)
                self._ledger.mark_terminated(p, uid, self.now)
                if self._probe_ledger.live_count:
                    self._probe_ledger.mark_ended(
                        p,
                        np.array([uid], dtype=np.int64),
                        np.array([self.now]),
                    )
                self.n_running[p] -= 1

    def set_node_pool(self, pool_id: str, n_nodes: int) -> None:
        """Declare a ground-truth node pool that tries to keep ``n_nodes``
        running (an autoscaling-group analogue; §III-B's 10-node pools)."""
        p = self._pool_index[pool_id]
        self.target_nodes[p] = int(n_nodes)
        self.replenish_at[p] = self.now  # acquire ASAP

    def running_count(self, pool_id: str) -> int:
        return int(self.n_running[self._pool_index[pool_id]])

    def running_counts(self, pool_idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Stacked running counts (a copy) for the fleet collector."""
        if pool_idx is None:
            return self.n_running.copy()
        return self.n_running[np.asarray(pool_idx, dtype=np.int64)]

    def running_cost(self, pool_id: str, now: Optional[float] = None) -> float:
        """Total compute cost billed so far for RUNNING time in this pool
        — a vectorized column read over the live-instance ledger (the old
        per-instance Python sum degraded to O(instances) per call)."""
        now = self.now if now is None else now
        p = self._pool_index[pool_id]
        _, starts = self._ledger.pool_live(p)
        price = self.price_per_hour[p] / 3600.0
        return float(np.maximum(now - starts, 0.0).sum() * price)

    def running_costs(self, now: Optional[float] = None) -> np.ndarray:
        """(pools,) compute dollars billed to currently-RUNNING time —
        one scatter-add over the whole instance ledger, for fleet-wide
        accounting without a per-pool loop."""
        now = self.now if now is None else now
        return self._ledger.running_seconds(now) * self.price_per_hour / 3600.0

    def running_instances(self, pool_id: str) -> Iterator[RunningInstance]:
        """Lazy per-object view of this pool's live instances (oldest
        first) — materialised on demand from the columnar ledger, the way
        ``InterruptionLog`` serves ``InterruptionEvent``."""
        return self._ledger.live(self._pool_index[pool_id])

    def probe_ledger_len(self) -> int:
        """Monotonic cursor into the leaked-probe ledger (rows ever
        appended).  Capture it before a campaign and pass it as
        ``since=`` to :meth:`probe_instance_cost` to scope accounting;
        unlike the raw list index this replaces, the cursor stays valid
        however the ledger is stored or compacted."""
        return self._probe_ledger.cursor

    def probe_instance_cost(
        self,
        now: Optional[float] = None,
        *,
        since: int = 0,
        until: Optional[int] = None,
    ) -> float:
        """Compute dollars billed to probe requests that leaked into
        RUNNING (≈ 0 by design: only a slow terminator leaks), restricted
        to the ledger cursor range ``[since, until)`` — cursors come from
        :meth:`probe_ledger_len`.  Disjoint segments sum to the whole;
        a stale or foreign cursor raises ``ValueError``."""
        now = self.now if now is None else now
        return self._probe_ledger.cost(self.price_per_hour, now, since, until)

    def ledger_stats(self) -> LedgerStats:
        """Host-side ledger footprint (see :class:`LedgerStats`) — the
        observable the bounded-memory tests and benchmarks watch."""
        return LedgerStats(
            instance_rows=len(self._ledger),
            instance_live=int(self.n_running.sum()),
            cohort_rows=len(self._cohort_ledger),
            probe_rows=self._probe_ledger.cursor,
            probe_live=self._probe_ledger.live_count,
            interruption_events=len(self.interruptions),
            nbytes=(
                self._ledger.nbytes
                + self._cohort_ledger.nbytes
                + self._probe_ledger.nbytes
            ),
        )

    def advance(self, to_time: float) -> None:
        """Advance simulation clock, stepping the whole fleet each tick."""
        if to_time < self.now:
            raise ValueError("time moves forward only")
        while self.now + self.tick <= to_time:
            self.step_batch()
        # fractional remainder advances the clock without a dynamics step
        if to_time > self.now:
            self.now = to_time
            self._settle_provisioning()

    def step_batch(self, dt: Optional[float] = None) -> None:
        """One dynamics tick for every pool at once (a constant number of
        vector ops over the stacked state, independent of fleet size).

        ``dt`` rescales the step's clock advance and margin decay; the
        regime/capacity increments are calibrated per tick, so dynamics
        are faithful at ``dt == tick`` (the default) and approximate
        otherwise.
        """
        if dt is None:
            dt, decay = self.tick, self._margin_decay
        else:
            dt = float(dt)
            decay = math.exp(-dt / self.margin_decay_tau)
        self.now += dt
        self._tick_count += 1
        self._settle_provisioning()
        self._step_fleet(decay)

    # -- internals ---------------------------------------------------------

    def _step_fleet(self, margin_decay: float) -> None:
        seed, k, idx = self._seed, self._tick_count, self._idx
        # -- regime transitions (due pools only) ---------------------------
        due = self.now >= self.regime_until
        if due.any():
            dp = idx[due]
            u = keyed_uniform(seed, dp, k, _TAG_NEXT_REGIME)
            r = self.regime[dp]
            # STABLE degrades, usually via TIGHT (prediction lead time),
            # rarely straight to CRUNCH (the hard, unpredictable case);
            # TIGHT mostly falls to CRUNCH; CRUNCH mostly recovers via TIGHT.
            new = np.where(
                r == STABLE,
                np.where(u < self._p_tight_first[dp], TIGHT, CRUNCH),
                np.where(
                    r == TIGHT,
                    np.where(u < 0.75, CRUNCH, STABLE),
                    np.where(u < 0.6, TIGHT, STABLE),
                ),
            )
            self.regime[dp] = new
            # Degraded regimes have concentrated dwell times: elapsed time
            # in degradation is informative about time-to-interruption,
            # which is what gives CUT its predictive value (§IV-B).
            ud = keyed_uniform(seed, dp, k, _TAG_DWELL)
            mean = self._dwell[dp, new]
            self.regime_until[dp] = self.now + np.where(
                new == STABLE,
                keyed_exponential(mean, ud),
                keyed_uniform_between(0.7 * mean, 1.3 * mean, ud),
            )
            # Degradation raises the admission margin — new requests start
            # failing *partially* before running instances are reclaimed
            # (paper Fig. 2 lead-time behaviour; Table I's Actual > SnS
            # cases are mostly graded, not blackouts).
            deg = dp[new != STABLE]
            if deg.size:
                ub = keyed_uniform(seed, deg, k, _TAG_DEGRADE_BUMP)
                bump = keyed_uniform_between(0.15, 0.7, ub) * np.maximum(
                    self.target_nodes[deg], 4
                )
                self.admission_margin[deg] = np.maximum(
                    self.admission_margin[deg], bump
                )
        # -- capacity mean-reversion to regime target ----------------------
        nmax = np.maximum(self.target_nodes, 1).astype(np.float64)
        ut = keyed_uniform(seed, idx, k, _TAG_TARGET)
        target = np.where(
            self.regime == STABLE,
            self.base_capacity,
            np.where(
                self.regime == TIGHT,
                # just around the running demand: probes contend with demand
                nmax + keyed_uniform_between(0.15 * nmax, 0.6 * nmax, ut),
                # CRUNCH: below running demand -> forces reclamation
                keyed_uniform_between(0.0, 0.8 * nmax, ut),
            ),
        )
        ua = keyed_uniform(seed, idx, k, _TAG_NOISE_A)
        ub = keyed_uniform(seed, idx, k, _TAG_NOISE_B)
        self.capacity += 0.35 * (target - self.capacity) + keyed_normal(
            self.volatility, ua, ub
        )
        np.maximum(self.capacity, 0.0, out=self.capacity)
        # -- admission margin decays slowly (conservative recovery) --------
        self.admission_margin *= margin_decay
        self.admission_margin[self.admission_margin < 0.05] = 0.0
        # -- reclaim running instances if capacity fell below them ---------
        # Hysteresis: providers reclaim in sweeps, not single-node dribbles;
        # a 1-2 node transient dip outside CRUNCH does not trigger a sweep.
        overflow = self.n_running - self.capacity.astype(np.int64)
        sweep = (overflow > 0) & ((self.regime == CRUNCH) | (overflow >= 3))
        if sweep.any():
            for p in np.nonzero(sweep)[0]:
                self._reclaim(int(p), int(overflow[p]))
        # -- node-pool replenishment ---------------------------------------
        self._replenish_batch()

    def _reclaim(self, p: int, k: int) -> None:
        """Interrupt ``k`` running instances with clustered timestamps.

        Co-interrupt proximity calibration (paper Fig. 3): delays are a
        mixture of a fast exponential (same reclamation sweep, ~88 %) and a
        slower uniform tail (independent follow-up sweeps).  Calibrated to
        >85 % of proximities < 1 min and ≈93 % < 3 min.
        """
        k = min(k, int(self.n_running[p]))
        if k == 0:
            return
        tick = self._tick_count
        delay = reclaim_sweep_delays(self._seed, p, tick, k)
        times = self.now + delay[:k]
        # oldest first: sweeps reclaim in order — an O(1) head-uid advance
        # on the columnar ledger (uids ascending == FIFO order)
        uids = self._ledger.pop_oldest(p, k)
        if self._uid_objs:
            for j, u in enumerate(uids):
                obj = self._uid_objs.pop((p, int(u)), None)
                if obj is not None:
                    self._obj_uids.pop(obj.request_id, None)
                    obj.transition(RequestState.INTERRUPTED, float(times[j]))
        if self._probe_ledger.live_count:
            self._probe_ledger.mark_ended(p, uids, times)
        self.interruptions.append_sweep(p, uids, times)
        self.n_running[p] -= k
        # A sweep that actually reclaimed nodes means the pool has zero
        # spare capacity: new admissions black out until the margin decays
        # (this is what keeps post-interruption unavailability episodes
        # alive for tens of minutes, as in the paper's Fig. 2 traces).
        ubump = keyed_uniform(self._seed, p, tick, _TAG_RECLAIM_BUMP)
        self.admission_margin[p] += k + float(
            keyed_uniform_between(0.4, 1.0, ubump)
        ) * max(int(self.target_nodes[p]), 4)
        self.replenish_at[p] = max(
            self.replenish_at[p], self.now + self.replenish_delay
        )

    def _replenish_batch(self) -> None:
        """Node pools try to restore target_nodes (ASG behaviour): retry
        every tick once the post-interruption cooldown has passed, stopping
        at the first failed admission (retry next tick)."""
        deficit = self.target_nodes - self.n_running - self.n_provisioning
        mask = (self.target_nodes > 0) & (self.now >= self.replenish_at) & (deficit > 0)
        plan = self._fault_plan
        if plan is not None and plan.blackout is not None and mask.any():
            # AZ blackout suppresses the control plane wholesale: node
            # pools cannot replenish while their region is dark (the
            # sharded engine applies the same host-precomputed mask)
            mask &= ~plan.blackout_mask([self.now], self._region_code)[0]
        if not mask.any():
            return
        mp = self._idx[mask]
        d = deficit[mp]
        dmax = int(d.max())
        j = np.arange(dmax)
        u = keyed_uniform(
            self._seed, mp[:, None], self._tick_count, _TAG_REPLENISH + j[None, :]
        )
        headroom = (
            self.capacity[mp]
            - self.n_running[mp]
            - self.n_provisioning[mp]
            - self.admission_margin[mp]
        )
        # attempt j succeeds while j < headroom (each accept consumes one
        # unit), passes the flake draw, and is within the pool's deficit;
        # the first failure stops the pool's attempts for this tick.
        ok = (j[None, :] < headroom[:, None]) & (u >= _FLAKE_P) & (j[None, :] < d[:, None])
        accepts = np.where(ok.all(axis=1), dmax, np.argmax(~ok, axis=1))
        got = accepts > 0
        if got.any():
            self._cohort_ledger.append_batch(
                mp[got], self.now, accepts[got].astype(np.int64)
            )
        self.n_provisioning[mp] += accepts

    def _settle_provisioning(self) -> None:
        """Provisioning completes after `provisioning_duration`: cohorts
        not cancelled by then transition to RUNNING (and start billing).

        One vectorized pass over the cohort ledger — uid assignment, the
        running/provisioning count updates, and the instance/probe ledger
        appends are all column ops; per-object work happens only for rows
        that carry scalar-API ``SpotRequest`` views."""
        batch = self._cohort_ledger.settle_due(self.now, self.provisioning_duration)
        if batch is None:
            return
        pools, counts, probes, ids, dropped = batch
        for cid in dropped:  # fully-cancelled rows: finalise any handles
            h = self._cohort_handles.pop(int(cid), None)
            if h is not None:
                h._final = 0
        if len(pools) == 0:
            return
        uid0 = grouped_uid0(pools, counts, self._instance_seq)
        np.add.at(self._instance_seq, pools, counts)
        np.add.at(self.n_provisioning, pools, -counts)
        np.add.at(self.n_running, pools, counts)
        self._ledger.append_blocks(pools, uid0, counts, self.now, probes)
        if probes.any():
            m = probes.astype(bool)
            self._probe_ledger.append_blocks(pools[m], uid0[m], counts[m], self.now)
        if self._cohort_handles:
            for r, cid in enumerate(ids):
                h = self._cohort_handles.pop(int(cid), None)
                if h is None:
                    continue
                h._final = -1  # settled marker: no longer cancellable
                p, u0 = int(pools[r]), int(uid0[r])
                for i, obj in enumerate(h.requests):
                    obj.transition(RequestState.RUNNING, self.now)
                    self._req_cohort.pop(obj.request_id, None)
                    self._uid_objs[(p, u0 + i)] = obj
                    self._obj_uids[obj.request_id] = (p, u0 + i)

    # -- rate limiting -----------------------------------------------------

    def _prune_rate_window(self, rc: int) -> None:
        window = self._rate_window[rc]
        cutoff = self.now - 60.0
        while window and window[0][0] <= cutoff:
            _, c = window.popleft()
            self._rate_sum[rc] -= c

    def _charge_rate_limit(self, rc: int, n: int) -> None:
        self._prune_rate_window(rc)
        if self._rate_sum[rc] + n > self.rate_limit:
            raise RateLimitError(
                f"region {self._region_names[rc]}: {int(self._rate_sum[rc]) + n} "
                f"requests in 60 s exceeds limit {self.rate_limit}"
            )
        self._rate_window[rc].append((self.now, n))
        self._rate_sum[rc] += n
        self.api_calls += n

    def _charge_rate_limit_batch(self, pool_idx: np.ndarray, n: int) -> np.ndarray:
        """Sequential-semantics budget check for a batch: per region, the
        first ``floor(budget / n)`` pools (in submission order) are
        admitted, the rest fail without consuming budget — exactly what a
        pool-by-pool loop of :meth:`_charge_rate_limit` yields."""
        admitted = np.zeros(len(pool_idx), dtype=bool)
        codes = self._region_code[pool_idx]
        for rc in np.unique(codes):
            rc = int(rc)
            self._prune_rate_window(rc)
            sel = np.nonzero(codes == rc)[0]
            budget = int(self.rate_limit - self._rate_sum[rc])
            k = min(len(sel), max(0, budget // n))
            if k > 0:
                admitted[sel[:k]] = True
                self._rate_window[rc].append((self.now, k * n))
                self._rate_sum[rc] += k * n
                self.api_calls += k * n
        return admitted

    # -- crash-consistent checkpointing ------------------------------------

    def state_dict(self) -> dict:
        """Snapshot of the full dynamic provider state as plain
        numpy/python containers (pickleable).

        Restoring this dict into a freshly-constructed provider with the
        same configs/seed/knobs reproduces the uninterrupted trajectory
        bit-identically — every RNG draw is a pure function of the
        counters captured here.  Live scalar-API ``SpotRequest`` views
        cannot be snapshotted (they hold Python object identity), so
        slow-terminator scalar campaigns must checkpoint between probe
        batches or not at all.
        """
        if self._uid_objs or self._req_cohort:
            raise NotImplementedError(
                "cannot checkpoint while scalar-API SpotRequest views are "
                "live (slow-terminator scalar campaigns); checkpoint at a "
                "cycle boundary with terminator_delay=0 instead"
            )
        return {
            "now": float(self.now),
            "tick_count": int(self._tick_count),
            "capacity": self.capacity.copy(),
            "regime": self.regime.copy(),
            "regime_until": self.regime_until.copy(),
            "admission_margin": self.admission_margin.copy(),
            "n_running": self.n_running.copy(),
            "n_provisioning": self.n_provisioning.copy(),
            "target_nodes": self.target_nodes.copy(),
            "replenish_at": self.replenish_at.copy(),
            "submit_seq": self._submit_seq.copy(),
            "instance_seq": self._instance_seq.copy(),
            "api_calls": int(self.api_calls),
            "fault_api_calls": int(self.fault_api_calls),
            "rate_window": [list(w) for w in self._rate_window],
            "rate_sum": self._rate_sum.copy(),
            "ledger": self._ledger.state_dict(),
            "cohorts": self._cohort_ledger.state_dict(),
            "probes": self._probe_ledger.state_dict(),
            "interruptions": self.interruptions.state_dict(),
        }

    def restore(self, sd: dict) -> None:
        """Overwrite this provider's dynamic state from a
        :meth:`state_dict` snapshot (configs/seed/knobs must match the
        snapshotting provider — they are not stored)."""
        self.now = float(sd["now"])
        self._tick_count = int(sd["tick_count"])
        self.capacity[:] = sd["capacity"]
        self.regime[:] = sd["regime"]
        self.regime_until[:] = sd["regime_until"]
        self.admission_margin[:] = sd["admission_margin"]
        self.n_running[:] = sd["n_running"]
        self.n_provisioning[:] = sd["n_provisioning"]
        self.target_nodes[:] = sd["target_nodes"]
        self.replenish_at[:] = sd["replenish_at"]
        self._submit_seq[:] = sd["submit_seq"]
        self._instance_seq[:] = sd["instance_seq"]
        self.api_calls = int(sd["api_calls"])
        self.fault_api_calls = int(sd["fault_api_calls"])
        self._rate_window = [deque(map(tuple, w)) for w in sd["rate_window"]]
        self._rate_sum[:] = sd["rate_sum"]
        self._ledger.restore(sd["ledger"])
        self._cohort_ledger.restore(sd["cohorts"])
        self._probe_ledger.restore(sd["probes"])
        self.interruptions.restore(sd["interruptions"])
        self._cohort_handles.clear()
        self._req_cohort.clear()
        self._uid_objs.clear()
        self._obj_uids.clear()


class ProbeCostMeter:
    """Campaign-scoped probe-cost accounting over monotonic ledger cursors.

    Captures the provider's probe-ledger cursor at construction;
    :meth:`total` bills exactly the leaked-probe rows appended since then
    (and, after :meth:`freeze`, before the frozen end cursor), so two
    campaigns on one provider never double-bill each other — disjoint
    meters sum to the whole ledger's cost.
    """

    __slots__ = ("provider", "since", "until")

    def __init__(self, provider: SimulatedProvider):
        self.provider = provider
        self.since = provider.probe_ledger_len()
        self.until: Optional[int] = None

    def freeze(self) -> int:
        """Pin the end cursor (rows appended later are someone else's)."""
        if self.until is None:
            self.until = self.provider.probe_ledger_len()
        return self.until

    def total(self, now: Optional[float] = None) -> float:
        return float(
            self.provider.probe_instance_cost(
                now, since=self.since, until=self.until
            )
        )


# --------------------------------------------------------------------------
# Fleet construction helpers
# --------------------------------------------------------------------------

_AWS_REGIONS = [
    "us-east-1", "us-west-2", "eu-west-1", "ap-northeast-1", "us-east-2",
    "eu-central-1", "ap-southeast-1", "sa-east-1", "ca-central-1",
    "ap-south-1", "eu-north-1",
]
_AZURE_REGIONS = ["eastus", "westus2", "westeurope", "japaneast"]

_INSTANCE_FAMILIES = [
    ("m5.large", 0.096), ("m5.xlarge", 0.192), ("c5.large", 0.085),
    ("c5.2xlarge", 0.34), ("r5.large", 0.126), ("r5.2xlarge", 0.504),
    ("g4dn.xlarge", 0.526), ("p3.2xlarge", 3.06), ("t3.medium", 0.0416),
    ("i3.large", 0.156), ("m6i.large", 0.096), ("c6i.xlarge", 0.17),
]


def default_fleet(
    n_pools: int = 68,
    *,
    seed: int = 0,
    providers: Tuple[str, ...] = ("aws", "azure"),
) -> List[PoolConfig]:
    """Build a fleet of pool configs shaped like the paper's campaign:
    68 instance types across 15 regions (47 AWS + 21 Azure)."""
    rng = np.random.default_rng(seed)
    n_aws = round(n_pools * 47 / 68) if "azure" in providers else n_pools
    configs: List[PoolConfig] = []
    for i in range(n_pools):
        if "aws" in providers and (i < n_aws or "azure" not in providers):
            region = _AWS_REGIONS[i % len(_AWS_REGIONS)]
            cloud = "aws"
        else:
            region = _AZURE_REGIONS[i % len(_AZURE_REGIONS)]
            cloud = "azure"
        itype, price = _INSTANCE_FAMILIES[i % len(_INSTANCE_FAMILIES)]
        # Azure pools are calmer in Table I (88.7 % vs 77.1 % match):
        stability = 3.0 if cloud == "azure" else 1.0
        configs.append(
            PoolConfig(
                instance_type=f"{cloud}:{itype}:{i}",
                region=region,
                az=chr(ord("a") + int(rng.integers(0, 3))),
                price_per_hour=price * float(rng.uniform(0.8, 1.25)),
                base_capacity=float(rng.uniform(25.0, 45.0)),
                volatility=float(rng.uniform(1.0, 2.5)),
                dwell_stable=float(rng.uniform(4.0, 12.0)) * 3600.0 * stability,
                dwell_tight=float(rng.uniform(30.0, 80.0)) * 60.0,
                dwell_crunch=float(rng.uniform(5.0, 18.0)) * 60.0,
            )
        )
    return configs
