"""Elastic mesh management: re-mesh + re-shard when pods come and go.

JAX's SPMD model has no dynamic membership — the idiomatic elastic
pattern is *checkpoint → rebuild mesh → restore*: on pod loss the job
restarts its jit functions on a smaller `(pod, data, model)` mesh and
re-shards the latest checkpoint onto it; on pod recovery it scales back
up.  ``ElasticMeshManager`` encapsulates that decision logic (which mesh
for how many pods, when a re-mesh is worth it) and the resharding itself,
which is a plain ``device_put`` with the new mesh's NamedShardings — XLA
moves the bytes.

Data determinism across re-meshes: the data iterator is indexed by
(global step, microbatch id), not by device, so a re-meshed run consumes
exactly the same token stream (straggler/ordering safety).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshPlan", "ElasticMeshManager", "reshard"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def build(self, devices: Optional[np.ndarray] = None) -> Mesh:
        devices = devices if devices is not None else np.array(jax.devices())
        n = int(np.prod(self.shape))
        if devices.size < n:
            raise ValueError(f"need {n} devices, have {devices.size}")
        return Mesh(
            devices.reshape(-1)[:n].reshape(self.shape), self.axes
        )


class ElasticMeshManager:
    """Chooses a mesh for the currently-available pods.

    ``pod_capacity`` devices per pod; the `(data, model)` in-pod layout is
    fixed, the pod axis grows/shrinks.  Scale-down to zero pods pauses the
    job (the runner accounts that as unavailable time).
    """

    def __init__(
        self,
        *,
        n_pods: int,
        data_per_pod: int,
        model_parallel: int,
        min_pods: int = 1,
    ):
        self.n_pods = n_pods
        self.data = data_per_pod
        self.model = model_parallel
        self.min_pods = min_pods

    def plan_for(self, up_pods: List[int]) -> Optional[MeshPlan]:
        k = len(up_pods)
        if k < self.min_pods:
            return None  # job pauses
        if k == 1:
            return MeshPlan((self.data, self.model), ("data", "model"))
        return MeshPlan((k, self.data, self.model), ("pod", "data", "model"))

    def global_batch_scale(self, up_pods: List[int]) -> float:
        """Elastic batch policy: keep per-pod batch fixed, so global batch
        scales with surviving pods (loss scaling handled by the trainer)."""
        return max(len(up_pods), 0) / self.n_pods


def reshard(tree, mesh: Mesh, specs) -> object:
    """Re-shard a (restored) pytree onto a new mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree,
        specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )
