"""Columnar (struct-of-arrays) provider ledgers — the host-memory core.

A SpotLake-class campaign (10^4–10^6 pools, multi-day) cannot afford one
Python object per instance or one list append per event: the host side
must stay **flat in cycles and bounded by the live fleet**.  This module
holds the three event-driven ledgers behind
:class:`~repro.core.provider.SimulatedProvider`, rebuilt in the same
style as :class:`~repro.core.provider.InterruptionLog` (PR 3): growable
parallel numpy columns with chunked (amortised doubling) growth, lazy
dataclass views instead of stored objects, and vectorized sweep /
settle / cost reads instead of per-instance Python loops.

* :class:`InstanceLedger` — RUNNING instances.  FIFO reclamation is a
  **uid-range** operation (the same contract the sharded engine's
  ``head_uid``/``next_uid`` device columns use): per-pool live instances
  are the uids ``[head_uid[p], next_uid[p])`` minus a (normally empty)
  per-pool terminated-uid exception set, so a reclamation sweep advances
  ``head_uid`` in O(1) and never walks a deque.  Dead rows are compacted
  away once they outnumber live rows, so the ledger's footprint is
  bounded by the *live* fleet, not by campaign length.
* :class:`ProbeLedger` — probes that leaked into RUNNING (slow-terminator
  studies; empty on the event-driven default path).  Append-only with a
  **monotonic cursor** (`cursor`): cost queries bill explicit
  ``[since, until)`` cursor ranges, so campaign-scoped accounting stays
  exact no matter how the ledger is stored or compacted — raw list
  indices (the pre-cursor bug) are gone.
* :class:`CohortLedger` — requests accepted together and still
  provisioning.  Rows are dropped at settle, so the pending set is
  bounded by in-flight cohorts; scalar-API cohorts keep their
  ``SpotRequest`` views in side tables keyed by cohort id, touched only
  when objects actually exist.

Everything here is engine-agnostic bookkeeping: the fleet and scalar
engines share these ledgers directly, and the sharded engine mirrors the
uid-range contract on device (``repro.core.sharded``), which is what
keeps interruption logs and cost accounting bit-identical across all
three.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "RunningInstance",
    "InstanceLedger",
    "ProbeLedger",
    "CohortLedger",
    "CohortBatch",
    "grouped_uid0",
]


def grouped_uid0(pools: np.ndarray, counts: np.ndarray, next_uid: np.ndarray) -> np.ndarray:
    """Per-row starting uid for a settle batch.

    Row ``r`` (a cohort of ``counts[r]`` instances in pool ``pools[r]``)
    gets ``next_uid[pools[r]]`` plus the number of same-pool instances in
    *earlier* rows of the batch — exactly the uids a row-by-row settle
    loop would hand out.  ``next_uid`` is not modified (callers advance it
    with ``np.add.at``).
    """
    m = len(pools)
    if m == 0:
        return np.empty(0, dtype=np.int64)
    order = np.argsort(pools, kind="stable")
    sp, sc = pools[order], counts[order]
    excl = np.cumsum(sc) - sc                      # exclusive cumsum overall
    starts = np.r_[0, np.nonzero(sp[1:] != sp[:-1])[0] + 1]
    lens = np.diff(np.r_[starts, m])
    off = excl - np.repeat(excl[starts], lens)     # exclusive cumsum per pool
    uid0 = np.empty(m, dtype=np.int64)
    uid0[order] = next_uid[sp] + off
    return uid0


class _Columns:
    """Chunked-growth parallel columns (amortised-doubling, like
    :class:`~repro.core.provider.InterruptionLog`)."""

    _COLS: Tuple[Tuple[str, type], ...] = ()

    def __init__(self, capacity: int = 256):
        for name, dtype in self._COLS:
            setattr(self, name, np.empty(capacity, dtype=dtype))
        self._n = 0

    def _grow_to(self, need: int) -> None:
        cap = len(getattr(self, self._COLS[0][0]))
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name, _ in self._COLS:
            old = getattr(self, name)
            new = np.empty(cap, dtype=old.dtype)
            new[: self._n] = old[: self._n]
            setattr(self, name, new)

    def __len__(self) -> int:
        return self._n

    @property
    def nbytes(self) -> int:
        """Allocated column bytes (capacity, not just filled rows)."""
        return sum(getattr(self, name).nbytes for name, _ in self._COLS)

    # -- checkpointing (subclasses extend with their side state) -----------

    def state_dict(self) -> dict:
        return {name: getattr(self, name)[: self._n].copy() for name, _ in self._COLS}

    def restore(self, sd: dict) -> None:
        n = len(sd[self._COLS[0][0]])
        self._grow_to(n)
        for name, _ in self._COLS:
            getattr(self, name)[:n] = sd[name]
        self._n = n


# --------------------------------------------------------------------------
# Running instances
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunningInstance:
    """Lazy scalar view of one live ledger row (materialised on demand,
    like :class:`~repro.core.provider.InterruptionEvent`)."""

    pool: int
    uid: int
    start: float
    probe: bool


class InstanceLedger(_Columns):
    """Struct-of-arrays ledger of RUNNING instances.

    Columns: ``pool`` / ``uid`` / ``start`` / ``end`` / ``probe``.  Live
    rows have ``end == +inf`` *and* ``uid >= head_uid[pool]`` — a
    reclamation sweep kills its k oldest instances by advancing
    ``head_uid`` alone (O(1)); only out-of-band ``terminate()`` calls
    (scalar object API) write ``end`` on an individual row.  Dead rows
    are lazily compacted, keeping the footprint bounded by live
    instances.
    """

    _COLS = (
        ("pool", np.int64),
        ("uid", np.int64),
        ("start", np.float64),
        ("end", np.float64),
        ("probe", np.bool_),
    )

    def __init__(self, n_pools: int, capacity: int = 256):
        super().__init__(capacity)
        self.head_uid = np.zeros(n_pools, dtype=np.int64)
        self._dead = 0
        # uids terminated out of FIFO order, per pool (scalar API only;
        # normally empty — the fast uid-range paths check `if not ...`)
        self._term_uids: Dict[int, Set[int]] = {}

    # -- write path --------------------------------------------------------

    def append_blocks(
        self,
        pools: np.ndarray,
        uid0: np.ndarray,
        counts: np.ndarray,
        start: float,
        probe: np.ndarray,
    ) -> None:
        """Append one settle batch: ``counts[r]`` instances of pool
        ``pools[r]`` with uids ``uid0[r] + 0..counts[r]-1``, all entering
        RUNNING at ``start``."""
        k = int(counts.sum())
        if k == 0:
            return
        self._grow_to(self._n + k)
        sl = slice(self._n, self._n + k)
        reps = np.repeat(np.arange(len(pools)), counts)
        within = np.arange(k) - np.repeat(np.cumsum(counts) - counts, counts)
        self.pool[sl] = pools[reps]
        self.uid[sl] = uid0[reps] + within
        self.start[sl] = start
        self.end[sl] = np.inf
        self.probe[sl] = probe[reps]
        self._n += k

    def pop_oldest(self, p: int, k: int) -> np.ndarray:
        """Remove the ``k`` oldest live instances of pool ``p`` (a
        reclamation sweep) and return their uids, ascending.  O(1) via
        the head-uid advance unless out-of-order terminations exist."""
        term = self._term_uids.get(p)
        head = int(self.head_uid[p])
        if not term:
            uids = head + np.arange(k, dtype=np.int64)
            self.head_uid[p] = head + k
        else:
            sel = (
                (self.pool[: self._n] == p)
                & (self.uid[: self._n] >= head)
                & np.isinf(self.end[: self._n])
            )
            uids = np.sort(self.uid[: self._n][sel])[:k]  # row order == uid order
            new_head = int(uids[-1]) + 1
            self.head_uid[p] = new_head
            term.difference_update(u for u in tuple(term) if u < new_head)
            if not term:
                del self._term_uids[p]
        self._dead += k
        self._maybe_compact()
        return uids

    def mark_terminated(self, p: int, uid: int, end: float) -> None:
        """Out-of-FIFO-order removal (scalar ``terminate`` API)."""
        sel = (self.pool[: self._n] == p) & (self.uid[: self._n] == uid)
        rows = np.nonzero(sel)[0]
        if rows.size:
            self.end[rows[-1]] = end
            self._term_uids.setdefault(p, set()).add(int(uid))
            self._dead += 1

    # -- read path ---------------------------------------------------------

    def live_mask(self) -> np.ndarray:
        n = self._n
        return np.isinf(self.end[:n]) & (self.uid[:n] >= self.head_uid[self.pool[:n]])

    @property
    def live_rows(self) -> int:
        return int(self.live_mask().sum())

    def live_counts(self) -> np.ndarray:
        """(pools,) live-instance counts (cross-checks ``n_running``)."""
        out = np.zeros(len(self.head_uid), dtype=np.int64)
        m = self.live_mask()
        np.add.at(out, self.pool[: self._n][m], 1)
        return out

    def pool_live(self, p: int) -> Tuple[np.ndarray, np.ndarray]:
        """(uids, starts) of pool ``p``'s live instances, oldest first."""
        m = self.live_mask() & (self.pool[: self._n] == p)
        return self.uid[: self._n][m], self.start[: self._n][m]

    def running_seconds(self, now: float) -> np.ndarray:
        """(pools,) summed RUNNING-seconds of live instances at ``now`` —
        the vectorized core of ``running_cost`` (one scatter-add, no
        per-instance Python)."""
        out = np.zeros(len(self.head_uid), dtype=np.float64)
        m = self.live_mask()
        np.add.at(
            out,
            self.pool[: self._n][m],
            np.maximum(now - self.start[: self._n][m], 0.0),
        )
        return out

    def live(self, p: Optional[int] = None) -> Iterator[RunningInstance]:
        """Lazy object view of live rows (oldest-first per pool)."""
        m = self.live_mask()
        if p is not None:
            m &= self.pool[: self._n] == p
        for i in np.nonzero(m)[0]:
            yield RunningInstance(
                int(self.pool[i]), int(self.uid[i]),
                float(self.start[i]), bool(self.probe[i]),
            )

    # -- compaction --------------------------------------------------------

    def _maybe_compact(self) -> None:
        if self._dead > 64 and self._dead * 2 > self._n:
            self.compact()

    def compact(self) -> None:
        """Drop dead rows (order-preserving, so per-pool rows stay in uid
        order)."""
        m = self.live_mask()
        k = int(m.sum())
        for name, _ in self._COLS:
            col = getattr(self, name)
            col[:k] = col[: self._n][m]
        self._n = k
        self._dead = 0

    @property
    def nbytes(self) -> int:
        return super().nbytes + self.head_uid.nbytes

    def state_dict(self) -> dict:
        sd = super().state_dict()
        sd["head_uid"] = self.head_uid.copy()
        sd["dead"] = self._dead
        sd["term_uids"] = {p: sorted(s) for p, s in self._term_uids.items()}
        return sd

    def restore(self, sd: dict) -> None:
        super().restore(sd)
        self.head_uid[:] = sd["head_uid"]
        self._dead = int(sd["dead"])
        self._term_uids = {int(p): set(s) for p, s in sd["term_uids"].items()}


# --------------------------------------------------------------------------
# Leaked probes
# --------------------------------------------------------------------------


class ProbeLedger(_Columns):
    """Append-only columnar ledger of probes that leaked into RUNNING.

    Empty whenever the event-driven terminator runs (the default and the
    million-pool path); populated only by slow-terminator studies.  The
    **cursor** is the monotonic count of rows ever appended — campaign
    accounting captures a cursor at start and bills the explicit
    ``[since, until)`` range, which stays valid regardless of how rows
    are stored (the raw-list-index marker this replaces silently
    mis-billed under any ledger reorganisation).
    """

    _COLS = (
        ("pool", np.int64),
        ("uid", np.int64),
        ("start", np.float64),
        ("end", np.float64),
    )

    def __init__(self, capacity: int = 64):
        super().__init__(capacity)
        self.live_count = 0

    @property
    def cursor(self) -> int:
        """Monotonic ledger cursor (rows ever appended)."""
        return self._n

    def append_blocks(
        self, pools: np.ndarray, uid0: np.ndarray, counts: np.ndarray, start: float
    ) -> None:
        k = int(counts.sum())
        if k == 0:
            return
        self._grow_to(self._n + k)
        sl = slice(self._n, self._n + k)
        reps = np.repeat(np.arange(len(pools)), counts)
        within = np.arange(k) - np.repeat(np.cumsum(counts) - counts, counts)
        self.pool[sl] = pools[reps]
        self.uid[sl] = uid0[reps] + within
        self.start[sl] = start
        self.end[sl] = np.inf
        self._n += k
        self.live_count += k

    def mark_ended(self, p: int, uids: np.ndarray, times: np.ndarray) -> None:
        """Record end-of-billing for pool ``p`` rows with the given uids
        (``uids`` ascending; ``times`` aligned).  Vectorized; callers
        skip the call entirely while ``live_count == 0``."""
        n = self._n
        cand = (self.pool[:n] == p) & np.isinf(self.end[:n])
        rows = np.nonzero(cand)[0]
        if rows.size == 0:
            return
        pos = np.searchsorted(uids, self.uid[rows])
        hit = (pos < len(uids)) & (uids[np.minimum(pos, len(uids) - 1)] == self.uid[rows])
        rows = rows[hit]
        self.end[rows] = times[pos[hit]]
        self.live_count -= int(rows.size)

    def state_dict(self) -> dict:
        sd = super().state_dict()
        sd["live_count"] = self.live_count
        return sd

    def restore(self, sd: dict) -> None:
        super().restore(sd)
        self.live_count = int(sd["live_count"])

    def cost(
        self,
        prices_per_hour: np.ndarray,
        now: float,
        since: int = 0,
        until: Optional[int] = None,
    ) -> float:
        """Dollars billed to rows in cursor range ``[since, until)``,
        live rows billed through ``now``.  Raises ``ValueError`` on a
        stale or foreign cursor."""
        until = self._n if until is None else until
        if not 0 <= since <= until <= self._n:
            raise ValueError(
                f"stale probe-ledger cursor: [since={since}, until={until}) "
                f"outside [0, {self._n}] — cursors come from "
                "probe_ledger_len() on this provider"
            )
        sl = slice(since, until)
        end = np.where(np.isinf(self.end[sl]), now, self.end[sl])
        seconds = np.maximum(end - self.start[sl], 0.0)
        return float((seconds * prices_per_hour[self.pool[sl]]).sum()) / 3600.0


# --------------------------------------------------------------------------
# Provisioning cohorts
# --------------------------------------------------------------------------


class CohortBatch:
    """Opaque handle for one held batched submission (``hold=True``):
    just the cohort ids, cancellable in one vector op."""

    __slots__ = ("ids",)

    def __init__(self, ids: np.ndarray):
        self.ids = ids

    def __len__(self) -> int:
        return len(self.ids)


class CohortLedger(_Columns):
    """Pending provisioning cohorts as parallel columns.

    Rows live only while provisioning: the settle pass removes due and
    fully-cancelled rows, so the ledger is bounded by in-flight cohorts
    (≤ pools, with ``provisioning_duration <= tick``).  Cohort ids are
    monotonic and never reused; id → row lookups go through a small dict
    rebuilt at each compaction.
    """

    _COLS = (
        ("pool", np.int64),
        ("start", np.float64),
        ("count", np.int64),
        ("probe", np.bool_),
        ("cid", np.int64),
    )

    def __init__(self, capacity: int = 256):
        super().__init__(capacity)
        self._next_id = 0
        self._row: Dict[int, int] = {}

    # -- append ------------------------------------------------------------

    def append_batch(
        self,
        pools: np.ndarray,
        start: float,
        counts: np.ndarray,
        probe: bool = False,
    ) -> np.ndarray:
        """Append one cohort per (pool, count) pair; returns their ids."""
        m = len(pools)
        if m == 0:
            return np.empty(0, dtype=np.int64)
        self._grow_to(self._n + m)
        sl = slice(self._n, self._n + m)
        ids = np.arange(self._next_id, self._next_id + m, dtype=np.int64)
        self.pool[sl] = pools
        self.start[sl] = start
        self.count[sl] = counts
        self.probe[sl] = probe
        self.cid[sl] = ids
        for j, i in enumerate(ids):
            self._row[int(i)] = self._n + j
        self._n += m
        self._next_id += m
        return ids

    def append(self, pool: int, start: float, count: int, probe: bool) -> int:
        return int(
            self.append_batch(
                np.array([pool], dtype=np.int64), start,
                np.array([count], dtype=np.int64), probe,
            )[0]
        )

    # -- mutation ----------------------------------------------------------

    def peek_count(self, cid: int) -> Optional[int]:
        row = self._row.get(cid)
        return None if row is None else int(self.count[row])

    def dec_count(self, cid: int) -> int:
        """Cancel one member of a pending cohort; returns the pool index."""
        row = self._row[cid]
        self.count[row] -= 1
        return int(self.pool[row])

    def cancel_ids(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Zero every still-pending cohort in ``ids``; returns the
        ``(pools, counts)`` that were actually cancelled (settled or
        already-cancelled ids are skipped, like cancelling a RUNNING
        request)."""
        rows = np.array(
            [self._row[i] for i in map(int, ids) if i in self._row], dtype=np.int64
        )
        if rows.size == 0:
            return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
        rows = rows[self.count[rows] > 0]
        pools, counts = self.pool[rows].copy(), self.count[rows].copy()
        self.count[rows] = 0
        return pools, counts

    # -- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        sd = super().state_dict()
        sd["next_id"] = self._next_id
        return sd

    def restore(self, sd: dict) -> None:
        super().restore(sd)
        self._next_id = int(sd["next_id"])
        self._row = {int(c): r for r, c in enumerate(self.cid[: self._n])}

    # -- settle ------------------------------------------------------------

    def settle_due(self, now: float, provisioning_duration: float):
        """Split off cohorts whose provisioning completed.

        Returns ``None`` when nothing is due and nothing needs dropping;
        otherwise ``(pools, counts, probes, ids, dropped_ids)`` for the
        due rows (ledger row order — the uid-assignment order) and the
        ids of cancelled rows dropped alongside.  Due and dropped rows
        are removed; pending rows keep their relative order.
        """
        n = self._n
        if n == 0:
            return None
        elapsed = now - self.start[:n] >= provisioning_duration
        due = elapsed & (self.count[:n] > 0)
        drop = elapsed & (self.count[:n] <= 0)
        if not (due.any() or drop.any()):
            return None
        out = (
            self.pool[:n][due].copy(),
            self.count[:n][due].copy(),
            self.probe[:n][due].copy(),
            self.cid[:n][due].copy(),
            self.cid[:n][drop].copy(),
        )
        keep = ~(due | drop)
        k = int(keep.sum())
        for name, _ in self._COLS:
            col = getattr(self, name)
            col[:k] = col[:n][keep]
        self._n = k
        self._row = {int(c): r for r, c in enumerate(self.cid[:k])}
        return out
