"""Goodput-engine throughput — replay rows/sec across the four engines.

Measures the elastic-training frontier sweep (pods × checkpoint policies
over a Markov-preempted fleet) flowing through:

1. ``python-loop``  — scalar :func:`repro.fleet.run_replay` per row (the
                      readable contract reference; timed on a subset);
2. ``numpy-batch``  — ``run_replay_batch(engine="numpy")``: the
                      vectorised per-cycle loop (the parity oracle).
                      Policies enter as tiled rows, so each pod's
                      availability/hazard row is re-streamed once per
                      policy;
3. ``scan``         — ``run_replay_batch(engine="scan")``: the jitted
                      ``lax.scan`` closed form over the same tiled rows
                      (float64 under a scoped ``enable_x64``);
4. ``kernel``       — ``run_replay_fleet(engine="kernel")``: the fused
                      policy-planes engine (``kernels.goodput_scan``) —
                      every pod's flag/hazard row is loaded once and
                      replayed through all policy planes in one pass;
5. ``kernel_f32``   — the fused engine on the float32 fast tier.  On
                      this workload every time quantity (dt, step time,
                      checkpoint/restore costs) is exactly representable
                      in f32 and the adaptive-τ decisions sit far from
                      comparison boundaries, so the f32 tier reproduces
                      the f64 oracle bit for bit (asserted:
                      ``f32_decisions_identical``).

All timed legs use best-of-``max(repeats, 3)`` after a warm-up call —
the committed trajectory once disagreed 2.3× between records minutes
apart because the python loop and cold jit caches were timed once.

Also verifies the acceptance properties end-to-end:

* all four engines agree **bit-identically (atol=0)** — scalar on a row
  subset, numpy ≡ scan ≡ kernel on the full workload;
* the scan path clears ``REQUIRED_SPEEDUP`` × the per-pod python loop
  and the fused kernel engine clears ``REQUIRED_KERNEL_SPEEDUP`` × the
  numpy batch (both asserted in full mode);
* on the recorded workload the SnS hazard policy strictly beats the
  fixed-interval baseline on lost work (asserted in full mode) — the
  predictor here is a soft oracle over the Markov chain, so this checks
  the *policy machinery* (panic + adaptive cadence), not forecast skill.

Usage:
    PYTHONPATH=src python benchmarks/goodput_throughput.py [--smoke]
        [--pods 4096] [--cycles 320] [--repeats 3]

Each full run appends one JSON record to ``BENCH_goodput.json`` (perf
trajectory across PRs).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.fleet import (
    FixedInterval,
    PolicyTable,
    SnSHazard,
    YoungDaly,
    run_goodput_frontier,
    run_replay,
    run_replay_batch,
    run_replay_fleet,
)
from repro.fleet.events import PodTrace

DT = 180.0
STEP_TIME = 2.0
CKPT_COST = 30.0
RESTORE_COST = 60.0
HORIZON_CYCLES = 5                 # SnSHazard horizon = 5 cycles = 900 s
P_FAIL = 0.02                      # per-cycle preemption hazard (Markov)
P_RECOVER = 0.3
REQUIRED_SPEEDUP = 20.0            # scan vs python loop, asserted full mode
REQUIRED_KERNEL_SPEEDUP = 1.5     # fused kernel vs numpy batch, asserted


def _policies():
    mtbf = DT / P_FAIL             # the chain's true mean time between failures
    return (
        [
            FixedInterval(1800.0),
            YoungDaly(ckpt_cost=CKPT_COST, mtbf=mtbf),
            SnSHazard(ckpt_cost=CKPT_COST, horizon=HORIZON_CYCLES * DT,
                      panic_threshold=0.5),
        ],
        ["fixed_30min", "young_daly", "sns_hazard"],
    )


def _workload(pods: int, cycles: int, seed: int = 0):
    """Markov up/down traces + a soft-oracle survival forecast.

    ``p_survive ∈ {0.95, 0.05}`` depending on whether the pod really stays
    up through the policy horizon — high-skill (not perfect) forecasts, so
    the hazard policy's panic path fires exactly where it should.
    """
    rng = np.random.default_rng(seed)
    up = np.empty((pods, cycles), dtype=bool)
    state = np.ones(pods, dtype=bool)
    for c in range(cycles):
        r = rng.random(pods)
        state = np.where(state, r >= P_FAIL, r < P_RECOVER)
        up[:, c] = state
    stays = np.ones((pods, cycles), dtype=bool)
    for h in range(1, HORIZON_CYCLES + 1):
        fut = np.roll(up, -h, axis=1)
        fut[:, -h:] = True
        stays &= fut
    p_survive = np.where(stays, 0.95, 0.05)
    return up, p_survive


def _best(fn, repeats: int) -> float:
    """Best-of-N wall time after one untimed warm-up call (fills jit
    caches and allocator pools so every leg is timed steady-state)."""
    fn()
    best = float("inf")
    for _ in range(max(repeats, 3)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _stack(avail, p, n_pol):
    return np.tile(avail, (n_pol, 1)), np.tile(p, (n_pol, 1))


def bench_python_loop(avail, p, policies, rows: int, repeats: int) -> float:
    """rows/sec of the scalar reference (on a pod subset × all policies)."""
    rows = min(rows, avail.shape[0])
    T = avail.shape[1]
    times = np.arange(T, dtype=np.float64) * DT
    feats = np.zeros((T, 3))

    def sweep():
        for pol in policies:
            for b in range(rows):
                trace = PodTrace(pod_id=b, pool_id=str(b), times=times,
                                 available=avail[b], features=feats, dt=DT)
                run_replay(trace, policy=pol, step_time=STEP_TIME,
                           ckpt_cost=CKPT_COST, restore_cost=RESTORE_COST,
                           p_survive=p[b])

    return rows * len(policies) / _best(sweep, repeats)


def check_parity(avail, p, policies, names) -> bool:
    """scalar ≡ numpy ≡ scan ≡ kernel, atol=0, on a reduced row subset."""
    n = min(avail.shape[0], 16)
    t = min(avail.shape[1], 200)
    T = t
    times = np.arange(T, dtype=np.float64) * DT
    feats = np.zeros((T, 3))
    table = PolicyTable.from_policies(policies, repeat=n)
    big_avail, big_p = _stack(avail[:n, :t], p[:n, :t], len(policies))
    kw = dict(dt=DT, step_time=STEP_TIME, ckpt_cost=CKPT_COST,
              restore_cost=RESTORE_COST)
    engines = {
        e: run_replay_batch(big_avail, table, p_survive=big_p, engine=e, **kw)
        for e in ("numpy", "scan")
    }
    # the fused engine takes the un-tiled (pods, cycles) workload and
    # returns policy-major rows — the same layout as the tiled batch
    engines["kernel"] = run_replay_fleet(
        avail[:n, :t], policies, p_survive=p[:n, :t], names=names,
        engine="kernel", **kw)
    row = 0
    for pol in policies:
        for b in range(n):
            trace = PodTrace(pod_id=b, pool_id=str(b), times=times,
                             available=avail[b, :t], features=feats, dt=DT)
            ref = run_replay(trace, policy=pol, p_survive=p[b, :t], **{
                k: v for k, v in kw.items() if k != "dt"})
            for got in engines.values():
                assert got["steps_completed"][row] == ref.steps_completed
                assert got["steps_lost"][row] == ref.steps_lost
                assert got["checkpoints"][row] == ref.checkpoints
                assert got["ckpt_overhead_s"][row] == ref.ckpt_overhead_s
            row += 1
    for k in engines["numpy"]:
        for e in ("scan", "kernel"):
            np.testing.assert_array_equal(
                engines["numpy"][k], engines[e][k], err_msg=f"{e}:{k}")
    return True


def check_f32_identity(f64_res, f32_res) -> bool:
    """The f32 fast tier must reproduce the f64 kernel engine exactly on
    the bench workload — integer decisions always, and here the float
    metrics too (every time quantity is f32-representable)."""
    for k in ("steps_completed", "steps_lost", "checkpoints"):
        np.testing.assert_array_equal(f64_res[k], f32_res[k], err_msg=k)
    for k in ("ckpt_overhead_s", "unavailable_s", "lost_work_s", "goodput"):
        if k in f64_res:
            np.testing.assert_array_equal(
                np.asarray(f64_res[k], dtype=np.float64),
                np.asarray(f32_res[k], dtype=np.float64), err_msg=k)
    return True


def run(pods: int = 4096, cycles: int = 320, smoke: bool = False,
        repeats: int = 3) -> dict:
    import jax

    if smoke:
        pods, cycles = min(pods, 256), min(cycles, 64)
    policies, names = _policies()
    avail, p = _workload(pods, cycles)
    table = PolicyTable.from_policies(policies, repeat=pods, names=names)
    big_avail, big_p = _stack(avail, p, len(policies))
    rows = big_avail.shape[0]
    kw = dict(dt=DT, step_time=STEP_TIME, ckpt_cost=CKPT_COST,
              restore_cost=RESTORE_COST)

    loop_rate = bench_python_loop(avail, p, policies,
                                  rows=16 if smoke else 64, repeats=repeats)
    numpy_time = _best(
        lambda: run_replay_batch(big_avail, table, p_survive=big_p,
                                 engine="numpy", **kw), repeats)
    scan_time = _best(
        lambda: run_replay_batch(big_avail, table, p_survive=big_p,
                                 engine="scan", **kw), repeats)
    kernel_time = _best(
        lambda: run_replay_fleet(avail, policies, p_survive=p, names=names,
                                 engine="kernel", **kw), repeats)
    kernel_f32_time = _best(
        lambda: run_replay_fleet(avail, policies, p_survive=p, names=names,
                                 engine="kernel", precision="f32", **kw),
        repeats)

    parity = check_parity(avail, p, policies, names)
    f64_res = run_replay_fleet(avail, policies, p_survive=p, names=names,
                               engine="kernel", **kw)
    f32_res = run_replay_fleet(avail, policies, p_survive=p, names=names,
                               engine="kernel", precision="f32", **kw)
    f32_identical = check_f32_identity(f64_res, f32_res)
    # assert the frontier itself off the fused kernel path (it now routes
    # through run_replay_fleet, so this exercises the production engine)
    frontier = run_goodput_frontier(avail, policies, p_survive=p,
                                    names=names, engine="kernel", **kw)

    numpy_rate = rows / numpy_time
    scan_rate = rows / scan_time
    kernel_rate = rows / kernel_time
    kernel_f32_rate = rows / kernel_f32_time
    result = {
        "pods": pods,
        "cycles": cycles,
        "policies": names,
        "rows": rows,
        "devices": len(jax.devices()),
        "rows_per_sec": {
            "python_loop": round(loop_rate, 1),
            "numpy_batch": round(numpy_rate, 1),
            "scan": round(scan_rate, 1),
            "kernel": round(kernel_rate, 1),
            "kernel_f32": round(kernel_f32_rate, 1),
        },
        "speedup_vs_python_loop": round(scan_rate / loop_rate, 1),
        "speedup_vs_numpy": round(scan_rate / numpy_rate, 2),
        "speedup": {
            "kernel_vs_numpy": round(kernel_rate / numpy_rate, 2),
            "kernel_f32_vs_numpy": round(kernel_f32_rate / numpy_rate, 2),
            "kernel_vs_scan": round(kernel_rate / scan_rate, 2),
        },
        "parity_atol0": parity,
        "f32_decisions_identical": f32_identical,
        "frontier": {
            name: {
                "goodput": round(r.goodput, 4),
                "lost_work_s": round(r.lost_work_s, 1),
                "ckpt_overhead_s": round(r.ckpt_overhead_s, 1),
                "checkpoints": r.checkpoints,
            }
            for name, r in frontier.items()
        },
        "smoke": smoke,
    }
    if not smoke:
        assert scan_rate / loop_rate >= REQUIRED_SPEEDUP, result
        assert kernel_rate / numpy_rate >= REQUIRED_KERNEL_SPEEDUP, result
        assert (frontier["sns_hazard"].lost_work_s
                < frontier["fixed_30min"].lost_work_s), result
        _append_record(result)
    return result


def _append_record(result: dict) -> None:
    rec = dict(result, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    with open(Path.cwd() / "BENCH_goodput.json", "a") as f:
        f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pods", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=320)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; parity checks only, no assertion")
    args = ap.parse_args()
    result = run(pods=args.pods, cycles=args.cycles, smoke=args.smoke,
                 repeats=args.repeats)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
