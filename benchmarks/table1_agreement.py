"""Table I: per-time-point SnS success count vs running instance count."""

from __future__ import annotations

import numpy as np

from .common import provider_split_campaigns

PAPER = {
    "AWS": {"actual_gt_sns": 22.31, "equal": 77.12, "actual_lt_sns": 0.56},
    "Azure": {"actual_gt_sns": 11.03, "equal": 88.68, "actual_lt_sns": 0.30},
}


def run():
    c_aws, c_az = provider_split_campaigns()
    rows = []
    for name, c in (("AWS", c_aws), ("Azure", c_az)):
        gt = float((c.running > c.s).mean() * 100)
        eq = float((c.running == c.s).mean() * 100)
        lt = float((c.running < c.s).mean() * 100)
        rows.append({
            "provider": name,
            "actual_gt_sns_pct": round(gt, 2),
            "equal_pct": round(eq, 2),
            "actual_lt_sns_pct": round(lt, 2),
            "paper_equal_pct": PAPER[name]["equal"],
            "paper_gt_pct": PAPER[name]["actual_gt_sns"],
            "paper_lt_pct": PAPER[name]["actual_lt_sns"],
            "requests": int(np.prod(c.s.shape)) * c.n,
        })
    return {"table": rows}


if __name__ == "__main__":
    print(run())
