"""Small MLP predictor (point-wise model group)."""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ._train import fit_adam

__all__ = ["MLP"]


def _init_mlp(key, n_in: int, hidden: int) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    s1 = (2.0 / n_in) ** 0.5
    s2 = (2.0 / hidden) ** 0.5
    return {
        "w1": jax.random.normal(k1, (n_in, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, 1)) * s2,
        "b2": jnp.zeros((1,)),
    }


def _forward(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


@dataclasses.dataclass
class MLP:
    hidden: int = 32
    l2: float = 1e-5
    steps: int = 800
    lr: float = 3e-3
    seed: int = 0
    params: Dict = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "MLP":
        l2 = self.l2

        def loss(params, xb, yb, wb):
            logits = _forward(params, xb)
            ll = wb * (jax.nn.softplus(logits) - yb * logits)
            reg = sum(jnp.sum(p**2) for k, p in params.items() if k.startswith("w"))
            return ll.mean() + l2 * reg

        init = _init_mlp(jax.random.PRNGKey(self.seed), x.shape[-1], self.hidden)
        self.params = fit_adam(
            init, loss, x, y, steps=self.steps, lr=self.lr, seed=self.seed
        )
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jax.nn.sigmoid(_forward(self.params, jnp.asarray(x))))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)
