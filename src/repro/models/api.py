"""Family-agnostic model API.

Every launcher / trainer / server entry point goes through these four
functions with a `batch` dict, so decoder-only and encoder–decoder
families are interchangeable behind ``--arch``:

* train batch:   {"tokens": (B,S) i32, "labels": (B,S) i32
                  [, "frames": (B,T_enc,d) for encdec]}
* prefill batch: {"tokens": (B,S) i32 [, "frames": ...]}
* decode:        token (B,) i32 + cache pytree
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from . import encdec, lm
from .common import ModelConfig

__all__ = [
    "init_params", "train_loss", "prefill", "init_cache", "decode_step",
]


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict:
    if cfg.family == "encdec":
        return encdec.init_params(cfg, seed)
    return lm.init_params(cfg, seed)


def train_loss(cfg: ModelConfig, params, batch: Dict, *, mesh=None,
               data_axes=("data",), remat: str = "dots",
               q_chunk: int = 1024, mamba_chunk: int = 64) -> jnp.ndarray:
    if cfg.family == "encdec":
        return encdec.train_loss(
            cfg, params, batch["frames"], batch["tokens"], batch["labels"],
            mesh=mesh, data_axes=data_axes, q_chunk=q_chunk, remat=remat,
        )
    return lm.train_loss(
        cfg, params, batch["tokens"], batch["labels"],
        mesh=mesh, data_axes=data_axes, remat=remat,
        q_chunk=q_chunk, mamba_chunk=mamba_chunk,
    )


def prefill(cfg: ModelConfig, params, batch: Dict, *, mesh=None,
            data_axes=("data",), max_seq: Optional[int] = None,
            q_chunk: int = 1024, mamba_chunk: int = 64):
    if cfg.family == "encdec":
        return encdec.prefill(
            cfg, params, batch["frames"], batch["tokens"],
            mesh=mesh, data_axes=data_axes, max_seq=max_seq, q_chunk=q_chunk,
        )
    return lm.prefill(
        cfg, params, batch["tokens"],
        mesh=mesh, data_axes=data_axes, max_seq=max_seq,
        q_chunk=q_chunk, mamba_chunk=mamba_chunk,
    )


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *, mesh=None,
               data_axes=("data",)):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_seq, mesh=mesh, data_axes=data_axes)
    return lm.init_cache(cfg, batch, max_seq, mesh=mesh, data_axes=data_axes)


def decode_step(cfg: ModelConfig, params, cache, token, *, mesh=None,
                data_axes=("data",)):
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, cache, token, mesh=mesh, data_axes=data_axes)
    return lm.decode_step(cfg, params, cache, token, mesh=mesh, data_axes=data_axes)
