"""Campaign engine throughput — pool-cycles/sec, scalar vs fleet.

Measures a full measure→record campaign (`repro.core.run_campaign`:
regime dynamics + node pools + SnS probing) through both collector
engines on the same fleet:

1. ``scalar`` — the paper-faithful per-pool path: one
   ``submit_spot_request`` per pool per cycle, per-request
   ``SpotRequest`` objects, per-probe Data-Lake rows (hot-path record
   retention off, the fair configuration at this scale);
2. ``fleet``  — the batched engine: one ``submit_spot_requests``
   admission call per cycle for the whole fleet, matrices in place of
   objects.

Because both engines ride the provider's counter-based per-pool RNG
streams, the benchmark also *asserts* the parity anchor: identical
``S_t`` / ``running_t`` matrices and interruption event logs.

Usage:
    PYTHONPATH=src python benchmarks/campaign_throughput.py [--smoke]
        [--pools 4096] [--cycles 16]

The full run asserts the fleet engine clears >= 20x the scalar engine at
4096 pools x 16 cycles on CPU; ``--smoke`` only checks plumbing + parity.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

N_REQ = 10
INTERVAL = 180.0
REQUIRED_SPEEDUP = 20.0


def _provider(pools: int, seed: int = 0):
    from repro.core import SimulatedProvider, default_fleet

    # rate limits sized for the paper's 68-pool campaign would starve a
    # SpotLake-class fleet; lift them so both engines probe every pool
    return SimulatedProvider(
        default_fleet(pools, seed=seed),
        seed=seed + 1,
        requests_per_minute_per_region=10**9,
    )


def bench_engine(engine: str, pools: int, cycles: int) -> float:
    """pool-cycles/sec for one engine (fresh provider, same seed)."""
    from repro.core import run_campaign

    provider = _provider(pools)
    t0 = time.perf_counter()
    run_campaign(
        provider,
        duration=cycles * INTERVAL,
        interval=INTERVAL,
        n_requests=N_REQ,
        engine=engine,
        retain_records=False,
    )
    return pools * cycles / (time.perf_counter() - t0)


def check_parity(pools: int = 256, cycles: int = 8) -> bool:
    """engine='fleet' == engine='scalar' bit-for-bit on shared RNG streams."""
    from repro.core import run_campaign

    results = []
    for engine in ("scalar", "fleet"):
        results.append(
            run_campaign(
                _provider(pools, seed=3),
                duration=cycles * INTERVAL,
                interval=INTERVAL,
                n_requests=N_REQ,
                engine=engine,
                retain_records=False,
            )
        )
    ca, cb = results
    np.testing.assert_array_equal(ca.s, cb.s)
    np.testing.assert_array_equal(ca.running, cb.running)
    assert ca.interruptions == cb.interruptions, "interruption logs diverged"
    assert ca.api_calls == cb.api_calls
    return True


def run(pools: int = 4096, cycles: int = 16, smoke: bool = False) -> dict:
    if smoke:
        pools, cycles = min(pools, 256), min(cycles, 8)
    sizes = sorted({min(1024, pools), pools})

    per_size = {}
    for p in sizes:
        scalar_rate = bench_engine("scalar", p, cycles)
        fleet_rate = bench_engine("fleet", p, cycles)
        per_size[p] = {
            "pool_cycles_per_sec": {
                "scalar": round(scalar_rate),
                "fleet": round(fleet_rate),
            },
            "speedup": round(fleet_rate / scalar_rate, 1),
        }

    result = {
        "cycles": cycles,
        "per_pools": per_size,
        "speedup": per_size[pools]["speedup"],
        "parity_identical": check_parity(
            pools=min(pools, 256), cycles=min(cycles, 8)
        ),
        "smoke": smoke,
    }
    if not smoke:
        assert result["speedup"] >= REQUIRED_SPEEDUP, result
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; skip the 20x assertion")
    args = ap.parse_args()
    result = run(pools=args.pools, cycles=args.cycles, smoke=args.smoke)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
