"""qwen1.5-4b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] — 40L d_model=2560 20H (kv=20) d_ff=6912
vocab=151936.  MHA (kv == heads), RoPE, RMSNorm, SwiGLU, bias on QKV.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    use_rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    gated_mlp=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
