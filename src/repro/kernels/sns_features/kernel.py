"""Batched SnS feature Pallas kernels (Algorithm 1 at fleet scale).

The paper's Data Pipeline updates SR/UR/CUT per pool in O(1); at
SpotLake-class collection scale (instance types × regions × AZs ≈ 10⁴–10⁶
pools) the natural TPU formulation is a *batched replay*.  Two kernels
share the same math:

* :func:`sns_features` — full-trace replay: one fused kernel recomputes
  all three features for a (pool-block × T) tile entirely in VMEM — one
  HBM read of the success counts, one write per feature, no intermediate
  cumulative arrays in HBM.  Requires the whole trace resident per tile,
  so T is bounded by VMEM.
* :func:`sns_features_stream` — **chunked streaming replay**: the grid's
  innermost axis walks ``chunk``-cycle time slabs sequentially while the
  carry state lives in VMEM scratch, so arbitrarily long traces are
  processed in (block_p × chunk) tiles.  The carry per pool block is
  exactly Algorithm 1's constant-memory state:

  - ``tail``  (block_p, w) — the last ``w`` values of the cumulative
    unfulfilled array ``P`` (``P[t0-w+1 .. t0]``; entries for t ≤ 0 stay
    0 ≡ P[0], which makes the paper's partial-window case fall out for
    free), giving both ``P[t]`` (its last column) and the lagged
    ``P[t-w]`` lookups for the next chunk;
  - ``lf``    (block_p, 1) — the global index of the last fully-fulfilled
    cycle (the associative-scan rewrite of the CUT reset counter).

Per tile:
* ``SR`` — elementwise scale;
* ``UR`` — carry-seeded prefix-sum of unfulfilled counts along the chunk,
  then a lagged difference against the (tail ++ chunk) buffer;
* ``CUT`` — running max of the last fully-fulfilled index, seeded with the
  carry (a ``cummax`` replaces the sequential reset-counter recurrence).

All ``P`` arithmetic is int32, so chunked and full-trace paths are
bit-identical to each other and to the float64 numpy replay
(``repro.core.features.compute_features``) wherever the final f32
divisions are exact or correctly rounded — in practice for any
``T·N < 2²⁴``.

full:   grid = (pools / block_p,);           block = (block_p, T)
stream: grid = (pools / block_p, T / chunk); block = (block_p, chunk)
        [chunk axis innermost/sequential; scratch persists across chunks]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _features_kernel(s_ref, sr_ref, ur_ref, cut_ref, *, n: int, w: int, dt: float):
    s = s_ref[...].astype(jnp.float32)                       # (bp, T)
    bp, t_max = s.shape

    sr_ref[...] = s / n

    unful = n - s
    p = jnp.cumsum(unful, axis=1)                            # P[t], t >= 1
    lagged = jnp.pad(p, ((0, 0), (w, 0)))[:, :t_max]         # P[t - w] (P<=0 -> 0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bp, t_max), 1) + 1
    wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
    ur_ref[...] = (p - lagged) / (wlen * n)

    idx = jax.lax.broadcasted_iota(jnp.int32, (bp, t_max), 1)
    full = (s == n) | (idx == 0)
    last_full = jax.lax.cummax(jnp.where(full, idx, -1), axis=1)
    cut_ref[...] = (idx - last_full).astype(jnp.float32) * dt


@functools.partial(jax.jit, static_argnames=("n", "w", "dt", "block_p", "interpret"))
def sns_features(
    s: jnp.ndarray,        # (pools, T) int32
    *,
    n: int,
    w: int,
    dt: float,
    block_p: int = 8,
    interpret: bool = False,
):
    pools, t_max = s.shape
    block_p = min(block_p, pools)
    if pools % block_p:
        raise ValueError(f"pools={pools} not divisible by block_p={block_p}")
    grid = (pools // block_p,)

    kernel = functools.partial(_features_kernel, n=n, w=w, dt=dt)
    out_shape = jax.ShapeDtypeStruct((pools, t_max), jnp.float32)
    sr, ur, cut = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_p, t_max), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_p, t_max), lambda i: (i, 0))] * 3,
        out_shape=[out_shape] * 3,
        interpret=interpret,
    )(s)
    return jnp.stack([sr, ur, cut], axis=-1)


def _stream_kernel(
    s_ref, sr_ref, ur_ref, cut_ref,
    tail_scr, lf_scr,
    *,
    n: int,
    w: int,
    dt: float,
    chunk: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        tail_scr[...] = jnp.zeros_like(tail_scr)   # P[t] = 0 for t <= 0
        lf_scr[...] = jnp.full_like(lf_scr, -1)    # no full cycle seen yet

    s = s_ref[...]                                 # (bp, C) int32
    bp, c = s.shape
    g0 = ic * chunk                                # 0-based global index offset

    sr_ref[...] = s.astype(jnp.float32) / n

    tail = tail_scr[...]                           # (bp, w): P[t0-w+1 .. t0]
    p = tail[:, -1:] + jnp.cumsum(n - s, axis=1)   # (bp, C): P[t0+1 .. t0+C]
    buf = jnp.concatenate([tail, p], axis=1)       # (bp, w+C): P[t0-w+1 .. t0+C]
    lagged = buf[:, :c]                            # P[t-w]  (0 ≡ P[0] while t <= w)
    t_idx = g0 + jax.lax.broadcasted_iota(jnp.int32, (bp, c), 1) + 1
    wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
    ur_ref[...] = (p - lagged).astype(jnp.float32) / (wlen * n)

    g = t_idx - 1
    full = (s == n) | (g == 0)
    lf = jnp.maximum(jax.lax.cummax(jnp.where(full, g, -1), axis=1), lf_scr[...])
    cut_ref[...] = (g - lf).astype(jnp.float32) * dt

    tail_scr[...] = buf[:, c:]                     # last w columns
    lf_scr[...] = lf[:, -1:]


@functools.partial(
    jax.jit, static_argnames=("n", "w", "dt", "block_p", "chunk", "interpret")
)
def sns_features_stream(
    s: jnp.ndarray,        # (pools, T) int32
    *,
    n: int,
    w: int,
    dt: float,
    block_p: int = 8,
    chunk: int = 128,
    interpret: bool = False,
):
    """Chunked streaming replay; bit-identical to :func:`sns_features`.

    Requires ``pools % block_p == 0`` and ``T % chunk == 0`` — use
    ``ops.sns_features_stream_op`` for the padded general-shape wrapper.
    """
    pools, t_max = s.shape
    block_p = min(block_p, pools)
    chunk = min(chunk, t_max)
    if pools % block_p or t_max % chunk:
        # a bare assert would vanish under -O and leave grid-uncovered
        # output rows silently uninitialized
        raise ValueError(
            f"pools={pools} / T={t_max} not divisible by block_p={block_p} / "
            f"chunk={chunk}; use ops.sns_features_stream_op for padding"
        )
    grid = (pools // block_p, t_max // chunk)

    kernel = functools.partial(_stream_kernel, n=n, w=w, dt=dt, chunk=chunk)
    out_shape = jax.ShapeDtypeStruct((pools, t_max), jnp.float32)
    sr, ur, cut = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_p, chunk), lambda i, ic: (i, ic))],
        out_specs=[pl.BlockSpec((block_p, chunk), lambda i, ic: (i, ic))] * 3,
        out_shape=[out_shape] * 3,
        scratch_shapes=[
            pltpu.VMEM((block_p, w), jnp.int32),
            pltpu.VMEM((block_p, 1), jnp.int32),
        ],
        interpret=interpret,
    )(s)
    return jnp.stack([sr, ur, cut], axis=-1)
