"""End-to-end driver: train an LM on preemptible capacity with SnS guidance.

The complete loop the paper's signals enable, run for real (small model,
CPU-sized, a few hundred steps by default):

* a simulated spot fleet hosts the training pod; the pool's availability
  trace drives preemptions;
* SnS probes the pool every cycle; the hazard-adaptive policy
  (Young–Daly with predictor-estimated hazard) decides when to checkpoint;
* on preemption, training restarts from the latest checkpoint (the
  elastic manager re-meshes; on a 1-device host this is a same-mesh
  restore) and lost steps are accounted;
* the same trace replayed with a sparse fixed-interval baseline shows the
  SnS advantage (the paper's Fig. 9 logic, applied to training).

Run:  PYTHONPATH=src python examples/elastic_training.py [--steps 300] [--d-model 256]
(--d-model 768 --layers 12 approximates a 100M-class model if you have
the minutes to spare.)
"""

import argparse
import os
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    SimulatedProvider,
    build_dataset,
    default_fleet,
    fit_predictor,
    run_campaign,
)
from repro.fleet import FixedInterval, SnSHazard, traces_from_campaign
from repro.models import api
from repro.train import (
    OptConfig,
    init_opt_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    synthetic_batch,
)


def train_through_trace(cfg, trace, policy, predictor, *, steps_budget,
                        step_fn, params0, opt0, ckpt_dir, batch_fn,
                        sim_step_time=20.0, sim_ckpt_cost=40.0,
                        start_cycle=0):
    """Drive REAL training steps through a pod availability trace.

    Simulation clock: each completed step advances `sim_step_time` seconds
    of trace time; checkpoints cost `sim_ckpt_cost` trace-seconds."""
    params, opt_state = params0, opt0
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    done = lost = ckpts = since_ckpt = 0
    cycle = start_cycle
    t_last_ckpt = now = cycle * trace.dt
    cyc_len = trace.dt
    losses = []
    while done < steps_budget and cycle < len(trace.available):
        if not trace.available[cycle]:
            # preemption: roll back to the last checkpoint
            if since_ckpt:
                lost += since_ckpt
                if latest_step(ckpt_dir) is not None:
                    params, opt_state, _ = load_checkpoint(
                        ckpt_dir, params, opt_state
                    )
                else:
                    params, opt_state = params0, opt0
                done -= since_ckpt
                since_ckpt = 0
            cycle += 1
            now = cycle * cyc_len
            continue

        p_survive = predictor(trace.features[cycle]) if predictor else None
        budget = cyc_len
        while budget >= sim_step_time and done < steps_budget:
            if policy.should_checkpoint(now + (cyc_len - budget), t_last_ckpt,
                                        p_survive) and since_ckpt:
                save_checkpoint(ckpt_dir, done, params, opt_state)
                ckpts += 1
                since_ckpt = 0
                t_last_ckpt = now + (cyc_len - budget)
                budget -= sim_ckpt_cost
                continue
            params, opt_state, metrics = step_fn(
                params, opt_state, batch_fn(done)
            )
            losses.append(float(metrics["loss"]))
            done += 1
            since_ckpt += 1
            budget -= sim_step_time
        cycle += 1
        now = cycle * cyc_len
    return {
        "steps_done": done, "steps_lost": lost, "checkpoints": ckpts,
        "final_loss": losses[-1] if losses else float("nan"),
        "loss_start": losses[0] if losses else float("nan"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # -- SnS control plane: campaign + predictor --------------------------
    fleet = default_fleet(12, seed=3)
    provider = SimulatedProvider(fleet, seed=4)
    campaign = run_campaign(provider, duration=24 * 3600.0)
    ds = build_dataset(campaign, window_minutes=240, horizon_minutes=15,
                       split="pool", seed=0)
    predictor_model = fit_predictor("xgb", ds)
    std = ds.standardizer

    def p_survive(features):
        x = std(features[None, :]) if std else features[None, :]
        return float(predictor_model.predict_proba(x)[0])

    traces = traces_from_campaign(campaign, window_minutes=240)
    # train on the bumpiest pod, starting shortly before its first outage
    trace = min(traces, key=lambda t: t.available.mean())
    down = np.flatnonzero(~trace.available.astype(bool))
    start_cycle = int(max(0, (down[0] if down.size else 0) - 15))
    print(f"pod pool {trace.pool_id}: availability "
          f"{trace.available.mean():.1%} over 24h "
          f"(starting at cycle {start_cycle})")

    # -- data plane: a real LM + production train step --------------------
    cfg = get_config("gemma3-1b").scaled_down(
        d_model=args.d_model, n_layers=args.layers,
        d_ff=args.d_model * 4, vocab_size=2048,
        head_dim=max(16, args.d_model // 8),
    )
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")
    params0 = api.init_params(cfg, seed=0)
    opt0 = init_opt_state(params0)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=3e-4, warmup_steps=20,
                                                     total_steps=args.steps)))

    def batch_fn(step):  # deterministic per-step data (elastic-safe)
        return synthetic_batch(cfg, args.batch, args.seq, seed=step)

    ckpt_root = tempfile.mkdtemp(prefix="elastic_")
    results = {}
    for name, policy, pred in [
        ("fixed_30min", FixedInterval(1800.0), None),
        ("sns_hazard", SnSHazard(ckpt_cost=20.0, horizon=900.0,
                                 panic_threshold=0.4), p_survive),
    ]:
        t0 = time.time()
        r = train_through_trace(
            cfg, trace, policy, pred,
            steps_budget=args.steps, step_fn=step_fn,
            params0=params0, opt0=opt0,
            ckpt_dir=os.path.join(ckpt_root, name), batch_fn=batch_fn,
            start_cycle=start_cycle,
        )
        r["wall_s"] = round(time.time() - t0, 1)
        results[name] = r
        print(f"{name:12s}: {r['steps_done']} steps done, "
              f"{r['steps_lost']} lost, {r['checkpoints']} ckpts, "
              f"loss {r['loss_start']:.3f} -> {r['final_loss']:.3f} "
              f"[{r['wall_s']}s]")

    f, s = results["fixed_30min"], results["sns_hazard"]
    if f["steps_lost"] > 0:
        print(f"\nSnS-guided checkpointing cut lost steps by "
              f"{1 - s['steps_lost']/max(1, f['steps_lost']):.0%} "
              f"vs the fixed-interval baseline")
    shutil.rmtree(ckpt_root, ignore_errors=True)


if __name__ == "__main__":
    main()
