"""Preemption replay + goodput accounting — closed-form, fleet-vectorised.

Replays pod availability traces against a (simulated) training job and
accounts lost computation under a checkpoint policy — the training-side
analogue of the paper's §VI-E query simulation, scaled the same way:

* between checkpoints, completed steps are *at risk*: a preemption rolls
  the job back to the last **completed** checkpoint (work since then is
  lost — a write still in flight protects nothing);
* each checkpoint costs ``ckpt_cost`` seconds of training time; a write
  clipped by the cycle budget **carries across cycles** (the
  ``write_rem`` register) exactly like restores do — it only counts, and
  only protects steps, once the last byte lands;
* after a preemption the job waits for the pool to recover, restores
  (``restore_cost`` seconds, resumable across cycles), and continues;
* the **SnSHazard** policy consumes per-cycle survival probabilities from
  the SnS predictor to adapt cadence / force panic checkpoints.

The replay contract (per-cycle closed form)
-------------------------------------------

Every engine advances one *closed-form state transition per collection
cycle* — there is no data-dependent inner ``while`` (house style of
``core.simulate`` / ``kernels.replay_scan``).  Per trace row the carried
state is ``(steps_done, steps_since_ckpt, steps_lost, ckpts, overhead,
unavailable, t_last_ckpt, restore_rem, write_rem)``; with ``now = c·dt``
the cycle-``c`` transition is:

* **down cycle** — steps since the last completed checkpoint are lost, an
  in-flight write is aborted (overhead already paid stays paid),
  ``restore_rem`` re-arms to ``restore_cost``, ``unavailable += dt``.
* **up cycle** — budget ``b = dt``:

  - *drain restore*: ``b`` pays down ``restore_rem`` first;
  - *drain write*: then any carried checkpoint write; if it completes,
    ``ckpts += 1``, ``t_last_ckpt`` = the completion instant, and the
    steps it covers become safe (``steps_since_ckpt = 0``);
  - *policy consult* (**once per cycle**, at ``t_c = now + (dt − b)``,
    only with ``b > 0``): the policy reduces to a per-cycle interval
    ``τ`` (see :class:`~repro.fleet.ckpt_policy.PolicyTable`); if
    ``t_c − t_last_ckpt ≥ τ`` a write starts when there are unprotected
    steps (paying ``min(b, ckpt_cost)`` now and carrying the rest), and
    otherwise merely refreshes ``t_last_ckpt = t_c`` (nothing new to
    save — no redundant write, no cost);
  - *training*: the leftover budget runs ``k = floor(b / step_time)``
    whole steps; fractional-step budget is discarded (a step either
    completes within the cycle or is never started).

Predictions enter as per-cycle *arrays* (one batched model call for the
whole fleet — the pipeline's batched-predictor contract), and every
policy decision reduces to comparing ``t_c − t_last_ckpt`` against a
per-(row, cycle) ``τ`` matrix evaluated by the same ufunc formulas in
every engine.  That pins all float arithmetic, which is what makes the
three implementations **bit-identical (atol=0)** row by row:

* :func:`run_replay` — the scalar reference: one pod, one policy object,
  a plain Python cycle loop (readable; the semantic spec).
* :func:`run_replay_batch` — the batched engines over a stacked
  ``(pods × policies × seeds)`` row axis: ``engine="numpy"`` is the
  vectorised per-cycle loop (the parity oracle), ``engine="scan"`` the
  ``lax.scan`` closed form (float64 under a scoped ``enable_x64``; the
  fast CPU path), ``engine="kernel"`` the fused
  :mod:`repro.kernels.goodput_scan` engine (τ re-derived in-graph from
  host-packed flags + negative log survival — no host ``(R, T)`` τ
  matrix; Pallas on TPU, fused scan elsewhere; opt-in ``precision="f32"``
  fast tier), ``engine="auto"`` picks scan for non-degenerate shapes.

:func:`run_replay_fleet` crosses pods × policies *fused*: on the kernel
engine each pod's availability/hazard column is read once and replayed
through every policy plane in one pass (policy-major ``(S·P,)`` rows).
:func:`run_goodput_frontier` aggregates it per policy (the
goodput-frontier experiment), and
:class:`GoodputStream` is the *online* form: it consumes live
``StreamCycleView.probs`` from a :class:`~repro.core.pipeline.
CampaignPipelineStream` cycle by cycle — streamed ≡ batch bit-identical,
resumable via the ``state_dict()`` / ``restore()`` protocol.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .ckpt_policy import PolicyTable, neg_log_survival
from .events import PodTrace

__all__ = [
    "ReplayResult",
    "run_replay",
    "run_replay_batch",
    "run_replay_fleet",
    "run_goodput_frontier",
    "GoodputCycleView",
    "GoodputStream",
]

ENGINES = ("auto", "numpy", "scan", "kernel")

#: numeric tiers of the kernel engine: "f64" is the atol=0 house contract,
#: "f32" the bandwidth-lean fast tier (kernel engine only)
PRECISIONS = ("f64", "f32")


@dataclasses.dataclass
class ReplayResult:
    policy: str
    steps_completed: int
    steps_lost: int
    checkpoints: int
    ckpt_overhead_s: float
    lost_work_s: float
    unavailable_s: float

    @property
    def goodput(self) -> float:
        total = self.steps_completed + self.steps_lost
        return self.steps_completed / total if total else 0.0


def run_replay(
    trace: PodTrace,
    *,
    policy,
    step_time: float = 2.0,            # seconds per training step
    ckpt_cost: float = 30.0,           # seconds per checkpoint write
    restore_cost: float = 60.0,        # seconds to restore after preemption
    predictor: Optional[Callable[[np.ndarray], float]] = None,
    p_survive: Optional[np.ndarray] = None,
    policy_name: str = "",
) -> ReplayResult:
    """Replay one pod's availability trace under a checkpoint policy.

    The scalar contract reference (see the module docstring).  The
    predictor feeds SnSHazard either as a per-cycle callable
    ``predictor(features[c]) -> P(pool survives the horizon)`` or as a
    precomputed ``p_survive`` array (the batched-predictor form).
    """
    avail = trace.available.astype(bool)
    dt = float(trace.dt)
    t_cycles = len(avail)

    done = 0            # completed training steps
    since = 0           # steps since the last *completed* checkpoint
    lost = 0
    ckpts = 0
    overhead = 0.0
    unavailable = 0.0
    t_last = 0.0
    restore_rem = 0.0
    write_rem = 0.0     # carried partial checkpoint write

    for c in range(t_cycles):
        now = c * dt
        if not avail[c]:
            # preemption: everything since the last completed checkpoint
            # is lost; an in-flight write is aborted (its cost stays paid)
            lost += since
            since = 0
            unavailable += dt
            restore_rem = restore_cost
            write_rem = 0.0
            continue

        p = None
        if predictor is not None:
            p = float(predictor(trace.features[c]))
        elif p_survive is not None:
            p = float(p_survive[c])

        budget = dt
        # -- drain restore, then the carried checkpoint write -------------
        used = min(budget, restore_rem)
        restore_rem -= used
        budget -= used
        if write_rem > 0.0:
            w = min(budget, write_rem)
            write_rem -= w
            budget -= w
            overhead += w
            if write_rem <= 0.0:       # the write completes this cycle
                ckpts += 1
                t_last = now + (dt - budget)
                since = 0
        # -- policy consult: once per cycle, at t_c -----------------------
        if budget > 0.0:
            t_c = now + (dt - budget)
            if policy.should_checkpoint(t_c, t_last, p):
                if since > 0:
                    w2 = min(budget, ckpt_cost)
                    budget -= w2
                    overhead += w2
                    if w2 >= ckpt_cost:   # wrote whole ckpt within the cycle
                        ckpts += 1
                        t_last = now + (dt - budget)
                        since = 0
                    else:                 # clipped: carry the partial write
                        write_rem = ckpt_cost - w2
                else:
                    t_last = t_c          # nothing new to save; no write
        # -- training steps fill the remainder ----------------------------
        k = int(math.floor(budget / step_time))
        done += k
        since += k

    return ReplayResult(
        policy=policy_name or type(policy).__name__,
        steps_completed=done,
        steps_lost=lost,
        checkpoints=ckpts,
        ckpt_overhead_s=overhead,
        lost_work_s=lost * step_time,
        unavailable_s=unavailable,
    )


# --------------------------------------------------------------------------
# Batched engines
# --------------------------------------------------------------------------


def _init_state(rows: int) -> Dict[str, np.ndarray]:
    """The stacked per-row replay state (see the contract docstring)."""
    return {
        "done": np.zeros(rows, dtype=np.int64),
        "since": np.zeros(rows, dtype=np.int64),
        "lost": np.zeros(rows, dtype=np.int64),
        "ckpts": np.zeros(rows, dtype=np.int64),
        "overhead": np.zeros(rows, dtype=np.float64),
        "unavailable": np.zeros(rows, dtype=np.float64),
        "t_last": np.zeros(rows, dtype=np.float64),
        "restore_rem": np.zeros(rows, dtype=np.float64),
        "write_rem": np.zeros(rows, dtype=np.float64),
    }


def _cycle_update(
    st: Dict[str, np.ndarray],
    up: np.ndarray,          # (R,) bool
    tau_c: np.ndarray,       # (R,) f64 — this cycle's policy intervals
    now: float,
    *,
    dt: float,
    step_time: float,
    ckpt_cost: float,
    restore_cost: float,
):
    """One closed-form transition over the stacked state (in place).

    The vectorised mirror of the scalar cycle body in :func:`run_replay`
    — op for op, so rows are bit-identical to per-pod scalar replays.
    Returns ``(write_started, ckpt_completed, steps)`` per row for online
    consumers (:class:`GoodputStream`).
    """
    down = ~up
    st["lost"] += np.where(down, st["since"], 0)
    st["since"] = np.where(down, 0, st["since"])
    st["unavailable"] += np.where(down, dt, 0.0)
    st["restore_rem"] = np.where(down, restore_cost, st["restore_rem"])
    st["write_rem"] = np.where(down, 0.0, st["write_rem"])

    budget = np.where(up, dt, 0.0)
    # -- drain restore, then the carried checkpoint write -----------------
    used = np.minimum(budget, st["restore_rem"])
    st["restore_rem"] = st["restore_rem"] - used
    budget = budget - used
    was_writing = st["write_rem"] > 0.0
    w = np.minimum(budget, st["write_rem"])
    st["write_rem"] = st["write_rem"] - w
    budget = budget - w
    st["overhead"] = st["overhead"] + w
    done_write = was_writing & (st["write_rem"] <= 0.0)
    st["ckpts"] += done_write.astype(np.int64)
    st["t_last"] = np.where(done_write, now + (dt - budget), st["t_last"])
    st["since"] = np.where(done_write, 0, st["since"])
    # -- policy consult: once per cycle, at t_c ---------------------------
    t_c = now + (dt - budget)
    can = up & (budget > 0.0)
    decide = can & (t_c - st["t_last"] >= tau_c)
    start = decide & (st["since"] > 0)
    st["t_last"] = np.where(decide & (st["since"] == 0), t_c, st["t_last"])
    w2 = np.where(start, np.minimum(budget, ckpt_cost), 0.0)
    budget = budget - w2
    st["overhead"] = st["overhead"] + w2
    full = start & (w2 >= ckpt_cost)
    st["write_rem"] = np.where(start & ~full, ckpt_cost - w2, st["write_rem"])
    st["ckpts"] += full.astype(np.int64)
    st["t_last"] = np.where(full, now + (dt - budget), st["t_last"])
    st["since"] = np.where(full, 0, st["since"])
    # -- training steps fill the remainder --------------------------------
    steps = np.floor(budget / step_time).astype(np.int64)
    st["done"] += steps
    st["since"] += steps
    return start, done_write | full, steps


def _metrics_from_state(st: Dict[str, np.ndarray], step_time: float) -> Dict[str, np.ndarray]:
    total = st["done"] + st["lost"]
    return {
        "steps_completed": st["done"].copy(),
        "steps_lost": st["lost"].copy(),
        "checkpoints": st["ckpts"].copy(),
        "ckpt_overhead_s": st["overhead"].copy(),
        "lost_work_s": st["lost"] * step_time,
        "unavailable_s": st["unavailable"].copy(),
        "goodput": np.where(total > 0, st["done"] / np.maximum(total, 1), 0.0),
    }


def _run_replay_batch_numpy(avail, tau, *, dt, step_time, ckpt_cost, restore_cost):
    """The vectorised per-cycle numpy loop — the batch parity oracle."""
    R, T = avail.shape
    st = _init_state(R)
    for c in range(T):
        _cycle_update(
            st, avail[:, c], tau[:, c], c * dt,
            dt=dt, step_time=step_time, ckpt_cost=ckpt_cost,
            restore_cost=restore_cost,
        )
    return _metrics_from_state(st, step_time)


_SCAN_CACHE: dict = {}


def _scan_fn():
    """The jitted ``lax.scan`` engine (built once; shapes are traced)."""
    fn = _SCAN_CACHE.get("fn")
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp

    def engine(avail_t, tau_t, now_t, dt, step_time, ckpt_cost, restore_cost):
        R = avail_t.shape[1]
        f = tau_t.dtype
        i64 = jnp.int64
        zf = jnp.zeros(R, f)
        zi = jnp.zeros(R, i64)

        def cycle(carry, xs):
            (done, since, lost, ckpts, overhead, unavailable,
             t_last, restore_rem, write_rem) = carry
            up, tau_c, now = xs
            down = ~up
            lost = lost + jnp.where(down, since, 0)
            since = jnp.where(down, 0, since)
            unavailable = unavailable + jnp.where(down, dt, 0.0)
            restore_rem = jnp.where(down, restore_cost, restore_rem)
            write_rem = jnp.where(down, 0.0, write_rem)

            budget = jnp.where(up, dt, 0.0)
            used = jnp.minimum(budget, restore_rem)
            restore_rem = restore_rem - used
            budget = budget - used
            was_writing = write_rem > 0.0
            w = jnp.minimum(budget, write_rem)
            write_rem = write_rem - w
            budget = budget - w
            overhead = overhead + w
            done_write = was_writing & (write_rem <= 0.0)
            ckpts = ckpts + done_write.astype(i64)
            t_last = jnp.where(done_write, now + (dt - budget), t_last)
            since = jnp.where(done_write, 0, since)

            t_c = now + (dt - budget)
            can = up & (budget > 0.0)
            decide = can & (t_c - t_last >= tau_c)
            start = decide & (since > 0)
            t_last = jnp.where(decide & (since == 0), t_c, t_last)
            w2 = jnp.where(start, jnp.minimum(budget, ckpt_cost), 0.0)
            budget = budget - w2
            overhead = overhead + w2
            full = start & (w2 >= ckpt_cost)
            write_rem = jnp.where(start & ~full, ckpt_cost - w2, write_rem)
            ckpts = ckpts + full.astype(i64)
            t_last = jnp.where(full, now + (dt - budget), t_last)
            since = jnp.where(full, 0, since)

            steps = jnp.floor(budget / step_time).astype(i64)
            done = done + steps
            since = since + steps
            return (done, since, lost, ckpts, overhead, unavailable,
                    t_last, restore_rem, write_rem), None

        init = (zi, zi, zi, zi, zf, zf, zf, zf, zf)
        final, _ = jax.lax.scan(cycle, init, (avail_t, tau_t, now_t))
        return final

    fn = jax.jit(engine)
    _SCAN_CACHE["fn"] = fn
    return fn


def _run_replay_batch_scan(avail, tau, *, dt, step_time, ckpt_cost, restore_cost):
    """The ``lax.scan`` engine — float64 under a scoped ``enable_x64``."""
    from jax.experimental import enable_x64

    T = avail.shape[1]
    now_t = np.arange(T, dtype=np.float64) * dt
    with enable_x64():
        final = _scan_fn()(
            np.ascontiguousarray(avail.T),
            np.ascontiguousarray(tau.T),
            now_t,
            np.float64(dt), np.float64(step_time),
            np.float64(ckpt_cost), np.float64(restore_cost),
        )
        (done, since, lost, ckpts, overhead, unavailable, *_rest) = [
            np.asarray(x) for x in final
        ]
    st = {
        "done": done, "since": since, "lost": lost, "ckpts": ckpts,
        "overhead": overhead, "unavailable": unavailable,
    }
    return _metrics_from_state(st, step_time)


def _finish_kernel_metrics(res: Dict[str, np.ndarray], step_time: float):
    """Derive the host-side metrics over the kernel engine's counters —
    the same f64 ufuncs as :func:`_metrics_from_state`."""
    total = res["steps_completed"] + res["steps_lost"]
    res["lost_work_s"] = res["steps_lost"] * step_time
    res["goodput"] = np.where(
        total > 0, res["steps_completed"] / np.maximum(total, 1), 0.0
    )
    return res


def _run_replay_batch_kernel(
    avail, table: PolicyTable, p_survive,
    *, dt, step_time, ckpt_cost, restore_cost, precision, backend,
):
    """The fused kernel engine over per-row policies (``S == 1`` plane of
    the policy-fused sweep, one pod row per table row)."""
    from ..kernels.goodput_scan import goodput_sweep_op

    R, T = avail.shape
    p = np.ones((R, T)) if p_survive is None else p_survive
    nlp = neg_log_survival(p)                       # (R, T) f64, host log
    panic = table.panic(p)                          # host predicate
    flags = avail.astype(np.int32) | (panic.astype(np.int32) << 1)
    planes = {
        k: np.broadcast_to(np.asarray(v), (R,))[None, :]
        for k, v in table.engine_planes().items()
    }
    if precision == "f32":
        nlp = nlp.astype(np.float32)
    res = goodput_sweep_op(
        flags, nlp, planes, dt=dt, step_time=step_time,
        ckpt_cost=ckpt_cost, restore_cost=restore_cost, backend=backend,
    )
    return _finish_kernel_metrics({k: v[0] for k, v in res.items()}, step_time)


def _policy_table(policies, rows: int, names=None) -> PolicyTable:
    """Normalise the ``policies`` argument of :func:`run_replay_batch`."""
    if isinstance(policies, PolicyTable):
        if len(policies) not in (rows, 1):
            raise ValueError(
                f"policy table has {len(policies)} rows, traces have {rows}"
            )
        return policies
    if not isinstance(policies, (list, tuple)):
        policies = [policies] * rows
        names = [names] * rows if isinstance(names, str) else names
    if len(policies) != rows:
        raise ValueError(f"{len(policies)} policies for {rows} trace rows")
    return PolicyTable.from_policies(policies, names=names)


def run_replay_batch(
    avail: np.ndarray,
    policies,
    *,
    p_survive: Optional[np.ndarray] = None,
    dt: float = 180.0,
    step_time: float = 2.0,
    ckpt_cost: float = 30.0,
    restore_cost: float = 60.0,
    engine: str = "auto",
    precision: str = "f64",
    backend: str = "auto",
    names=None,
) -> Dict[str, np.ndarray]:
    """Replay a stack of traces, one checkpoint policy per row.

    Args:
      avail: (R, T) — or (T,), broadcast — binary availability per row;
        the row axis is any flattening of pods × policies × seeds.
      policies: a :class:`~repro.fleet.ckpt_policy.PolicyTable` with R
        rows, a sequence of R policy objects, or a single policy
        broadcast to every row.
      p_survive: (R, T) or (T,) per-cycle survival probabilities from the
        SnS predictor (the batched-predictor contract); hazard rows fall
        back to ``p = 1`` when omitted.
      engine: ``"numpy"`` (vectorised per-cycle loop, the parity oracle)
        | ``"scan"`` (the jitted ``lax.scan`` closed form, float64 under
        a scoped ``enable_x64`` — the fast CPU path) | ``"kernel"`` (the
        fused :mod:`repro.kernels.goodput_scan` engine: τ re-derived
        in-graph from host-packed flags + negative log survival, no host
        ``(R, T)`` τ matrix) | ``"auto"`` (scan, except degenerate empty
        shapes).  All engines at f64 are **bit-identical (atol=0)** to
        per-row scalar :func:`run_replay`.
      precision: ``"f64"`` (house contract) or ``"f32"`` — the
        bandwidth-lean fast tier, kernel engine only.
      backend: kernel-engine backend override (``"auto"`` | ``"jnp"`` |
        ``"pallas"``); ``"auto"`` is Pallas on TPU (f32), fused scan
        elsewhere.

    Returns stacked metrics ``{"steps_completed", "steps_lost",
    "checkpoints", "ckpt_overhead_s", "lost_work_s", "unavailable_s",
    "goodput"}``, each of shape (R,).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (want one of {ENGINES})")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r} (want one of {PRECISIONS})"
        )
    if precision != "f64" and engine != "kernel":
        raise ValueError("precision='f32' is the kernel-engine fast tier")
    avail = np.atleast_2d(np.asarray(avail)).astype(bool)
    R, T = avail.shape
    table = _policy_table(policies, R, names)
    if p_survive is not None:
        p_survive = np.broadcast_to(
            np.atleast_2d(np.asarray(p_survive, dtype=np.float64)), (R, T)
        )
    if engine == "kernel":
        return _run_replay_batch_kernel(
            avail, table, p_survive, dt=dt, step_time=step_time,
            ckpt_cost=ckpt_cost, restore_cost=restore_cost,
            precision=precision, backend=backend,
        )
    # τ is engine-independent input data: one vectorised evaluation feeds
    # numpy and scan identically (the scalar spec recomputes the same
    # ufuncs per cycle through the policy objects)
    tau = np.broadcast_to(table.tau(p_survive, cycles=T), (R, T))
    if engine == "auto":
        engine = "numpy" if (R == 0 or T == 0) else "scan"
    run = _run_replay_batch_numpy if engine == "numpy" else _run_replay_batch_scan
    return run(
        avail, tau, dt=dt, step_time=step_time, ckpt_cost=ckpt_cost,
        restore_cost=restore_cost,
    )


def run_replay_fleet(
    avail: np.ndarray,
    policies: Sequence,
    *,
    p_survive: Optional[np.ndarray] = None,
    names: Optional[Sequence[str]] = None,
    dt: float = 180.0,
    step_time: float = 2.0,
    ckpt_cost: float = 30.0,
    restore_cost: float = 60.0,
    engine: str = "auto",
    precision: str = "f64",
    backend: str = "auto",
) -> Dict[str, np.ndarray]:
    """Cross ``(pods, T)`` traces with S policies — policy-major
    ``(S·pods,)`` :func:`run_replay_batch` metrics.

    On ``engine="kernel"`` the cross product is **fused**: each pod's
    availability / hazard column is loaded once and replayed through all
    S policy planes in one :mod:`repro.kernels.goodput_scan` pass (panic
    bits for every plane packed into one int32 flag matrix — at most 30
    policies).  Other engines tile the traces over the policy axis and
    delegate (bit-identical by construction).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r} (want one of {ENGINES})")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r} (want one of {PRECISIONS})"
        )
    avail = np.atleast_2d(np.asarray(avail)).astype(bool)
    pods, T = avail.shape
    n_pol = len(policies)
    if p_survive is not None:
        p_survive = np.broadcast_to(
            np.atleast_2d(np.asarray(p_survive, dtype=np.float64)), (pods, T)
        )
    if engine != "kernel":
        table = PolicyTable.from_policies(policies, repeat=pods, names=names)
        big_avail = np.tile(avail, (n_pol, 1))
        big_p = None if p_survive is None else np.tile(p_survive, (n_pol, 1))
        return run_replay_batch(
            big_avail, table, p_survive=big_p, dt=dt, step_time=step_time,
            ckpt_cost=ckpt_cost, restore_cost=restore_cost, engine=engine,
            precision=precision,
        )
    if n_pol > 30:
        raise ValueError(
            f"{n_pol} policy planes exceed the 30 panic flag bits"
        )
    from ..kernels.goodput_scan import goodput_sweep_op

    table = PolicyTable.from_policies(policies, names=names)   # S rows
    p = np.ones((pods, T)) if p_survive is None else p_survive
    nlp = neg_log_survival(p)                       # (pods, T) f64, host log
    # per-plane panic bits: the same host predicate as PolicyTable.panic
    flags = avail.astype(np.int32)
    for s in range(n_pol):
        if table.is_hazard[s]:
            pan = (1.0 - p) >= table.panic_threshold[s]
            flags = flags | (pan.astype(np.int32) << (s + 1))
    planes = {
        k: np.broadcast_to(np.asarray(v)[:, None], (n_pol, pods))
        for k, v in table.engine_planes().items()
    }
    if precision == "f32":
        nlp = nlp.astype(np.float32)
    res = goodput_sweep_op(
        flags, nlp, planes, dt=dt, step_time=step_time,
        ckpt_cost=ckpt_cost, restore_cost=restore_cost, backend=backend,
    )
    return _finish_kernel_metrics(
        {k: v.reshape(n_pol * pods) for k, v in res.items()}, step_time
    )


def run_goodput_frontier(
    avail: np.ndarray,
    policies: Sequence,
    *,
    p_survive: Optional[np.ndarray] = None,
    names: Optional[Sequence[str]] = None,
    dt: float = 180.0,
    step_time: float = 2.0,
    ckpt_cost: float = 30.0,
    restore_cost: float = 60.0,
    engine: str = "auto",
    precision: str = "f64",
    backend: str = "auto",
) -> Dict[str, ReplayResult]:
    """The goodput-frontier experiment: pods × policies in one batch.

    Crosses the ``(pods, T)`` traces with the policy list through
    :func:`run_replay_fleet` (fused on ``engine="kernel"``, policy-tiled
    otherwise) and returns per-policy fleet aggregates ``{policy name:
    ReplayResult summed over pods}``.  Stack traces from several campaign
    seeds along the pod axis to add the seeds dimension.
    """
    avail = np.atleast_2d(np.asarray(avail)).astype(bool)
    pods, T = avail.shape
    batch = run_replay_fleet(
        avail, policies, p_survive=p_survive, names=names, dt=dt,
        step_time=step_time, ckpt_cost=ckpt_cost, restore_cost=restore_cost,
        engine=engine, precision=precision, backend=backend,
    )
    out: Dict[str, ReplayResult] = {}
    for i, pol in enumerate(policies):
        name = names[i] if names is not None else type(pol).__name__
        rows = slice(i * pods, (i + 1) * pods)
        done = int(batch["steps_completed"][rows].sum())
        lost = int(batch["steps_lost"][rows].sum())
        out[name] = ReplayResult(
            policy=name,
            steps_completed=done,
            steps_lost=lost,
            checkpoints=int(batch["checkpoints"][rows].sum()),
            ckpt_overhead_s=float(batch["ckpt_overhead_s"][rows].sum()),
            lost_work_s=float(batch["lost_work_s"][rows].sum()),
            unavailable_s=float(batch["unavailable_s"][rows].sum()),
        )
    return out


# --------------------------------------------------------------------------
# Live-hazard streaming (online form)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class GoodputCycleView:
    """One cycle of online checkpoint decisions for the pod fleet.

    Arrays are ``(policies, pods)`` except the per-pod ``up`` /
    ``p_survive``.  ``write_started`` marks rows whose policy began a
    checkpoint write this cycle (the actionable signal — trigger the real
    write now); ``panic`` marks hazard rows in the imminent-interrupt
    regime; ``ckpt_completed`` marks writes whose last byte landed this
    cycle (including carried partial writes).
    """

    cycle: int
    time: float
    up: np.ndarray                    # (pods,) bool — pod availability
    p_survive: Optional[np.ndarray]   # (pods,) f64 or None (no prediction yet)
    write_started: np.ndarray         # (policies, pods) bool
    ckpt_completed: np.ndarray        # (policies, pods) bool
    panic: np.ndarray                 # (policies, pods) bool
    steps: np.ndarray                 # (policies, pods) int64 — steps this cycle


class GoodputStream:
    """Online goodput engine: live SnS hazards → checkpoint decisions.

    Wraps a :class:`~repro.core.pipeline.CampaignPipelineStream` and
    advances the replay contract one cycle per :meth:`step`: the cycle's
    ``StreamCycleView.probs`` column (one batched predictor call for the
    whole fleet) becomes the hazard input of every policy row, and the
    same closed-form transition as :func:`run_replay_batch` updates the
    stacked ``(policies × pods)`` state — so draining the stream is
    **bit-identical (atol=0)** to the offline batch replay of the
    finished campaign's traces under the same per-cycle probabilities.

    Pod availability is the paper's binary formulation (``running == N``)
    read live off the campaign stream; cycles whose predictions are not
    yet available (sequence predictors warming up, or no predictor)
    replay under ``p = 1`` for hazard rows.

    Resumable: :meth:`state_dict` / :meth:`restore` snapshot the stacked
    replay state *and* the wrapped pipeline stream (the PR-8 protocol) —
    kill at cycle k, restore onto a fresh stream, drain, and the result
    is bit-identical to the uninterrupted run.
    """

    def __init__(
        self,
        stream,
        policies: Sequence,
        *,
        n_pods: Optional[int] = None,
        names: Optional[Sequence[str]] = None,
        step_time: float = 2.0,
        ckpt_cost: float = 30.0,
        restore_cost: float = 60.0,
    ):
        self.stream = stream
        pools = len(stream.processor.pool_ids)
        self.n_pods = min(n_pods, pools) if n_pods is not None else pools
        self.n_policies = len(policies)
        self.policy_names = list(
            names if names is not None else (type(p).__name__ for p in policies)
        )
        self.table = PolicyTable.from_policies(
            policies, repeat=self.n_pods, names=names
        )
        self.dt = float(stream.campaign.interval)
        self._n = int(stream.campaign.n)
        self.step_time = float(step_time)
        self.ckpt_cost = float(ckpt_cost)
        self.restore_cost = float(restore_cost)
        self._st = _init_state(self.n_pods * self.n_policies)
        self.cycles_run = 0

    @property
    def done(self) -> bool:
        return self.stream.done

    def step(self) -> Optional[GoodputCycleView]:
        """Advance one cycle (measure → featurize → predict → decide);
        ``None`` once the campaign is over."""
        view = self.stream.step()
        if view is None:
            return None
        up = np.asarray(view.running_t[: self.n_pods] >= self._n)
        p_col = None
        if view.probs is not None:
            p_col = np.asarray(view.probs[: self.n_pods], dtype=np.float64)
        p_rows = None if p_col is None else np.tile(p_col, self.n_policies)
        tau_c = self.table.tau(p_rows)
        shape = (self.n_policies, self.n_pods)
        started, completed, steps = _cycle_update(
            self._st,
            np.tile(up, self.n_policies),
            tau_c,
            view.cycle * self.dt,
            dt=self.dt,
            step_time=self.step_time,
            ckpt_cost=self.ckpt_cost,
            restore_cost=self.restore_cost,
        )
        self.cycles_run += 1
        return GoodputCycleView(
            cycle=view.cycle,
            time=view.time,
            up=up,
            p_survive=p_col,
            write_started=started.reshape(shape),
            ckpt_completed=completed.reshape(shape),
            panic=self.table.panic(p_rows).reshape(shape),
            steps=steps.reshape(shape),
        )

    def __iter__(self):
        while True:
            view = self.step()
            if view is None:
                return
            yield view

    def result(self) -> Dict[str, np.ndarray]:
        """Stacked replay metrics so far — the :func:`run_replay_batch`
        dict over the ``(policies × pods)`` row axis (policy-major)."""
        return _metrics_from_state(self._st, self.step_time)

    def frontier(self) -> Dict[str, ReplayResult]:
        """Per-policy fleet aggregates (cf. :func:`run_goodput_frontier`)."""
        batch = self.result()
        out: Dict[str, ReplayResult] = {}
        for i, name in enumerate(self.policy_names):
            rows = slice(i * self.n_pods, (i + 1) * self.n_pods)
            out[name] = ReplayResult(
                policy=name,
                steps_completed=int(batch["steps_completed"][rows].sum()),
                steps_lost=int(batch["steps_lost"][rows].sum()),
                checkpoints=int(batch["checkpoints"][rows].sum()),
                ckpt_overhead_s=float(batch["ckpt_overhead_s"][rows].sum()),
                lost_work_s=float(batch["lost_work_s"][rows].sum()),
                unavailable_s=float(batch["unavailable_s"][rows].sum()),
            )
        return out

    def state_dict(self) -> dict:
        """Crash-consistent snapshot: the stacked replay state plus the
        wrapped pipeline stream's own ``state_dict()``."""
        return {
            "cycles_run": self.cycles_run,
            "replay": {k: v.copy() for k, v in self._st.items()},
            "stream": self.stream.state_dict(),
        }

    def restore(self, sd: dict) -> None:
        """Inverse of :meth:`state_dict` onto an identically-configured
        goodput stream (same policies / pods / costs / stream config)."""
        self.cycles_run = int(sd["cycles_run"])
        for k in self._st:
            self._st[k] = np.asarray(sd["replay"][k]).copy()
        self.stream.restore(sd["stream"])
