"""Public entry point for the scan-form lock-step replay.

``replay_scan_op`` takes the normalised batch inputs prepared by
``repro.core.simulate.replay_batch`` (broadcast availability, launch-order
durations, their prefix sums, and the "predicted unavailable" mask) and
runs the closed-form replay on the selected backend:

* ``"jnp"``    — the ``lax.scan`` reference (the fast CPU path).  Rows
  are embarrassingly parallel, so large batches optionally split across
  a small thread pool (``shards``) — each shard is an independent jitted
  call over a row slice, and the concatenated result is bit-identical to
  the unsharded run by construction.
* ``"pallas"`` — the chunked Pallas kernel (interpret mode off-TPU).
  Handles ragged shapes by padding cycles (``avail = 0`` beyond the real
  trace, masked inert inside the kernel) and rows (sliced off).
* ``"auto"``   — Pallas on TPU, scan elsewhere.

float64 inputs run under a scoped ``enable_x64`` context, so importing
this module never flips global JAX precision.
"""

from __future__ import annotations

import contextlib
import os
import threading
from typing import Dict, Optional

import numpy as np

__all__ = ["replay_scan_op"]

_AUTO_SHARD_MIN_ROWS = 8192

#: shard shapes whose jit cache is already populated (see replay_scan_op)
_WARM_SHAPES = set()


def _x64_if(dtype):
    if np.dtype(dtype) == np.float64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def _auto_shards(rows: int) -> int:
    if rows < _AUTO_SHARD_MIN_ROWS:
        return 1
    return min(2, os.cpu_count() or 1)


def _run_scan_shard(avail, predz, cum_pad, dt, horizon_cycles, q, use_pred,
                    window, unroll, out, idx, errors=None):
    try:
        import jax.numpy as jnp

        from .ref import replay_scan_ref

        with _x64_if(cum_pad.dtype):
            res = replay_scan_ref(
                jnp.asarray(avail.T),
                jnp.asarray(predz.T),
                jnp.asarray(cum_pad),
                dt,
                horizon_cycles,
                q=q,
                use_pred=use_pred,
                window=window,
                unroll=unroll,
            )
            out[idx] = {k: np.asarray(v) for k, v in res.items()}
    except BaseException as exc:     # worker threads: surface after join
        if errors is None:
            raise
        errors[idx] = exc


def replay_scan_op(
    avail: np.ndarray,            # (B, T) bool
    dur: np.ndarray,              # (B, Q) float, launch order
    cum: np.ndarray,              # (B, Q+1) float prefix sums of dur
    pred_zero: Optional[np.ndarray],  # (B, T) bool or None
    *,
    dt: float,
    horizon_cycles: int,
    backend: str = "auto",
    block_b: int = 8,
    chunk: int = 128,
    window: int = 16,
    unroll: int = 1,
    shards=None,
) -> Dict[str, np.ndarray]:
    """Scan-form replay; returns the ``replay_batch`` metric dict."""
    import jax

    if backend == "auto":
        # the Mosaic kernel has no float64 support: f64 contracts stay on
        # the bit-identical scan even on TPU (pass f32 inputs — or request
        # backend="pallas" explicitly — for the native kernel path)
        on_tpu = jax.default_backend() == "tpu"
        f64 = np.dtype(cum.dtype) == np.float64
        backend = "pallas" if on_tpu and not f64 else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")

    avail = np.asarray(avail, dtype=bool)
    B, T = avail.shape
    Q = cum.shape[1] - 1
    use_pred = pred_zero is not None
    predz = (
        np.asarray(pred_zero, dtype=bool)
        if use_pred
        else np.zeros((B, T), dtype=bool)
    )

    if backend == "jnp":
        pad = np.full((B, window + 1), np.inf, dtype=cum.dtype)
        cum_pad = np.concatenate([cum, pad], axis=1)
        n_shards = _auto_shards(B) if shards in (None, "auto") else int(shards)
        n_shards = max(1, min(n_shards, B))
        bounds = [
            (i * B // n_shards, (i + 1) * B // n_shards)
            for i in range(n_shards)
        ]
        out = [None] * n_shards
        keys = {
            (hi - lo, T, Q, use_pred, window, unroll, np.dtype(cum.dtype))
            for lo, hi in bounds
        }
        if n_shards == 1 or not keys <= _WARM_SHAPES:
            # first sighting of a shard shape compiles; run serially so the
            # jit cache is populated exactly once per shape
            for i, (lo, hi) in enumerate(bounds):
                _run_scan_shard(avail[lo:hi], predz[lo:hi], cum_pad[lo:hi],
                                dt, horizon_cycles, Q, use_pred, window,
                                unroll, out, i)
            _WARM_SHAPES.update(keys)
        else:
            errors = [None] * n_shards
            threads = [
                threading.Thread(
                    target=_run_scan_shard,
                    args=(avail[lo:hi], predz[lo:hi], cum_pad[lo:hi], dt,
                          horizon_cycles, Q, use_pred, window, unroll, out, i,
                          errors),
                )
                for i, (lo, hi) in enumerate(bounds)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for exc in errors:
                if exc is not None:
                    raise exc
        res = {
            k: np.concatenate([o[k] for o in out]) if n_shards > 1 else out[0][k]
            for k in out[0]
        }
    else:
        import jax.numpy as jnp

        from .kernel import replay_scan_kernel

        block_b = min(block_b, B)
        chunk = min(chunk, T)
        pad_b = (-B) % block_b
        pad_t = (-T) % chunk
        av = np.zeros((B + pad_b, T + pad_t), dtype=np.int32)
        av[:B, :T] = avail
        pz = np.zeros_like(av)
        pz[:B, :T] = predz
        cm = np.zeros((B + pad_b, Q + 1), dtype=cum.dtype)
        cm[:B] = cum
        with _x64_if(cum.dtype):
            res = replay_scan_kernel(
                jnp.asarray(av),
                jnp.asarray(pz),
                jnp.asarray(cm),
                dt=dt,
                horizon_cycles=horizon_cycles,
                t_real=T,
                use_pred=use_pred,
                block_b=block_b,
                chunk=chunk,
                interpret=jax.default_backend() != "tpu",
            )
            res = {k: np.asarray(v)[:B] for k, v in res.items()}

    return {
        "lost_seconds": res["lost_seconds"],
        "idle_seconds": res["idle_seconds"],
        "completed": res["completed"].astype(np.int64),
        "total_queries": np.full(B, Q, dtype=np.int64),
        "makespan_seconds": res["makespan_seconds"],
    }
