"""Fleet-vectorised Data Pipeline (§V at SpotLake scale).

FleetFeatureProcessor must be an observationally-equivalent drop-in for
the per-pool FeatureProcessor loop — same features bit-for-bit, same
predictions to float32 round-off — while doing O(1) interpreter work and
exactly one batched predictor call per cycle.
"""

import numpy as np
import pytest

from repro.core import (
    FeatureProcessor,
    FleetFeatureProcessor,
    batched_predict_fn,
    compute_features,
    make_model,
    pointwise_predict_fn,
)

RNG = np.random.default_rng(7)

N_REQ = 10
POOLS = 6
CYCLES = 40

#: small hyperparameters — these tests check plumbing, not model quality
POINT_MODELS = {
    "lr": dict(steps=40),
    "svm": dict(steps=40),
    "mlp": dict(steps=40, hidden=8),
    "rf": dict(n_rounds=5, depth=3, n_bins=16),
    "xgb": dict(n_rounds=5, depth=3, n_bins=16),
}


@pytest.fixture(scope="module")
def traces():
    return RNG.integers(0, N_REQ + 1, size=(POOLS, CYCLES))


@pytest.fixture(scope="module")
def fitted_models():
    x = RNG.normal(size=(256, 3)).astype(np.float32)
    y = (x[:, 0] + 0.3 * RNG.normal(size=256) > 0).astype(np.int64)
    return {
        name: make_model(name, **hp).fit(x, y)
        for name, hp in POINT_MODELS.items()
    }


def run_both(traces, *, window_minutes=30.0, dt=3.0, point_fn=None, batch_fn=None):
    pools, cycles = traces.shape
    ids = [f"p{i}" for i in range(pools)]
    ref = FeatureProcessor(
        ids, n_requests=N_REQ, window_minutes=window_minutes, dt_minutes=dt,
        predict_fn=point_fn,
    )
    fleet = FleetFeatureProcessor(
        ids, n_requests=N_REQ, window_minutes=window_minutes, dt_minutes=dt,
        predict_fn=batch_fn,
    )
    ref_feats = np.empty((pools, cycles, 3))
    ref_preds = np.full((pools, cycles), np.nan)
    fleet_feats = np.empty_like(ref_feats)
    fleet_preds = np.full_like(ref_preds, np.nan)
    for t in range(cycles):
        rows = ref.on_cycle(t, t * dt * 60.0, traces[:, t])
        res = fleet.on_cycle(t, t * dt * 60.0, traces[:, t])
        for i, pid in enumerate(ids):
            ref_feats[i, t] = rows[pid].features
            if rows[pid].prediction is not None:
                ref_preds[i, t] = rows[pid].prediction
        fleet_feats[:, t] = res.features
        if res.predictions is not None:
            fleet_preds[:, t] = res.predictions
    return ref, fleet, (ref_feats, ref_preds), (fleet_feats, fleet_preds)


class TestFeatureParity:
    def test_features_bit_identical_and_match_replay(self, traces):
        _, _, (ref_feats, _), (fleet_feats, _) = run_both(traces)
        np.testing.assert_array_equal(fleet_feats, ref_feats)
        replay = compute_features(traces, N_REQ, 30.0, 3.0)
        np.testing.assert_array_equal(fleet_feats, replay)

    def test_window_table_contents_match(self, traces):
        ref, fleet, _, _ = run_both(traces)
        for i, pid in enumerate(ref.pool_ids):
            np.testing.assert_array_equal(
                fleet.feature_matrix(pid), ref.feature_matrix(pid)
            )
            assert fleet.feature_matrix(i).shape[0] == fleet.window_cycles

    def test_window_bounded_and_archive_counts(self, traces):
        _, fleet, _, _ = run_both(traces)
        w = fleet.window_cycles
        assert fleet.table.count == w
        assert fleet.table.archived == POOLS * (CYCLES - w)
        latest = fleet.table.latest()
        assert latest.cycle == CYCLES - 1
        np.testing.assert_array_equal(latest.s_t, traces[:, -1])


class TestBatchedPrediction:
    @pytest.mark.parametrize("name", sorted(POINT_MODELS))
    def test_fleet_agrees_with_per_pool_loop(self, traces, fitted_models, name):
        """One batched predict per cycle ≡ the per-pool PredictFn loop."""
        model = fitted_models[name]
        _, fleet, (_, ref_preds), (_, fleet_preds) = run_both(
            traces,
            point_fn=pointwise_predict_fn(model),
            batch_fn=batched_predict_fn(model),
        )
        np.testing.assert_allclose(fleet_preds, ref_preds, atol=1e-6)
        assert fleet.predict_calls == CYCLES  # exactly one call per cycle

    def test_predictions_attached_to_table(self, traces, fitted_models):
        model = fitted_models["lr"]
        _, fleet, _, (_, fleet_preds) = run_both(
            traces, batch_fn=batched_predict_fn(model)
        )
        latest = fleet.table.latest()
        np.testing.assert_allclose(latest.predictions, fleet_preds[:, -1])

    def test_sequence_model_serving_path(self, traces):
        """sequence_length=L feeds the trailing (pools, L, F) tensor to the
        predictor once L cycles of history exist; None before that."""
        from repro.core.models.lstm import LSTM

        L = 4
        x = RNG.normal(size=(64, L, 3)).astype(np.float32)
        y = RNG.integers(0, 2, 64)
        lstm = LSTM(hidden=4, steps=5).fit(x, y)
        fn = batched_predict_fn(lstm)

        fleet = FleetFeatureProcessor(
            POOLS, n_requests=N_REQ, window_minutes=30, dt_minutes=3,
            predict_fn=fn, sequence_length=L,
        )
        for t in range(L - 1):
            res = fleet.on_cycle(t, t * 180.0, traces[:, t])
            assert res.predictions is None           # history still short
        res = fleet.on_cycle(L - 1, (L - 1) * 180.0, traces[:, L - 1])
        assert res.predictions.shape == (POOLS,)
        # the attached scores equal a manual call on the trailing tensor
        np.testing.assert_allclose(
            res.predictions, fn(fleet.table.trailing(L)), atol=1e-7
        )
        assert fleet.predict_calls == 1

        with pytest.raises(ValueError):
            fn(fleet.table.trailing(L)[:, -1, :])    # point batch rejected
        with pytest.raises(ValueError):
            pointwise_predict_fn(lstm)               # no per-point adapter
        with pytest.raises(ValueError):
            FleetFeatureProcessor(
                POOLS, n_requests=N_REQ, window_minutes=30, dt_minutes=3,
                sequence_length=11,                  # > window_cycles
            )

    def test_bad_predictor_shape_rejected(self, traces):
        fleet = FleetFeatureProcessor(
            POOLS, n_requests=N_REQ, window_minutes=30, dt_minutes=3,
            predict_fn=lambda feats: np.zeros(3),    # wrong fleet size
        )
        with pytest.raises(ValueError):
            fleet.on_cycle(0, 0.0, traces[:, 0])

    def test_failed_predictor_keeps_state_and_table_in_sync(self, traces):
        """A predictor failure must not leave the cycle half-applied: the
        row is committed (predictions=None) so a catching caller never
        re-ingests the same S_t."""
        calls = {"n": 0}

        def flaky(feats):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("predictor briefly down")
            return np.zeros(len(feats))

        fleet = FleetFeatureProcessor(
            POOLS, n_requests=N_REQ, window_minutes=30, dt_minutes=3,
            predict_fn=flaky,
        )
        fleet.on_cycle(0, 0.0, traces[:, 0])
        with pytest.raises(RuntimeError):
            fleet.on_cycle(1, 180.0, traces[:, 1])
        assert fleet.state.t == fleet.table.count == 2  # cycle committed
        assert fleet.table.latest().predictions is None
        res = fleet.on_cycle(2, 360.0, traces[:, 2])    # clean resume
        np.testing.assert_array_equal(
            np.stack([fleet.table.latest().features]),
            np.stack([res.features]),
        )

    def test_latest_is_a_stable_snapshot(self, traces):
        """latest() must not alias the ring arrays — a held result stays
        unchanged after the window wraps (parity with WindowRow)."""
        fleet = FleetFeatureProcessor(
            POOLS, n_requests=N_REQ, window_minutes=9, dt_minutes=3,  # w=3
        )
        fleet.on_cycle(0, 0.0, traces[:, 0])
        held = fleet.table.latest()
        s_before = held.s_t.copy()
        f_before = held.features.copy()
        for t in range(1, 8):   # wrap the 3-slot ring several times
            fleet.on_cycle(t, t * 180.0, traces[:, t])
        np.testing.assert_array_equal(held.s_t, s_before)
        np.testing.assert_array_equal(held.features, f_before)


class TestConstantWorkPerCycle:
    def test_fleet_update_work_is_constant_per_cycle(self):
        """The fleet path's O(1) accounting: ONE batched state update and
        at most ONE predictor call per cycle, independent of both fleet
        size and history length (vs. pools × cycles for the loop)."""
        for pools in (5, 50):
            loop = FeatureProcessor(
                [f"p{i}" for i in range(pools)],
                n_requests=N_REQ, window_minutes=60, dt_minutes=3,
            )
            fleet = FleetFeatureProcessor(
                pools, n_requests=N_REQ, window_minutes=60, dt_minutes=3,
                predict_fn=lambda feats: np.zeros(len(feats)),
            )
            for t in range(100):
                loop.on_cycle(t, t * 180.0, [N_REQ] * pools)
                fleet.on_cycle(t, t * 180.0, [N_REQ] * pools)
            assert loop.update_ops == pools * 100
            assert fleet.update_ops == 100       # independent of fleet size
            assert fleet.predict_calls == 100
