"""gemma3-1b — dense decoder with 5:1 local:global attention.

[hf:google/gemma-3-1b-pt; unverified] — 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144.  Sliding window 512 on local layers, every 6th
layer global (the per-layer window rides the layer scan as a scalar);
head_dim 256, qk-norm, tied embeddings, 128k-class context via the local
patterns — the one dense arch that runs `long_500k`.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    qk_norm=True,
    use_rope=True,
    rope_theta=1e6,
    sliding_window=512,
    global_every=6,
    norm="rmsnorm",
    gated_mlp=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
