"""``lax.scan`` reference for the lock-step replay contract.

One closed-form state transition per cycle (the contract pinned in
``repro.core.simulate``): the carried row state is ``(head, front,
has_front, running, remaining, progress, defer, lost, idle, completed,
makespan)`` and queue consumption resolves against the prefix-sum rows
``cum`` — phase B's "how many queries finish in this cycle's budget" is a
prefix *count*, never a data-dependent walk over the queue.

The count is evaluated over a ``window``-wide slice of ``cum`` starting
at the queue head (one contiguous ``dynamic_slice`` per row — the cheap
gather shape on CPU); a vectorised overflow loop extends the window for
the rare burst cycles that complete more than ``window`` queries at once
(e.g. the first cycles of an ``sjf`` queue).  The window width is a pure
tuning knob — any ``window >= 1`` yields identical counts because the
overflow loop re-slices until the budget is resolved — and the per-cycle
window compare/sum is the widest op in the body, so smaller is faster
until overflow iterations dominate (``window=8`` measures ~1.2× over the
old 16 on the TPC-DS bench, whose worst single-cycle burst is 5).  ``cum`` arrives padded with
``+inf`` tail entries (see ``ops``) so window slices never clamp and
beyond-queue entries can never pass the ``<= target`` test.

Fusion over the strategies axis
-------------------------------

:func:`replay_sweep_ref` is the primary form: the carried state is
``(S, B)`` — one strategy plane per trace row — and each cycle's
availability column is loaded **once** and broadcast through every
strategy's transition, instead of re-streaming the whole trace per
strategy.  ``use_pred`` is a static tuple of per-strategy flags (the
Predict-AR deferral machinery only runs when any strategy wants it);
per-strategy queues (``sjf`` sorts, permutations) enter as the stacked
``(S, B, Q + window + 2)`` prefix-sum planes.  Because the fused body
executes exactly the same elementwise ops in the same order as the
single-strategy scan, the fused results are bit-identical (atol=0) to S
independent per-strategy scans — asserted in ``tests/test_replay_scan``.
:func:`replay_scan_ref` is the single-strategy wrapper (``S == 1``).

Every floating-point op matches the numpy oracle
(``core.simulate._replay_batch_numpy``) in kind and order, so results are
bit-identical row by row in the shared dtype — float64 under a scoped
``enable_x64`` (the atol=0 house contract) or float32 end to end (the
bandwidth-lean fast tier; see ``ops``).  This function is also the
production CPU path: XLA compiles the scan body into a handful of fused
passes over the (S, B) state, which is what clears the throughput bar
over the per-cycle numpy loop (``benchmarks/replay_throughput.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.simulate import EPS


@functools.partial(
    jax.jit, static_argnames=("use_pred", "window", "unroll")
)
def replay_sweep_ref(
    avail_t: jnp.ndarray,     # (T, B) bool — time-major availability
    predz_t: jnp.ndarray,     # (T, B) bool — "predictor says unavailable"
    cum_pad: jnp.ndarray,     # (S, B, Q + window + 2) f — prefix sums, +inf tail
    dt,
    horizon_cycles,
    *,
    q: int = None,            # true queue length (cum_pad is padded)
    use_pred: tuple = (False,),   # (S,) static per-strategy Predict-AR flags
    window: int = 8,
    unroll: int = 1,
):
    T, B = avail_t.shape
    S = cum_pad.shape[0]
    W = window
    Q = cum_pad.shape[-1] - W - 2 if q is None else q
    f = cum_pad.dtype
    i32 = jnp.int32
    dtc = jnp.asarray(dt, f)
    horizon = jnp.asarray(horizon_cycles, i32)
    zero = jnp.zeros((), f)
    eps = jnp.asarray(EPS, f)
    any_pred = any(use_pred)
    # static (S, 1) mask: which strategy planes run the deferral machinery
    pm = jnp.asarray(use_pred, dtype=bool)[:, None]

    slice_w = jax.vmap(jax.vmap(
        lambda row, s: jax.lax.dynamic_slice(row, (s,), (W + 2,))
    ))
    slice_2 = jax.vmap(jax.vmap(
        lambda row, s: jax.lax.dynamic_slice(row, (s,), (2,))
    ))

    def cycle(carry, xs):
        (head, front, has_front, running, remaining, progress, defer,
         lost, idle, completed, makespan) = carry
        up, pz, c = xs               # up/pz: (B,) — shared by every strategy

        # -- down cycle: running query loses progress, re-queued at front --
        drop = (~up) & running
        lost = lost + jnp.where(drop, progress, zero)
        front = jnp.where(drop, progress + remaining, front)
        has_front = has_front | drop
        running = running & up
        progress = jnp.where(drop, zero, progress)

        if any_pred:
            trig = up & (c > defer) & pz & pm
            defer = jnp.where(trig, c + horizon, defer)
            # non-pred planes keep defer == -1, so c <= defer stays False
            deferred = up & (c <= defer)
        else:
            deferred = jnp.zeros_like(running)

        b = jnp.where(up, dtc, zero)
        mk_edge = (c + 1).astype(f) * dtc

        # -- phase A: the in-hand item -------------------------------------
        a_run = up & running
        a_frt = up & ~running & has_front & ~deferred
        has_a = a_run | a_frt
        x = jnp.where(a_run, remaining, front)
        step = jnp.where(has_a, jnp.minimum(b, x), zero)
        xr = x - step
        progress = jnp.where(a_run, progress + step,
                             jnp.where(a_frt, step, progress))
        b = b - step
        has_front = has_front & ~a_frt
        fin = has_a & (xr <= eps)
        completed = completed + fin.astype(i32)
        running = has_a & ~fin
        remaining = jnp.where(has_a & ~fin, xr, remaining)
        progress = jnp.where(fin, zero, progress)
        mk_a = fin & (head >= Q) & ~has_front
        makespan = jnp.where(mk_a, jnp.minimum(makespan, mk_edge - b), makespan)

        # -- phase B: prefix count over the queue window -------------------
        qb = up & ~running & ~deferred & (head < Q) & (b > eps)
        win = slice_w(cum_pad, head)           # win[s, :, j] = cum[s, :, head+j]
        base = win[:, :, 0]
        target = base + (b + eps)
        k = (win[:, :, 1 : W + 1] <= target[:, :, None]).sum(axis=2).astype(i32)
        more = qb & (k == W)

        def ovf_cond(st):
            return jnp.any(st[1])

        def ovf_body(st):
            k, more = st
            win2 = slice_w(cum_pad, head + k)
            k2 = (win2[:, :, 1 : W + 1] <= target[:, :, None]).sum(
                axis=2
            ).astype(i32)
            k = k + jnp.where(more, k2, 0)
            more = more & (k2 == W)
            return (k, more)

        k, _ = jax.lax.while_loop(ovf_cond, ovf_body, (k, more))
        k = jnp.where(qb, k, 0)
        pair = slice_2(cum_pad, head + k)  # [cum[head+k], cum[head+k+1]]
        used = pair[:, :, 0] - base
        b2 = jnp.maximum(b - used, zero)
        completed = completed + jnp.where(qb, k, 0)
        h2 = head + k
        mk_b = qb & (k > 0) & (h2 >= Q)
        makespan = jnp.where(mk_b, jnp.minimum(makespan, mk_edge - b2), makespan)
        part = qb & (h2 < Q) & (b2 > eps)
        d = pair[:, :, 1] - pair[:, :, 0]
        remaining = jnp.where(part, d - b2, remaining)
        progress = jnp.where(part, b2, progress)
        running = running | part
        head = h2 + part.astype(i32)
        b = jnp.where(qb, jnp.where(part, zero, b2), b)

        # -- phase C: leftover budget is idle time -------------------------
        sit = ~running & (b > eps)
        idle = idle + jnp.where(sit, b, zero)

        return (head, front, has_front, running, remaining, progress, defer,
                lost, idle, completed, makespan), None

    carry = (
        jnp.zeros((S, B), i32),             # head
        jnp.zeros((S, B), f),               # front
        jnp.zeros((S, B), bool),            # has_front
        jnp.zeros((S, B), bool),            # running
        jnp.zeros((S, B), f),               # remaining
        jnp.zeros((S, B), f),               # progress
        jnp.full((S, B), -1, i32),          # defer
        jnp.zeros((S, B), f),               # lost
        jnp.zeros((S, B), f),               # idle
        jnp.zeros((S, B), i32),             # completed
        jnp.full((S, B), T, f) * dtc,       # makespan = T * dt
    )
    xs = (avail_t, predz_t, jnp.arange(T, dtype=i32))
    carry, _ = jax.lax.scan(cycle, carry, xs, unroll=unroll)
    return {
        "lost_seconds": carry[7],
        "idle_seconds": carry[8],
        "completed": carry[9],
        "makespan_seconds": carry[10],
    }


def replay_scan_ref(
    avail_t: jnp.ndarray,     # (T, B) bool — time-major availability
    predz_t: jnp.ndarray,     # (T, B) bool — "predictor says unavailable"
    cum_pad: jnp.ndarray,     # (B, Q + window + 2) f — prefix sums, +inf tail
    dt,
    horizon_cycles,
    *,
    q: int = None,            # true queue length (cum_pad is padded)
    use_pred: bool = False,
    window: int = 8,
    unroll: int = 1,
):
    """Single-strategy scan: the ``S == 1`` plane of the fused sweep."""
    res = replay_sweep_ref(
        avail_t, predz_t, cum_pad[None], dt, horizon_cycles,
        q=q, use_pred=(bool(use_pred),), window=window, unroll=unroll,
    )
    return {k: v[0] for k, v in res.items()}
