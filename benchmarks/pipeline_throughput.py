"""Fleet Data Pipeline throughput — pools/sec across the three paths.

Measures one day's worth of SnS cycles flowing through:

1. ``python-loop``      — the per-pool :class:`FeatureProcessor` reference
                          (dict of FeatureState objects, one update per
                          pool per cycle);
2. ``vectorized-numpy`` — :class:`FleetFeatureProcessor` /
                          ``update_batch`` (stacked arrays, constant
                          vector-op count per cycle);
3. ``kernel-replay``    — the chunked streaming kernel
                          (``sns_features_stream_op``: Pallas on TPU, the
                          bit-identical jnp carry-scan on CPU) replaying
                          whole traces in (block_p × chunk) tiles.

Also verifies the acceptance property end-to-end: the streaming kernel's
f32 output is **bit-identical (atol=0)** to the float64
``compute_features`` replay on full traces (N and window are powers of
two and dt is exactly representable, so every division is exact or
correctly rounded in both precisions).

Usage:
    PYTHONPATH=src python benchmarks/pipeline_throughput.py [--smoke]
        [--pools 4096] [--cycles 16]

The full run asserts the vectorized paths clear >= 50x the python loop at
4096 pools on CPU; ``--smoke`` only checks plumbing + bit-identity.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

N_REQ = 8            # power of two -> SR/UR divisions exact in f32 and f64
WINDOW_CYCLES = 16   # power of two -> full-window UR denominator exact
DT_MIN = 3.0         # exactly representable in f32

REQUIRED_SPEEDUP = 50.0


def _traces(pools: int, cycles: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, N_REQ + 1, size=(pools, cycles)
    )


def _rate(fn, pool_cycles: int, repeats: int = 1) -> float:
    """pool-cycles/sec for `fn` (best of `repeats`)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return pool_cycles / best


def bench_python_loop(s: np.ndarray) -> float:
    from repro.core import FeatureProcessor

    pools, cycles = s.shape
    proc = FeatureProcessor(
        [f"p{i}" for i in range(pools)], n_requests=N_REQ,
        window_minutes=WINDOW_CYCLES * DT_MIN, dt_minutes=DT_MIN,
    )

    def run():
        for t in range(cycles):
            proc.on_cycle(t, t * DT_MIN * 60.0, s[:, t])

    return _rate(run, pools * cycles)


def bench_vectorized_numpy(s: np.ndarray, repeats: int = 3) -> float:
    from repro.core import FleetFeatureProcessor

    pools, cycles = s.shape

    def run():
        proc = FleetFeatureProcessor(
            pools, n_requests=N_REQ,
            window_minutes=WINDOW_CYCLES * DT_MIN, dt_minutes=DT_MIN,
        )
        for t in range(cycles):
            proc.on_cycle(t, t * DT_MIN * 60.0, s[:, t])

    return _rate(run, pools * cycles, repeats=repeats)


def bench_kernel_replay(s: np.ndarray, chunk: int = 128, repeats: int = 3) -> float:
    import jax

    from repro.kernels.sns_features.ops import sns_features_stream_op

    pools, cycles = s.shape

    def run():
        out = sns_features_stream_op(
            s, n=N_REQ, window_minutes=WINDOW_CYCLES * DT_MIN,
            dt_minutes=DT_MIN, chunk=chunk,
        )
        jax.block_until_ready(out)

    run()  # warm-up: jit compile outside the timed region
    return _rate(run, pools * cycles, repeats=repeats)


def check_bit_identical(pools: int = 64, cycles: int = 500, chunk: int = 96) -> bool:
    """Streaming kernel output == compute_features, atol=0, ragged shapes."""
    from repro.core import compute_features
    from repro.kernels.sns_features.ops import sns_features_stream_op

    s = _traces(pools, cycles, seed=1)
    core = compute_features(
        s, N_REQ, WINDOW_CYCLES * DT_MIN, DT_MIN
    ).astype(np.float32)
    out = sns_features_stream_op(
        s, n=N_REQ, window_minutes=WINDOW_CYCLES * DT_MIN,
        dt_minutes=DT_MIN, chunk=chunk,
    )
    np.testing.assert_array_equal(np.asarray(out), core)
    return True


def run(pools: int = 4096, cycles: int = 16, smoke: bool = False) -> dict:
    if smoke:
        pools, cycles = min(pools, 256), min(cycles, 8)
    s = _traces(pools, cycles)

    # All three paths timed on the SAME (pools, cycles) workload.
    loop_rate = bench_python_loop(s)
    numpy_rate = bench_vectorized_numpy(s)
    kernel_rate = bench_kernel_replay(s, chunk=128)
    # The streaming kernel's real use case is long-trace bulk replay where
    # per-call dispatch amortizes away — reported separately, with its own
    # cycle count, NOT folded into the like-for-like speedups.
    long_cycles = 512 if not smoke else 64
    kernel_long_rate = bench_kernel_replay(_traces(pools, long_cycles), chunk=128)
    identical = check_bit_identical(
        pools=min(pools, 64), cycles=500 if not smoke else 100
    )

    result = {
        "pools": pools,
        "cycles": cycles,
        "pool_cycles_per_sec": {
            "python_loop": round(loop_rate),
            "vectorized_numpy": round(numpy_rate),
            "kernel_replay": round(kernel_rate),
        },
        "speedup": {
            "vectorized_numpy": round(numpy_rate / loop_rate, 1),
            "kernel_replay": round(kernel_rate / loop_rate, 1),
        },
        "kernel_replay_long": {
            "cycles": long_cycles,
            "pool_cycles_per_sec": round(kernel_long_rate),
            "speedup_vs_loop": round(kernel_long_rate / loop_rate, 1),
        },
        "kernel_bit_identical_atol0": identical,
        "smoke": smoke,
    }
    if not smoke:
        assert result["speedup"]["vectorized_numpy"] >= REQUIRED_SPEEDUP, result
        assert result["speedup"]["kernel_replay"] >= REQUIRED_SPEEDUP, result
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; skip the 50x assertion")
    args = ap.parse_args()
    result = run(pools=args.pools, cycles=args.cycles, smoke=args.smoke)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
