"""Shared fixtures.

NOTE: XLA_FLAGS / host device count is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device.  Only
``repro.launch.dryrun`` (run as a subprocess) uses placeholder devices.
"""

try:
    import hypothesis  # noqa: F401  — the declared dev dependency, when present
except ModuleNotFoundError:
    # Hermetic environments can't pip-install; fall back to the in-repo
    # deterministic shim so the property tests still collect and run.
    import _hypothesis_shim  # noqa: F401  — registers sys.modules["hypothesis"]

import numpy as np
import pytest

from repro.core import SimulatedProvider, default_fleet, run_campaign


@pytest.fixture(scope="session")
def small_campaign():
    """A small but statistically meaningful campaign, shared session-wide."""
    fleet = default_fleet(12, seed=1)
    provider = SimulatedProvider(fleet, seed=2)
    return run_campaign(provider, duration=12 * 3600.0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
