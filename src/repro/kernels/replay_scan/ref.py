"""``lax.scan`` reference for the lock-step replay contract.

One closed-form state transition per cycle (the contract pinned in
``repro.core.simulate``): the carried row state is ``(head, front,
has_front, running, remaining, progress, defer, lost, idle, completed,
makespan)`` and queue consumption resolves against the prefix-sum rows
``cum`` — phase B's "how many queries finish in this cycle's budget" is a
prefix *count*, never a data-dependent walk over the queue.

The count is evaluated over a ``window``-wide slice of ``cum`` starting
at the queue head (one contiguous ``dynamic_slice`` per row — the cheap
gather shape on CPU); a vectorised overflow loop extends the window for
the rare burst cycles that complete more than ``window`` queries at once
(e.g. the first cycles of an ``sjf`` queue).  ``cum`` arrives padded with
``+inf`` tail entries (see ``ops``) so window slices never clamp and
beyond-queue entries can never pass the ``<= target`` test.

Every floating-point op matches the numpy oracle
(``core.simulate._replay_batch_numpy``) in kind and order, so results are
bit-identical row by row in the shared dtype.  This function is also the
production CPU path: XLA compiles the scan body into a handful of fused
passes over the (B,) state, which is what clears the 10× bar over the
per-cycle numpy loop (``benchmarks/replay_throughput.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.simulate import EPS


@functools.partial(
    jax.jit, static_argnames=("use_pred", "window", "unroll")
)
def replay_scan_ref(
    avail_t: jnp.ndarray,     # (T, B) bool — time-major availability
    predz_t: jnp.ndarray,     # (T, B) bool — "predictor says unavailable"
    cum_pad: jnp.ndarray,     # (B, Q + window + 2) f — prefix sums, +inf tail
    dt,
    horizon_cycles,
    *,
    q: int = None,            # true queue length (cum_pad is padded)
    use_pred: bool = False,
    window: int = 16,
    unroll: int = 1,
):
    T, B = avail_t.shape
    W = window
    Q = cum_pad.shape[1] - W - 2 if q is None else q
    f = cum_pad.dtype
    i32 = jnp.int32
    dtc = jnp.asarray(dt, f)
    horizon = jnp.asarray(horizon_cycles, i32)
    zero = jnp.zeros((), f)
    eps = jnp.asarray(EPS, f)

    slice_w = jax.vmap(lambda row, s: jax.lax.dynamic_slice(row, (s,), (W + 2,)))
    slice_2 = jax.vmap(lambda row, s: jax.lax.dynamic_slice(row, (s,), (2,)))

    def cycle(carry, xs):
        (head, front, has_front, running, remaining, progress, defer,
         lost, idle, completed, makespan) = carry
        up, pz, c = xs

        # -- down cycle: running query loses progress, re-queued at front --
        drop = (~up) & running
        lost = lost + jnp.where(drop, progress, zero)
        front = jnp.where(drop, progress + remaining, front)
        has_front = has_front | drop
        running = running & up
        progress = jnp.where(drop, zero, progress)

        if use_pred:
            trig = up & (c > defer) & pz
            defer = jnp.where(trig, c + horizon, defer)
            deferred = up & (c <= defer)
        else:
            deferred = jnp.zeros_like(up)

        b = jnp.where(up, dtc, zero)
        mk_edge = (c + 1).astype(f) * dtc

        # -- phase A: the in-hand item -------------------------------------
        a_run = up & running
        a_frt = up & ~running & has_front & ~deferred
        has_a = a_run | a_frt
        x = jnp.where(a_run, remaining, front)
        step = jnp.where(has_a, jnp.minimum(b, x), zero)
        xr = x - step
        progress = jnp.where(a_run, progress + step,
                             jnp.where(a_frt, step, progress))
        b = b - step
        has_front = has_front & ~a_frt
        fin = has_a & (xr <= eps)
        completed = completed + fin.astype(i32)
        running = has_a & ~fin
        remaining = jnp.where(has_a & ~fin, xr, remaining)
        progress = jnp.where(fin, zero, progress)
        mk_a = fin & (head >= Q) & ~has_front
        makespan = jnp.where(mk_a, jnp.minimum(makespan, mk_edge - b), makespan)

        # -- phase B: prefix count over the queue window -------------------
        qb = up & ~running & ~deferred & (head < Q) & (b > eps)
        win = slice_w(cum_pad, head)                   # win[:, j] = cum[head+j]
        base = win[:, 0]
        target = base + (b + eps)
        k = (win[:, 1 : W + 1] <= target[:, None]).sum(axis=1).astype(i32)
        more = qb & (k == W)

        def ovf_cond(st):
            return jnp.any(st[1])

        def ovf_body(st):
            k, more = st
            win2 = slice_w(cum_pad, head + k)
            k2 = (win2[:, 1 : W + 1] <= target[:, None]).sum(axis=1).astype(i32)
            k = k + jnp.where(more, k2, 0)
            more = more & (k2 == W)
            return (k, more)

        k, _ = jax.lax.while_loop(ovf_cond, ovf_body, (k, more))
        k = jnp.where(qb, k, 0)
        pair = slice_2(cum_pad, head + k)     # [cum[head+k], cum[head+k+1]]
        used = pair[:, 0] - base
        b2 = jnp.maximum(b - used, zero)
        completed = completed + jnp.where(qb, k, 0)
        h2 = head + k
        mk_b = qb & (k > 0) & (h2 >= Q)
        makespan = jnp.where(mk_b, jnp.minimum(makespan, mk_edge - b2), makespan)
        part = qb & (h2 < Q) & (b2 > eps)
        d = pair[:, 1] - pair[:, 0]
        remaining = jnp.where(part, d - b2, remaining)
        progress = jnp.where(part, b2, progress)
        running = running | part
        head = h2 + part.astype(i32)
        b = jnp.where(qb, jnp.where(part, zero, b2), b)

        # -- phase C: leftover budget is idle time -------------------------
        sit = ~running & (b > eps)
        idle = idle + jnp.where(sit, b, zero)

        return (head, front, has_front, running, remaining, progress, defer,
                lost, idle, completed, makespan), None

    carry = (
        jnp.zeros(B, i32),              # head
        jnp.zeros(B, f),                # front
        jnp.zeros(B, bool),             # has_front
        jnp.zeros(B, bool),             # running
        jnp.zeros(B, f),                # remaining
        jnp.zeros(B, f),                # progress
        jnp.full(B, -1, i32),           # defer
        jnp.zeros(B, f),                # lost
        jnp.zeros(B, f),                # idle
        jnp.zeros(B, i32),              # completed
        jnp.full(B, T, f) * dtc,        # makespan = T * dt
    )
    xs = (avail_t, predz_t, jnp.arange(T, dtype=i32))
    carry, _ = jax.lax.scan(cycle, carry, xs, unroll=unroll)
    return {
        "lost_seconds": carry[7],
        "idle_seconds": carry[8],
        "completed": carry[9],
        "makespan_seconds": carry[10],
    }
