# Tier-1 verification — identical to what CI runs.
#   make verify   : full test suite + pipeline/campaign/replay/serve-throughput smokes
#   make test     : test suite only (includes the bounded-host-memory
#                   property tests in tests/test_memory.py)
#   make docs     : docs checks only (examples compile, README snippets
#                   import, markdown links resolve, example smoke runs)
#   make bench    : full throughput benchmarks (assert >= 50x / >= 20x /
#                   sharded >= 0.5x fleet / >= 3x / serve >= 20x)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: verify test docs bench

verify: test
	python benchmarks/pipeline_throughput.py --smoke
	python benchmarks/campaign_throughput.py --smoke
	python benchmarks/replay_throughput.py --smoke
	python benchmarks/serve_throughput.py --smoke

test:
	python -m pytest -x -q

docs:
	python -m pytest -x -q tests/test_docs.py tests/test_examples.py

bench:
	python benchmarks/pipeline_throughput.py
	python benchmarks/campaign_throughput.py
	python benchmarks/replay_throughput.py
	python benchmarks/serve_throughput.py
