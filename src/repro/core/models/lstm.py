"""LSTM sequence predictor — paper §VI-A sequence model group.

Operates on the trailing ``L`` collection cycles of features (the paper
sets the input sequence length equal to the selected feature window).
Single LSTM layer via ``lax.scan`` + linear head on the final hidden state.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ._train import fit_adam

__all__ = ["LSTM"]


def _init_lstm(key, n_in: int, hidden: int) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    scale = (1.0 / (n_in + hidden)) ** 0.5
    return {
        "wx": jax.random.normal(k1, (n_in + hidden, 4 * hidden)) * scale,
        "b": jnp.zeros((4 * hidden,)),
        "head_w": jax.random.normal(k2, (hidden, 1)) * (1.0 / hidden) ** 0.5,
        "head_b": jnp.zeros((1,)),
    }


def _forward(params, x):
    """x: (B, L, F) -> logits (B,)."""
    b, l, f = x.shape
    hidden = params["head_w"].shape[0]
    h0 = jnp.zeros((b, hidden))
    c0 = jnp.zeros((b, hidden))

    def cell(carry, xt):
        h, c = carry
        z = jnp.concatenate([xt, h], axis=-1) @ params["wx"] + params["b"]
        i, f_, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f_ + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    (h, _), _ = jax.lax.scan(cell, (h0, c0), jnp.swapaxes(x, 0, 1))
    return (h @ params["head_w"] + params["head_b"])[..., 0]


@dataclasses.dataclass
class LSTM:
    hidden: int = 32
    steps: int = 500
    batch: int = 512
    lr: float = 3e-3
    seed: int = 0
    params: Dict = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "LSTM":
        assert x.ndim == 3, "LSTM expects (N, L, F) sequences"

        def loss(params, xb, yb, wb):
            logits = _forward(params, xb)
            return (wb * (jax.nn.softplus(logits) - yb * logits)).mean()

        init = _init_lstm(jax.random.PRNGKey(self.seed), x.shape[-1], self.hidden)
        self.params = fit_adam(
            init, loss, x, y,
            steps=self.steps, batch=self.batch, lr=self.lr, seed=self.seed,
        )
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jax.nn.sigmoid(_forward(self.params, jnp.asarray(x))))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)
