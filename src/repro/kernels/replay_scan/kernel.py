"""Chunked Pallas kernel for the lock-step replay contract.

Tiles the (traces × cycles) grid as ``(block_b × chunk)`` blocks: the
grid's innermost axis walks ``chunk``-cycle time slabs sequentially while
the whole carried row state — queue head, re-queued front value, running
query remaining/progress, deferral clock, and the four metric
accumulators — lives in VMEM scratch, exactly the
``sns_features_stream`` pattern.  Per cycle the kernel applies the same
closed-form transition as the ``lax.scan`` reference; phase B's prefix
count and the ``cum`` lookups are evaluated as one-hot / masked
reductions over the resident prefix-sum tile (gather-free,
Mosaic-friendly).

The kernel is **fused over the strategies axis**: state and the
prefix-sum tile carry a leading ``S`` plane (``(S, block_b, Q+1)`` in
VMEM), so each ``(block_b, chunk)`` availability tile is loaded from HBM
once and replayed through every strategy — the bandwidth-lean form of
the S-pass dispatch.  ``replay_scan_kernel`` is the single-strategy
(``S == 1``) wrapper.

The arithmetic matches ``ref.replay_sweep_ref`` op for op, so outputs
are bit-identical in the shared dtype.  On CPU the kernel runs in
interpret mode (parity/testing); float64 state requires x64, so real-TPU
use means float32 inputs (then kernel ≡ ref still holds at f32, while
the f64 scalar oracle is the CPU story).

grid = (B / block_b, T / chunk)   [chunk axis innermost / sequential]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.simulate import EPS

# scratch column layout
_F_FRONT, _F_REMAINING, _F_PROGRESS, _F_LOST, _F_IDLE, _F_MAKESPAN = range(6)
_I_HEAD, _I_DEFER, _I_COMPLETED, _I_RUNNING, _I_HASFRONT = range(5)


def _sweep_kernel(
    avail_ref, predz_ref, cum_ref,
    lost_ref, idle_ref, comp_ref, mk_ref,
    fstate, istate,
    *,
    dt: float,
    horizon: int,
    use_pred: tuple,
    chunk: int,
    t_real: int,
    q: int,
):
    ic = pl.program_id(1)
    f = cum_ref.dtype
    i32 = jnp.int32
    s_pl, bp = cum_ref.shape[0], cum_ref.shape[1]
    zero = jnp.zeros((), f)
    eps = jnp.asarray(EPS, f)
    dtc = jnp.asarray(dt, f)
    any_pred = any(use_pred)
    # static (S, 1) mask: which strategy planes run the deferral machinery.
    # Pallas kernels may not capture constant arrays, so the mask is
    # rebuilt in-kernel from a bit-packed static int via iota.
    pred_bits = sum(1 << s for s, u in enumerate(use_pred) if u)
    s_iota = jax.lax.broadcasted_iota(i32, (s_pl, 1), 0)
    pm = ((pred_bits >> s_iota) & 1) > 0

    @pl.when(ic == 0)
    def _init():
        fstate[...] = jnp.zeros_like(fstate)
        init_i = jnp.zeros_like(istate)
        fstate[:, :, _F_MAKESPAN] = jnp.full((s_pl, bp), t_real, f) * dtc
        istate[...] = init_i.at[:, :, _I_DEFER].set(-1)

    avail = avail_ref[...]            # (bp, chunk) int32 — shared by planes
    predz = predz_ref[...]            # (bp, chunk) int32
    cum = cum_ref[...]                # (s_pl, bp, q + 1) f
    col_iota = jax.lax.broadcasted_iota(i32, (bp, chunk), 1)
    q_iota = jax.lax.broadcasted_iota(i32, (s_pl, bp, q + 1), 2)

    def cycle(j, st):
        (head, front, has_front, running, remaining, progress, defer,
         lost, idle, completed, makespan) = st
        g = ic * chunk + j
        valid = g < t_real
        up = (jnp.sum(jnp.where(col_iota == j, avail, 0), axis=1) > 0) & valid
        c = g

        # padded cycles beyond t_real are inert, not down-cycles: they must
        # never interrupt a query still running at trace end
        drop = (~up) & running & valid
        lost = lost + jnp.where(drop, progress, zero)
        front = jnp.where(drop, progress + remaining, front)
        has_front = has_front | drop
        running = running & up
        progress = jnp.where(drop, zero, progress)

        if any_pred:
            pz = (jnp.sum(jnp.where(col_iota == j, predz, 0), axis=1) > 0)
            trig = up & (c > defer) & pz & pm
            defer = jnp.where(trig, c + horizon, defer)
            deferred = up & (c <= defer)
        else:
            deferred = jnp.zeros_like(running)

        b = jnp.where(up, dtc, zero)
        mk_edge = (c + 1).astype(f) * dtc

        # -- phase A -------------------------------------------------------
        a_run = up & running
        a_frt = up & ~running & has_front & ~deferred
        has_a = a_run | a_frt
        x = jnp.where(a_run, remaining, front)
        step = jnp.where(has_a, jnp.minimum(b, x), zero)
        xr = x - step
        progress = jnp.where(a_run, progress + step,
                             jnp.where(a_frt, step, progress))
        b = b - step
        has_front = has_front & ~a_frt
        fin = has_a & (xr <= eps)
        completed = completed + fin.astype(i32)
        running = has_a & ~fin
        remaining = jnp.where(has_a & ~fin, xr, remaining)
        progress = jnp.where(fin, zero, progress)
        mk_a = fin & (head >= q) & ~has_front
        makespan = jnp.where(mk_a, jnp.minimum(makespan, mk_edge - b), makespan)

        # -- phase B: prefix count over the resident cum tile --------------
        qb = up & ~running & ~deferred & (head < q) & (b > eps)
        base = jnp.sum(jnp.where(q_iota == head[..., None], cum, zero), axis=2)
        target = base + (b + eps)
        k = jnp.sum(
            (cum <= target[..., None]) & (q_iota > head[..., None]), axis=2
        ).astype(i32)
        k = jnp.where(qb, k, 0)
        h2 = head + k
        cum_k = jnp.sum(jnp.where(q_iota == h2[..., None], cum, zero), axis=2)
        cum_k1 = jnp.sum(
            jnp.where(q_iota == (h2 + 1)[..., None], cum, zero), axis=2
        )
        used = cum_k - base
        b2 = jnp.maximum(b - used, zero)
        completed = completed + k
        mk_b = qb & (k > 0) & (h2 >= q)
        makespan = jnp.where(mk_b, jnp.minimum(makespan, mk_edge - b2), makespan)
        part = qb & (h2 < q) & (b2 > eps)
        d = cum_k1 - cum_k
        remaining = jnp.where(part, d - b2, remaining)
        progress = jnp.where(part, b2, progress)
        running = running | part
        head = h2 + part.astype(i32)
        b = jnp.where(qb, jnp.where(part, zero, b2), b)

        # -- phase C -------------------------------------------------------
        sit = ~running & (b > eps)
        idle = idle + jnp.where(sit, b, zero)

        return (head, front, has_front, running, remaining, progress, defer,
                lost, idle, completed, makespan)

    st = (
        istate[:, :, _I_HEAD],
        fstate[:, :, _F_FRONT],
        istate[:, :, _I_HASFRONT] > 0,
        istate[:, :, _I_RUNNING] > 0,
        fstate[:, :, _F_REMAINING],
        fstate[:, :, _F_PROGRESS],
        istate[:, :, _I_DEFER],
        fstate[:, :, _F_LOST],
        fstate[:, :, _F_IDLE],
        istate[:, :, _I_COMPLETED],
        fstate[:, :, _F_MAKESPAN],
    )
    st = jax.lax.fori_loop(0, chunk, cycle, st)
    (head, front, has_front, running, remaining, progress, defer,
     lost, idle, completed, makespan) = st

    istate[:, :, _I_HEAD] = head
    fstate[:, :, _F_FRONT] = front
    istate[:, :, _I_HASFRONT] = has_front.astype(i32)
    istate[:, :, _I_RUNNING] = running.astype(i32)
    fstate[:, :, _F_REMAINING] = remaining
    fstate[:, :, _F_PROGRESS] = progress
    istate[:, :, _I_DEFER] = defer
    fstate[:, :, _F_LOST] = lost
    fstate[:, :, _F_IDLE] = idle
    istate[:, :, _I_COMPLETED] = completed
    fstate[:, :, _F_MAKESPAN] = makespan

    # same out block every chunk step: the final write is the result
    lost_ref[...] = lost[..., None]
    idle_ref[...] = idle[..., None]
    comp_ref[...] = completed[..., None]
    mk_ref[...] = makespan[..., None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "dt", "horizon_cycles", "use_pred", "block_b", "chunk", "t_real",
        "interpret",
    ),
)
def replay_sweep_kernel(
    avail: jnp.ndarray,       # (B, Tpad) int32 availability (0 beyond t_real)
    predz: jnp.ndarray,       # (B, Tpad) int32 "predicted unavailable"
    cum: jnp.ndarray,         # (S, B, Q+1) f prefix sums per strategy plane
    *,
    dt: float,
    horizon_cycles: int,
    t_real: int,
    use_pred: tuple = (False,),
    block_b: int = 8,
    chunk: int = 128,
    interpret: bool = False,
):
    """Strategy-fused chunked replay; bit-identical to ``replay_sweep_ref``.

    Requires ``B % block_b == 0`` and ``Tpad % chunk == 0`` — use
    ``ops`` for the padded general-shape wrappers.
    """
    S, B = cum.shape[0], cum.shape[1]
    t_pad = avail.shape[1]
    q = cum.shape[2] - 1
    if len(use_pred) != S:
        raise ValueError(f"use_pred has {len(use_pred)} flags for {S} planes")
    block_b = min(block_b, B)
    chunk = min(chunk, t_pad)
    if B % block_b or t_pad % chunk:
        # a bare assert would vanish under -O and leave grid-uncovered
        # output rows silently uninitialized
        raise ValueError(
            f"B={B} / T={t_pad} not divisible by block_b={block_b} / "
            f"chunk={chunk}; use ops.replay_scan_op for padding"
        )
    grid = (B // block_b, t_pad // chunk)
    f = cum.dtype

    kernel = functools.partial(
        _sweep_kernel,
        dt=dt, horizon=horizon_cycles, use_pred=tuple(use_pred),
        chunk=chunk, t_real=t_real, q=q,
    )
    out_shapes = [
        jax.ShapeDtypeStruct((S, B, 1), f),          # lost
        jax.ShapeDtypeStruct((S, B, 1), f),          # idle
        jax.ShapeDtypeStruct((S, B, 1), jnp.int32),  # completed
        jax.ShapeDtypeStruct((S, B, 1), f),          # makespan
    ]
    lost, idle, comp, mk = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, chunk), lambda i, ic: (i, ic)),
            pl.BlockSpec((block_b, chunk), lambda i, ic: (i, ic)),
            pl.BlockSpec((S, block_b, q + 1), lambda i, ic: (0, i, 0)),
        ],
        out_specs=[pl.BlockSpec((S, block_b, 1), lambda i, ic: (0, i, 0))] * 4,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((S, block_b, 6), f),
            pltpu.VMEM((S, block_b, 5), jnp.int32),
        ],
        interpret=interpret,
    )(avail, predz, cum)
    return {
        "lost_seconds": lost[..., 0],
        "idle_seconds": idle[..., 0],
        "completed": comp[..., 0],
        "makespan_seconds": mk[..., 0],
    }


def replay_scan_kernel(
    avail: jnp.ndarray,       # (B, Tpad) int32 availability (0 beyond t_real)
    predz: jnp.ndarray,       # (B, Tpad) int32 "predicted unavailable"
    cum: jnp.ndarray,         # (B, Q+1) f prefix sums of durations
    *,
    dt: float,
    horizon_cycles: int,
    t_real: int,
    use_pred: bool = False,
    block_b: int = 8,
    chunk: int = 128,
    interpret: bool = False,
):
    """Single-strategy kernel: the ``S == 1`` plane of the fused sweep."""
    res = replay_sweep_kernel(
        avail, predz, cum[None],
        dt=dt, horizon_cycles=horizon_cycles, t_real=t_real,
        use_pred=(bool(use_pred),), block_b=block_b, chunk=chunk,
        interpret=interpret,
    )
    return {k: v[0] for k, v in res.items()}
