"""Simulated cloud provider with spot capacity pools.

This is the offline stand-in for the AWS/Azure control planes probed in the
paper (no cloud credentials in this environment).  It reproduces the
*structural* properties the paper measures, with dynamics calibrated to the
paper's published statistics:

* **Shared capacity pool per (instance type, AZ)** — all instances of a type
  in an AZ draw from one hidden capacity process ``C_t`` (§IV-A).
* **Regime-switching dynamics** — STABLE / TIGHT / CRUNCH Markov regimes.
  TIGHT tends to precede CRUNCH, so probe-visible degradation *leads*
  interruptions (the paper's §III-B observation that SnS "reflects capacity
  changes that have not yet manifested as actual interruptions").
* **Admission conservatism** — new spot requests are admitted against
  ``C_t`` minus a non-negative *admission margin* that spikes when the
  regime degrades and decays slowly afterwards.  Running instances are only
  reclaimed when ``C_t`` drops below the running count.  This yields the
  Table-I asymmetry: SnS under-counts actual availability far more often
  than it over-counts.
* **Clustered reclamation** — when capacity crunches, reclaimed nodes are
  interrupted within seconds-to-minutes of each other, calibrated to the
  Fig.-3 co-interrupt proximity CDF (>85 % < 1 min, ~93 % < 3 min).
* **Rate limits** — per-region request budgets per minute; the 3-minute
  probe cadence in the paper is the fastest cadence that stays within them.

The provider is deliberately *interface-first* (`submit_spot_request` /
`cancel` / node-pool maintenance) so the SnS collector code is portable to
a real cloud backend (§VII provider-agnostic claim).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .lifecycle import RequestState, SpotRequest

__all__ = [
    "PoolConfig",
    "InterruptionEvent",
    "RateLimitError",
    "SimulatedProvider",
    "default_fleet",
]


class RateLimitError(RuntimeError):
    """Raised when a region's API request budget is exhausted."""


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

STABLE, TIGHT, CRUNCH = 0, 1, 2
_REGIME_NAMES = ("stable", "tight", "crunch")


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static description of one (instance type, AZ) capacity pool."""

    instance_type: str
    region: str
    az: str = "a"
    price_per_hour: float = 1.0          # on-demand-discounted spot price
    base_capacity: float = 30.0          # STABLE-regime mean capacity
    volatility: float = 2.0              # capacity noise std per tick
    # Regime dwell means (seconds).  STABLE >> TIGHT >> CRUNCH.
    dwell_stable: float = 8 * 3600.0
    dwell_tight: float = 50 * 60.0
    dwell_crunch: float = 10 * 60.0
    # Probability that a degradation passes through TIGHT before CRUNCH
    # (gives probes predictive lead time).
    p_tight_first: float = 0.85

    @property
    def pool_id(self) -> str:
        return f"{self.instance_type}/{self.region}/{self.az}"


@dataclasses.dataclass(frozen=True)
class InterruptionEvent:
    pool_id: str
    instance_id: int
    time: float                           # continuous timestamp (seconds)


@dataclasses.dataclass
class _PoolState:
    cfg: PoolConfig
    capacity: float                       # hidden C_t
    regime: int = STABLE
    regime_until: float = 0.0             # next regime re-draw time
    admission_margin: float = 0.0         # conservatism margin (decaying)
    running: Dict[int, SpotRequest] = dataclasses.field(default_factory=dict)
    provisioning: Dict[int, SpotRequest] = dataclasses.field(default_factory=dict)
    # node-pool ground truth bookkeeping
    target_nodes: int = 0
    replenish_at: float = math.inf


# --------------------------------------------------------------------------
# Provider
# --------------------------------------------------------------------------


class SimulatedProvider:
    """Discrete-event simulated spot control plane.

    Time is continuous (seconds); dynamics advance on a fixed tick
    (default 60 s).  Clients call :meth:`advance` to move the clock, then
    interact via the request API.
    """

    def __init__(
        self,
        pools: Sequence[PoolConfig],
        *,
        seed: int = 0,
        tick: float = 60.0,
        provisioning_duration: float = 8.0,
        requests_per_minute_per_region: int = 300,
        replenish_delay: float = 300.0,
        margin_decay_tau: float = 30 * 60.0,
    ):
        self.tick = float(tick)
        self.provisioning_duration = float(provisioning_duration)
        self.rate_limit = int(requests_per_minute_per_region)
        self.replenish_delay = float(replenish_delay)
        self.margin_decay_tau = float(margin_decay_tau)
        self._rng = np.random.default_rng(seed)
        self.now = 0.0
        self._pools: Dict[str, _PoolState] = {}
        for cfg in pools:
            st = _PoolState(cfg=cfg, capacity=cfg.base_capacity)
            st.regime_until = self._draw_dwell(cfg, STABLE)
            self._pools[cfg.pool_id] = st
        self.interruptions: List[InterruptionEvent] = []
        self._provision_listeners: List[Callable[[SpotRequest], None]] = []
        self._rate_window: Dict[str, List[float]] = {}
        self.api_calls = 0

    # -- public API -------------------------------------------------------

    @property
    def pool_ids(self) -> List[str]:
        return list(self._pools)

    def pool_config(self, pool_id: str) -> PoolConfig:
        return self._pools[pool_id].cfg

    def on_provisioning(self, callback: Callable[[SpotRequest], None]) -> None:
        """Subscribe to provisioning-started lifecycle events (the hook the
        SnS Request Terminator uses)."""
        self._provision_listeners.append(callback)

    def submit_spot_request(self, pool_id: str, *, n: int = 1) -> List[SpotRequest]:
        """Submit ``n`` *concurrent* spot requests.

        Two-phase, modelling true concurrency: (1) all ``n`` requests pass
        the capacity check together, each accepted request consuming one
        unit of headroom; (2) provisioning lifecycle events fire afterwards
        (so an event-driven canceller cannot free capacity mid-batch).
        This is what makes the accepted/submitted ratio a *graded* estimate
        of available capacity (§III-A).
        """
        st = self._pools[pool_id]
        self._charge_rate_limit(st.cfg.region, n)
        out, accepted = [], []
        headroom = (
            st.capacity - len(st.running) - len(st.provisioning) - st.admission_margin
        )
        for _ in range(n):
            req = SpotRequest(pool_id=pool_id, submit_time=self.now)
            if headroom > 0.0 and self._rng.random() >= 0.012:
                headroom -= 1.0
                req.transition(RequestState.PROVISIONING, self.now)
                st.provisioning[req.request_id] = req
                accepted.append(req)
            else:
                req.transition(RequestState.REJECTED, self.now)
            out.append(req)
        for req in accepted:
            for cb in self._provision_listeners:
                cb(req)
        return out

    def cancel(self, request: SpotRequest) -> None:
        """Cancel a PROVISIONING request (the scoot)."""
        st = self._pools[request.pool_id]
        if request.state is RequestState.PROVISIONING:
            request.transition(RequestState.CANCELLED, self.now)
            st.provisioning.pop(request.request_id, None)
        # cancelling REJECTED/terminal requests is a no-op, like real APIs

    def terminate(self, request: SpotRequest) -> None:
        st = self._pools[request.pool_id]
        if request.state is RequestState.RUNNING:
            request.transition(RequestState.TERMINATED, self.now)
            st.running.pop(request.request_id, None)

    def set_node_pool(self, pool_id: str, n_nodes: int) -> None:
        """Declare a ground-truth node pool that tries to keep ``n_nodes``
        running (an autoscaling-group analogue; §III-B's 10-node pools)."""
        self._pools[pool_id].target_nodes = int(n_nodes)
        self._pools[pool_id].replenish_at = self.now  # acquire ASAP

    def running_count(self, pool_id: str) -> int:
        return len(self._pools[pool_id].running)

    def running_cost(self, pool_id: str, now: Optional[float] = None) -> float:
        """Total compute cost billed so far for RUNNING time in this pool."""
        now = self.now if now is None else now
        st = self._pools[pool_id]
        price = st.cfg.price_per_hour / 3600.0
        total = 0.0
        for req in st.running.values():
            total += req.billed_seconds(now) * price
        return total

    def advance(self, to_time: float) -> None:
        """Advance simulation clock, stepping pool dynamics each tick."""
        if to_time < self.now:
            raise ValueError("time moves forward only")
        while self.now + self.tick <= to_time:
            self.now += self.tick
            for st in self._pools.values():
                self._step_pool(st)
        # fractional remainder advances the clock without a dynamics step
        if to_time > self.now:
            self.now = to_time
            self._settle_provisioning()

    # -- internals ---------------------------------------------------------

    def _draw_dwell(self, cfg: PoolConfig, regime: int) -> float:
        mean = (cfg.dwell_stable, cfg.dwell_tight, cfg.dwell_crunch)[regime]
        if regime == STABLE:
            return self.now + float(self._rng.exponential(mean))
        # Degraded regimes have concentrated dwell times: elapsed time in
        # degradation is informative about time-to-interruption, which is
        # what gives CUT its predictive value at long horizons (§IV-B).
        return self.now + float(self._rng.uniform(0.7 * mean, 1.3 * mean))

    def _admit(self, st: _PoolState) -> bool:
        """Capacity check for a single new request (Fig. 1, first decision)."""
        headroom = (
            st.capacity - len(st.running) - len(st.provisioning) - st.admission_margin
        )
        if headroom <= 0.0:
            return False
        # Transient API flakiness: rare spurious rejections even with room.
        if self._rng.random() < 0.012:
            return False
        return True

    def _step_pool(self, st: _PoolState) -> None:
        cfg = st.cfg
        # -- regime transitions ------------------------------------------
        if self.now >= st.regime_until:
            st.regime = self._next_regime(st)
            st.regime_until = self._draw_dwell(cfg, st.regime)
            if st.regime in (TIGHT, CRUNCH):
                # Degradation raises the admission margin — new requests
                # start failing *partially* before running instances are
                # reclaimed (paper Fig. 2 lead-time behaviour; Table I's
                # Actual > SnS cases are mostly graded, not blackouts).
                bump = self._rng.uniform(0.15, 0.7) * max(st.target_nodes, 4)
                st.admission_margin = max(st.admission_margin, bump)
        # -- capacity mean-reversion to regime target ----------------------
        target = self._regime_target(st)
        st.capacity += 0.35 * (target - st.capacity) + float(
            self._rng.normal(0.0, cfg.volatility)
        )
        st.capacity = max(0.0, st.capacity)
        # -- admission margin decays slowly (conservative recovery) --------
        st.admission_margin *= math.exp(-self.tick / self.margin_decay_tau)
        if st.admission_margin < 0.05:
            st.admission_margin = 0.0
        # -- reclaim running instances if capacity fell below them ---------
        # Hysteresis: providers reclaim in sweeps, not single-node dribbles;
        # a 1-2 node transient dip outside CRUNCH does not trigger a sweep.
        overflow = len(st.running) - int(st.capacity)
        if overflow > 0 and (st.regime == CRUNCH or overflow >= 3):
            self._reclaim(st, overflow)
        # -- node-pool replenishment ---------------------------------------
        self._replenish(st)
        self._settle_provisioning()

    def _next_regime(self, st: _PoolState) -> int:
        r = st.regime
        u = self._rng.random()
        if r == STABLE:
            # degrade; usually via TIGHT (prediction lead time), rarely
            # straight to CRUNCH (the hard, unpredictable case)
            return TIGHT if u < st.cfg.p_tight_first else CRUNCH
        if r == TIGHT:
            return CRUNCH if u < 0.75 else STABLE
        # CRUNCH: mostly recover through TIGHT
        return TIGHT if u < 0.6 else STABLE

    def _regime_target(self, st: _PoolState) -> float:
        cfg, n = st.cfg, max(st.target_nodes, 1)
        if st.regime == STABLE:
            return cfg.base_capacity
        if st.regime == TIGHT:
            # just around the running demand: probes contend with demand
            return n + float(self._rng.uniform(0.15 * n, 0.6 * n))
        # CRUNCH: below running demand -> forces reclamation
        return float(self._rng.uniform(0.0, 0.8 * n))

    def _reclaim(self, st: _PoolState, k: int) -> None:
        """Interrupt ``k`` running instances with clustered timestamps.

        Co-interrupt proximity calibration (paper Fig. 3): delays are a
        mixture of a fast exponential (same reclamation sweep, ~88 %) and a
        slower uniform tail (independent follow-up sweeps).  Calibrated to
        >85 % of proximities < 1 min and ≈93 % < 3 min.
        """
        victims = list(st.running.values())[:k]
        base = self.now
        for i, req in enumerate(victims):
            if i == 0 or self._rng.random() < 0.86:
                delay = float(self._rng.exponential(16.0))
            else:
                delay = float(self._rng.uniform(60.0, 600.0))
            t = base + delay
            req.transition(RequestState.INTERRUPTED, t)
            st.running.pop(req.request_id, None)
            self.interruptions.append(
                InterruptionEvent(st.cfg.pool_id, req.request_id, t)
            )
        # A sweep that actually reclaimed nodes means the pool has zero
        # spare capacity: new admissions black out until the margin decays
        # (this is what keeps post-interruption unavailability episodes
        # alive for tens of minutes, as in the paper's Fig. 2 traces).
        st.admission_margin += k + self._rng.uniform(0.4, 1.0) * max(
            st.target_nodes, 4
        )
        st.replenish_at = max(st.replenish_at, self.now + self.replenish_delay)

    def _replenish(self, st: _PoolState) -> None:
        """Node pool tries to restore target_nodes (ASG behaviour): retries
        every tick once the post-interruption cooldown has passed."""
        if st.target_nodes <= 0 or self.now < st.replenish_at:
            return
        deficit = st.target_nodes - len(st.running) - len(st.provisioning)
        for _ in range(max(0, deficit)):
            if not self._admit(st):
                break  # retry next tick
            req = SpotRequest(pool_id=st.cfg.pool_id, submit_time=self.now)
            req.transition(RequestState.PROVISIONING, self.now)
            st.provisioning[req.request_id] = req

    def _settle_provisioning(self) -> None:
        """Provisioning completes after `provisioning_duration`: requests
        not cancelled by then transition to RUNNING (and start billing)."""
        for st in self._pools.values():
            done = [
                r
                for r in st.provisioning.values()
                if self.now - r.history[-1][0] >= self.provisioning_duration
            ]
            for req in done:
                req.transition(RequestState.RUNNING, self.now)
                st.provisioning.pop(req.request_id)
                st.running[req.request_id] = req

    def _charge_rate_limit(self, region: str, n: int) -> None:
        window = self._rate_window.setdefault(region, [])
        cutoff = self.now - 60.0
        window[:] = [t for t in window if t > cutoff]
        if len(window) + n > self.rate_limit:
            raise RateLimitError(
                f"region {region}: {len(window) + n} requests in 60 s "
                f"exceeds limit {self.rate_limit}"
            )
        window.extend([self.now] * n)
        self.api_calls += n


# --------------------------------------------------------------------------
# Fleet construction helpers
# --------------------------------------------------------------------------

_AWS_REGIONS = [
    "us-east-1", "us-west-2", "eu-west-1", "ap-northeast-1", "us-east-2",
    "eu-central-1", "ap-southeast-1", "sa-east-1", "ca-central-1",
    "ap-south-1", "eu-north-1",
]
_AZURE_REGIONS = ["eastus", "westus2", "westeurope", "japaneast"]

_INSTANCE_FAMILIES = [
    ("m5.large", 0.096), ("m5.xlarge", 0.192), ("c5.large", 0.085),
    ("c5.2xlarge", 0.34), ("r5.large", 0.126), ("r5.2xlarge", 0.504),
    ("g4dn.xlarge", 0.526), ("p3.2xlarge", 3.06), ("t3.medium", 0.0416),
    ("i3.large", 0.156), ("m6i.large", 0.096), ("c6i.xlarge", 0.17),
]


def default_fleet(
    n_pools: int = 68,
    *,
    seed: int = 0,
    providers: Tuple[str, ...] = ("aws", "azure"),
) -> List[PoolConfig]:
    """Build a fleet of pool configs shaped like the paper's campaign:
    68 instance types across 15 regions (47 AWS + 21 Azure)."""
    rng = np.random.default_rng(seed)
    n_aws = round(n_pools * 47 / 68) if "azure" in providers else n_pools
    configs: List[PoolConfig] = []
    for i in range(n_pools):
        if "aws" in providers and (i < n_aws or "azure" not in providers):
            region = _AWS_REGIONS[i % len(_AWS_REGIONS)]
            cloud = "aws"
        else:
            region = _AZURE_REGIONS[i % len(_AZURE_REGIONS)]
            cloud = "azure"
        itype, price = _INSTANCE_FAMILIES[i % len(_INSTANCE_FAMILIES)]
        # Azure pools are calmer in Table I (88.7 % vs 77.1 % match):
        stability = 3.0 if cloud == "azure" else 1.0
        configs.append(
            PoolConfig(
                instance_type=f"{cloud}:{itype}:{i}",
                region=region,
                az=chr(ord("a") + int(rng.integers(0, 3))),
                price_per_hour=price * float(rng.uniform(0.8, 1.25)),
                base_capacity=float(rng.uniform(25.0, 45.0)),
                volatility=float(rng.uniform(1.0, 2.5)),
                dwell_stable=float(rng.uniform(4.0, 12.0)) * 3600.0 * stability,
                dwell_tight=float(rng.uniform(30.0, 80.0)) * 60.0,
                dwell_crunch=float(rng.uniform(5.0, 18.0)) * 60.0,
            )
        )
    return configs
