"""Pure-jnp oracles for the batched SnS feature kernels (Algorithm 1).

Two forms, mirroring the two kernels in ``kernel.py``:

* :func:`sns_features_ref` — whole-trace vectorised replay (the shape
  oracle for the full-trace kernel);
* :func:`sns_features_stream_ref` — a ``lax.scan`` over ``chunk``-cycle
  slabs carrying exactly the streaming kernel's state (the ``P`` tail
  ring and the last-fully-fulfilled index).  This is also the production
  CPU fallback for fleet-scale traces: it XLA-compiles to a tight scan
  with O(pools · w) live state instead of materialising whole-trace
  intermediates, and is bit-identical to the chunked Pallas kernel
  (identical int32 / f32 operations).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sns_features_ref(
    s: jnp.ndarray,       # (pools, T) int32 success counts
    n: int,
    w: int,               # window length in cycles
    dt: float,            # collection interval (minutes)
):
    """Vectorised replay of Algorithm 1; returns (pools, T, 3) f32.

    Matches ``repro.core.features.compute_features`` bit-for-bit (that
    numpy implementation is itself property-tested against the streaming
    update)."""
    pools, t_max = s.shape
    sf = s.astype(jnp.float32)
    sr = sf / n

    unful = n - sf
    p = jnp.concatenate(
        [jnp.zeros((pools, 1), jnp.float32), jnp.cumsum(unful, axis=1)], axis=1
    )
    t_idx = jnp.arange(1, t_max + 1)
    lag = jnp.maximum(t_idx - w, 0)
    wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
    ur = (p[:, t_idx] - p[:, lag]) / (wlen * n)

    # CUT via running max of "last fully-fulfilled index"
    idx = jnp.arange(t_max)
    full = (s == n) | (idx == 0)[None, :]
    last_full = jax.lax.cummax(jnp.where(full, idx, -1), axis=1)
    cut = (idx[None, :] - last_full).astype(jnp.float32) * dt

    return jnp.stack([sr, ur, cut], axis=-1)


@functools.partial(jax.jit, static_argnames=("n", "w", "dt", "chunk"))
def sns_features_stream_ref(
    s: jnp.ndarray,       # (pools, T) int32 success counts
    n: int,
    w: int,
    dt: float,
    chunk: int = 128,
):
    """Carry-scan replay of Algorithm 1 in ``chunk``-cycle slabs.

    Returns (pools, T, 3) f32; requires ``T % chunk == 0`` (the ops
    wrapper pads).  Carry = (``tail`` (pools, w) int32 — last w values of
    the cumulative unfulfilled array P, zeros standing in for P[t ≤ 0];
    ``lf`` (pools,) int32 — last fully-fulfilled 0-based cycle index).
    """
    pools, t_max = s.shape
    chunk = min(chunk, t_max)
    assert t_max % chunk == 0, f"T={t_max} not a multiple of chunk={chunk}"
    n_chunks = t_max // chunk
    s = s.astype(jnp.int32)
    s_chunks = s.reshape(pools, n_chunks, chunk).transpose(1, 0, 2)
    g0s = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

    local_iota = jnp.arange(chunk, dtype=jnp.int32)[None, :]

    def step(carry, xs):
        tail, lf_prev = carry
        s_c, g0 = xs
        sr = s_c.astype(jnp.float32) / n

        p = tail[:, -1:] + jnp.cumsum(n - s_c, axis=1)
        buf = jnp.concatenate([tail, p], axis=1)
        lagged = buf[:, :chunk]
        t_idx = g0 + local_iota + 1
        wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
        ur = (p - lagged).astype(jnp.float32) / (wlen * n)

        g = t_idx - 1
        full = (s_c == n) | (g == 0)
        lf = jnp.maximum(
            jax.lax.cummax(jnp.where(full, g, -1), axis=1), lf_prev[:, None]
        )
        cut = (g - lf).astype(jnp.float32) * dt

        out = jnp.stack([sr, ur, cut], axis=-1)
        return (buf[:, chunk:], lf[:, -1]), out

    init = (
        jnp.zeros((pools, w), jnp.int32),
        jnp.full((pools,), -1, jnp.int32),
    )
    _, outs = jax.lax.scan(step, init, (s_chunks, g0s))   # (nc, pools, C, 3)
    return outs.transpose(1, 0, 2, 3).reshape(pools, t_max, 3)
