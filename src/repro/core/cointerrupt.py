"""Co-interruption proximity analysis — paper §IV-A, Fig. 3.

Co-interrupt proximity: for each interruption event, the time to the
*nearest* interruption of another node of the same instance type in the
same availability zone (= same capacity pool here).  The paper finds >85 %
of proximities under one minute and 92.9 % under three, motivating the
binary availability formulation.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from .provider import InterruptionEvent

__all__ = ["proximities", "proximity_cdf", "fraction_within"]


def proximities(events: Iterable[InterruptionEvent]) -> np.ndarray:
    """Nearest co-interrupt gap (seconds) per event, pools with >= 2 events.

    Columnar inputs (an ``InterruptionLog`` / campaign snapshot) take a
    vectorised sort-and-diff path; any other iterable of events falls
    back to the per-pool dict walk.  Both produce the same multiset of
    gaps (ordering differs; every consumer aggregates).
    """
    columns = getattr(events, "columns", None)
    if columns is not None:
        pool, _, time = columns
        if len(pool) == 0:
            return np.asarray([], dtype=np.float64)
        order = np.lexsort((time, pool))
        p, ts = pool[order], time[order]
        same_prev = np.zeros(len(ts), dtype=bool)
        same_prev[1:] = p[1:] == p[:-1]
        same_next = np.zeros(len(ts), dtype=bool)
        same_next[:-1] = same_prev[1:]
        gap = np.empty(len(ts))
        gap[1:] = ts[1:] - ts[:-1]
        prev_gap = np.where(same_prev, gap, np.inf)
        next_gap = np.full(len(ts), np.inf)
        next_gap[:-1] = np.where(same_next[:-1], gap[1:], np.inf)
        nearest = np.minimum(prev_gap, next_gap)
        keep = same_prev | same_next        # pools with >= 2 events only
        return nearest[keep]
    by_pool: Dict[str, List[float]] = {}
    for ev in events:
        by_pool.setdefault(ev.pool_id, []).append(ev.time)
    gaps: List[float] = []
    for times in by_pool.values():
        if len(times) < 2:
            continue
        ts = np.sort(np.asarray(times))
        diffs = np.diff(ts)
        # nearest neighbour = min(gap to predecessor, gap to successor)
        nearest = np.empty_like(ts)
        nearest[0] = diffs[0]
        nearest[-1] = diffs[-1]
        if len(ts) > 2:
            nearest[1:-1] = np.minimum(diffs[:-1], diffs[1:])
        gaps.extend(nearest.tolist())
    return np.asarray(gaps, dtype=np.float64)


def proximity_cdf(
    events: Iterable[InterruptionEvent], grid_seconds: Sequence[float]
) -> Tuple[np.ndarray, np.ndarray]:
    """CDF of co-interrupt proximity over ``grid_seconds``."""
    gaps = proximities(events)
    grid = np.asarray(grid_seconds, dtype=np.float64)
    if gaps.size == 0:
        return grid, np.zeros_like(grid)
    cdf = np.array([(gaps <= g).mean() for g in grid])
    return grid, cdf


def fraction_within(events: Iterable[InterruptionEvent], seconds: float) -> float:
    gaps = proximities(events)
    return float((gaps <= seconds).mean()) if gaps.size else float("nan")
