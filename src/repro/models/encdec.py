"""Encoder–decoder stack (whisper-large-v3 backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings ``(B, T_enc, d_model)`` directly to the
encoder (sinusoidal positions stand in for whisper's learned/conv
positions — noted in DESIGN.md).  Encoder layers are bidirectional
self-attention + GELU MLP with LayerNorm; decoder layers add causal
self-attention and cross-attention to the encoder output.  Embeddings are
tied to the LM head as in whisper.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import attention as attn_mod
from . import mlp as mlp_mod
from .common import (
    GLOBAL_WINDOW,
    ModelConfig,
    apply_norm,
    init_dense,
    make_norm_params,
    shard_map,
    sincos_positions,
)

__all__ = [
    "init_params",
    "encode",
    "train_loss",
    "prefill",
    "init_cache",
    "decode_step",
]


def _shard(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _norm_axes(data_axes):
    """() / None -> None (replicated batch, e.g. long_500k's B=1)."""
    return tuple(data_axes) if data_axes else None


def _sincos_at(pos, d: int) -> jnp.ndarray:
    """Sinusoidal position vector at a (traced) scalar position, (1, 1, d)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32) / (10000.0 ** (2.0 * i / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])[None, None, :]


def _init_enc_layer(key, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": make_norm_params(cfg, (cfg.d_model,)),
        "attn": attn_mod.init_attention(k1, cfg),
        "norm2": make_norm_params(cfg, (cfg.d_model,)),
        "mlp": mlp_mod.init_mlp(k2, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": make_norm_params(cfg, (cfg.d_model,)),
        "attn": attn_mod.init_attention(k1, cfg),
        "norm_x": make_norm_params(cfg, (cfg.d_model,)),
        "xattn": attn_mod.init_attention(k2, cfg, cross=True),
        "norm2": make_norm_params(cfg, (cfg.d_model,)),
        "mlp": mlp_mod.init_mlp(k3, cfg),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict:
    key = jax.random.PRNGKey(seed)
    ke, kd, kemb = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embedding": init_dense(
            kemb, (cfg.vocab_size, cfg.d_model), cfg.pdtype, fan_in=cfg.d_model
        ),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": make_norm_params(cfg, (cfg.d_model,)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": make_norm_params(cfg, (cfg.d_model,)),
    }


def encode(
    cfg: ModelConfig,
    params: Dict,
    frames: jnp.ndarray,             # (B, T_enc, d) stubbed frame embeddings
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    q_chunk: int = 1024,
) -> jnp.ndarray:
    data_axes = _norm_axes(data_axes)
    x = frames.astype(cfg.adtype) + sincos_positions(
        frames.shape[1], cfg.d_model
    ).astype(cfg.adtype)
    x = _shard(x, mesh, P(data_axes, None, None))

    def body(h, p):
        hn = apply_norm(cfg, p["norm1"], h)
        mixed, _ = attn_mod.attention(cfg, p["attn"], hn, causal=False, q_chunk=q_chunk)
        h = h + mixed
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + mlp_mod.mlp(cfg, p["mlp"], hn)
        return _shard(h, mesh, P(data_axes, None, None)), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def _decoder_stack(
    cfg, params, x, enc_out, *, mesh, data_axes, q_chunk
) -> jnp.ndarray:
    def body(h, p):
        hn = apply_norm(cfg, p["norm1"], h)
        mixed, _ = attn_mod.attention(cfg, p["attn"], hn, causal=True, q_chunk=q_chunk)
        h = h + mixed
        hn = apply_norm(cfg, p["norm_x"], h)
        h = h + attn_mod.cross_attention(cfg, p["xattn"], hn, enc_out, q_chunk=q_chunk)
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + mlp_mod.mlp(cfg, p["mlp"], hn)
        return _shard(h, mesh, P(data_axes, None, None)), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(cfg, params["final_norm"], x)


def _embed_tokens(cfg, params, tokens):
    s = tokens.shape[1]
    x = params["embedding"][tokens].astype(cfg.adtype)
    return x + sincos_positions(s, cfg.d_model).astype(cfg.adtype)


def train_loss(
    cfg: ModelConfig,
    params: Dict,
    frames: jnp.ndarray,             # (B, T_enc, d)
    tokens: jnp.ndarray,             # (B, S)
    labels: jnp.ndarray,             # (B, S)
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    q_chunk: int = 1024,
    remat: str = "none",
) -> jnp.ndarray:
    del remat  # enc-dec stack is shallow-activation; scan already bounds it
    data_axes = _norm_axes(data_axes)
    enc_out = encode(cfg, params, frames, mesh=mesh, data_axes=data_axes, q_chunk=q_chunk)
    x = _embed_tokens(cfg, params, tokens)
    x = _shard(x, mesh, P(data_axes, None, None))
    h = _decoder_stack(cfg, params, x, enc_out, mesh=mesh, data_axes=data_axes, q_chunk=q_chunk)
    from .lm import chunked_cross_entropy
    return chunked_cross_entropy(cfg, params, h, labels, mesh=mesh,
                                 data_axes=data_axes)


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Dict:
    data_axes = _norm_axes(data_axes)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    spec = P(None, data_axes, "model", None, None)
    cache = {
        "k": _shard(jnp.zeros(shape, cfg.adtype), mesh, spec),
        "v": _shard(jnp.zeros(shape, cfg.adtype), mesh, spec),
        # cross-attention K/V computed once at prefill
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), cfg.adtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), cfg.adtype),
        "len": jnp.zeros((), jnp.int32),
    }
    return cache


def prefill(
    cfg: ModelConfig,
    params: Dict,
    frames: jnp.ndarray,
    tokens: jnp.ndarray,
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    max_seq: Optional[int] = None,
    q_chunk: int = 1024,
) -> Tuple[jnp.ndarray, Dict]:
    data_axes = _norm_axes(data_axes)
    b, s = tokens.shape
    max_seq = max_seq or s
    enc_out = encode(cfg, params, frames, mesh=mesh, data_axes=data_axes, q_chunk=q_chunk)
    x = _embed_tokens(cfg, params, tokens)
    x = _shard(x, mesh, P(data_axes, None, None))

    def body(h, p):
        hn = apply_norm(cfg, p["norm1"], h)
        mixed, (k_new, v_new) = attn_mod.attention(
            cfg, p["attn"], hn, causal=True, q_chunk=q_chunk
        )
        h = h + mixed
        hn = apply_norm(cfg, p["norm_x"], h)
        h = h + attn_mod.cross_attention(cfg, p["xattn"], hn, enc_out, q_chunk=q_chunk)
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + mlp_mod.mlp(cfg, p["mlp"], hn)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        if cfg.qkv_bias:
            xk, xv = xk + p["xattn"]["bk"], xv + p["xattn"]["bv"]
        if max_seq > s:
            pad = ((0, 0), (0, max_seq - s), (0, 0), (0, 0))
            k_new, v_new = jnp.pad(k_new, pad), jnp.pad(v_new, pad)
        return _shard(h, mesh, P(data_axes, None, None)), (
            k_new.astype(cfg.adtype), v_new.astype(cfg.adtype),
            xk.astype(cfg.adtype), xv.astype(cfg.adtype),
        )

    h, (k, v, xk, xv) = jax.lax.scan(body, x, params["dec_layers"])
    h = apply_norm(cfg, params["final_norm"], h)
    last = (h[:, -1:, :] @ params["embedding"].T.astype(h.dtype))[:, 0]
    spec = P(None, data_axes, "model", None, None)
    cache = {
        "k": _shard(k, mesh, spec),
        "v": _shard(v, mesh, spec),
        "xk": xk,
        "xv": xv,
        "len": jnp.asarray(s, jnp.int32),
    }
    return last, cache


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    token: jnp.ndarray,              # (B,)
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Tuple[jnp.ndarray, Dict]:
    data_axes = _norm_axes(data_axes)
    new_len = cache["len"] + 1
    x = params["embedding"][token[:, None]].astype(cfg.adtype)
    # decoder learned-position stub: sinusoid at the *current* position
    x = x + _sincos_at(new_len - 1, cfg.d_model).astype(cfg.adtype)
    x = _shard(x, mesh, P(data_axes, None, None))

    def attn_decode(p, h, k_cache, v_cache):
        q = attn_mod.decode_project_q(cfg, p, h, new_len)
        k_new, v_new = attn_mod.decode_project_kv(cfg, p, h, new_len)
        if mesh is None:
            out, k_c, v_c = attn_mod.flash_decode(
                q, k_cache, v_cache, k_new, v_new, new_len, model_axis=None
            )
        else:
            def body(q_, kc_, vc_, kn_, vn_):
                return attn_mod.flash_decode(
                    q_, kc_, vc_, kn_, vn_, new_len, model_axis="model"
                )

            out, k_c, v_c = shard_map(
                body, mesh=mesh,
                in_specs=(
                    P(data_axes, None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, None, None, None),
                    P(data_axes, None, None, None),
                ),
                out_specs=(
                    P(data_axes, None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, "model", None, None),
                ),
                check_vma=False,
            )(q, k_cache, v_cache, k_new, v_new)
        y = jnp.einsum("bhk,hkd->bd", out.astype(h.dtype), p["wo"])[:, None, :]
        return y, k_c, v_c

    def body(h, xs):
        p, k_c, v_c, xk, xv = xs
        hn = apply_norm(cfg, p["norm1"], h)
        y, k_c, v_c = attn_decode(p["attn"], hn, k_c, v_c)
        h = h + y
        hn = apply_norm(cfg, p["norm_x"], h)
        h = h + _cross_decode(cfg, p["xattn"], hn, xk, xv)
        hn = apply_norm(cfg, p["norm2"], h)
        h = h + mlp_mod.mlp(cfg, p["mlp"], hn)
        return h, (k_c, v_c)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x @ params["embedding"].T.astype(x.dtype))[:, 0]
    new_cache = dict(cache)
    new_cache.update({"k": k, "v": v, "len": new_len})
    return logits, new_cache


def _cross_decode(cfg, p, x, xk, xv):
    """Single-token cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kf = attn_mod._repeat_kv(xk, n_rep)
    vf = attn_mod._repeat_kv(xv, n_rep)
    scale = 1.0 / (cfg.hd ** 0.5)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, kf).astype(jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", probs.astype(vf.dtype), vf)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])