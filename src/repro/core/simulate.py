"""Trace-driven workload simulation — paper §VI-E, Fig. 9.

Replays a 24-hour availability trace (3-minute cycles) against a batch
query workload and compares scheduling strategies:

* **Always Run** — launch the next queued query immediately whenever the
  pool is available and idle (unguided baseline).
* **Shortest Job First** — same, with the queue sorted by ascending
  duration (reduces expected loss per interruption without prediction).
* **Predict-AR** — consults the SnS-trained predictor every collection
  cycle; when it forecasts upcoming unavailability, *defers launching new
  queries* for the prediction-horizon duration while leaving any running
  query undisturbed (the paper's strategy).

Semantics follow the paper: queries proceed only while the pool is fully
available; the running query's progress is lost the moment the pool
becomes unavailable (binary formulation — §IV-A), and the query is retried
later.  Metrics: total lost computation, idle-while-available time, and
makespan.  The experiment repeats each run over random permutations of the
query queue and averages (§VI-E).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

__all__ = ["SimResult", "replay", "run_strategies"]

# prediction callback: cycle index -> 1 if pool forecast to stay available
PredictorFn = Callable[[int], int]


@dataclasses.dataclass
class SimResult:
    strategy: str
    lost_seconds: float
    idle_seconds: float          # pool available but deliberately idle
    completed: int
    total_queries: int
    makespan_seconds: float

    def __add__(self, other: "SimResult") -> "SimResult":
        assert self.strategy == other.strategy
        return SimResult(
            self.strategy,
            self.lost_seconds + other.lost_seconds,
            self.idle_seconds + other.idle_seconds,
            self.completed + other.completed,
            self.total_queries + other.total_queries,
            self.makespan_seconds + other.makespan_seconds,
        )

    def scaled(self, k: float) -> "SimResult":
        return SimResult(
            self.strategy,
            self.lost_seconds * k,
            self.idle_seconds * k,
            int(round(self.completed * k)),
            int(round(self.total_queries * k)),
            self.makespan_seconds * k,
        )


def replay(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    strategy: str = "always_run",
    dt: float = 180.0,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
) -> SimResult:
    """Replay one trace with one strategy.

    Args:
      avail: (T,) binary pool availability per collection cycle.
      durations: query durations (seconds).
      strategy: "always_run" | "sjf" | "predict_ar".
      predictor: required for predict_ar — maps cycle -> predicted label
        (1 = stays available over the horizon).
      horizon_cycles: deferral length when the predictor flags risk.
    """
    avail = np.asarray(avail).astype(bool)
    queue: List[float] = list(durations)
    if strategy == "sjf":
        queue.sort()
    elif strategy == "predict_ar" and predictor is None:
        raise ValueError("predict_ar requires a predictor")

    t_cycles = len(avail)
    lost = 0.0
    idle = 0.0
    completed = 0
    makespan = t_cycles * dt
    remaining: Optional[float] = None    # remaining work of running query
    progress = 0.0                        # work done on the running query
    defer_until_cycle = -1

    for c in range(t_cycles):
        if not avail[c]:
            # pool down for this cycle: running query loses all progress
            if remaining is not None:
                lost += progress
                queue.insert(0, progress + remaining)  # retry full query
                remaining, progress = None, 0.0
            continue

        if strategy == "predict_ar" and c > defer_until_cycle:
            if predictor(c) == 0:  # forecast: will NOT stay available
                defer_until_cycle = c + horizon_cycles

        budget = dt
        while budget > 1e-9:
            if remaining is None:
                deferred = strategy == "predict_ar" and c <= defer_until_cycle
                if not queue or deferred:
                    idle += budget
                    break
                remaining, progress = queue.pop(0), 0.0
            step = min(budget, remaining)
            remaining -= step
            progress += step
            budget -= step
            if remaining <= 1e-9:
                completed += 1
                remaining, progress = None, 0.0
                if not queue:
                    makespan = min(makespan, (c + 1) * dt - budget)

    # a query still running when the trace ends is neither lost nor complete
    return SimResult(
        strategy=strategy,
        lost_seconds=lost,
        idle_seconds=idle,
        completed=completed,
        total_queries=len(durations),
        makespan_seconds=makespan,
    )


def run_strategies(
    avail: np.ndarray,
    durations: Sequence[float],
    *,
    dt: float = 180.0,
    predictor: Optional[PredictorFn] = None,
    horizon_cycles: int = 1,
    n_permutations: int = 5,
    seed: int = 0,
) -> List[SimResult]:
    """Average each strategy over query-order permutations (§VI-E)."""
    rng = np.random.default_rng(seed)
    durations = np.asarray(durations, dtype=np.float64)
    strategies = ["always_run", "sjf"]
    if predictor is not None:
        strategies.append("predict_ar")
    totals = {}
    for _ in range(n_permutations):
        perm = rng.permutation(durations)
        for s in strategies:
            r = replay(
                avail, perm, strategy=s, dt=dt,
                predictor=predictor, horizon_cycles=horizon_cycles,
            )
            totals[s] = r if s not in totals else totals[s] + r
    return [totals[s].scaled(1.0 / n_permutations) for s in strategies]
