"""§VI-E replay throughput — traces/sec across the four replay paths.

Measures the Fig-9 sweep shape (TPC-DS permutations × availability
traces, all three strategies) flowing through:

1. ``python-loop``  — scalar :func:`repro.core.replay` per trace (the
                      readable contract reference; timed on a subset);
2. ``numpy-batch``  — ``replay_batch(engine="numpy")``, the vectorised
                      per-cycle loop (the parity oracle / baseline);
3. ``scan``         — ``replay_batch(engine="scan")`` per strategy: the
                      ``lax.scan`` closed form, one pass per strategy
                      (the historical trajectory leg); with more than
                      one visible device the trace axis is
                      ``shard_map``-ped over a 1-D ``("traces",)`` mesh;
4. ``fused``        — one ``replay_sweep`` pass carrying all three
                      strategy planes through the shared availability
                      columns (each trace cycle read once);
5. ``fused_f32``    — the fused pass on the float32 fast tier.  The
                      benchmark workload is quantised to 1/32-second
                      durations, which makes every f32 quantity exactly
                      representable — the f32 tier then reproduces the
                      f64 oracle bit for bit (asserted:
                      ``f32_decisions_identical``).

(The chunked Pallas kernel is native on TPU; on CPU it is parity-checked
in interpret mode on a reduced shape while the fused scan is the
production path.)

Also verifies the acceptance properties end-to-end:

* numpy-batch ≡ scan ≡ fused **bit-identically (atol=0)** on the full
  benchmark workload, and ``run_fleet_strategies`` produces *identical*
  SimResults through either engine (the fig9 path identity);
* the scan path clears ``REQUIRED_SPEEDUP`` × the numpy per-cycle loop,
  the fused f32 tier clears ``REQUIRED_FUSED_SPEEDUP`` × numpy-batch,
  and fusion never *regresses* the per-strategy scan
  (``REQUIRED_FUSED_PARITY``) — all asserted in full mode.

A note on what fusion can and cannot buy on CPU: the fused sweep loads
each availability column once for all three strategy planes, but on a
CPU host the per-strategy working set (~100 KB per state plane) is
L2-resident, so the re-streamed trace bytes the fusion amortises were
already cache hits — measured fused-vs-scan is ~1.0–1.3×, not the 2×
a bandwidth-bound accelerator realises (the f32 tier's ~1.45× over
fused f64 shows the bandwidth-sensitive share directly).  The asserted
floors are therefore numpy-relative (engine-level, noise-robust on
2-core CI) plus a no-regression parity floor; the raw
``speedup.fused_f32_vs_scan`` ratio is recorded unasserted so the
``BENCH_replay.json`` trajectory shows exactly where each backend
stands, and the ``speedup_10x`` flag (best path vs numpy-batch) keeps
tracking the issue's wide-machine target.

Usage:
    PYTHONPATH=src python benchmarks/replay_throughput.py [--smoke]
        [--traces 8192] [--cycles 160] [--repeats 3] [--multidev]

Each full run appends one JSON record to ``BENCH_replay.json`` (perf
trajectory across PRs).  Records carry ``devices`` (the visible device
count the scan ran on); ``--multidev`` additionally records a
``scan_scaling`` curve — the scan sweep re-benched in subprocesses at
1/2/4 virtual host devices (the XLA virtual-device flag must be set
before jax first initialises).  Virtual devices share the same physical
cores, so the curve measures mesh plumbing overhead, not parallel
speedup; it is recorded, never asserted.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import (
    replay,
    replay_batch,
    replay_sweep,
    run_fleet_strategies,
    tpcds_profile,
)

DT = 180.0
HORIZON_CYCLES = 5
REQUIRED_SPEEDUP = 3.0     # conservative floor asserted on 2-core CI
REQUIRED_FUSED_SPEEDUP = 2.0   # fused f32 vs numpy-batch, asserted
REQUIRED_FUSED_PARITY = 0.85   # fused f32 vs per-strategy scan: no regression
TARGET_SPEEDUP = 10.0      # the issue's wide-machine target, reported
STRATEGIES = ("always_run", "sjf", "predict_ar")
METRICS = (
    "lost_seconds", "idle_seconds", "completed", "total_queries",
    "makespan_seconds",
)


def _workload(traces: int, cycles: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    # durations quantised to 1/32 s: with Q=99 queries bounded by ~700 s,
    # every prefix sum scaled by 32 stays below 2^24 — all f32 quantities
    # are then exactly representable and the f32 fast tier reproduces the
    # f64 oracle bit for bit (the quantisation error itself is < 16 ms on
    # second-scale TPC-DS durations, irrelevant to the measured workload)
    prof = np.round(tpcds_profile() * 32.0) / 32.0
    base = min(traces, 2048)
    perms = np.stack([rng.permutation(prof) for _ in range(base)])
    reps = -(-traces // base)
    dur = np.tile(perms, (reps, 1))[:traces]
    avail = (rng.random((traces, cycles)) > 0.2).astype(int)
    pred = (rng.random((traces, cycles)) > 0.3).astype(int)
    return avail, dur, pred


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _sweep(avail, dur, pred, engine):
    """One fig9-style strategy sweep: three replay_batch calls."""
    out = {}
    for s in STRATEGIES:
        out[s] = replay_batch(
            avail, dur, strategy=s, dt=DT, predictions=pred,
            horizon_cycles=HORIZON_CYCLES, engine=engine,
        )
    return out


def _fused(avail, dur, pred, precision):
    """The fused form: one ``replay_sweep`` pass over all strategies."""
    return replay_sweep(
        avail, dur, strategies=STRATEGIES, dt=DT, predictions=pred,
        horizon_cycles=HORIZON_CYCLES, engine="scan", precision=precision,
    )


def bench_python_loop(avail, dur, pred, rows: int) -> float:
    """traces/sec of the scalar reference (on a row subset)."""
    rows = min(rows, avail.shape[0])
    t0 = time.perf_counter()
    for s in STRATEGIES:
        for b in range(rows):
            replay(avail[b], dur[b], strategy=s, dt=DT,
                   predictions=pred[b], horizon_cycles=HORIZON_CYCLES)
    return rows * len(STRATEGIES) / (time.perf_counter() - t0)


def bench_scan_rate(traces: int, cycles: int, repeats: int) -> float:
    """traces/sec of one warmed scan sweep (the ``--scan-rate-only``
    child body for :func:`bench_multidev_curve`)."""
    avail, dur, pred = _workload(traces, cycles)
    _sweep(avail, dur, pred, "scan")              # warm the jit caches
    best = _best(lambda: _sweep(avail, dur, pred, "scan"), max(repeats, 3))
    return traces * len(STRATEGIES) / best


def bench_multidev_curve(
    traces: int, cycles: int, repeats: int, devices=(1, 2, 4)
) -> dict:
    """Scan-sweep traces/sec at 1/2/4 virtual host devices, each point a
    subprocess (the XLA virtual-device flag is init-time only)."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    curve = {}
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [
                sys.executable, os.path.abspath(__file__),
                "--scan-rate-only",
                "--traces", str(traces), "--cycles", str(cycles),
                "--repeats", str(repeats),
            ],
            capture_output=True, text=True, timeout=1800, env=env,
        )
        assert proc.returncode == 0, proc.stderr
        curve[str(n)] = round(float(proc.stdout.strip().splitlines()[-1]), 1)
    return {
        "traces": traces,
        "cycles": cycles,
        "traces_per_sec": curve,
    }


def check_parity(avail, dur, pred) -> bool:
    """numpy ≡ scan ≡ kernel, atol=0, incl. ragged kernel padding.

    The (11, 133) shape forces nonzero block/chunk padding in the kernel
    path (ops clamps block_b/chunk to the input shape, so round shapes
    pad nothing), and row 0 carries a query past trace end through the
    padded tail cycles.
    """
    n = min(avail.shape[0], 11)
    t = min(avail.shape[1], 133)
    dur = dur.copy()
    dur[0, :] = 1e9          # still running at trace end
    for s in STRATEGIES:
        kw = dict(strategy=s, dt=DT, predictions=pred[:n, :t],
                  horizon_cycles=HORIZON_CYCLES)
        a = replay_batch(avail[:n, :t], dur[:n], engine="numpy", **kw)
        b = replay_batch(avail[:n, :t], dur[:n], engine="scan", **kw)
        c = replay_batch(avail[:n, :t], dur[:n], engine="kernel", **kw)
        for k in METRICS:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"scan {s} {k}")
            np.testing.assert_array_equal(a[k], c[k], err_msg=f"kernel {s} {k}")
    # the fused sweep must reproduce the per-strategy engines plane by plane
    fused = replay_sweep(avail[:n, :t], dur[:n], strategies=STRATEGIES,
                         dt=DT, predictions=pred[:n, :t],
                         horizon_cycles=HORIZON_CYCLES, engine="scan")
    for s in STRATEGIES:
        ref = replay_batch(avail[:n, :t], dur[:n], strategy=s, dt=DT,
                           predictions=pred[:n, :t],
                           horizon_cycles=HORIZON_CYCLES, engine="numpy")
        for k in METRICS:
            np.testing.assert_array_equal(
                fused[s][k], ref[k], err_msg=f"fused {s} {k}")
    return True


def check_f32_identity(f64_sweep, f32_sweep) -> bool:
    """The f32 fast tier must reproduce the f64 oracle exactly on the
    quantised benchmark workload — integer decisions always, and every
    float metric bit for bit (dyadic times are f32-representable)."""
    for s in STRATEGIES:
        for k in ("completed", "total_queries"):
            np.testing.assert_array_equal(
                f32_sweep[s][k], f64_sweep[s][k], err_msg=f"f32 {s} {k}")
        for k in ("lost_seconds", "idle_seconds", "makespan_seconds"):
            np.testing.assert_array_equal(
                np.asarray(f32_sweep[s][k], dtype=np.float64),
                np.asarray(f64_sweep[s][k], dtype=np.float64),
                err_msg=f"f32 {s} {k}")
    return True


def check_fig9_identity() -> bool:
    """run_fleet_strategies: identical SimResults through either engine."""
    pools, cycles = 4, 120
    rng = np.random.default_rng(3)
    avail = (rng.random((pools, cycles)) > 0.2).astype(int)
    pred = (rng.random((pools, cycles)) > 0.3).astype(int)
    dur = tpcds_profile()
    a = run_fleet_strategies(avail, dur, predictions=pred, horizon_cycles=5,
                             n_permutations=3, engine="numpy")
    b = run_fleet_strategies(avail, dur, predictions=pred, horizon_cycles=5,
                             n_permutations=3, engine="scan")
    assert set(a) == set(b)
    for s in a:
        assert a[s] == b[s], f"fig9 SimResults diverged for {s}"
    return True


def run(traces: int = 8192, cycles: int = 160, smoke: bool = False,
        repeats: int = 3, multidev: bool = False) -> dict:
    import jax

    if smoke:
        traces, cycles = min(traces, 512), min(cycles, 48)
    avail, dur, pred = _workload(traces, cycles)
    n_traces = traces * len(STRATEGIES)

    loop_rate = bench_python_loop(avail, dur, pred, rows=64 if smoke else 256)

    numpy_time = _best(lambda: _sweep(avail, dur, pred, "numpy"), repeats)
    _sweep(avail, dur, pred, "scan")              # warm the jit caches
    scan_time = _best(lambda: _sweep(avail, dur, pred, "scan"),
                      max(repeats, 3))
    f64_sweep = _fused(avail, dur, pred, "f64")   # warm + f32-oracle output
    fused_time = _best(lambda: _fused(avail, dur, pred, "f64"),
                       max(repeats, 3))
    f32_sweep = _fused(avail, dur, pred, "f32")   # warm + identity check
    fused_f32_time = _best(lambda: _fused(avail, dur, pred, "f32"),
                           max(repeats, 3))

    parity = check_parity(avail, dur, pred)
    f32_identical = check_f32_identity(f64_sweep, f32_sweep)
    fig9_identical = check_fig9_identity()

    numpy_rate = n_traces / numpy_time
    scan_rate = n_traces / scan_time
    fused_rate = n_traces / fused_time
    fused_f32_rate = n_traces / fused_f32_time
    speedup = scan_rate / numpy_rate
    best_rate = max(scan_rate, fused_rate, fused_f32_rate)
    result = {
        "traces": traces,
        "cycles": cycles,
        "queries": dur.shape[1],
        "devices": len(jax.devices()),
        "traces_per_sec": {
            "python_loop": round(loop_rate, 1),
            "numpy_batch": round(numpy_rate, 1),
            "scan": round(scan_rate, 1),
            "fused": round(fused_rate, 1),
            "fused_f32": round(fused_f32_rate, 1),
        },
        "speedup_vs_numpy": round(speedup, 2),
        "speedup_vs_python_loop": round(scan_rate / loop_rate, 1),
        "speedup": {
            "fused_vs_scan": round(fused_rate / scan_rate, 2),
            "fused_f32_vs_scan": round(fused_f32_rate / scan_rate, 2),
            "fused_f32_vs_numpy": round(fused_f32_rate / numpy_rate, 2),
        },
        "speedup_10x": bool(best_rate / numpy_rate >= TARGET_SPEEDUP),
        "parity_atol0": parity,
        "f32_decisions_identical": f32_identical,
        "fig9_simresults_identical": fig9_identical,
        "smoke": smoke,
    }
    if multidev and not smoke:
        result["scan_scaling"] = bench_multidev_curve(
            traces, cycles, repeats
        )
    if not smoke:
        assert speedup >= REQUIRED_SPEEDUP, result
        assert fused_f32_rate / numpy_rate >= REQUIRED_FUSED_SPEEDUP, result
        assert fused_f32_rate / scan_rate >= REQUIRED_FUSED_PARITY, result
        _append_record(result)
    return result


def _append_record(result: dict) -> None:
    rec = dict(result, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
    with open(Path.cwd() / "BENCH_replay.json", "a") as f:
        f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--traces", type=int, default=8192)
    ap.add_argument("--cycles", type=int, default=160)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; parity checks only, no assertion")
    ap.add_argument("--multidev", action="store_true",
                    help="also record the 1/2/4-virtual-device scan "
                         "scaling curve (spawns subprocesses)")
    ap.add_argument("--scan-rate-only", action="store_true",
                    help=argparse.SUPPRESS)  # bench_multidev_curve child
    args = ap.parse_args()
    if args.scan_rate_only:
        print(bench_scan_rate(args.traces, args.cycles, args.repeats))
        return
    result = run(traces=args.traces, cycles=args.cycles, smoke=args.smoke,
                 repeats=args.repeats, multidev=args.multidev)
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
