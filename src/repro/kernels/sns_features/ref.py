"""Pure-jnp oracle for the batched SnS feature kernel (Algorithm 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sns_features_ref(
    s: jnp.ndarray,       # (pools, T) int32 success counts
    n: int,
    w: int,               # window length in cycles
    dt: float,            # collection interval (minutes)
):
    """Vectorised replay of Algorithm 1; returns (pools, T, 3) f32.

    Matches ``repro.core.features.compute_features`` bit-for-bit (that
    numpy implementation is itself property-tested against the streaming
    update)."""
    pools, t_max = s.shape
    sf = s.astype(jnp.float32)
    sr = sf / n

    unful = n - sf
    p = jnp.concatenate(
        [jnp.zeros((pools, 1), jnp.float32), jnp.cumsum(unful, axis=1)], axis=1
    )
    t_idx = jnp.arange(1, t_max + 1)
    lag = jnp.maximum(t_idx - w, 0)
    wlen = jnp.where(t_idx >= w, w, t_idx).astype(jnp.float32)
    ur = (p[:, t_idx] - p[:, lag]) / (wlen * n)

    # CUT via running max of "last fully-fulfilled index"
    idx = jnp.arange(t_max)
    full = (s == n) | (idx == 0)[None, :]
    last_full = jax.lax.cummax(jnp.where(full, idx, -1), axis=1)
    cut = (idx[None, :] - last_full).astype(jnp.float32) * dt

    return jnp.stack([sr, ur, cut], axis=-1)
