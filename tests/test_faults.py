"""Chaos substrate: fault injection, retry/backoff control plane, and
graceful degradation — cross-engine bit-identity and unit properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BILLED_FAULT_CODES,
    OUTCOME_BLACKOUT,
    OUTCOME_DEFERRED,
    OUTCOME_NAMES,
    OUTCOME_OK,
    OUTCOME_RATE_LIMITED,
    OUTCOME_THROTTLED,
    OUTCOME_TIMEOUT,
    BlackoutWindows,
    FaultPlan,
    RetryController,
    RetryPolicy,
    SimulatedProvider,
    ThrottleBursts,
    backoff_delays,
    base_backoff,
    cost_report,
    default_fleet,
    describe_codes,
    run_campaign,
)
from repro.core.features import init_fleet_state, update_batch
from repro.core.retry import BREAKER_CLOSED, BREAKER_HALF_OPEN, BREAKER_OPEN
from repro.serve import FleetAdmissionController


def fresh(n_pools=6, seed=3, **kw):
    return SimulatedProvider(default_fleet(n_pools, seed=seed), seed=seed, **kw)


CHAOS_PLAN = FaultPlan(
    seed=11,
    throttle=ThrottleBursts(p=0.5, epoch=900.0, mean_duration=400.0),
    blackout=BlackoutWindows(p=0.3, epoch=1800.0, mean_duration=600.0),
    request_error_p=0.05,
    timeout_p=0.1,
)


def assert_chaos_identical(ca, cb):
    np.testing.assert_array_equal(ca.s, cb.s)
    np.testing.assert_array_equal(ca.running, cb.running)
    np.testing.assert_array_equal(ca.codes, cb.codes)
    np.testing.assert_array_equal(ca.errors, cb.errors)
    np.testing.assert_array_equal(ca.valid, cb.valid)
    assert ca.interruptions == cb.interruptions
    assert ca.api_calls == cb.api_calls
    assert ca.fault_api_calls == cb.fault_api_calls
    assert ca.probe_compute_cost == cb.probe_compute_cost
    assert ca.node_pool_cost == cb.node_pool_cost


class TestEngineParityUnderFaults:
    """Acceptance (a): scalar ≡ fleet ≡ sharded, atol=0, under any
    FaultPlan — including ledgers, cost, and API-call accounting."""

    @pytest.fixture(scope="class")
    def trio(self):
        kw = dict(
            duration=2 * 3600.0,
            fault_plan=CHAOS_PLAN,
            retry_policy=RetryPolicy(seed=5),
        )
        return {
            eng: run_campaign(fresh(), engine=eng, **kw)
            for eng in ("fleet", "scalar", "sharded")
        }

    def test_all_engines_identical(self, trio):
        assert_chaos_identical(trio["fleet"], trio["scalar"])
        assert_chaos_identical(trio["fleet"], trio["sharded"])

    def test_faults_actually_fired(self, trio):
        hist = describe_codes(trio["fleet"].codes)
        # the comparison must have teeth: every injected class shows up
        for name in ("throttled", "timeout", "blackout", "deferred"):
            assert hist.get(name, 0) > 0, hist
        assert trio["fleet"].fault_api_calls > 0
        assert trio["fleet"].errors.sum() > 0

    def test_fault_seed_changes_faults_only_determinism(self):
        kw = dict(duration=3600.0, fault_plan=CHAOS_PLAN)
        a = run_campaign(fresh(), engine="fleet", **kw)
        b = run_campaign(fresh(), engine="fleet", **kw)
        assert_chaos_identical(a, b)  # same plan → fully reproducible

    def test_billing_split(self, trio):
        res = trio["fleet"]
        # billed fault calls are a subset of total api_calls, and each
        # billed fault cycle bills exactly n requests
        assert 0 < res.fault_api_calls < res.api_calls
        billed = np.isin(res.codes, np.array(BILLED_FAULT_CODES, np.uint8))
        assert res.fault_api_calls == billed.sum() * res.n
        # deferred and rate-limited cycles charge nothing
        free = np.isin(
            res.codes, np.array([OUTCOME_DEFERRED, OUTCOME_RATE_LIMITED], np.uint8)
        )
        ok = res.codes == OUTCOME_OK
        assert res.api_calls == (ok.sum() + billed.sum()) * res.n
        assert free.sum() > 0

    def test_faulted_cycles_count_zero(self, trio):
        res = trio["fleet"]
        assert (res.s[res.codes != OUTCOME_OK] == 0).all()
        np.testing.assert_array_equal(res.valid, res.codes == OUTCOME_OK)

    def test_cost_report_breaks_out_fault_spend(self, trio):
        rep = cost_report(trio["fleet"])
        assert rep.fault_api_calls == trio["fleet"].fault_api_calls
        clean = run_campaign(fresh(), engine="fleet", duration=3600.0)
        assert cost_report(clean).fault_api_calls == 0


class TestFaultsOffUnchanged:
    """plan=None / policy=None is the exact historical campaign."""

    def test_no_plan_no_codes(self):
        res = run_campaign(fresh(), engine="fleet", duration=3600.0)
        assert res.codes is None and res.errors is None and res.valid is None
        assert res.fault_api_calls == 0

    def test_trivial_plan_matches_no_plan(self):
        # a plan with all rates zero draws nothing and changes nothing
        base = run_campaign(fresh(), engine="fleet", duration=3600.0)
        noop = run_campaign(
            fresh(), engine="fleet", duration=3600.0, fault_plan=FaultPlan(seed=9)
        )
        np.testing.assert_array_equal(base.s, noop.s)
        np.testing.assert_array_equal(base.running, noop.running)
        assert base.api_calls == noop.api_calls
        assert noop.fault_api_calls == 0
        assert (noop.codes == OUTCOME_OK).all()


class TestOutcomeLedger:
    """Satellite (b): fault outcomes are first-class in the DataLake."""

    def test_scalar_lake_outcome_counts_match_codes(self):
        prov = fresh()
        from repro.core.collector import CampaignStream

        stream = CampaignStream(
            prov, duration=3600.0, engine="scalar", fault_plan=CHAOS_PLAN
        )
        while stream.step() is not None:
            pass
        res = stream.result()
        lake = stream._collector.lake
        counts = lake.outcome_counts(stream.pool_ids)
        assert counts.shape == (len(stream.pool_ids), len(OUTCOME_NAMES))
        # every billed-fault pool-cycle wrote n rows with its fault code
        for code in (OUTCOME_THROTTLED, OUTCOME_TIMEOUT, OUTCOME_BLACKOUT):
            per_pool = (res.codes == code).sum(axis=1) * res.n
            np.testing.assert_array_equal(counts[:, code], per_pool)
        # deferred / rate-limited cycles record nothing
        assert counts[:, OUTCOME_DEFERRED].sum() == 0
        assert counts[:, OUTCOME_RATE_LIMITED].sum() == 0

    def test_lake_outcome_counts_survive_block_flush(self, monkeypatch):
        import repro.core.collector as collector_mod

        monkeypatch.setattr(collector_mod, "_LAKE_BLOCK", 4)
        for retain in (True, False):
            lake = collector_mod.DataLake(retain_records=retain)
            for i in range(13):
                lake.add(
                    float(i), "poolA", i % 2 == 0, i,
                    OUTCOME_TIMEOUT if i % 3 == 0 else None,
                )
            counts = lake.outcome_counts(["poolA"])
            assert counts[0, OUTCOME_TIMEOUT] == 5
            assert counts.sum() == 13


class TestBackoffProperties:
    """Satellite (c): property tests for the retry control plane."""

    @settings(max_examples=50)
    @given(
        base=st.integers(min_value=1, max_value=8),
        cap=st.integers(min_value=8, max_value=64),
    )
    def test_backoff_monotone_and_capped(self, base, cap):
        pol = RetryPolicy(base_delay_cycles=base, max_delay_cycles=cap)
        streaks = np.arange(1, 80)
        d = base_backoff(pol, streaks)
        assert (np.diff(d) >= 0).all()          # monotone in streak
        assert (d <= cap).all() and d[-1] == cap  # capped, cap reached
        assert d[0] == base
        # no int64 overflow at absurd streaks
        assert base_backoff(pol, np.array([10_000]))[0] == cap

    @settings(max_examples=30)
    @given(
        seed=st.integers(min_value=0, max_value=2**31),
        cycle=st.integers(min_value=0, max_value=10_000),
        streak=st.integers(min_value=1, max_value=40),
        jitter=st.floats(min_value=0.0, max_value=1.0),
    )
    def test_jitter_deterministic_and_bounded(self, seed, cycle, streak, jitter):
        pol = RetryPolicy(seed=seed, jitter=jitter)
        pools = np.arange(5)
        streaks = np.full(5, streak)
        a = backoff_delays(pol, streaks, pools, cycle)
        b = backoff_delays(pol, streaks, pools, cycle)
        np.testing.assert_array_equal(a, b)  # pure in (seed, pool, cycle)
        base = base_backoff(pol, streaks)
        assert (a >= base).all()
        # extra = floor(u * (jitter*base + 1)) with u < 1, so strictly
        # below jitter*base + 1 above the un-jittered delay
        assert (a < base + jitter * base + 1).all()

    def test_breaker_state_machine(self):
        pol = RetryPolicy(
            base_delay_cycles=1, max_delay_cycles=1, jitter=0.0,
            breaker_threshold=3, breaker_cooldown_cycles=4,
        )
        ctrl = RetryController(1, pol)
        fault = np.array([OUTCOME_THROTTLED], np.uint8)
        ok = np.array([OUTCOME_OK], np.uint8)
        on = np.array([True])
        cycle = 0
        # threshold-1 faults keep the breaker closed
        for _ in range(pol.breaker_threshold - 1):
            assert ctrl.attempt_mask(cycle)[0]
            ctrl.observe(cycle, on, fault)
            assert ctrl.breaker[0] == BREAKER_CLOSED
            cycle = int(ctrl.retry_at[0])
        # the threshold-th trips it open
        assert ctrl.attempt_mask(cycle)[0]
        ctrl.observe(cycle, on, fault)
        assert ctrl.breaker[0] == BREAKER_OPEN
        # open: no attempts until cooldown elapses, then half-open probe
        for c in range(cycle + 1, cycle + pol.breaker_cooldown_cycles):
            assert not ctrl.attempt_mask(c)[0]
        probe_cycle = cycle + pol.breaker_cooldown_cycles
        assert ctrl.attempt_mask(probe_cycle)[0]
        assert ctrl.breaker[0] == BREAKER_HALF_OPEN
        # half-open + fault → straight back to open
        ctrl.observe(probe_cycle, on, fault)
        assert ctrl.breaker[0] == BREAKER_OPEN
        # next probe succeeds → closed, streak cleared
        probe2 = probe_cycle + pol.breaker_cooldown_cycles
        assert ctrl.attempt_mask(probe2)[0]
        ctrl.observe(probe2, on, ok)
        assert ctrl.breaker[0] == BREAKER_CLOSED
        assert ctrl.fail_streak[0] == 0
        assert ctrl.attempt_mask(probe2 + 1)[0]

    def test_capacity_rejection_is_not_a_control_plane_fault(self):
        ctrl = RetryController(1, RetryPolicy(breaker_threshold=1))
        for cycle in range(5):
            ctrl.observe(cycle, np.array([True]), np.array([OUTCOME_OK], np.uint8))
        assert ctrl.breaker[0] == BREAKER_CLOSED

    def test_token_bucket_pre_gates_in_pool_order(self):
        rc = np.zeros(6, np.int64)
        ctrl = RetryController(6, RetryPolicy(), region_code=rc, n_requests=10)
        mask = ctrl.attempt_mask(0, region_budget=np.array([35]))
        # 35 // 10 = 3 attempts fit; first three eligible pools win
        np.testing.assert_array_equal(mask, [True, True, True, False, False, False])


class TestRateLimitSemantics:
    """Satellite (a): scalar strict/lenient rate-limit reconciliation."""

    def _tight(self, seed=7):
        # all pools in ONE region + a budget of 2 pools' worth of requests
        # per minute: every cycle starves 4 of the 6 pools
        import dataclasses

        pools = [
            dataclasses.replace(p, region="us-east-1")
            for p in default_fleet(6, seed=seed)
        ]
        return SimulatedProvider(
            pools, seed=seed, requests_per_minute_per_region=25
        )

    def test_starvation_parity_scalar_vs_fleet(self):
        ca = run_campaign(self._tight(), engine="fleet", duration=3600.0,
                          fault_plan=FaultPlan(seed=1))
        cb = run_campaign(self._tight(), engine="scalar", duration=3600.0,
                          fault_plan=FaultPlan(seed=1))
        assert_chaos_identical(ca, cb)
        # starvation really happened: some cycles were rate-limited
        assert (ca.codes == OUTCOME_RATE_LIMITED).sum() > 0

    def test_strict_flag_same_observables(self):
        from repro.core.collector import SnSCollector

        outs = []
        for strict in (False, True):
            prov = self._tight()
            coll = SnSCollector(
                prov, prov.pool_ids, n_requests=10, strict_rate_limit=strict
            )
            s = [list(map(int, coll.run_cycle(c))) for c in range(8)]
            outs.append((s, prov.api_calls, len(coll.lake)))
        assert outs[0] == outs[1]


class TestGracefulDegradation:
    """Tentpole part 4: masked observations + staleness + conservative
    admission."""

    def test_update_batch_all_valid_is_historical_path(self):
        rng = np.random.default_rng(0)
        n, cycles, pools = 10, 40, 5
        s = rng.integers(0, n + 1, size=(pools, cycles))
        a = init_fleet_state(pools, n, 30.0, 3.0)
        b = init_fleet_state(pools, n, 30.0, 3.0)
        for t in range(cycles):
            a, fa = update_batch(a, s[:, t])
            b, fb = update_batch(b, s[:, t], np.ones(pools, bool))
            np.testing.assert_array_equal(fa, fb)  # bit-identical
        np.testing.assert_array_equal(a.p_t, b.p_t)
        np.testing.assert_array_equal(a.cut, b.cut)

    def test_update_batch_invalid_cycles_carry_forward(self):
        # invalid cycles ingest nothing: P and CUT untouched, feature
        # row carried forward verbatim (time still marches — UR treats
        # the masked span as adding no unfulfillment)
        rng = np.random.default_rng(1)
        n, cycles = 10, 30
        s = rng.integers(0, n, size=(1, cycles))  # never full → CUT grows
        valid = rng.random(cycles) > 0.4
        valid[0] = True
        state = init_fleet_state(1, n, 30.0, 3.0)
        prev_feats = None
        for t in range(cycles):
            p_before, cut_before = int(state.p_t[0]), float(state.cut[0])
            state, feats = update_batch(state, s[:, t], np.array([valid[t]]))
            if valid[t]:
                assert int(state.p_t[0]) == p_before + n - int(s[0, t])
            else:
                assert int(state.p_t[0]) == p_before
                assert float(state.cut[0]) == cut_before
                np.testing.assert_array_equal(feats, prev_feats)
            prev_feats = feats
        assert not valid.all() and valid.sum() > 2  # the test had teeth

    def test_staleness_counts_consecutive_invalid(self):
        state = init_fleet_state(2, 10, 30.0, 3.0)
        v = np.array([True, False])
        for t in range(3):
            state, _ = update_batch(state, np.array([5, 0]), v)
        np.testing.assert_array_equal(state.staleness, [0, 3])
        state, _ = update_batch(state, np.array([5, 5]), np.array([True, True]))
        np.testing.assert_array_equal(state.staleness, [0, 0])

    def test_admission_controller_blocks_stale_pools(self):
        ctrl = FleetAdmissionController(3, threshold=0.9)
        probs = np.array([0.99, 0.99, 0.99])  # all healthy by score
        admit = ctrl.on_cycle(
            0, probs, staleness=np.array([0, 1, 5]), max_staleness=1
        )
        np.testing.assert_array_equal(admit, [True, True, False])
        # staleness gating must not start defer windows
        admit = ctrl.on_cycle(1, probs, staleness=np.zeros(3, int))
        assert admit.all()

    def test_pipeline_stream_surfaces_staleness(self):
        from repro.core.pipeline import CampaignPipelineStream

        stream = CampaignPipelineStream(
            fresh(), duration=3600.0, engine="fleet", fault_plan=CHAOS_PLAN
        )
        views = list(stream)
        assert any(v.staleness is not None and v.staleness.max() > 0 for v in views)
        clean = CampaignPipelineStream(fresh(), duration=1800.0, engine="fleet")
        assert all(v.staleness is None for v in clean)
