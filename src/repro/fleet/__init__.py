from .ckpt_policy import FixedInterval, SnSHazard, YoungDaly
from .elastic import ElasticMeshManager, MeshPlan, reshard
from .events import PodEvent, PodTrace, traces_from_campaign
from .runner import ReplayResult, run_replay

__all__ = [
    "FixedInterval", "SnSHazard", "YoungDaly",
    "ElasticMeshManager", "MeshPlan", "reshard",
    "PodEvent", "PodTrace", "traces_from_campaign",
    "ReplayResult", "run_replay",
]
