"""Columnar provider ledgers: uid-range FIFO semantics, monotonic probe
cursors, vectorized cost reads, lazy object views, cohort batches."""

import numpy as np
import pytest

from repro.core import (
    PoolConfig,
    SimulatedProvider,
    default_fleet,
    run_campaign,
)
from repro.core.ledger import grouped_uid0
from repro.core.lifecycle import RequestState


def make_provider(n_pools=2, seed=0, **kw):
    cfgs = [
        PoolConfig(instance_type=f"t{i}", region="r", base_capacity=30.0)
        for i in range(n_pools)
    ]
    return SimulatedProvider(cfgs, seed=seed, **kw)


def leaky_fleet_provider(seed=1):
    """A provider where held probe cohorts leak into RUNNING."""
    return SimulatedProvider(
        default_fleet(4, seed=seed), seed=seed + 1, provisioning_duration=8.0
    )


def leak_once(prov):
    """Submit a held probe batch and let it leak; returns leaked count."""
    idx = prov.pool_index(prov.pool_ids)
    counts, cohorts = prov.submit_spot_requests(idx, n=10, hold=True)
    prov.advance(prov.now + 30.0)     # > provisioning_duration: leak
    prov.cancel_cohorts(cohorts)      # too late — already RUNNING
    return int(counts.sum())


class TestProbeCursor:
    """The `since=` marker bugfix: explicit monotonic cursors."""

    def test_disjoint_segments_sum_to_whole(self):
        prov = leaky_fleet_provider()
        c0 = prov.probe_ledger_len()
        leak_once(prov)
        c1 = prov.probe_ledger_len()
        leak_once(prov)
        c2 = prov.probe_ledger_len()
        assert c0 < c1 < c2  # cursors are monotonic row counts
        prov.advance(prov.now + 600.0)
        now = prov.now
        seg_a = prov.probe_instance_cost(now, since=c0, until=c1)
        seg_b = prov.probe_instance_cost(now, since=c1)
        whole = prov.probe_instance_cost(now, since=c0)
        assert seg_a > 0.0 and seg_b > 0.0
        assert seg_a + seg_b == pytest.approx(whole, rel=1e-12)

    def test_stale_cursor_raises(self):
        prov = leaky_fleet_provider()
        leak_once(prov)
        end = prov.probe_ledger_len()
        with pytest.raises(ValueError):
            prov.probe_instance_cost(since=end + 1)
        with pytest.raises(ValueError):
            prov.probe_instance_cost(since=-1)
        with pytest.raises(ValueError):
            prov.probe_instance_cost(since=2, until=1)
        with pytest.raises(ValueError):
            prov.probe_instance_cost(until=end + 1)

    def test_meter_scopes_and_freezes(self):
        from repro.core import ProbeCostMeter

        prov = leaky_fleet_provider()
        leak_once(prov)              # pre-existing leak: not ours
        meter = ProbeCostMeter(prov)
        leak_once(prov)              # ours
        meter.freeze()
        leak_once(prov)              # someone else's
        prov.advance(prov.now + 600.0)
        now = prov.now
        ours = meter.total(now)
        before = prov.probe_instance_cost(now, until=meter.since)
        after = prov.probe_instance_cost(now, since=meter.until)
        whole = prov.probe_instance_cost(now)
        assert ours > 0.0 and before > 0.0 and after > 0.0
        assert before + ours + after == pytest.approx(whole, rel=1e-12)


class TestRunningCost:
    """The O(instances) `running_cost` loop, vectorized."""

    @pytest.fixture(scope="class")
    def seeded_provider(self):
        # a campaign with interruptions mid-window leaves a ledger mixing
        # live rows, reclaimed uid ranges, and fresh replenishments
        prov = SimulatedProvider(default_fleet(8, seed=31), seed=32)
        res = run_campaign(prov, duration=6 * 3600.0, engine="fleet")
        assert len(res.interruptions) > 0
        return prov

    def old_loop(self, prov, pool_id, now):
        # the historical per-instance Python sum, kept as the oracle,
        # driven through the lazy RunningInstance view
        price = prov.pool_config(pool_id).price_per_hour / 3600.0
        return sum(
            max(0.0, now - inst.start) * price
            for inst in prov.running_instances(pool_id)
        )

    def test_parity_with_old_loop(self, seeded_provider):
        prov = seeded_provider
        now = prov.now + 123.0
        for pid in prov.pool_ids:
            np.testing.assert_allclose(
                prov.running_cost(pid, now), self.old_loop(prov, pid, now),
                rtol=1e-12,
            )

    def test_fleet_read_matches_per_pool(self, seeded_provider):
        prov = seeded_provider
        fleet = prov.running_costs()
        per_pool = [prov.running_cost(pid) for pid in prov.pool_ids]
        np.testing.assert_allclose(fleet, per_pool, rtol=1e-12)
        assert fleet.sum() > 0.0

    def test_live_view_matches_counts(self, seeded_provider):
        prov = seeded_provider
        np.testing.assert_array_equal(
            prov._ledger.live_counts(), prov.n_running
        )
        for i, pid in enumerate(prov.pool_ids):
            insts = list(prov.running_instances(pid))
            assert len(insts) == prov.n_running[i]
            uids = [inst.uid for inst in insts]
            assert uids == sorted(uids)  # FIFO == uid ascending


class TestUidRangeFifo:
    def test_grouped_uid0_matches_loop(self, rng):
        next_uid = rng.integers(0, 100, size=5).astype(np.int64)
        pools = rng.integers(0, 5, size=12).astype(np.int64)
        counts = rng.integers(1, 4, size=12).astype(np.int64)
        got = grouped_uid0(pools, counts, next_uid)
        seq = next_uid.copy()
        for r in range(len(pools)):
            assert got[r] == seq[pools[r]], r
            seq[pools[r]] += counts[r]
        assert grouped_uid0(
            np.empty(0, np.int64), np.empty(0, np.int64), next_uid
        ).size == 0

    def test_terminate_mid_ledger_skips_uid_on_reclaim(self):
        # out-of-FIFO-order terminate() must not let the dead uid be
        # "reclaimed": the sweep skips it and takes the next-oldest
        prov = make_provider(1, seed=3)
        pid = prov.pool_ids[0]
        reqs = [r for r in prov.submit_spot_request(pid, n=6)
                if r.state is RequestState.PROVISIONING]
        prov.advance(60.0)  # settle to RUNNING
        assert all(r.state is RequestState.RUNNING for r in reqs)
        victim = reqs[2]
        prov.terminate(victim)
        n_before = int(prov.n_running[0])
        assert n_before == len(reqs) - 1
        prov._reclaim(0, n_before)  # sweep everything that is left
        _, uids, _ = prov.interruptions.columns
        assert 2 not in uids.tolist()  # the terminated uid never re-dies
        assert len(uids) == n_before
        assert prov.n_running[0] == 0
        assert prov._ledger.live_counts()[0] == 0
        interrupted = [r for r in reqs if r.state is RequestState.INTERRUPTED]
        assert len(interrupted) == n_before
        assert victim.state is RequestState.TERMINATED

    def test_cohort_batch_cancel_is_idempotent(self):
        prov = make_provider(2, seed=4)
        idx = prov.pool_index(prov.pool_ids)
        counts, cohorts = prov.submit_spot_requests(idx, n=5, hold=True)
        assert prov.n_provisioning.sum() == counts.sum() > 0
        prov.cancel_cohorts(cohorts)
        prov.cancel_cohorts(cohorts)  # double-cancel must not go negative
        assert prov.n_provisioning.sum() == 0
        prov.advance(600.0)
        assert prov.running_counts().sum() == 0


class TestLedgerStats:
    def test_stats_reflect_campaign(self):
        prov = SimulatedProvider(default_fleet(6, seed=41), seed=42)
        run_campaign(prov, duration=2 * 3600.0, engine="fleet")
        st = prov.ledger_stats()
        assert st.instance_live == int(prov.n_running.sum()) > 0
        assert st.instance_rows >= st.instance_live
        assert st.probe_rows == 0 == st.probe_live  # event-driven: no leaks
        assert st.interruption_events == len(prov.interruptions)
        assert st.nbytes > 0

    def test_cost_report_attaches_host_ledger(self):
        from repro.core import cost_report

        prov = SimulatedProvider(default_fleet(4, seed=43), seed=44)
        res = run_campaign(prov, duration=3600.0, engine="fleet")
        rep = cost_report(res, provider=prov)
        assert rep.host_ledger is not None
        assert rep.host_ledger.instance_live == int(prov.n_running.sum())
        assert cost_report(res).host_ledger is None
