"""Unified Interrupt Predictor API — paper §V (right module) + §VI-A zoo.

Six model families, matching the paper's comparison set:

==============  ==========================  ====================
name            class                        input
==============  ==========================  ====================
``lr``          LogisticRegression           single data point
``svm``         LinearSVM                    single data point
``rf``          RandomForest                 single data point
``xgb``         GradientBoostedTrees         single data point
``lstm``        LSTM                         trailing sequence
``transformer`` TransformerClassifier        trailing sequence
``mlp``         MLP (extra, not in paper)    single data point
==============  ==========================  ====================

``fit_predictor`` trains on a :class:`~repro.core.dataset.Dataset`;
``evaluate`` reports F1-macro and per-class scores.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .dataset import Dataset
from .models.linear import LinearSVM, LogisticRegression
from .models.lstm import LSTM
from .models.metrics import classification_report, f1_macro
from .models.mlp import MLP
from .models.transformer import TransformerClassifier
from .models.trees import GradientBoostedTrees, RandomForest

__all__ = [
    "MODEL_REGISTRY",
    "SEQUENCE_MODELS",
    "make_model",
    "fit_predictor",
    "evaluate",
]

MODEL_REGISTRY = {
    "lr": LogisticRegression,
    "svm": LinearSVM,
    "rf": RandomForest,
    "xgb": GradientBoostedTrees,
    "mlp": MLP,
    "lstm": LSTM,
    "transformer": TransformerClassifier,
}

#: models that consume (N, L, F) sequences instead of (N, F) points
SEQUENCE_MODELS = frozenset({"lstm", "transformer"})


def make_model(name: str, **hparams):
    try:
        cls = MODEL_REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown model {name!r}; choose from {sorted(MODEL_REGISTRY)}")
    return cls(**hparams)


def fit_predictor(name: str, dataset: Dataset, **hparams):
    """Train one predictor on the dataset's train split."""
    model = make_model(name, **hparams)
    wants_seq = name in SEQUENCE_MODELS
    has_seq = dataset.x_train.ndim == 3
    if wants_seq and not has_seq:
        raise ValueError(f"{name} needs sequence_length in build_dataset")
    x = dataset.x_train if wants_seq or not has_seq else dataset.x_train[:, -1, :]
    return model.fit(x, dataset.y_train)


def evaluate(model, dataset: Dataset) -> Dict[str, float]:
    """F1-macro & friends on the dataset's test split."""
    wants_seq = isinstance(model, (LSTM, TransformerClassifier))
    has_seq = dataset.x_test.ndim == 3
    x = dataset.x_test if wants_seq or not has_seq else dataset.x_test[:, -1, :]
    y_pred = model.predict(x)
    return classification_report(dataset.y_test, y_pred)
