"""Predictor zoo: sanity on synthetic separable data + campaign F1 bands."""

import numpy as np
import pytest

from repro.core import build_dataset, evaluate, fit_predictor, make_model
from repro.core.models.metrics import classification_report, f1_macro
from repro.core.models.trees import GradientBoostedTrees, RandomForest


def synthetic_points(n=2000, seed=0):
    """Linearly separable-ish blobs."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    x = rng.normal(size=(n, 3)).astype(np.float32) + 1.8 * y[:, None]
    return x.astype(np.float32), y.astype(np.int32)


def synthetic_sequences(n=800, l=6, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    x = rng.normal(size=(n, l, 3)).astype(np.float32)
    x += (y[:, None] * np.linspace(0, 1.5, l))[:, :, None]  # diverging trend
    return x.astype(np.float32), y.astype(np.int32)


class TestMetrics:
    def test_f1_macro_perfect(self):
        y = np.array([0, 1, 0, 1])
        assert f1_macro(y, y) == 1.0

    def test_f1_macro_worst(self):
        y = np.array([0, 1, 0, 1])
        assert f1_macro(y, 1 - y) == 0.0

    def test_report_keys(self):
        rep = classification_report(np.array([0, 1]), np.array([0, 1]))
        assert {"f1_macro", "f1_available", "f1_unavailable", "accuracy"} <= set(rep)


@pytest.mark.parametrize("name", ["lr", "svm", "mlp", "xgb", "rf"])
def test_pointwise_models_learn_separable_data(name):
    x, y = synthetic_points()
    model = make_model(name)
    model.fit(x[:1500], y[:1500])
    pred = model.predict(x[1500:])
    assert f1_macro(y[1500:], pred) > 0.85, name


@pytest.mark.parametrize("name", ["lstm", "transformer"])
def test_sequence_models_learn_trends(name):
    x, y = synthetic_sequences()
    model = make_model(name, steps=300)
    model.fit(x[:600], y[:600])
    pred = model.predict(x[600:])
    assert f1_macro(y[600:], pred) > 0.8, name


class TestTrees:
    def test_gbdt_probability_range(self):
        x, y = synthetic_points(500)
        m = GradientBoostedTrees(n_rounds=20).fit(x, y)
        p = m.predict_proba(x)
        assert ((0 < p) & (p < 1)).all()

    def test_rf_probability_is_leaf_mean(self):
        x, y = synthetic_points(500)
        m = RandomForest(n_rounds=15).fit(x, y)
        p = m.predict_proba(x)
        assert ((0 <= p) & (p <= 1.0 + 1e-6)).all()

    def test_gbdt_improves_with_rounds(self):
        x, y = synthetic_points(1200, seed=3)
        weak = GradientBoostedTrees(n_rounds=2, learning_rate=0.1).fit(x[:900], y[:900])
        strong = GradientBoostedTrees(n_rounds=40, learning_rate=0.2).fit(x[:900], y[:900])
        f_weak = f1_macro(y[900:], weak.predict(x[900:]))
        f_strong = f1_macro(y[900:], strong.predict(x[900:]))
        assert f_strong >= f_weak - 0.02

    def test_deterministic_given_seed(self):
        x, y = synthetic_points(400)
        p1 = GradientBoostedTrees(n_rounds=8, seed=5).fit(x, y).predict_proba(x)
        p2 = GradientBoostedTrees(n_rounds=8, seed=5).fit(x, y).predict_proba(x)
        np.testing.assert_allclose(p1, p2)


class TestOnCampaign:
    """Integration: paper §VI-D bands on the simulated campaign."""

    def test_xgb_current_availability(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=240, horizon_minutes=0)
        model = fit_predictor("xgb", ds)
        rep = evaluate(model, ds)
        # paper: up to 0.90 at horizon 0 (small campaign -> looser floor)
        assert rep["f1_macro"] > 0.8, rep

    def test_xgb_horizon_holds_up(self, small_campaign):
        ds = build_dataset(small_campaign, window_minutes=240, horizon_minutes=30)
        model = fit_predictor("xgb", ds)
        rep = evaluate(model, ds)
        assert rep["f1_macro"] > 0.7, rep

    def test_sr_alone_is_a_strong_baseline(self, small_campaign):
        """Paper: 'using SR alone yields consistent performance'."""
        ds = build_dataset(
            small_campaign, window_minutes=240, feature_set=("SR",)
        )
        model = fit_predictor("lr", ds)
        rep = evaluate(model, ds)
        assert rep["f1_macro"] > 0.75, rep
