"""Smoke-run the documented example entry points (tiny shapes) so the
quickstart paths in README.md cannot silently rot."""

import importlib.util
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_example(name):
    path = os.path.join(REPO, "examples", f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("engine", ["fleet", "sharded"])
def test_probe_campaign_smoke(engine, capsys):
    mod = load_example("probe_campaign")
    campaign = mod.main(["--pools", "6", "--hours", "2", "--engine", engine])
    assert campaign.engine == engine
    assert campaign.s.shape == (6, 40)
    out = capsys.readouterr().out
    assert "Table I" in out and "probe compute cost" in out


def test_quickstart_smoke(capsys):
    mod = load_example("quickstart")
    mod.main(pools=6, hours=6.0, train_steps=1)
    out = capsys.readouterr().out
    assert "probed 6 pools" in out
    assert "F1-macro" in out
    assert "step 0: loss" in out


@pytest.mark.parametrize("engine", ["fleet", "sharded"])
def test_serve_spot_smoke(engine, capsys):
    """The streaming serve path end to end at tiny shapes; the fleet run
    keeps the LM data plane, the sharded run is control-plane only."""
    mod = load_example("serve_spot")
    argv = ["--pools", "6", "--train-hours", "2", "--serve-hours", "1",
            "--engine", engine]
    if engine == "sharded":
        argv.append("--no-lm")
    out_dict = mod.main(argv)
    n_cycles = out_dict["result"].s.shape[1]
    assert out_dict["result"].engine == engine
    assert out_dict["served"] + out_dict["deferred"] == 2 * n_cycles
    x, y = out_dict["streamer"].matrices(5)
    assert x.shape == (6, n_cycles - 5, 3) and y.shape == (6, n_cycles - 5)
    out = capsys.readouterr().out
    assert "served" in out and "streamed dataset" in out


def test_elastic_training_smoke(capsys):
    """The elastic-training loop end to end at tiny shapes: real train
    steps on a re-meshed data plane, checkpoint decisions from the live
    goodput stream, frontier accounting over the whole campaign."""
    mod = load_example("elastic_training")
    out_dict = mod.main([
        "--pools", "6", "--pods", "4", "--hours", "2", "--steps", "8",
        "--d-model", "32", "--layers", "1", "--batch", "2", "--seq", "16",
        "--engine", "sharded",
    ])
    assert out_dict["steps_done"] <= 8
    assert out_dict["remeshes"] >= 1           # the loop really re-meshed
    assert len(out_dict["losses"]) == out_dict["steps_done"] + out_dict["steps_lost"]
    frontier = out_dict["frontier"]
    assert set(frontier) == {"fixed_30min", "sns_hazard"}
    # frontier accounting ran over the full 2h campaign (40 cycles)
    gs = out_dict["goodput"]
    assert gs.cycles_run == 40 and gs.done
    for r in frontier.values():
        assert 0.0 <= r.goodput <= 1.0
    out = capsys.readouterr().out
    assert "re-meshes" in out and "sns_hazard" in out
