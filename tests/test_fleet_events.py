"""Fleet plumbing: pod event edges, campaign→trace mapping, elastic meshes.

Covers the previously-untested glue between the measurement plane and the
training data plane: edge emission in :meth:`PodTrace.events`, the
slice-before-featurize fast path of :func:`traces_from_campaign`, and the
:class:`ElasticMeshManager` degradation ladder down to a 1-device box.
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.features import compute_features
from repro.core.labels import binary_availability
from repro.fleet import (
    ElasticMeshManager,
    MeshPlan,
    PodEvent,
    PodTrace,
    reshard,
    traces_from_campaign,
)

DT = 180.0


def _trace(avail):
    avail = np.asarray(avail)
    T = len(avail)
    return PodTrace(
        pod_id=3,
        pool_id="pool-3",
        times=np.arange(T, dtype=np.float64) * DT,
        available=avail.astype(np.int8),
        features=np.zeros((T, 3)),
        dt=DT,
    )


class TestPodEvents:
    def test_all_up_emits_nothing(self):
        assert _trace([1, 1, 1, 1]).events() == []

    def test_starts_down_emits_immediate_down(self):
        events = _trace([0, 0, 1]).events()
        assert events[0] == PodEvent(0.0, 3, False)
        assert events[1] == PodEvent(2 * DT, 3, True)
        assert len(events) == 2

    def test_flapping_emits_every_edge(self):
        events = _trace([1, 0, 1, 0, 1]).events()
        assert [(e.time, e.up) for e in events] == [
            (DT, False), (2 * DT, True), (3 * DT, False), (4 * DT, True)]
        assert all(e.pod_id == 3 for e in events)


class TestTracesFromCampaign:
    def test_slice_before_featurize_is_identity(self, small_campaign):
        """Featurizing only the kept pools must equal featurizing the
        whole campaign and slicing after (per-pool row independence)."""
        n_pods = 4
        traces = traces_from_campaign(small_campaign, n_pods=n_pods,
                                      window_minutes=240.0)
        assert len(traces) == n_pods
        full = compute_features(small_campaign.s, small_campaign.n, 240.0,
                                small_campaign.interval / 60.0)
        avail = binary_availability(small_campaign.running, small_campaign.n)
        for pod, tr in enumerate(traces):
            assert tr.pod_id == pod
            assert tr.pool_id == small_campaign.pool_ids[pod]
            np.testing.assert_array_equal(tr.available, avail[pod])
            np.testing.assert_array_equal(tr.features, full[pod])
            assert tr.dt == small_campaign.interval

    def test_n_pods_clamps_to_pool_count(self, small_campaign):
        traces = traces_from_campaign(small_campaign, n_pods=10_000)
        assert len(traces) == len(small_campaign.pool_ids)


class TestElasticMeshManager:
    MGR = dict(n_pods=4, data_per_pod=2, model_parallel=1)

    def test_plan_degrades_with_membership(self):
        mgr = ElasticMeshManager(**self.MGR)
        assert mgr.plan_for([0, 1, 2, 3]).shape == (4, 2, 1)
        assert mgr.plan_for([0, 2]).shape == (2, 2, 1)
        # single pod drops the pod axis entirely
        assert mgr.plan_for([1]).shape == (2, 1)
        assert mgr.plan_for([1]).axes == ("data", "model")
        assert mgr.plan_for([]) is None  # below min_pods → job pauses

    def test_global_batch_scale(self):
        mgr = ElasticMeshManager(**self.MGR)
        assert mgr.global_batch_scale([0, 1, 2, 3]) == 1.0
        assert mgr.global_batch_scale([0, 1]) == 0.5
        assert mgr.global_batch_scale([]) == 0.0

    def test_feasible_plan_on_one_device(self):
        mgr = ElasticMeshManager(n_pods=4, data_per_pod=1, model_parallel=1)
        plan = mgr.feasible_plan([0, 1, 2, 3], n_devices=1)
        assert plan is not None and plan.shape == (1, 1)
        # a pod that needs 2 devices cannot fit on 1 → pause
        wide = ElasticMeshManager(**self.MGR)
        assert wide.feasible_plan([0, 1], n_devices=1) is None

    def test_build_rejects_oversized_plan(self):
        n = len(jax.devices())
        plan = MeshPlan((n + 1, 1), ("data", "model"))
        with pytest.raises(ValueError, match="devices"):
            plan.build()


class TestReshard:
    def test_reshard_smoke_single_device(self):
        """Round-trip a params pytree through a fresh 1-device mesh built
        via the version-compat helpers (never raw ``jax.set_mesh``)."""
        plan = MeshPlan((1, 1), ("data", "model"))
        mesh = plan.build()
        tree = {"w": np.arange(8.0).reshape(2, 4), "b": np.zeros(4)}
        specs = {"w": P(), "b": P()}
        out = reshard(tree, mesh, specs)
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
        np.testing.assert_array_equal(np.asarray(out["b"]), tree["b"])
        assert out["w"].sharding.mesh.shape == {"data": 1, "model": 1}
