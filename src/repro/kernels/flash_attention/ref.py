"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,     # (B, H, S_q, hd)
    k: jnp.ndarray,     # (B, K, S_k, hd)
    v: jnp.ndarray,     # (B, K, S_k, hd)
    *,
    causal: bool = True,
    window: int = 2**30,
) -> jnp.ndarray:
    """Naive GQA attention with causal + sliding-window masking."""
    b, h, s_q, hd = q.shape
    kv = k.shape[1]
    n_rep = h // kv
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (hd**0.5)
    q_pos = jnp.arange(s_q)[:, None]
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((s_q, k.shape[2]), bool)
    if causal:
        mask &= k_pos <= q_pos
    mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
