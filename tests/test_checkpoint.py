"""Crash-consistent campaign checkpoints: kill at cycle k, restore into a
fresh identically-configured stream, drain — bit-identical to the
uninterrupted run on every engine, with and without chaos."""

import pickle

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    FaultPlan,
    RetryPolicy,
    SimulatedProvider,
    ThrottleBursts,
    default_fleet,
)
from repro.core.collector import CampaignStream
from repro.core.pipeline import CampaignPipelineStream

ENGINES = ("fleet", "scalar", "sharded")

CHAOS = dict(
    fault_plan=FaultPlan(
        seed=11,
        throttle=ThrottleBursts(p=0.5, epoch=900.0, mean_duration=400.0),
        request_error_p=0.05,
        timeout_p=0.1,
    ),
    retry_policy=RetryPolicy(seed=5),
)


def mk_stream(engine, chaos=False, **kw):
    prov = SimulatedProvider(default_fleet(6, seed=3), seed=3)
    kw.setdefault("duration", 3600.0)
    if chaos:
        kw.update(CHAOS)
    return CampaignStream(prov, engine=engine, **kw)


def assert_results_identical(ra, rb):
    np.testing.assert_array_equal(ra.s, rb.s)
    np.testing.assert_array_equal(ra.running, rb.running)
    np.testing.assert_array_equal(ra.times, rb.times)
    assert ra.interruptions == rb.interruptions
    assert ra.api_calls == rb.api_calls
    assert ra.fault_api_calls == rb.fault_api_calls
    assert ra.probe_compute_cost == rb.probe_compute_cost
    assert ra.node_pool_cost == rb.node_pool_cost
    if ra.codes is None:
        assert rb.codes is None
    else:
        np.testing.assert_array_equal(ra.codes, rb.codes)
        np.testing.assert_array_equal(ra.errors, rb.errors)


def kill_restore_drain(engine, k, chaos, **kw):
    ref = mk_stream(engine, chaos, **kw)
    while ref.step() is not None:
        pass
    interrupted = mk_stream(engine, chaos, **kw)
    for _ in range(k):
        interrupted.step()
    # a checkpoint must survive serialization — the crash-consistency
    # contract is over the persisted bytes, not live object graphs
    blob = pickle.dumps(interrupted.state_dict())
    del interrupted
    resumed = mk_stream(engine, chaos, **kw)
    resumed.restore(pickle.loads(blob))
    while resumed.step() is not None:
        pass
    assert_results_identical(ref.result(), resumed.result())


class TestKillRestoreDrain:
    """Acceptance (b), all engines × {clean, chaos} at a fixed boundary."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_clean(self, engine):
        kill_restore_drain(engine, k=7, chaos=False)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_chaos(self, engine):
        kill_restore_drain(engine, k=7, chaos=True)

    @pytest.mark.parametrize("engine", ENGINES)
    def test_boundary_cycles(self, engine):
        # kill before the first step and after the last one
        kill_restore_drain(engine, k=0, chaos=True, duration=1800.0)
        kill_restore_drain(engine, k=10, chaos=True, duration=1800.0)

    @settings(max_examples=6)
    @given(
        engine=st.sampled_from(ENGINES),
        k=st.integers(min_value=0, max_value=20),
        chaos=st.booleans(),
    )
    def test_randomized_boundaries(self, engine, k, chaos):
        kill_restore_drain(engine, k=k, chaos=chaos)

    def test_terminator_delay_pending_cancels(self):
        # the slow-terminator scoot path holds pending cancels across
        # cycles — the snapshot must reproduce them
        for engine in ("fleet", "sharded"):
            kill_restore_drain(engine, k=5, chaos=True, terminator_delay=30.0)

    def test_engine_mismatch_rejected(self):
        sd = mk_stream("fleet").state_dict()
        with pytest.raises(ValueError):
            mk_stream("scalar").restore(sd)


class TestPipelineCheckpoint:
    """The full measure → featurize → predict stream restores too."""

    def _mk(self, engine="fleet"):
        prov = SimulatedProvider(default_fleet(6, seed=3), seed=3)
        return CampaignPipelineStream(
            prov,
            duration=3600.0,
            engine=engine,
            predict_fn=lambda X: 1.0 - 0.5 * X[:, 0],
            **CHAOS,
        )

    def test_kill_restore_views_and_tables(self):
        ref = self._mk()
        ref_views = [
            (v.features.copy(), None if v.probs is None else v.probs.copy(),
             None if v.staleness is None else v.staleness.copy())
            for v in ref
        ]
        a = self._mk()
        for _ in range(7):
            a.step()
        blob = pickle.dumps(a.state_dict())
        b = self._mk()
        b.restore(pickle.loads(blob))
        tail = [
            (v.features.copy(), None if v.probs is None else v.probs.copy(),
             None if v.staleness is None else v.staleness.copy())
            for v in b
        ]
        assert len(tail) == len(ref_views) - 7
        for x, y in zip(ref_views[7:], tail):
            np.testing.assert_array_equal(x[0], y[0])
            np.testing.assert_array_equal(x[1], y[1])
            np.testing.assert_array_equal(x[2], y[2])
        assert_results_identical(ref.result(), b.result())
        pa, pb = ref.processor, b.processor
        np.testing.assert_array_equal(pa.table.features, pb.table.features)
        np.testing.assert_array_equal(
            pa.table.predictions, pb.table.predictions
        )
        np.testing.assert_array_equal(pa.state.staleness, pb.state.staleness)

    def test_window_wrap_archives_restore(self):
        # long enough that the ring wraps and evictions archive
        from repro.core.pipeline import FleetFeatureProcessor

        def mk():
            prov = SimulatedProvider(default_fleet(4, seed=1), seed=1)
            proc = FleetFeatureProcessor(
                prov.pool_ids, window_minutes=15.0, archive_evicted=True
            )
            return CampaignPipelineStream(
                prov, processor=proc, duration=4 * 3600.0, engine="fleet"
            )

        a = mk()
        for _ in range(30):
            a.step()
        assert a.processor.table.archived_cycles > 0
        blob = pickle.dumps(a.state_dict())
        b = mk()
        b.restore(pickle.loads(blob))
        while a.step() is not None:
            pass
        while b.step() is not None:
            pass
        np.testing.assert_array_equal(a.result().s, b.result().s)
        ta, tb = a.processor.table, b.processor.table
        assert ta.archived_cycles == tb.archived_cycles
        assert len(ta._archive_blocks) == len(tb._archive_blocks)
        for x, y in zip(ta._archive_blocks, tb._archive_blocks):
            np.testing.assert_array_equal(x, y)


class TestSnapshotHygiene:
    def test_state_dict_is_a_deep_snapshot(self):
        # mutating the live stream after state_dict() must not leak into
        # the snapshot
        a = mk_stream("fleet", chaos=True)
        for _ in range(5):
            a.step()
        sd = a.state_dict()
        blob = pickle.dumps(sd)
        for _ in range(5):
            a.step()
        assert pickle.dumps(a.state_dict()) != blob  # stream moved on
        b = mk_stream("fleet", chaos=True)
        b.restore(pickle.loads(blob))
        c = mk_stream("fleet", chaos=True)
        c.restore(sd)
        while b.step() is not None:
            pass
        while c.step() is not None:
            pass
        assert_results_identical(b.result(), c.result())

    def test_scalar_slow_terminator_snapshot_unsupported(self):
        # the scalar engine's slow-terminator path holds live request
        # objects — snapshotting mid-flight is explicitly refused rather
        # than silently wrong
        s = mk_stream("scalar", chaos=False, terminator_delay=30.0)
        for _ in range(3):
            s.step()
        with pytest.raises(NotImplementedError):
            s.state_dict()
