"""SnS collector: probing protocol, terminator, data lake, near-zero cost."""

import numpy as np

from repro.core import run_campaign
from repro.core.collector import SnSCollector
from repro.core.lifecycle import RequestState
from repro.core.provider import PoolConfig, SimulatedProvider


def make_provider(n_pools=2, **kw):
    cfgs = [
        PoolConfig(instance_type=f"t{i}", region="r", base_capacity=30.0)
        for i in range(n_pools)
    ]
    return SimulatedProvider(cfgs, seed=0, **kw)


class TestProbing:
    def test_probe_returns_graded_counts(self):
        prov = make_provider()
        col = SnSCollector(prov, prov.pool_ids, n_requests=10)
        s = col.run_cycle(0)
        assert s.shape == (2,)
        assert ((0 <= s) & (s <= 10)).all()

    def test_probes_never_reach_running(self):
        prov = make_provider()
        col = SnSCollector(prov, prov.pool_ids, n_requests=10)
        for c in range(5):
            prov.advance(prov.now + 180.0)
            col.run_cycle(c)
        assert all(
            r.state in (RequestState.CANCELLED, RequestState.REJECTED)
            for r in col.probe_requests
        )
        assert col.probe_compute_cost() == 0.0

    def test_slow_terminator_leaks_cost(self):
        """Without the event-driven design, probes reach RUNNING and bill —
        the failure mode the paper's architecture (§V) eliminates."""
        prov = make_provider(provisioning_duration=8.0)
        col = SnSCollector(
            prov, prov.pool_ids, n_requests=10, terminator_delay=30.0
        )
        for c in range(3):
            col.run_cycle(c)
            prov.advance(prov.now + 180.0)
        leaked = [r for r in col.probe_requests if r.run_started is not None]
        assert leaked, "slow terminator should leak probes into RUNNING"
        assert col.probe_compute_cost() > 0.0

    def test_data_lake_aggregation_matches_cycle_counts(self):
        prov = make_provider()
        col = SnSCollector(prov, prov.pool_ids, n_requests=10)
        counts = []
        for c in range(4):
            counts.append(col.run_cycle(c))
            prov.advance(prov.now + 180.0)
        lake = col.lake.success_counts(prov.pool_ids, 4)
        np.testing.assert_array_equal(lake, np.stack(counts, axis=1))


class TestCampaign:
    def test_shapes_and_alignment(self, small_campaign):
        res = small_campaign
        pools, t = res.s.shape
        assert res.running.shape == (pools, t)
        assert res.times.shape == (t,)
        assert np.all(np.diff(res.times) == res.interval)

    def test_request_volume_accounting(self, small_campaign):
        res = small_campaign
        pools, t = res.s.shape
        # every pool-cycle submits n probes (rate limits permitting)
        assert res.api_calls >= pools * t * res.n
