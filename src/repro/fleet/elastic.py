"""Elastic mesh management: re-mesh + re-shard when pods come and go.

JAX's SPMD model has no dynamic membership — the idiomatic elastic
pattern is *checkpoint → rebuild mesh → restore*: on pod loss the job
restarts its jit functions on a smaller `(pod, data, model)` mesh and
re-shards the latest checkpoint onto it; on pod recovery it scales back
up.  ``ElasticMeshManager`` encapsulates that decision logic (which mesh
for how many pods, when a re-mesh is worth it) and the resharding itself,
which is a plain ``device_put`` with the new mesh's NamedShardings — XLA
moves the bytes.

Data determinism across re-meshes: the data iterator is indexed by
(global step, microbatch id), not by device, so a re-meshed run consumes
exactly the same token stream (straggler/ordering safety).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshPlan", "ElasticMeshManager", "reshard"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def build(self, devices: Optional[np.ndarray] = None) -> Mesh:
        """Materialise the mesh through the JAX version-compat helpers
        (``repro.launch.mesh.make_explicit_mesh``) — never the raw
        newer-JAX-only mesh APIs.  Passing an explicit ``devices`` subset
        keeps the legacy ``Mesh``-constructor path (compatible
        everywhere)."""
        if devices is None:
            from repro.launch.mesh import make_explicit_mesh

            n = int(np.prod(self.shape))
            if len(jax.devices()) < n:
                raise ValueError(
                    f"need {n} devices, have {len(jax.devices())}"
                )
            return make_explicit_mesh(self.shape, self.axes)
        devices = np.asarray(devices)
        n = int(np.prod(self.shape))
        if devices.size < n:
            raise ValueError(f"need {n} devices, have {devices.size}")
        return Mesh(
            devices.reshape(-1)[:n].reshape(self.shape), self.axes
        )


class ElasticMeshManager:
    """Chooses a mesh for the currently-available pods.

    ``pod_capacity`` devices per pod; the `(data, model)` in-pod layout is
    fixed, the pod axis grows/shrinks.  Scale-down to zero pods pauses the
    job (the runner accounts that as unavailable time).
    """

    def __init__(
        self,
        *,
        n_pods: int,
        data_per_pod: int,
        model_parallel: int,
        min_pods: int = 1,
    ):
        self.n_pods = n_pods
        self.data = data_per_pod
        self.model = model_parallel
        self.min_pods = min_pods

    def plan_for(self, up_pods: List[int]) -> Optional[MeshPlan]:
        k = len(up_pods)
        if k < self.min_pods:
            return None  # job pauses
        if k == 1:
            return MeshPlan((self.data, self.model), ("data", "model"))
        return MeshPlan((k, self.data, self.model), ("pod", "data", "model"))

    def global_batch_scale(self, up_pods: List[int]) -> float:
        """Elastic batch policy: keep per-pod batch fixed, so global batch
        scales with surviving pods (loss scaling handled by the trainer)."""
        return max(len(up_pods), 0) / self.n_pods

    def feasible_plan(
        self, up_pods: List[int], n_devices: Optional[int] = None
    ) -> Optional[MeshPlan]:
        """:meth:`plan_for` clamped to what the visible device count can
        actually build.

        Each pod consumes ``data_per_pod × model_parallel`` devices; with
        fewer devices than surviving pods (a CPU dev box standing in for
        the fleet), the mesh covers the first ``cap`` pods and the rest
        contribute only through :meth:`global_batch_scale`.  ``None``
        still means the job pauses (below ``min_pods`` or no device can
        host even one pod)."""
        if n_devices is None:
            n_devices = len(jax.devices())
        cap = n_devices // (self.data * self.model)
        if cap < 1:
            return None
        return self.plan_for(up_pods[: min(len(up_pods), cap)])


def reshard(tree, mesh: Mesh, specs) -> object:
    """Re-shard a (restored) pytree onto a new mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree,
        specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )
