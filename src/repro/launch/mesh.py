"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so tests/benches keep seeing the single
real CPU device; only the dry-run subprocess sets the 512-placeholder-
device XLA flag before first jax init).
"""

from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "data_axes_of", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod mesh, or 2×16×16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def data_axes_of(mesh) -> Tuple[str, ...]:
    """The batch/data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_axis_sizes(mesh) -> dict:
    return {name: size for name, size in zip(mesh.axis_names, mesh.devices.shape)}
