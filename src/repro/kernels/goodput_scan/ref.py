"""``lax.scan`` reference for the fused goodput replay.

One scan over cycles carries the whole ``(S, P)`` replay state — ``S``
checkpoint-policy planes sharing each pod's availability / hazard column,
so every trace cycle is read once and replayed through all policies (the
bandwidth-lean form of the policy-tiled batch).

The per-cycle transition is the closed form of
``repro.fleet.runner._cycle_update`` op for op, with one difference in
*where* the policy interval τ comes from: the batch engines consume a
host-precomputed ``(R, T)`` τ matrix, while this engine re-derives τ
in-graph from the host-precomputed negative log survival ``nlp`` and the
traced per-policy parameter planes:

``lam = max(nlp / horizon, floor);  hz = clip(sqrt((2·δ)/lam), δ, τ_max);
τ = where(is_hz, where(panic, 2·δ, hz), interval)``

Every divisor / clip bound is a **traced** operand — XLA must then emit
exact IEEE division instead of strength-reducing a constant divisor into
a multiply-by-reciprocal — so the in-graph τ is bit-identical to the host
``PolicyTable.tau`` ufunc chain, which is what keeps this engine atol=0
against the scalar / numpy / scan trio.  Panic is a *host* predicate
(packed into the flag bits, one bit per policy plane) so no ``1 − p``
arithmetic happens in-graph.

Counters (steps done / since / lost, checkpoints) are int32 in-graph
(cast to int64 on output): ``T · dt / step_time`` must stay below 2³¹.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["goodput_sweep_ref"]


@jax.jit
def goodput_sweep_ref(
    flags_t,        # (T, P) int32 — bit0 avail, bit(1+s) panic for plane s
    nlp_t,          # (T, P) f — host -log(clip(p_survive))
    cyc_t,          # (T,) int32 — cycle indices
    is_hz,          # (S, P) bool
    interval,       # (S, P) f — τ for fixed rows
    delta,          # (S, P) f — δ for hazard rows
    horizon,        # (S, P) f
    tau_max,        # (S, P) f
    floor,          # (S, P) f
    dt,             # () f — all four scalars traced (exact IEEE division)
    step_time,      # () f
    ckpt_cost,      # () f
    restore_cost,   # () f
):
    """Fused goodput replay; returns final ``(S, P)`` metric planes."""
    f = nlp_t.dtype
    i32 = jnp.int32
    S, P = is_hz.shape
    zero = jnp.zeros((), f)
    two = jnp.asarray(2.0, f)
    zf = jnp.zeros((S, P), f)
    zi = jnp.zeros((S, P), i32)
    s_iota = jax.lax.broadcasted_iota(i32, (S, P), 0)

    def cycle(carry, xs):
        (done, since, lost, ckpts, overhead, unavailable,
         t_last, restore_rem, write_rem) = carry
        flags_c, nlp_c, c = xs
        now = c.astype(f) * dt
        up = jnp.broadcast_to(((flags_c & 1) > 0)[None, :], (S, P))
        panic = ((flags_c[None, :] >> (s_iota + 1)) & 1) > 0

        # -- policy interval, re-derived in-graph (see module docstring) --
        lam = jnp.maximum(nlp_c[None, :] / horizon, floor)
        hz = jnp.clip(jnp.sqrt((two * delta) / lam), delta, tau_max)
        tau_c = jnp.where(is_hz, jnp.where(panic, two * delta, hz), interval)

        down = ~up
        lost = lost + jnp.where(down, since, 0)
        since = jnp.where(down, 0, since)
        unavailable = unavailable + jnp.where(down, dt, zero)
        restore_rem = jnp.where(down, restore_cost, restore_rem)
        write_rem = jnp.where(down, zero, write_rem)

        budget = jnp.where(up, dt, zero)
        # -- drain restore, then the carried checkpoint write --------------
        used = jnp.minimum(budget, restore_rem)
        restore_rem = restore_rem - used
        budget = budget - used
        was_writing = write_rem > zero
        w = jnp.minimum(budget, write_rem)
        write_rem = write_rem - w
        budget = budget - w
        overhead = overhead + w
        done_write = was_writing & (write_rem <= zero)
        ckpts = ckpts + done_write.astype(i32)
        t_last = jnp.where(done_write, now + (dt - budget), t_last)
        since = jnp.where(done_write, 0, since)
        # -- policy consult: once per cycle, at t_c ------------------------
        t_c = now + (dt - budget)
        can = up & (budget > zero)
        decide = can & (t_c - t_last >= tau_c)
        start = decide & (since > 0)
        t_last = jnp.where(decide & (since == 0), t_c, t_last)
        w2 = jnp.where(start, jnp.minimum(budget, ckpt_cost), zero)
        budget = budget - w2
        overhead = overhead + w2
        full = start & (w2 >= ckpt_cost)
        write_rem = jnp.where(start & ~full, ckpt_cost - w2, write_rem)
        ckpts = ckpts + full.astype(i32)
        t_last = jnp.where(full, now + (dt - budget), t_last)
        since = jnp.where(full, 0, since)
        # -- training steps fill the remainder -----------------------------
        steps = jnp.floor(budget / step_time).astype(i32)
        done = done + steps
        since = since + steps
        return (done, since, lost, ckpts, overhead, unavailable,
                t_last, restore_rem, write_rem), None

    init = (zi, zi, zi, zi, zf, zf, zf, zf, zf)
    final, _ = jax.lax.scan(cycle, init, (flags_t, nlp_t, cyc_t))
    (done, _since, lost, ckpts, overhead, unavailable, *_rest) = final
    return {
        "steps_completed": done,
        "steps_lost": lost,
        "checkpoints": ckpts,
        "ckpt_overhead_s": overhead,
        "unavailable_s": unavailable,
    }
