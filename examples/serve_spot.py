"""Serving on spot pools with SnS-guided admission + migration.

A small LM serves batched requests while the pool's availability
fluctuates.  The AdmissionController applies Predict-AR (§VI-E) to request
admission: when the SnS predictor forecasts trouble, new requests queue
instead of starting; in-flight decodes finish undisturbed.  When the
current pool degrades, `plan_migration` picks the healthiest alternative
by live SnS features.

Run:  PYTHONPATH=src python examples/serve_spot.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    SimulatedProvider,
    build_dataset,
    compute_features,
    default_fleet,
    fit_predictor,
    run_campaign,
)
from repro.models import api
from repro.serve import AdmissionController, generate, plan_migration


def main():
    # -- control plane ----------------------------------------------------
    fleet = default_fleet(8, seed=5)
    provider = SimulatedProvider(fleet, seed=6)
    campaign = run_campaign(provider, duration=12 * 3600.0)
    ds = build_dataset(campaign, window_minutes=240, horizon_minutes=15)
    model = fit_predictor("xgb", ds)
    std = ds.standardizer
    feats = compute_features(campaign.s, campaign.n, 240.0,
                             campaign.interval / 60.0)

    def p_stay(f):
        x = std(f[None, :]) if std else f[None, :]
        return float(model.predict_proba(x)[0])

    # -- data plane: a small serving model --------------------------------
    cfg = get_config("qwen3-8b").scaled_down()
    params = api.init_params(cfg, seed=0)
    rng = np.random.default_rng(0)

    current_pool = 0
    ctl = AdmissionController(predictor=p_stay, horizon_cycles=5, threshold=0.5)
    served = deferred = migrations = 0
    for cycle in range(60, 160):          # a 5-hour serving window
        f = feats[current_pool, cycle]
        if ctl.on_cycle(cycle, f):
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32
            )
            out = generate(cfg, params, {"tokens": prompts}, max_new_tokens=4)
            assert out.shape == (2, 4)
            served += 2
        else:
            deferred += 2
            # degraded: consider migrating to the healthiest pool
            pool_feats = {
                str(p): feats[p, cycle] for p in range(len(campaign.pool_ids))
            }
            target = plan_migration(pool_feats, p_stay, current=str(current_pool))
            if target is not None:
                current_pool = int(target)
                migrations += 1
                ctl = AdmissionController(predictor=p_stay,
                                          horizon_cycles=5, threshold=0.5)
    print(f"served {served} requests, deferred {deferred}, "
          f"{migrations} pool migrations")


if __name__ == "__main__":
    main()
