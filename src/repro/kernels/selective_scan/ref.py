"""Pure-jnp oracle for the selective-scan (Mamba-1 SSM) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    x: jnp.ndarray,      # (B, S, D)   — conv+silu'd inputs (f32)
    dt: jnp.ndarray,     # (B, S, D)   — softplus'd step sizes
    a: jnp.ndarray,      # (D, N)      — negative state matrix
    b: jnp.ndarray,      # (B, S, N)
    c: jnp.ndarray,      # (B, S, N)
    h0: jnp.ndarray,     # (B, D, N)   — initial state
):
    """Sequential reference: h_t = exp(dt_t a) h_{t-1} + dt_t x_t b_t.

    Returns (y (B,S,D), h_final (B,D,N)).
    """

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * a)                 # (B, D, N)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b, 1, 0),
        jnp.moveaxis(c, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_final
