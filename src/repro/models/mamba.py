"""Mamba-1 selective-SSM block (falcon-mamba-7b; jamba's mamba layers).

Training/prefill uses a **chunked parallel scan**: the sequence is split
into chunks; within a chunk the linear recurrence ``h_t = a_t·h_{t-1} +
b_t`` runs as a `lax.associative_scan`, and a `lax.scan` threads the state
across chunks.  This bounds the materialised ``(B, chunk, d_inner, state)``
discretisation tensors — the full-sequence version would need ~17
GB/device at the falcon-mamba train_4k shape.  The chunk body is the
natural target for a Pallas selective-scan kernel on real TPUs; this repo
keeps the XLA chunked scan as the only (oracle) path, since the model zoo
is a workload generator here, not a compute hot-spot of the paper.

Decode is the O(1) recurrence: one state update per token, with a rolling
convolution buffer — no KV cache, which is why the SSM/hybrid archs are the
ones that run `long_500k`.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense

__all__ = [
    "init_mamba",
    "mamba_block",
    "mamba_decode_step",
    "init_mamba_state",
]


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg: ModelConfig) -> Dict:
    d, din, n, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    r = dt_rank(cfg)
    keys = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias so softplus(dt) spans (1e-3, 0.1)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, 1))
    u = jax.random.uniform(keys[0], (din,), minval=1e-3, maxval=0.1)
    dt_bias = jnp.log(jnp.expm1(u))  # inverse softplus
    return {
        "in_proj": init_dense(keys[1], (d, 2 * din), cfg.pdtype, fan_in=d),
        "conv_w": init_dense(keys[2], (dc, din), cfg.pdtype, fan_in=dc),
        "conv_b": jnp.zeros((din,), cfg.pdtype),
        "x_proj": init_dense(keys[3], (din, r + 2 * n), cfg.pdtype, fan_in=din),
        "dt_proj": init_dense(keys[4], (r, din), cfg.pdtype, fan_in=r),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": init_dense(keys[5], (din, d), cfg.pdtype, fan_in=din),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over sequence: x (B,S,din), w (dc,din)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(dc):  # dc is 4: unrolled taps beat a conv op at this size
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _ssm_params(cfg: ModelConfig, p: Dict, x: jnp.ndarray):
    """Input-dependent (dt, B, C) from x (B,S,din) — f32 for stability."""
    r, n = dt_rank(cfg), cfg.ssm_state
    proj = (x @ p["x_proj"]).astype(jnp.float32)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    return dt, b_ssm, c_ssm  # (B,S,din), (B,S,n), (B,S,n)


def mamba_block(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,               # (B, S, d)
    *,
    chunk: int = 64,
    return_state: bool = False,
):
    b, s, d = x.shape
    din, n = cfg.d_inner, cfg.ssm_state

    xz = x @ p["in_proj"]
    x1_pre, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(_causal_conv(x1_pre, p["conv_w"], p["conv_b"]))

    dt, b_ssm, c_ssm = _ssm_params(cfg, p, x1)
    a = -jnp.exp(p["a_log"])                                  # (din, n)
    x1f = x1.astype(jnp.float32)

    n_chunks = max(1, s // chunk)
    assert s % n_chunks == 0, f"seq {s} not divisible by chunk {chunk}"
    csz = s // n_chunks

    # The (B, chunk, d_inner, state) discretisation tensors dominate HBM
    # traffic on the XLA path (the Pallas kernel keeps them in VMEM); they
    # carry short-range products only, so bf16 storage with an f32 carry
    # keeps the recurrence stable at half the traffic (§Perf hillclimb).
    scan_dtype = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32

    a_sc = a.astype(scan_dtype)

    def scan_chunk(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * csz, csz, axis=1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(b_ssm), sl(c_ssm), sl(x1f)
        # discretise: decay (B,c,din,n), drive (B,c,din,n) — cast the
        # *small* (din-sized) factors first so the big (din×n) tensors are
        # BORN in scan_dtype; casting afterwards would materialise the f32
        # versions and double the traffic instead of halving it
        dt_sc = dt_c.astype(scan_dtype)
        decay = jnp.exp(dt_sc[..., None] * a_sc)             # ZOH on A
        drive = (dt_sc * x_c.astype(scan_dtype))[..., None] \
            * b_c.astype(scan_dtype)[:, :, None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        pref_a, pref_b = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        h_all = pref_b + pref_a * h[:, None].astype(scan_dtype)  # inject carry
        y_c = jnp.einsum(
            "bsdn,bsn->bsd", h_all, c_c.astype(scan_dtype),
            preferred_element_type=jnp.float32,
        )
        return h_all[:, -1].astype(jnp.float32), y_c

    h0 = jnp.zeros((b, din, n), jnp.float32)
    h_last, ys = jax.lax.scan(scan_chunk, h0, jnp.arange(n_chunks))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, din)

    y = y + p["d_skip"] * x1f
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    if return_state:
        # decode continues from the final SSM state + conv tail
        tail = x1_pre[:, -(cfg.d_conv - 1):, :].astype(cfg.adtype)
        return out, {"ssm": h_last, "conv": tail}
    return out


# --------------------------------------------------------------------------
# Decode path — O(1) per token
# --------------------------------------------------------------------------

def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    return {
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), cfg.adtype),
    }


def mamba_decode_step(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,               # (B, 1, d)
    state: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b = x.shape[0]
    din, n, dc = cfg.d_inner, cfg.ssm_state, cfg.d_conv

    xz = x[:, 0] @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)                        # (B, din)

    # rolling depthwise conv buffer
    window = jnp.concatenate([state["conv"], x1[:, None, :]], axis=1)  # (B,dc,din)
    conv_out = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    x1 = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :].astype(state["conv"].dtype)

    dt, b_ssm, c_ssm = _ssm_params(cfg, p, x1[:, None, :])
    dt, b_ssm, c_ssm = dt[:, 0], b_ssm[:, 0], c_ssm[:, 0]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a)                       # (B, din, n)
    drive = (dt * x1)[..., None] * b_ssm[:, None, :]
    h = decay * state["ssm"] + drive
    y = jnp.einsum("bdn,bn->bd", h, c_ssm) + p["d_skip"] * x1
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"ssm": h, "conv": new_conv}
