"""Serving launcher: ``python -m repro.launch.serve``.

Two entry points on the serving path:

* ``--arch <id>`` — the LM data plane: batched prefill + decode on a
  (reduced) config; demonstrates the public serving API end to end on
  CPU.
* ``--spot-pools N`` — the SnS control plane: drives a
  :class:`repro.core.CampaignPipelineStream` cycle at a time and feeds
  each cycle's fleet-wide availability probabilities straight into a
  :class:`repro.serve.FleetAdmissionController` — the streaming
  measure → featurize → predict → decide loop (§V + §VI-E Predict-AR) at
  fleet scale, with per-cycle decisions/sec reported.

Both can be combined in one invocation (control plane first).
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import arch_names, get_config
from repro.models import api
from repro.serve import FleetAdmissionController, generate


def serve_fleet(
    pools: int = 64,
    hours: float = 2.0,
    *,
    engine: str = "fleet",
    threshold: float = 0.5,
    horizon_cycles: int = 5,
    window_minutes: float = 60.0,
    seed: int = 0,
) -> dict:
    """Cycle-at-a-time fleet admission from the campaign pipeline stream.

    One collection cycle = one batched feature update, ONE batched
    predictor call, and ONE vectorised admission decision for the whole
    fleet (SR is used as the availability score, so the loop runs without
    a trained model — swap in ``repro.core.batched_predict_fn`` for a
    fitted predictor, as ``examples/serve_spot.py`` does).
    """
    from repro.core import CampaignPipelineStream, SimulatedProvider, default_fleet

    provider = SimulatedProvider(
        default_fleet(pools, seed=seed),
        seed=seed + 1,
        requests_per_minute_per_region=10**9,
    )
    stream = CampaignPipelineStream(
        provider,
        predict_fn=lambda x: x[:, 0],  # p_stay := SR
        window_minutes=window_minutes,
        duration=hours * 3600.0,
        engine=engine,
    )
    ctl = FleetAdmissionController(
        pools, threshold=threshold, horizon_cycles=horizon_cycles
    )
    admitted = deferred = 0
    t0 = time.perf_counter()
    for view in stream:
        admit = ctl.on_cycle(view.cycle, view.probs)
        admitted += int(admit.sum())
        deferred += pools - int(admit.sum())
    wall = time.perf_counter() - t0
    n_cycles = stream.n_cycles
    out = {
        "engine": engine,
        "pools": pools,
        "cycles": n_cycles,
        "admitted": admitted,
        "deferred": deferred,
        "decisions_per_sec": pools * n_cycles / wall if wall > 0 else float("inf"),
    }
    print(
        f"spot admission (engine={engine}): {pools} pools x {n_cycles} cycles"
        f" in {wall:.2f}s — {out['decisions_per_sec']:,.0f} decisions/sec,"
        f" {admitted} admitted / {deferred} deferred"
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=arch_names(),
                    help="LM data plane: run batched prefill + decode")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--spot-pools", type=int,
                    help="SnS control plane: streaming fleet admission "
                         "over this many pools")
    ap.add_argument("--spot-hours", type=float, default=2.0)
    ap.add_argument("--engine", choices=("fleet", "scalar", "sharded"),
                    default="fleet")
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--horizon-cycles", type=int, default=5)
    args = ap.parse_args()
    if args.arch is None and args.spot_pools is None:
        ap.error("nothing to do: pass --arch and/or --spot-pools")

    if args.spot_pools is not None:
        serve_fleet(
            args.spot_pools,
            args.spot_hours,
            engine=args.engine,
            threshold=args.threshold,
            horizon_cycles=args.horizon_cycles,
        )

    if args.arch is not None:
        cfg = get_config(args.arch).scaled_down()
        params = api.init_params(cfg, seed=0)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
                jnp.float32,
            )
        t0 = time.time()
        out = generate(cfg, params, batch, max_new_tokens=args.max_new_tokens)
        dt = time.time() - t0
        print(f"{cfg.name}: generated {out.shape} in {dt:.1f}s")
        print(np.asarray(out))


if __name__ == "__main__":
    main()
