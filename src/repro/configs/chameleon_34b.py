"""chameleon-34b — early-fusion VLM decoder.

[arXiv:2405.09818; unverified] — 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536.  Early fusion: VQ image tokens share the 65536
vocab with text, so the modality frontend is a STUB — input_specs()
provides interleaved token ids.  qk-norm (chameleon's training stabiliser),
RoPE, SwiGLU.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    use_rope=True,
    norm="rmsnorm",
    gated_mlp=True,
    source="arXiv:2405.09818; unverified",
)
