"""Decoder layer bodies: attention/mamba mixers × dense/MoE FFNs.

A layer is ``x + mixer(norm(x))`` then ``x + ffn(norm(x))`` (pre-norm).
Falcon-mamba layers are mixer-only (the assignment's ``d_ff=0``); arctic
adds a *dense residual* MLP in parallel with its MoE FFN.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from .common import GLOBAL_WINDOW, ModelConfig, apply_norm, make_norm_params

__all__ = ["init_layer", "layer_forward", "layer_kinds"]


def layer_kinds(cfg: ModelConfig):
    """Static per-layer structure: (mixer, is_moe, window) per layer.

    The window is part of the *static* kind so sliding-window layers can
    take the banded attention path (computing only S×W scores); gemma's
    5:1 local:global pattern folds into a period-6 block pattern (or an
    unrolled stack when layers don't divide the period)."""
    kinds = []
    windows = cfg.layer_windows()
    for i in range(cfg.n_layers):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        kinds.append((mixer, cfg.is_moe_layer(i), int(windows[i])))
    return kinds


def init_layer(key, cfg: ModelConfig, *, mixer: str, use_moe: bool) -> Dict:
    keys = jax.random.split(key, 4)
    p: Dict = {"norm1": make_norm_params(cfg, (cfg.d_model,))}
    if mixer == "attn":
        p["attn"] = attn_mod.init_attention(keys[0], cfg)
    else:
        p["mamba"] = mamba_mod.init_mamba(keys[0], cfg)
    if cfg.family == "ssm":
        return p  # mixer-only layers (falcon-mamba: d_ff = 0)
    p["norm2"] = make_norm_params(cfg, (cfg.d_model,))
    if use_moe:
        p["moe"] = moe_mod.init_moe(keys[1], cfg)
        if cfg.dense_residual:
            p["residual_mlp"] = mlp_mod.init_mlp(
                keys[2], cfg, d_ff=cfg.residual_d_ff or cfg.d_ff
            )
    else:
        p["mlp"] = mlp_mod.init_mlp(keys[1], cfg)
    return p


def layer_forward(
    cfg: ModelConfig,
    p: Dict,
    x: jnp.ndarray,
    *,
    mixer: str,
    use_moe: bool,
    window: int = int(GLOBAL_WINDOW),
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
) -> jnp.ndarray:
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        mixed, _ = attn_mod.attention(
            cfg, p["attn"], h, window=window, q_chunk=q_chunk,
            mesh=mesh, data_axes=data_axes,
        )
    else:
        mixed = mamba_mod.mamba_block(cfg, p["mamba"], h, chunk=mamba_chunk)
    x = x + mixed
    if cfg.family == "ssm":
        return x

    h = apply_norm(cfg, p["norm2"], x)
    if use_moe:
        y = moe_mod.moe_ffn(cfg, p["moe"], h, mesh=mesh, data_axes=data_axes)
        if cfg.dense_residual:
            y = y + mlp_mod.mlp(cfg, p["residual_mlp"], h)
    else:
        y = mlp_mod.mlp(cfg, p["mlp"], h)
    return x + y
