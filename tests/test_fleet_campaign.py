"""Fleet campaign engine: scalar/fleet parity, batched provider API,
terminator-delay leak accounting, Data Lake aggregation, pipeline glue."""

import numpy as np
import pytest

from repro.core import (
    FleetCollector,
    FleetFeatureProcessor,
    SimulatedProvider,
    compute_features,
    default_fleet,
    run_campaign,
    run_campaign_pipeline,
)
from repro.core.collector import DataLake, ProbeRecord
from repro.core.lifecycle import RequestState


def twin_providers(n_pools=8, seed=7, **kw):
    fleet = default_fleet(n_pools, seed=seed)
    return (
        SimulatedProvider(fleet, seed=seed + 1, **kw),
        SimulatedProvider(fleet, seed=seed + 1, **kw),
    )


class TestEngineParity:
    """The parity anchor: identical S_t / running_t / interruption logs
    when both engines are driven from the same per-pool RNG streams."""

    @pytest.fixture(scope="class")
    def pair(self):
        pa, pb = twin_providers(10, seed=11)
        ca = run_campaign(pa, duration=6 * 3600.0, engine="scalar")
        cb = run_campaign(pb, duration=6 * 3600.0, engine="fleet")
        return ca, cb

    def test_success_counts_identical(self, pair):
        ca, cb = pair
        np.testing.assert_array_equal(ca.s, cb.s)

    def test_running_counts_identical(self, pair):
        ca, cb = pair
        np.testing.assert_array_equal(ca.running, cb.running)
        np.testing.assert_array_equal(ca.times, cb.times)

    def test_interruption_logs_identical(self, pair):
        ca, cb = pair
        assert len(ca.interruptions) > 0
        assert ca.interruptions == cb.interruptions  # pool, instance, time

    def test_accounting_identical(self, pair):
        ca, cb = pair
        assert ca.api_calls == cb.api_calls
        assert ca.probe_compute_cost == cb.probe_compute_cost == 0.0
        assert ca.node_pool_cost == cb.node_pool_cost

    def test_subset_pool_campaign_parity(self):
        pa, pb = twin_providers(6, seed=3)
        subset = pa.pool_ids[1:4]
        ca = run_campaign(pa, pool_ids=subset, duration=2 * 3600.0, engine="scalar")
        cb = run_campaign(pb, pool_ids=subset, duration=2 * 3600.0, engine="fleet")
        np.testing.assert_array_equal(ca.s, cb.s)
        np.testing.assert_array_equal(ca.running, cb.running)
        assert ca.interruptions == cb.interruptions

    def test_rate_limited_parity(self):
        # all pools share one region and the budget covers only some of
        # them per cycle; both engines must zero-out the same starved ones
        from repro.core import PoolConfig

        fleet = [
            PoolConfig(instance_type=f"t{i}", region="r", base_capacity=30.0)
            for i in range(8)
        ]
        pa = SimulatedProvider(fleet, seed=5, requests_per_minute_per_region=30)
        pb = SimulatedProvider(fleet, seed=5, requests_per_minute_per_region=30)
        ca = run_campaign(pa, duration=2 * 3600.0, engine="scalar")
        cb = run_campaign(pb, duration=2 * 3600.0, engine="fleet")
        assert (ca.s.sum(axis=1) == 0).any(), "expected starved pools"
        np.testing.assert_array_equal(ca.s, cb.s)
        assert ca.api_calls == cb.api_calls

    def test_unknown_engine_rejected(self):
        pa, _ = twin_providers(2)
        with pytest.raises(ValueError):
            run_campaign(pa, duration=3600.0, engine="warp")


class TestTerminatorDelayLeak:
    """Slow terminator ⇒ probes reach RUNNING ⇒ nonzero probe instance
    cost — the §V failure mode, at both engine scales, with matching
    cost accounting."""

    DELAY = 30.0

    @pytest.fixture(scope="class")
    def pair(self):
        pa, pb = twin_providers(6, seed=21, provisioning_duration=8.0)
        ca = run_campaign(
            pa, duration=2 * 3600.0, engine="scalar", terminator_delay=self.DELAY
        )
        cb = run_campaign(
            pb, duration=2 * 3600.0, engine="fleet", terminator_delay=self.DELAY
        )
        return ca, cb

    def test_leak_bills_on_both_engines(self, pair):
        ca, cb = pair
        assert ca.probe_compute_cost > 0.0
        assert cb.probe_compute_cost > 0.0

    def test_cost_accounting_matches(self, pair):
        ca, cb = pair
        assert ca.probe_compute_cost == pytest.approx(
            cb.probe_compute_cost, rel=1e-12
        )

    def test_signal_matrices_still_identical(self, pair):
        ca, cb = pair
        np.testing.assert_array_equal(ca.s, cb.s)
        np.testing.assert_array_equal(ca.running, cb.running)
        assert ca.interruptions == cb.interruptions

    def test_fast_terminator_never_bills(self):
        pa, pb = twin_providers(6, seed=21, provisioning_duration=8.0)
        ca = run_campaign(pa, duration=3600.0, engine="scalar")
        cb = run_campaign(pb, duration=3600.0, engine="fleet")
        assert ca.probe_compute_cost == cb.probe_compute_cost == 0.0


class TestBatchedProviderAPI:
    def test_step_batch_advances_every_pool(self):
        prov, _ = twin_providers(5, seed=2)
        t0, ticks0 = prov.now, prov._tick_count
        prov.step_batch()
        assert prov.now == t0 + prov.tick
        assert prov._tick_count == ticks0 + 1

    def test_batched_submit_matches_scalar_submit(self):
        pa, pb = twin_providers(6, seed=9)
        idx = pa.pool_index(pa.pool_ids)
        counts = pa.submit_spot_requests(idx, n=10)
        for i, pid in enumerate(pb.pool_ids):
            reqs = pb.submit_spot_request(pid, n=10)
            accepted = sum(r.state is RequestState.PROVISIONING for r in reqs)
            assert counts[i] == accepted

    def test_batched_submit_leaves_state_untouched(self):
        prov, _ = twin_providers(4, seed=1)
        idx = prov.pool_index(prov.pool_ids)
        counts = prov.submit_spot_requests(idx, n=10)
        assert counts.sum() > 0
        assert prov.n_provisioning.sum() == 0  # scooted inside the call

    def test_held_cohorts_cancel_cleanly(self):
        prov, _ = twin_providers(4, seed=1)
        idx = prov.pool_index(prov.pool_ids)
        counts, cohorts = prov.submit_spot_requests(idx, n=10, hold=True)
        assert prov.n_provisioning.sum() == counts.sum() > 0
        prov.cancel_cohorts(cohorts)
        assert prov.n_provisioning.sum() == 0
        prov.advance(600.0)
        assert prov.probe_instance_cost() == 0.0

    def test_held_cohorts_leak_after_provisioning_duration(self):
        prov, _ = twin_providers(4, seed=1, provisioning_duration=8.0)
        idx = prov.pool_index(prov.pool_ids)
        counts, cohorts = prov.submit_spot_requests(idx, n=10, hold=True)
        prov.advance(prov.now + 30.0)  # > provisioning_duration: leak
        prov.cancel_cohorts(cohorts)   # too late — already RUNNING
        assert prov.running_counts().sum() == counts.sum()
        prov.advance(prov.now + 60.0)
        assert prov.probe_instance_cost() > 0.0


class TestDataLake:
    def records(self):
        return [
            ProbeRecord(0.0, "a", True, 0),
            ProbeRecord(0.0, "a", True, 0),
            ProbeRecord(0.0, "a", False, 1),
            ProbeRecord(0.0, "b", True, 1),
            ProbeRecord(0.0, "ghost", True, 0),   # unknown pool: dropped
            ProbeRecord(0.0, "b", True, 99),      # cycle out of range: dropped
        ]

    def reference_counts(self, records, pool_ids, n_cycles):
        # the historical per-record loop, kept as the oracle
        index = {p: i for i, p in enumerate(pool_ids)}
        s = np.zeros((len(pool_ids), n_cycles), dtype=np.int64)
        for rec in records:
            if rec.accepted and rec.cycle < n_cycles and rec.pool_id in index:
                s[index[rec.pool_id], rec.cycle] += 1
        return s

    def test_vectorized_matches_loop(self):
        lake = DataLake()
        for rec in self.records():
            lake.append(rec)
        got = lake.success_counts(["a", "b"], 3)
        np.testing.assert_array_equal(
            got, self.reference_counts(self.records(), ["a", "b"], 3)
        )

    def test_vectorized_matches_loop_randomized(self, rng):
        pools = [f"p{i}" for i in range(7)]
        recs = [
            ProbeRecord(
                float(t),
                rng.choice(pools + ["nope"]),
                bool(rng.random() < 0.7),
                int(rng.integers(0, 30)),
            )
            for t in range(500)
        ]
        lake = DataLake()
        for rec in recs:
            lake.append(rec)
        np.testing.assert_array_equal(
            lake.success_counts(pools, 20),
            self.reference_counts(recs, pools, 20),
        )

    def test_retention_flag_caps_objects(self):
        on, off = DataLake(), DataLake(retain_records=False)
        for rec in self.records():
            on.append(rec)
            off.append(rec)
        assert len(on.records) == len(on) == 6
        assert len(off.records) == 0 and len(off) == 6
        np.testing.assert_array_equal(
            on.success_counts(["a", "b"], 3), off.success_counts(["a", "b"], 3)
        )

    def test_block_boundary_exactness(self, rng):
        # cross the internal column-block boundary in both retention
        # modes: archived blocks, the folded aggregate, and the partial
        # block must all contribute exactly once
        from repro.core.collector import _LAKE_BLOCK

        pools = [f"p{i}" for i in range(5)]
        recs = [
            ProbeRecord(
                float(t),
                rng.choice(pools + ["ghost"]),
                bool(rng.random() < 0.6),
                int(rng.integers(-2, 12)),   # negative cycles wrap
            )
            for t in range(2 * _LAKE_BLOCK + 100)
        ]
        on, off = DataLake(), DataLake(retain_records=False)
        for rec in recs:
            on.append(rec)
            off.append(rec)
        assert len(on) == len(off) == len(recs)
        assert len(off.records) == 0
        expect = self.reference_counts(recs, pools, 10)
        np.testing.assert_array_equal(on.success_counts(pools, 10), expect)
        np.testing.assert_array_equal(off.success_counts(pools, 10), expect)
        # bounded mode holds one block + aggregate; archive mode grows
        assert off.nbytes < on.nbytes

    def test_negative_cycle_wraps_like_python_indexing(self):
        lake = DataLake(retain_records=False)
        lake.add(0.0, "a", True, -1)
        lake._flush_block()  # force the negative row through the fold path
        got = lake.success_counts(["a"], 3)
        np.testing.assert_array_equal(got, [[0, 0, 1]])

    def test_collector_retention_off_keeps_cost_accounting(self):
        pa, pb = twin_providers(4, seed=13, provisioning_duration=8.0)
        ca = run_campaign(
            pa, duration=3600.0, engine="scalar", terminator_delay=30.0
        )
        cb = run_campaign(
            pb, duration=3600.0, engine="scalar", terminator_delay=30.0,
            retain_records=False,
        )
        np.testing.assert_array_equal(ca.s, cb.s)
        assert ca.probe_compute_cost == pytest.approx(cb.probe_compute_cost)
        assert cb.probe_compute_cost > 0.0


class TestCostScoping:
    def test_second_campaign_excludes_prior_leaks(self):
        # leaked probes from campaign 1 keep billing on the provider, but
        # campaign 2's accounting must not inherit them (both engines)
        for engine in ("scalar", "fleet"):
            prov, _ = twin_providers(4, seed=23, provisioning_duration=8.0)
            c1 = run_campaign(
                prov, duration=3600.0, engine=engine, terminator_delay=30.0
            )
            assert c1.probe_compute_cost > 0.0
            c2 = run_campaign(prov, duration=3600.0, engine=engine)
            assert c2.probe_compute_cost == 0.0, engine


class TestCampaignPipelineGlue:
    def test_on_cycle_timestamps_match_across_engines(self):
        # with a slow terminator the fleet engine advances the clock
        # mid-cycle; the hook must still see the measurement timestamp
        seen = {}
        for engine in ("scalar", "fleet"):
            prov, _ = twin_providers(4, seed=29, provisioning_duration=8.0)
            stamps = []
            res = run_campaign(
                prov, duration=3600.0, engine=engine, terminator_delay=30.0,
                on_cycle=lambda c, t, s: stamps.append(t),
            )
            np.testing.assert_array_equal(np.asarray(stamps), res.times)
            seen[engine] = stamps
        assert seen["scalar"] == seen["fleet"]

    def test_campaign_streams_into_fleet_processor(self):
        prov, _ = twin_providers(6, seed=17)
        result, proc = run_campaign_pipeline(
            prov,
            duration=4 * 3600.0,
            predict_fn=lambda x: x[:, 0],  # score = SR
            window_minutes=30.0,
        )
        t = result.s.shape[1]
        assert proc.update_ops == t            # one batched update per cycle
        assert proc.predict_calls == t         # ONE predict_proba per cycle
        # streamed features == offline replay of the campaign's S matrix
        expect = compute_features(result.s, result.n, 30.0, result.interval / 60.0)
        w = proc.window_cycles
        np.testing.assert_array_equal(
            proc.table.features[:, proc.table._order()], expect[:, t - w:, :]
        )

    def test_existing_processor_is_reused(self):
        prov, _ = twin_providers(3, seed=19)
        proc = FleetFeatureProcessor(
            prov.pool_ids, n_requests=10, window_minutes=30.0, dt_minutes=3.0
        )
        result, got = run_campaign_pipeline(
            prov, processor=proc, duration=3600.0
        )
        assert got is proc
        assert proc.update_ops == result.s.shape[1]
