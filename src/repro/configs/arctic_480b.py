"""arctic-480b — 128-expert MoE with a dense residual path.

[hf:Snowflake/snowflake-arctic-base; hf] — 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 on every layer PLUS a dense residual
MLP in parallel (arctic's dense-MoE hybrid).  56 heads do not divide the
16-way model axis — attention falls back to replicated heads (see
sharding.py and EXPERIMENTS.md §Perf for the sequence-parallel fix).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_every=1,
    dense_residual=True,
    residual_d_ff=4864,
    use_rope=True,
    norm="rmsnorm",
    gated_mlp=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
