# Tier-1 verification — identical to what CI runs.
#   make verify   : full test suite + pipeline/campaign/replay/serve-throughput
#                   smokes + the chaos smoke (fault-plan matrix, 3-way
#                   engine parity + clean kill/restore resume)
#   make test     : test suite only (includes the bounded-host-memory
#                   property tests in tests/test_memory.py)
#   make docs     : docs checks only (examples compile, README snippets
#                   import, markdown links resolve, example smoke runs)
#   make bench    : full throughput benchmarks (assert >= 50x / >= 20x /
#                   sharded best-size >= 1x fleet / >= 3x / serve >= 20x /
#                   goodput scan >= 20x python loop)
#   make bench-multidev : campaign + replay full benches with the
#                   1/2/4-virtual-device scaling curves recorded in the
#                   BENCH_*.json entries (spawns XLA virtual-device
#                   subprocesses; curves are recorded, not asserted)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: verify test docs bench bench-multidev

verify: test
	python benchmarks/pipeline_throughput.py --smoke
	python benchmarks/campaign_throughput.py --smoke
	python benchmarks/replay_throughput.py --smoke
	python benchmarks/serve_throughput.py --smoke
	python benchmarks/goodput_throughput.py --smoke
	python benchmarks/chaos_smoke.py --smoke

test:
	python -m pytest -x -q

docs:
	python -m pytest -x -q tests/test_docs.py tests/test_examples.py

bench:
	python benchmarks/pipeline_throughput.py
	python benchmarks/campaign_throughput.py
	python benchmarks/replay_throughput.py
	python benchmarks/serve_throughput.py
	python benchmarks/goodput_throughput.py

bench-multidev:
	python benchmarks/campaign_throughput.py --multidev
	python benchmarks/replay_throughput.py --multidev
