"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

CPU-runnable smoke training for any assigned architecture (reduced config
by default; ``--full`` uses the production config — only sensible on a
real TPU fleet).  Supports resume-from-checkpoint and the SnS-hazard
checkpoint policy (see examples/elastic_training.py for the full elastic
loop).
"""

import argparse
import time

import jax

from repro.configs import arch_names, get_config
from repro.models import api
from repro.train import (
    OptConfig,
    init_opt_state,
    latest_step,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    synthetic_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=arch_names())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full production config (TPU-scale!)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.scaled_down()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    params = api.init_params(cfg, seed=0)
    opt_state = init_opt_state(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        params, opt_state, start = load_checkpoint(args.ckpt_dir, params, opt_state)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(lr=args.lr, total_steps=args.steps), remat="none"
    ))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, seed=i)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, params, opt_state)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params, opt_state)


if __name__ == "__main__":
    main()
