"""Architecture registry: ``--arch <id>`` resolves here.

One module per assigned architecture; exact hyper-parameters from the
assignment table (sources quoted per config).
"""

from __future__ import annotations

from typing import Dict, List

from repro.models.common import ModelConfig

from .base import SHAPES, InputShape, shape_applicability
from .whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from .qwen1_5_4b import CONFIG as QWEN1_5_4B
from .starcoder2_15b import CONFIG as STARCODER2_15B
from .gemma3_1b import CONFIG as GEMMA3_1B
from .qwen3_8b import CONFIG as QWEN3_8B
from .arctic_480b import CONFIG as ARCTIC_480B
from .phi3_5_moe import CONFIG as PHI3_5_MOE
from .jamba_v0_1 import CONFIG as JAMBA_V0_1
from .chameleon_34b import CONFIG as CHAMELEON_34B
from .falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        WHISPER_LARGE_V3,
        QWEN1_5_4B,
        STARCODER2_15B,
        GEMMA3_1B,
        QWEN3_8B,
        ARCTIC_480B,
        PHI3_5_MOE,
        JAMBA_V0_1,
        CHAMELEON_34B,
        FALCON_MAMBA_7B,
    ]
}


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; choose from {sorted(REGISTRY)}")


def arch_names() -> List[str]:
    return sorted(REGISTRY)


__all__ = [
    "REGISTRY", "get_config", "arch_names",
    "SHAPES", "InputShape", "shape_applicability",
]
