"""whisper-large-v3 — enc-dec audio transformer backbone.

[arXiv:2212.04356; unverified] — 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866.  Conv audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, T_enc, 1280).  Whisper uses LayerNorm,
GELU MLPs, biased projections, learned absolute positions (stubbed with
sinusoids) and no RoPE; embeddings tie to the LM head.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1500,       # 30 s of audio at 50 Hz after the conv stub
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    qkv_bias=True,
    use_rope=False,
    norm="layernorm",
    gated_mlp=False,
    tie_embeddings=True,
    causal=True,
    source="arXiv:2212.04356; unverified",
)
