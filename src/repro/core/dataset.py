"""Dataset construction: SnS traces → (features, labels) — paper §VI-A.

Features are computed from the SnS probe trace (:mod:`.features`), labels
from the simultaneously collected running-instance trace (:mod:`.labels`).
Two split protocols, both from the paper:

* ``split="random"`` — 75/25 random point split with a fixed seed (§VI-A,
  used for the prediction experiments of Figs. 7-8).
* ``split="pool"`` — 75/25 split at the *instance-type level* so no
  evaluation pool's trace is seen in training (§VI-E, used for the
  trace-driven simulation).

Point-wise models receive ``X[t] = (SR_t, UR_t, CUT_t)`` (or a feature
subset, Fig. 8); sequence models receive the trailing ``L`` cycles of the
same features, ``X[t] = F[t-L+1 : t+1]``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .collector import CampaignResult
from .features import FEATURE_NAMES, compute_features
from .labels import binary_availability, horizon_labels

__all__ = ["Dataset", "Standardizer", "build_dataset"]


@dataclasses.dataclass
class Standardizer:
    mean: np.ndarray
    std: np.ndarray

    @classmethod
    def fit(cls, x: np.ndarray) -> "Standardizer":
        flat = x.reshape(-1, x.shape[-1])
        std = flat.std(axis=0)
        std = np.where(std < 1e-8, 1.0, std)
        return cls(mean=flat.mean(axis=0), std=std)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        return (x - self.mean) / self.std


@dataclasses.dataclass
class Dataset:
    """Train/test split of SnS features and availability labels."""

    x_train: np.ndarray     # (n, F) or (n, L, F) for sequence models
    y_train: np.ndarray     # (n,)
    x_test: np.ndarray
    y_test: np.ndarray
    feature_names: Tuple[str, ...]
    horizon_cycles: int
    # bookkeeping for the trace-driven simulator (§VI-E)
    train_pools: Optional[np.ndarray] = None
    test_pools: Optional[np.ndarray] = None
    standardizer: Optional[Standardizer] = None


def _select_features(feats: np.ndarray, names: Sequence[str]) -> np.ndarray:
    idx = [FEATURE_NAMES.index(n) for n in names]
    return feats[..., idx]


def build_dataset(
    result: CampaignResult,
    *,
    window_minutes: float = 480.0,
    horizon_minutes: float = 0.0,
    feature_set: Sequence[str] = FEATURE_NAMES,
    sequence_length: Optional[int] = None,
    split: str = "random",
    train_fraction: float = 0.75,
    seed: int = 0,
    standardize: bool = True,
) -> Dataset:
    """Build a supervised dataset from a measurement campaign."""
    dt_minutes = result.interval / 60.0
    h = int(round(horizon_minutes / dt_minutes))

    feats = compute_features(result.s, result.n, window_minutes, dt_minutes)
    feats = _select_features(feats, feature_set)          # (pools, T, F)
    avail = binary_availability(result.running, result.n)  # (pools, T)
    y = horizon_labels(avail, h)                           # (pools, T - h)

    pools, t_total, n_feat = feats.shape
    t_lab = y.shape[-1]

    if sequence_length is None:
        # one point per (pool, cycle)
        x = feats[:, :t_lab, :]                            # (pools, T-h, F)
        start = 0
    else:
        # trailing L-cycle windows; first valid cycle index is L-1
        lseq = int(sequence_length)
        if lseq > t_lab:
            raise ValueError(f"sequence_length {lseq} > usable length {t_lab}")
        windows = np.stack(
            [feats[:, k : t_lab - lseq + 1 + k, :] for k in range(lseq)], axis=2
        )                                                   # (pools, T', L, F)
        x = windows
        start = lseq - 1
        y = y[:, start:]

    pool_idx = np.broadcast_to(
        np.arange(pools)[:, None], y.shape
    )

    if split == "random":
        rng = np.random.default_rng(seed)
        flat_x = x.reshape((-1,) + x.shape[2:])
        flat_y = y.reshape(-1)
        flat_p = pool_idx.reshape(-1)
        perm = rng.permutation(flat_y.shape[0])
        cut = int(train_fraction * len(perm))
        tr, te = perm[:cut], perm[cut:]
        xtr, ytr, xte, yte = flat_x[tr], flat_y[tr], flat_x[te], flat_y[te]
        ptr, pte = flat_p[tr], flat_p[te]
    elif split == "pool":
        rng = np.random.default_rng(seed)
        order = rng.permutation(pools)
        cut = max(1, int(train_fraction * pools))
        train_pools, test_pools = order[:cut], order[cut:]
        xtr = x[train_pools].reshape((-1,) + x.shape[2:])
        ytr = y[train_pools].reshape(-1)
        xte = x[test_pools].reshape((-1,) + x.shape[2:])
        yte = y[test_pools].reshape(-1)
        ptr = np.repeat(train_pools, y.shape[1])
        pte = np.repeat(test_pools, y.shape[1])
    else:
        raise ValueError(f"unknown split {split!r}")

    std = None
    if standardize:
        std = Standardizer.fit(xtr)
        xtr, xte = std(xtr), std(xte)

    return Dataset(
        x_train=xtr.astype(np.float32),
        y_train=ytr.astype(np.int32),
        x_test=xte.astype(np.float32),
        y_test=yte.astype(np.int32),
        feature_names=tuple(feature_set),
        horizon_cycles=h,
        train_pools=ptr,
        test_pools=pte,
        standardizer=std,
    )
