"""Fig. 6: Pearson correlation between SnS-derived and actual-instance-
derived features, per instance type (CDF medians)."""

from __future__ import annotations

import numpy as np

from repro.core import FEATURE_NAMES, compute_features

from .common import paper_campaign

PAPER_MEDIANS = {"SR": 0.40, "UR": 0.90, "CUT": 0.26}


def _pearson(a: np.ndarray, b: np.ndarray) -> float:
    if a.std() < 1e-12 or b.std() < 1e-12:
        return np.nan
    return float(np.corrcoef(a, b)[0, 1])


def run():
    c = paper_campaign()
    dt_min = c.interval / 60.0
    f_sns = compute_features(c.s, c.n, 480.0, dt_min)
    # "actual" features: same extraction applied to the running-node trace
    f_act = compute_features(np.minimum(c.running, c.n), c.n, 480.0, dt_min)

    corr = {name: [] for name in FEATURE_NAMES}
    excluded = 0
    for p in range(c.s.shape[0]):
        rs = [
            _pearson(f_sns[p, :, i], f_act[p, :, i])
            for i in range(len(FEATURE_NAMES))
        ]
        if any(np.isnan(r) for r in rs):
            excluded += 1  # no variation in one source (paper excludes these)
            continue
        for name, r in zip(FEATURE_NAMES, rs):
            corr[name].append(r)

    out = {"analyzed_types": len(corr["SR"]), "excluded_types": excluded}
    for name in FEATURE_NAMES:
        arr = np.asarray(corr[name])
        out[name] = {
            "median_r": round(float(np.median(arr)), 3),
            "frac_positive": round(float((arr > 0).mean()), 3),
            "paper_median_r": PAPER_MEDIANS[name],
        }
    return out


if __name__ == "__main__":
    print(run())
