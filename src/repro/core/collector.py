"""SnS Collector — paper §V, Fig. 4 (left module), at two scales.

Three components, mirrored from the paper's serverless deployment as an
in-process event-driven system with identical responsibilities:

* **RequestInvoker** — owns the target-pool list and the collection
  schedule (EventBridge analogue): triggers one collection cycle every
  ``interval`` seconds.
* **ParallelSpotRequester** — submits ``N`` concurrent spot requests per
  pool per cycle and records one probe outcome per request in the
  :class:`DataLake`.
* **RequestTerminator** — cancels accepted requests *immediately and
  independently of the requester* (the event-driven design in §V that
  keeps the provisioning window, and therefore cost, minimal).  A
  configurable ``terminator_delay`` models a slow/polling terminator; with
  delay ≥ the provider's provisioning duration, probes leak into RUNNING
  and start billing — the failure mode the paper's design eliminates
  (covered by tests at both engine scales).

Three engines share the protocol:

* :class:`SnSCollector` — the paper-faithful scalar engine: one
  ``submit_spot_request`` per pool per cycle, per-request
  :class:`~repro.core.lifecycle.SpotRequest` objects, an
  ``on_provisioning``-event terminator, and per-request
  :class:`ProbeRecord` rows.
* :class:`FleetCollector` — the SpotLake-scale engine: every pool probed
  per cycle in **one** batched admission call
  (``provider.submit_spot_requests``), outcomes written straight into
  preallocated ``(pools, cycles)`` matrices with no per-probe Python
  objects on the hot path; the terminator and its ``terminator_delay``
  leak are modelled at fleet granularity (held request cohorts, cancelled
  after the delay).
* the mesh-sharded engine (:mod:`repro.core.sharded`, via
  ``run_campaign(engine="sharded")``) — the 10^5–10^6-pool scale path:
  pool state device-sharded across a 1-D ``("pools",)`` mesh, one
  ``shard_map``-ped jitted step per cycle.

All engines ride the provider's counter-based per-pool RNG streams, so
:func:`run_campaign` produces **identical** ``S_t`` / ``running_t``
matrices, interruption event logs, and cost accounting from every engine
(the parity anchor, asserted in ``tests/test_fleet_campaign.py``,
``tests/test_sharded_campaign.py`` and
``benchmarks/campaign_throughput.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .faults import (
    OUTCOME_CAPACITY,
    OUTCOME_DEFERRED,
    OUTCOME_ERROR,
    OUTCOME_NAMES,
    OUTCOME_OK,
    OUTCOME_RATE_LIMITED,
    FaultPlan,
)
from .lifecycle import RequestState, SpotRequest
from .provider import ProbeCostMeter, RateLimitError, SimulatedProvider
from .retry import RetryController, RetryPolicy

__all__ = [
    "ProbeRecord",
    "DataLake",
    "SnSCollector",
    "FleetCollector",
    "CampaignResult",
    "CampaignCycle",
    "CampaignStream",
    "run_campaign",
]


@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    """Outcome of one SnS probe, as stored in the Data Lake (§V).

    ``outcome`` distinguishes *why* a probe was not accepted: capacity
    rejection (``OUTCOME_CAPACITY`` — real §V data), injected transient
    error (``OUTCOME_ERROR``), or a whole-call fault code — so
    fault-rejected probes are never folded into capacity rejections.
    """

    time: float
    pool_id: str
    accepted: bool
    cycle: int
    outcome: int = OUTCOME_OK


#: rows per DataLake column block — the hot-path retention unit
_LAKE_BLOCK = 4096


class DataLake:
    """Append-only store of probe outcomes with per-pool aggregation.

    Outcomes land in a fixed-size columnar block (interned pool codes,
    cycles, accept flags, timestamps), so aggregation is a vectorized
    ``np.add.at`` scatter rather than an O(records) Python loop.  What
    happens when the block fills depends on ``retain_records``:

    * ``True`` (the default) — the full block is archived and per-row
      :class:`ProbeRecord` objects are kept: the raw probe log grows with
      the campaign, as a data lake should.
    * ``False`` — the block is *folded* into a running
      ``(pool, cycle)`` success aggregate and reused: hot-path retention
      is genuinely bounded (one block plus the aggregate — no per-probe
      growth, which the old per-append Python lists never delivered).

    ``success_counts`` / ``__len__`` / ``append`` semantics are identical
    either way, and the aggregate is exact.
    """

    def __init__(self, *, retain_records: bool = True):
        self.retain_records = retain_records
        self.records: List[ProbeRecord] = []
        self._pool_code: Dict[str, int] = {}
        self._code_name: List[str] = []
        self._pcode = np.empty(_LAKE_BLOCK, dtype=np.int64)
        self._cycle = np.empty(_LAKE_BLOCK, dtype=np.int64)
        self._accepted = np.empty(_LAKE_BLOCK, dtype=bool)
        self._time = np.empty(_LAKE_BLOCK, dtype=np.float64)
        self._outcome = np.empty(_LAKE_BLOCK, dtype=np.uint8)
        self._fill = 0
        self._count = 0  # rows ever added (monotonic)
        self._blocks: List[tuple] = []          # archived full blocks
        self._agg = np.zeros((0, 0), dtype=np.int64)  # folded accept counts
        self._agg_neg: Dict[tuple, int] = {}    # folded negative-cycle rows
        # folded per-pool outcome-code histogram (pools, n_codes)
        self._agg_out = np.zeros((0, len(OUTCOME_NAMES)), dtype=np.int64)

    def add(
        self,
        time: float,
        pool_id: str,
        accepted: bool,
        cycle: int,
        outcome: Optional[int] = None,
    ) -> None:
        """Record one probe outcome (columnar hot path).

        ``outcome`` defaults to ``OUTCOME_OK`` for accepted probes and
        ``OUTCOME_CAPACITY`` for rejections — callers that know better
        (fault injection) pass the explicit ``OUTCOME_*`` code so the
        lake never folds faults into capacity rejections.
        """
        if outcome is None:
            outcome = OUTCOME_OK if accepted else OUTCOME_CAPACITY
        code = self._pool_code.get(pool_id)
        if code is None:
            code = self._pool_code[pool_id] = len(self._code_name)
            self._code_name.append(pool_id)
        i = self._fill
        self._pcode[i] = code
        self._cycle[i] = cycle
        self._accepted[i] = accepted
        self._time[i] = time
        self._outcome[i] = outcome
        self._fill = i + 1
        self._count += 1
        if self._fill == _LAKE_BLOCK:
            self._flush_block()
        if self.retain_records:
            self.records.append(
                ProbeRecord(time, pool_id, accepted, cycle, int(outcome))
            )

    def append(self, rec: ProbeRecord) -> None:
        self.add(rec.time, rec.pool_id, rec.accepted, rec.cycle, rec.outcome)

    def __len__(self) -> int:
        return self._count

    @property
    def nbytes(self) -> int:
        """Columnar buffer bytes (current block + archive + aggregate)."""
        block = (
            self._pcode.nbytes + self._cycle.nbytes
            + self._accepted.nbytes + self._time.nbytes
            + self._outcome.nbytes
        )
        arch = sum(sum(col.nbytes for col in blk) for blk in self._blocks)
        return block + arch + self._agg.nbytes + self._agg_out.nbytes

    def _flush_block(self) -> None:
        n = self._fill
        if self.retain_records:
            self._blocks.append(
                (
                    self._pcode[:n].copy(), self._cycle[:n].copy(),
                    self._accepted[:n].copy(), self._time[:n].copy(),
                    self._outcome[:n].copy(),
                )
            )
        else:
            self._fold(
                self._pcode[:n], self._cycle[:n],
                self._accepted[:n], self._outcome[:n],
            )
        self._fill = 0

    def _fold_outcomes(self, pcode: np.ndarray, outcome: np.ndarray) -> None:
        """Fold one block's outcome codes into the bounded per-pool histogram."""
        if pcode.size == 0:
            return
        need_r = int(pcode.max()) + 1
        r = self._agg_out.shape[0]
        if need_r > r:
            nr = max(r, 1)
            while nr < need_r:
                nr *= 2
            grown = np.zeros((nr, len(OUTCOME_NAMES)), dtype=np.int64)
            grown[:r] = self._agg_out
            self._agg_out = grown
        np.add.at(self._agg_out, (pcode, outcome.astype(np.int64)), 1)

    def _fold(
        self,
        pcode: np.ndarray,
        cycle: np.ndarray,
        acc: np.ndarray,
        outcome: np.ndarray,
    ) -> None:
        """Fold one block's accepts into the bounded running aggregate."""
        self._fold_outcomes(pcode, outcome)
        m = acc.astype(bool)
        pcode, cycle = pcode[m], cycle[m]
        neg = cycle < 0
        if neg.any():
            # negative cycles wrap at query time (a scalar-engine quirk);
            # too rare to earn array storage
            for c, cy in zip(pcode[neg], cycle[neg]):
                key = (int(c), int(cy))
                self._agg_neg[key] = self._agg_neg.get(key, 0) + 1
            pcode, cycle = pcode[~neg], cycle[~neg]
        if pcode.size == 0:
            return
        need_r = int(pcode.max()) + 1
        need_c = int(cycle.max()) + 1
        r, c = self._agg.shape
        if need_r > r or need_c > c:
            nr, nc = max(r, 1), max(c, 64)
            while nr < need_r:
                nr *= 2
            while nc < need_c:
                nc *= 2
            grown = np.zeros((nr, nc), dtype=np.int64)
            grown[:r, :c] = self._agg
            self._agg = grown
        np.add.at(self._agg, (pcode, cycle), 1)

    def success_counts(self, pool_ids: Sequence[str], n_cycles: int) -> np.ndarray:
        """Aggregate to ``S[pool, cycle]`` success-count matrix.

        Unknown pool ids and cycles ≥ ``n_cycles`` are dropped, matching
        the historical per-record loop (negative cycles wrap, as Python
        indexing did) — exact whether rows live in archived blocks, the
        current block, or the folded aggregate.
        """
        s = np.zeros((len(pool_ids), n_cycles), dtype=np.int64)
        if self._count == 0:
            return s
        index = {p: i for i, p in enumerate(pool_ids)}
        code_row = np.array(
            [index.get(name, -1) for name in self._code_name], dtype=np.int64
        )

        def scatter(pcode, cyc, acc):
            row = code_row[pcode]
            keep = acc.astype(bool) & (row >= 0) & (cyc < n_cycles)
            np.add.at(s, (row[keep], cyc[keep]), 1)

        for pcode, cyc, acc, _time, _out in self._blocks:
            scatter(pcode, cyc, acc)
        scatter(
            self._pcode[: self._fill],
            self._cycle[: self._fill],
            self._accepted[: self._fill],
        )
        if self._agg.size:
            r, c = self._agg.shape
            rows = code_row[: min(r, len(code_row))]
            known = rows >= 0
            cmax = min(c, n_cycles)
            # code → row is injective, so fancy-index add is safe
            s[rows[known], :cmax] += self._agg[: len(rows)][known, :cmax]
        for (code, cy), v in self._agg_neg.items():
            row = int(code_row[code]) if code < len(code_row) else -1
            if row >= 0 and cy < n_cycles:
                s[row, cy] += v  # negative: wraps (IndexError past -n_cycles)
        return s

    def outcome_counts(self, pool_ids: Sequence[str]) -> np.ndarray:
        """Per-pool outcome-code histogram ``(pools, n_codes)``.

        Columns follow :data:`~repro.core.faults.OUTCOME_NAMES`, so
        ``outcome_counts(ids)[:, OUTCOME_THROTTLED]`` is the throttled-call
        count per pool — fault-rejected probes stay distinguishable from
        capacity rejections in the interruption analysis (§V data lake).
        Exact whether rows live in archived blocks, the current block, or
        the folded aggregate.
        """
        out = np.zeros((len(pool_ids), len(OUTCOME_NAMES)), dtype=np.int64)
        if self._count == 0:
            return out
        index = {p: i for i, p in enumerate(pool_ids)}
        code_row = np.array(
            [index.get(name, -1) for name in self._code_name], dtype=np.int64
        )

        def scatter(pcode, outcome):
            row = code_row[pcode]
            keep = row >= 0
            np.add.at(out, (row[keep], outcome[keep].astype(np.int64)), 1)

        for pcode, _cyc, _acc, _time, outcome in self._blocks:
            scatter(pcode, outcome)
        scatter(self._pcode[: self._fill], self._outcome[: self._fill])
        if self._agg_out.size:
            r = self._agg_out.shape[0]
            rows = code_row[: min(r, len(code_row))]
            known = rows >= 0
            out[rows[known]] += self._agg_out[: len(rows)][known]
        return out

    # -- checkpointing -----------------------------------------------------

    def state_dict(self) -> dict:
        """Crash-consistent snapshot (plain numpy/python containers)."""
        n = self._fill
        return {
            "retain_records": self.retain_records,
            "code_name": list(self._code_name),
            "block": (
                self._pcode[:n].copy(), self._cycle[:n].copy(),
                self._accepted[:n].copy(), self._time[:n].copy(),
                self._outcome[:n].copy(),
            ),
            "count": self._count,
            "blocks": [tuple(col.copy() for col in blk) for blk in self._blocks],
            "agg": self._agg.copy(),
            "agg_neg": dict(self._agg_neg),
            "agg_out": self._agg_out.copy(),
            "records": [dataclasses.astuple(r) for r in self.records],
        }

    def restore(self, sd: dict) -> None:
        self.retain_records = sd["retain_records"]
        self._code_name = list(sd["code_name"])
        self._pool_code = {name: i for i, name in enumerate(self._code_name)}
        pcode, cyc, acc, time, outcome = sd["block"]
        n = len(pcode)
        self._pcode[:n] = pcode
        self._cycle[:n] = cyc
        self._accepted[:n] = acc
        self._time[:n] = time
        self._outcome[:n] = outcome
        self._fill = n
        self._count = sd["count"]
        self._blocks = [tuple(col.copy() for col in blk) for blk in sd["blocks"]]
        self._agg = sd["agg"].copy()
        self._agg_neg = dict(sd["agg_neg"])
        self._agg_out = sd["agg_out"].copy()
        self.records = [ProbeRecord(*t) for t in sd["records"]]


class SnSCollector:
    """Invoker + parallel requester + event-driven terminator (scalar
    engine: per-pool submissions, per-request objects)."""

    def __init__(
        self,
        provider: SimulatedProvider,
        pool_ids: Sequence[str],
        *,
        n_requests: int = 10,
        interval: float = 180.0,
        terminator_delay: float = 0.0,
        retain_records: bool = True,
        strict_rate_limit: bool = False,
    ):
        self.provider = provider
        self.pool_ids = list(pool_ids)
        self.n = int(n_requests)
        self.interval = float(interval)
        self.terminator_delay = float(terminator_delay)
        self.retain_records = retain_records
        # strict=True restores the historical raise-on-rate-limit call
        # style; either way a rate-limited pool counts 0 and records
        # nothing — the exact admit-what-fits observable of the fleet
        # path (asserted by the starvation-parity regression test)
        self.strict_rate_limit = bool(strict_rate_limit)
        self.lake = DataLake(retain_records=retain_records)
        self.probe_requests: List[SpotRequest] = []
        self._pending_cancel: List[SpotRequest] = []
        self._probing = False  # True only while the requester is submitting
        # Event-driven terminator: reacts to the provisioning lifecycle
        # event itself, independent of the requester control flow (§V).
        provider.on_provisioning(self._on_provisioning_event)

    # -- RequestTerminator -------------------------------------------------

    def _on_provisioning_event(self, req: SpotRequest) -> None:
        if not self._probing:
            return  # node-pool replenishment etc. — not ours to cancel
        if self.terminator_delay <= 0.0:
            self.provider.cancel(req)  # scoot immediately
        else:
            self._pending_cancel.append(req)  # slow-terminator model

    def _flush_delayed_cancels(self) -> None:
        for req in self._pending_cancel:
            self.provider.cancel(req)  # no-op if it already reached RUNNING
        self._pending_cancel.clear()
        if not self.retain_records:
            # keep only requests that actually leaked into RUNNING (the
            # only ones that can ever bill) — hot-path retention cap
            self.probe_requests = [
                r for r in self.probe_requests if r.run_started is not None
            ]

    # -- ParallelSpotRequester ----------------------------------------------

    def probe_pool(self, pool_id: str, cycle: int) -> int:
        """Submit N concurrent requests to one pool; return S_t."""
        s, _code, _nerr = self._probe_pool_ex(pool_id, cycle, OUTCOME_OK)
        return s

    def _probe_pool_ex(self, pool_id: str, cycle: int, fault_code: int):
        """Probe one pool under a whole-call fault code.

        Returns ``(successes, resolved_code, n_errors)``.  A faulted call
        is still billed (rate budget + API call) but never reaches
        admission; if the region budget is exhausted the rate limiter
        wins — nothing is charged, nothing is recorded (the historical
        rate-limited observable), and the code resolves to
        ``OUTCOME_RATE_LIMITED``.
        """
        prov = self.provider
        if fault_code != OUTCOME_OK:
            if not prov.charge_api_fault(pool_id, n=self.n):
                return 0, OUTCOME_RATE_LIMITED, 0
            for _ in range(self.n):
                self.lake.add(prov.now, pool_id, False, cycle, int(fault_code))
            return 0, int(fault_code), 0
        successes = 0
        self._probing = True
        try:
            reqs = prov.submit_spot_request(
                pool_id, n=self.n, strict=self.strict_rate_limit
            )
        except RateLimitError:
            reqs = []  # rate-limited cycle records total failure
        finally:
            self._probing = False
        if not reqs:
            return 0, OUTCOME_RATE_LIMITED, 0
        keep_all = self.retain_records
        err = prov.last_request_errors
        n_errors = 0
        for r, req in enumerate(reqs):
            accepted = req.state is not RequestState.REJECTED
            if accepted:
                successes += 1
                outcome = OUTCOME_OK
            elif err.size and err[r]:
                outcome = OUTCOME_ERROR
                n_errors += 1
            else:
                outcome = OUTCOME_CAPACITY
            self.lake.add(prov.now, pool_id, accepted, cycle, outcome)
            if keep_all or req.state is RequestState.PROVISIONING:
                self.probe_requests.append(req)
        return successes, OUTCOME_OK, n_errors

    # -- RequestInvoker -----------------------------------------------------

    def run_cycle(
        self,
        cycle: int,
        fault_codes: Optional[np.ndarray] = None,
        attempt: Optional[np.ndarray] = None,
        codes_out: Optional[np.ndarray] = None,
        errors_out: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """One collection cycle across all pools; returns S_t per pool.

        ``fault_codes`` carries per-pool whole-call ``OUTCOME_*`` codes
        (from :meth:`FaultPlan.call_codes`); ``attempt`` masks pools the
        retry control plane deferred this cycle (no API call, no lake
        record — ``OUTCOME_DEFERRED``).  ``codes_out`` / ``errors_out``
        receive the resolved per-pool codes and transient-error counts.
        """
        s = np.zeros(len(self.pool_ids), dtype=np.int64)
        for i, pool_id in enumerate(self.pool_ids):
            if attempt is not None and not attempt[i]:
                if codes_out is not None:
                    codes_out[i] = OUTCOME_DEFERRED
                continue
            fc = OUTCOME_OK if fault_codes is None else int(fault_codes[i])
            s[i], code, nerr = self._probe_pool_ex(pool_id, cycle, fc)
            if codes_out is not None:
                codes_out[i] = code
            if errors_out is not None:
                errors_out[i] = nerr
        if self.terminator_delay > 0.0:
            # slow terminator: cancels land only after the delay has passed
            self.provider.advance(self.provider.now + self.terminator_delay)
            self._flush_delayed_cancels()
        return s

    # -- accounting ----------------------------------------------------------

    def probe_compute_cost(self) -> float:
        """Total compute dollars billed to probe requests (≈ 0 by design)."""
        total = 0.0
        for req in self.probe_requests:
            if req.run_started is not None:
                price = self.provider.pool_config(req.pool_id).price_per_hour
                total += req.billed_seconds(self.provider.now) * price / 3600.0
        return total


class FleetCollector:
    """Batched SnS collector: the whole fleet per cycle in one admission
    call, matrices instead of per-probe objects.

    ``S_t`` and ``running_t`` land directly in preallocated
    ``(pools, cycles)`` matrices.  The event-driven terminator is modelled
    at fleet granularity: with ``terminator_delay == 0`` accepted probes
    are cancelled on provisioning acceptance inside the batched call
    (provider state untouched — the scoot); with a positive delay the
    accepted cohorts are *held*, the clock advances by the delay, and only
    then are the still-provisioning cohorts cancelled — probes that
    finished provisioning meanwhile leak into RUNNING and bill, exactly as
    in the scalar engine.
    """

    def __init__(
        self,
        provider: SimulatedProvider,
        pool_ids: Sequence[str],
        *,
        n_cycles: int,
        n_requests: int = 10,
        interval: float = 180.0,
        terminator_delay: float = 0.0,
    ):
        self.provider = provider
        self.pool_ids = list(pool_ids)
        self.idx = provider.pool_index(self.pool_ids)
        self.n = int(n_requests)
        self.interval = float(interval)
        self.terminator_delay = float(terminator_delay)
        self.n_cycles = int(n_cycles)
        self.s = np.zeros((len(self.pool_ids), self.n_cycles), dtype=np.int64)
        self.running = np.zeros_like(self.s)
        self.times = np.zeros(self.n_cycles)
        # per-cycle resolved outcome codes + injected-error counts
        self.codes = np.zeros((len(self.pool_ids), self.n_cycles), dtype=np.uint8)
        self.errors = np.zeros_like(self.s)
        # scope cost accounting to this campaign: leaked-probe rows
        # already on the provider's ledger belong to earlier collectors
        self._meter = ProbeCostMeter(provider)

    def run_cycle(
        self,
        cycle: int,
        fault_codes: Optional[np.ndarray] = None,
        attempt: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """One collection cycle: batched probe + ground-truth readout.

        ``fault_codes`` / ``attempt`` mirror the scalar collector: codes
        mark whole-call faults (billed, no admission), the attempt mask
        drops retry-deferred pools from the batch entirely (no API call,
        ``OUTCOME_DEFERRED`` in the codes matrix).
        """
        prov = self.provider
        self.times[cycle] = prov.now
        codes_col = self.codes[:, cycle]
        errs_col = self.errors[:, cycle]
        if attempt is None:
            idx, fc = self.idx, fault_codes
            codes_out, errs_out = codes_col, errs_col
        else:
            sel = np.nonzero(attempt)[0]
            codes_col[:] = OUTCOME_DEFERRED
            idx = self.idx[sel]
            fc = None if fault_codes is None else fault_codes[sel]
            codes_out = np.zeros(len(sel), dtype=np.uint8)
            errs_out = np.zeros(len(sel), dtype=np.int64)
        if self.terminator_delay <= 0.0:
            sub = prov.submit_spot_requests(
                idx, n=self.n,
                fault_codes=fc, codes_out=codes_out, errors_out=errs_out,
            )
        else:
            sub, cohorts = prov.submit_spot_requests(
                idx, n=self.n, hold=True,
                fault_codes=fc, codes_out=codes_out, errors_out=errs_out,
            )
            prov.advance(prov.now + self.terminator_delay)
            prov.cancel_cohorts(cohorts)  # leaked cohorts already RUNNING
        if attempt is None:
            s = sub
        else:
            s = np.zeros(len(self.pool_ids), dtype=np.int64)
            s[sel] = sub
            codes_col[sel] = codes_out
            errs_col[sel] = errs_out
        self.s[:, cycle] = s
        self.running[:, cycle] = prov.running_counts(self.idx)
        return s

    def probe_compute_cost(self) -> float:
        """$ billed to leaked probe instances (provider-side ledger,
        scoped via a monotonic-cursor meter to probes submitted since
        this collector was created)."""
        return self._meter.total()


@dataclasses.dataclass
class CampaignResult:
    pool_ids: List[str]
    times: np.ndarray          # (T,) cycle timestamps (seconds)
    s: np.ndarray              # (pools, T) SnS success counts
    running: np.ndarray        # (pools, T) actual running node counts
    n: int                     # requests per measurement point
    interval: float            # collection interval (seconds)
    interruptions: object      # InterruptionLog snapshot (lazy event view)
    probe_compute_cost: float  # $ billed to probes (≈ 0 by design)
    node_pool_cost: float      # $ billed to ground-truth running nodes
    api_calls: int
    engine: str = "scalar"     # which collector engine produced this
    codes: Optional[np.ndarray] = None   # (pools, T) uint8 OUTCOME_* codes
    errors: Optional[np.ndarray] = None  # (pools, T) injected-error counts
    valid: Optional[np.ndarray] = None   # (pools, T) bool: codes == OK
    fault_api_calls: int = 0   # API calls consumed by whole-call faults


#: per-cycle hook: (cycle index, timestamp, S_t vector) — the Data
#: Pipeline glue point (see ``repro.core.pipeline.run_campaign_pipeline``)
CycleHook = Callable[[int, float, np.ndarray], object]


@dataclasses.dataclass
class CampaignCycle:
    """One completed collection cycle, as yielded by :class:`CampaignStream`.

    ``s_t`` and ``running_t`` are **read-only** column views into the
    stream's preallocated ``(pools, cycles)`` matrices — zero-copy per
    cycle, and stable for the lifetime of the stream (campaign matrices
    are written once per column, never overwritten).  They are marked
    non-writeable because they alias the eventual ``CampaignResult``
    matrices: a consumer that wants to scribble must copy.
    """

    cycle: int
    time: float
    s_t: np.ndarray        # (pools,) int64 view — SnS success counts
    running_t: np.ndarray  # (pools,) int64 view — ground-truth node counts
    codes_t: Optional[np.ndarray] = None   # (pools,) uint8 OUTCOME_* view
    errors_t: Optional[np.ndarray] = None  # (pools,) injected-error counts

    @property
    def valid_t(self) -> Optional[np.ndarray]:
        """Pools whose ``s_t`` is live data this cycle (``codes == OK``).

        ``None`` when the stream runs without fault injection or retry
        control — every observation is valid, as before.
        """
        if self.codes_t is None:
            return None
        return self.codes_t == OUTCOME_OK


class CampaignStream:
    """Resumable, cycle-at-a-time form of :func:`run_campaign`.

    Owns the campaign setup (node pools declared, initial settle, collector
    construction) and exposes the measure loop as a stepper: each
    :meth:`step` advances the provider to the next collection timestamp,
    runs exactly one probe cycle on the chosen engine, lands the outcome in
    the preallocated ``S`` / ``running`` matrices, and returns a
    :class:`CampaignCycle` view — ``None`` once all cycles have run.  The
    stream is also iterable (``for cyc in stream``) and can be paused and
    resumed between steps: provider state only moves inside :meth:`step`.

    All three engines (``fleet`` / ``scalar`` / ``sharded``) run under the
    same contract and produce **bit-identical** matrices, interruption
    logs, and cost accounting; :func:`run_campaign` is a thin driver over
    this class, so streamed and batch campaigns cannot diverge.

    After exhaustion, :meth:`result` assembles the same
    :class:`CampaignResult` the batch driver returns.
    """

    def __init__(
        self,
        provider,
        *,
        pool_ids: Optional[Sequence[str]] = None,
        duration: float = 24 * 3600.0,
        interval: float = 180.0,
        n_requests: int = 10,
        node_pool_size: int = 10,
        terminator_delay: float = 0.0,
        engine: str = "fleet",
        retain_records: bool = True,
        shards: Optional[int] = None,
        pad_multiple: Optional[int] = None,
        fault_plan: Optional[FaultPlan] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ):
        if engine not in ("fleet", "scalar", "sharded"):
            raise ValueError(
                f"unknown engine {engine!r} (want 'fleet', 'scalar' or 'sharded')"
            )
        self.engine = engine
        self.interval = float(interval)
        self.n = int(n_requests)
        self.n_cycles = int(duration // interval)
        self.terminator_delay = float(terminator_delay)
        self.fault_plan = fault_plan
        self._next = 0
        self._result: Optional[CampaignResult] = None

        if engine == "sharded":
            from .sharded import ShardedProvider  # local: jax-dependent

            if isinstance(provider, ShardedProvider):
                sp = provider
            else:
                sp = ShardedProvider(
                    provider, shards=shards, pad_multiple=pad_multiple
                )
            self.pool_ids = (
                list(pool_ids) if pool_ids is not None else sp.pool_ids
            )
            if fault_plan is not None:
                # before the first advance: the initial settle must see the
                # same blackout gating (and hyper) as the other engines
                sp.set_fault_plan(fault_plan)
            sp.set_node_pools(self.pool_ids, node_pool_size)
            # Let pools acquire their initial nodes before the first
            # measurement (n_hint: share the compiled step with the probes).
            sp.advance(sp.now + 3 * sp.tick, n_hint=self.n)
            self.provider = sp
            self._idx = sp.pool_index(self.pool_ids)
            self._collector = None
            # scope leaked-probe cost to this campaign, like the fleet
            # collector does (rows appended earlier belong to others)
            self._meter = ProbeCostMeter(sp)
        else:
            self.pool_ids = (
                list(pool_ids) if pool_ids is not None else provider.pool_ids
            )
            if fault_plan is not None:
                provider.set_fault_plan(fault_plan)
            for pid in self.pool_ids:
                provider.set_node_pool(pid, node_pool_size)
            # Let pools acquire their initial nodes before the first cycle.
            provider.advance(provider.now + 3 * provider.tick)
            self.provider = provider
            self._idx = provider.pool_index(self.pool_ids)
            if engine == "fleet":
                self._collector = FleetCollector(
                    provider,
                    self.pool_ids,
                    n_cycles=self.n_cycles,
                    n_requests=self.n,
                    interval=self.interval,
                    terminator_delay=terminator_delay,
                )
            else:
                self._collector = SnSCollector(
                    provider,
                    self.pool_ids,
                    n_requests=self.n,
                    interval=self.interval,
                    terminator_delay=terminator_delay,
                    retain_records=retain_records,
                )
        if engine == "fleet":
            # the collector already owns the preallocated matrices — alias
            self.times = self._collector.times
            self.s = self._collector.s
            self.running = self._collector.running
            self.codes = self._collector.codes
            self.errors = self._collector.errors
        else:
            self.times = np.zeros(self.n_cycles)
            self.s = np.zeros((len(self.pool_ids), self.n_cycles), np.int64)
            self.running = np.zeros_like(self.s)
            self.codes = np.zeros(
                (len(self.pool_ids), self.n_cycles), dtype=np.uint8
            )
            self.errors = np.zeros_like(self.s)
        self._ctrl = (
            None
            if retry_policy is None
            else RetryController(
                len(self.pool_ids),
                retry_policy,
                region_code=self.provider.region_code[self._idx],
                n_requests=self.n,
            )
        )
        self._t0 = self.provider.now

    # -- stepping ------------------------------------------------------------

    @property
    def cycles_done(self) -> int:
        """Completed cycles so far (also the next cycle index)."""
        return self._next

    @property
    def done(self) -> bool:
        return self._next >= self.n_cycles

    def step(self) -> Optional[CampaignCycle]:
        """Run ONE collection cycle; ``None`` once the campaign is over."""
        c = self._next
        if c >= self.n_cycles:
            return None
        self._next = c + 1
        when = self._t0 + c * self.interval
        plan = self.fault_plan
        ctrl = self._ctrl
        chaos = plan is not None or ctrl is not None
        attempt = codes = None
        if chaos:
            # Whole-call faults and retry gating are evaluated host-side
            # ONCE per cycle, identically for every engine — so the clock
            # must sit at the measurement timestamp first.  The sharded
            # engine's subsequent probe_cycle(when) then adds zero ticks.
            if self.engine == "sharded":
                self.provider.advance(when, n_hint=self.n)
            else:
                self.provider.advance(when)
            if ctrl is not None:
                attempt = ctrl.attempt_mask(
                    c, region_budget=self.provider.rate_budget()
                )
            if plan is not None:
                codes = plan.call_codes(
                    self.provider.now, c, self._idx, self.provider.region_code
                )
        if self.engine == "fleet":
            if not chaos:
                self.provider.advance(when)
            self._collector.run_cycle(c, fault_codes=codes, attempt=attempt)
        elif self.engine == "scalar":
            if not chaos:
                self.provider.advance(when)
            self.times[c] = self.provider.now
            self.s[:, c] = self._collector.run_cycle(
                c,
                fault_codes=codes,
                attempt=attempt,
                codes_out=self.codes[:, c],
                errors_out=self.errors[:, c],
            )
            for i, pid in enumerate(self.pool_ids):
                self.running[i, c] = self.provider.running_count(pid)
        else:  # sharded: advance + probe in shard_map-ped device steps
            counts, run_t = self.provider.probe_cycle(
                when,
                self._idx,
                self.n,
                self.terminator_delay,
                fault_codes=codes,
                attempt=attempt,
                codes_out=self.codes[:, c] if chaos else None,
                errors_out=self.errors[:, c] if chaos else None,
            )
            # the measurement timestamp, not the post-terminator-delay clock
            self.times[c] = self.provider.probe_time
            self.s[:, c] = counts
            self.running[:, c] = run_t
        if ctrl is not None:
            att = (
                attempt
                if attempt is not None
                else np.ones(len(self.pool_ids), dtype=bool)
            )
            ctrl.observe(c, att, self.codes[:, c])
        s_t = self.s[:, c]
        s_t.flags.writeable = False
        running_t = self.running[:, c]
        running_t.flags.writeable = False
        codes_t = errors_t = None
        if chaos:
            codes_t = self.codes[:, c]
            codes_t.flags.writeable = False
            errors_t = self.errors[:, c]
            errors_t.flags.writeable = False
        return CampaignCycle(cycle=c, time=float(self.times[c]),
                             s_t=s_t, running_t=running_t,
                             codes_t=codes_t, errors_t=errors_t)

    def __iter__(self):
        while True:
            cyc = self.step()
            if cyc is None:
                return
            yield cyc

    # -- finalisation --------------------------------------------------------

    def result(self) -> CampaignResult:
        """The campaign's :class:`CampaignResult` (requires exhaustion —
        identical to what :func:`run_campaign` returns)."""
        if self._result is not None:
            return self._result
        if not self.done:
            raise RuntimeError(
                f"campaign stream not exhausted: {self._next} of "
                f"{self.n_cycles} cycles consumed"
            )
        if self.engine == "sharded":
            # flushes deferred leak records; 0 for the event-driven
            # terminator, which never leaks
            probe_cost = self._meter.total()
        else:
            probe_cost = self._collector.probe_compute_cost()
        # node-pool compute cost: integrate running counts over the campaign
        prices = np.array(
            [self.provider.pool_config(pid).price_per_hour for pid in self.pool_ids]
        )
        node_cost = float(
            (self.running.sum(axis=1) * (self.interval / 3600.0) * prices).sum()
        )
        chaos = self.fault_plan is not None or self._ctrl is not None
        self._result = CampaignResult(
            pool_ids=self.pool_ids,
            times=self.times,
            s=self.s,
            running=self.running,
            n=self.n,
            interval=self.interval,
            interruptions=self.provider.interruptions.snapshot(),
            probe_compute_cost=probe_cost,
            node_pool_cost=node_cost,
            api_calls=self.provider.api_calls,
            engine=self.engine,
            codes=self.codes if chaos else None,
            errors=self.errors if chaos else None,
            valid=(self.codes == OUTCOME_OK) if chaos else None,
            fault_api_calls=self.provider.fault_api_calls,
        )
        return self._result

    # -- crash-consistent checkpoints ----------------------------------------

    def state_dict(self) -> dict:
        """Crash-consistent campaign snapshot at a cycle boundary.

        Captures provider state (ledgers, RNG counters, rate windows),
        campaign matrices, the retry control plane, and the probe-cost
        meter cursor — everything needed so that *restore + drain* is
        bit-identical to an uninterrupted run on every engine.  Sharded
        device state is flushed and fetched to host at the boundary.
        Only call between :meth:`step` calls (the stream never holds
        in-flight state across steps).
        """
        sd = {
            "engine": self.engine,
            "next": self._next,
            "t0": self._t0,
            "times": self.times.copy(),
            "s": self.s.copy(),
            "running": self.running.copy(),
            "codes": self.codes.copy(),
            "errors": self.errors.copy(),
            "provider": self.provider.state_dict(),
            "retry": None if self._ctrl is None else self._ctrl.state_dict(),
        }
        if self.engine == "sharded":
            sd["meter"] = {"since": self._meter.since, "until": self._meter.until}
        else:
            if self.engine == "fleet":
                m = self._collector._meter
                sd["meter"] = {"since": m.since, "until": m.until}
            else:
                sd["lake"] = self._collector.lake.state_dict()
        return sd

    def restore(self, sd: dict) -> None:
        """Restore a :meth:`state_dict` snapshot onto a freshly
        constructed, identically configured stream (same provider seed
        and campaign parameters)."""
        if sd["engine"] != self.engine:
            raise ValueError(
                f"checkpoint is for engine {sd['engine']!r}, not {self.engine!r}"
            )
        self._next = sd["next"]
        self._t0 = sd["t0"]
        self.times[:] = sd["times"]
        self.s[:] = sd["s"]
        self.running[:] = sd["running"]
        self.codes[:] = sd["codes"]
        self.errors[:] = sd["errors"]
        self.provider.restore(sd["provider"])
        if sd["retry"] is not None:
            self._ctrl.restore(sd["retry"])
        if self.engine == "sharded":
            self._meter.since = sd["meter"]["since"]
            self._meter.until = sd["meter"]["until"]
        elif self.engine == "fleet":
            self._collector._meter.since = sd["meter"]["since"]
            self._collector._meter.until = sd["meter"]["until"]
        else:
            self._collector.lake.restore(sd["lake"])
            # scoot probe requests never bill (no run_started) — the
            # object log is not part of the crash-consistent surface
            self._collector.probe_requests = []
            self._collector._pending_cancel = []
        self._result = None


def run_campaign(
    provider: SimulatedProvider,
    *,
    pool_ids: Optional[Sequence[str]] = None,
    duration: float = 24 * 3600.0,
    interval: float = 180.0,
    n_requests: int = 10,
    node_pool_size: int = 10,
    terminator_delay: float = 0.0,
    engine: str = "fleet",
    retain_records: bool = True,
    on_cycle: Optional[CycleHook] = None,
    fault_plan: Optional[FaultPlan] = None,
    retry_policy: Optional[RetryPolicy] = None,
) -> CampaignResult:
    """Run a §III-B style campaign: node pools + SnS probing side by side.

    Every ``interval`` seconds each pool in ``pool_ids`` (default: the
    provider's whole fleet) is probed with ``n_requests`` concurrent spot
    requests while ``node_pool_size`` ground-truth nodes per pool record
    what was actually obtainable; the result carries the ``S_t`` /
    ``running_t`` matrices, interruption log, and cost accounting.

    Args:
      engine: which collector implementation runs the campaign — all
        three produce **bit-identical** results from the same provider
        seed (they share the counter-based per-pool RNG streams):

        * ``"fleet"`` (default) — batched numpy: one admission call per
          cycle for the whole fleet, matrices instead of per-probe
          objects.  The right choice up to ~10^4 pools on one host.
        * ``"scalar"`` — the paper-faithful per-pool object path
          (``SpotRequest`` lifecycles, event-driven terminator,
          per-probe Data-Lake rows).  Readable, O(pools) Python per
          cycle; use it to study per-request behaviour.
        * ``"sharded"`` — the mesh-sharded JAX engine
          (:mod:`repro.core.sharded`): per-pool state lives device-
          sharded on a 1-D ``("pools",)`` mesh and each cycle is one
          ``shard_map``-ped step — the 10^5–10^6-pool scale path.
          Requires a *fresh* provider.
      terminator_delay: seconds the Request Terminator lags behind
        provisioning acceptance.  ``0`` (default) models the paper's
        event-driven terminator: accepted probes are cancelled while
        still provisioning and never bill.  Positive values model a
        slow/polling terminator — probes that finish provisioning within
        the delay leak into RUNNING and show up in
        ``probe_compute_cost`` (the failure mode §V's design
        eliminates).  Supported by all three engines.
      retain_records: keep per-probe ``ProbeRecord`` objects /
        ``SpotRequest`` views on the scalar engine (switch off at fleet
        scale; aggregates stay exact).
      on_cycle: hook invoked after every collection cycle with
        ``(cycle, time, S_t)`` — the Data-Pipeline glue point used by
        :func:`repro.core.pipeline.run_campaign_pipeline`.  ``S_t`` is
        the cycle's measurement (at the measurement timestamp, not any
        post-terminator-delay clock), identical across engines.
      fault_plan: optional deterministic :class:`FaultPlan` — throttle
        bursts, blackouts, timeouts, transient request errors.  All
        engines inject *identical* faults (pure functions of the plan
        seed), so the bit-identity contract holds under chaos too; the
        result gains ``codes`` / ``errors`` / ``valid`` matrices and
        ``fault_api_calls``.
      retry_policy: optional :class:`RetryPolicy` — per-pool capped
        exponential backoff with deterministic jitter, a per-region
        token bucket, and per-pool circuit breakers; deferred cycles
        surface as ``OUTCOME_DEFERRED`` (no API charge).

    This is a thin driver over :class:`CampaignStream` — use the stream
    directly for cycle-at-a-time consumption (online serving, dataset
    streaming); both paths are bit-identical by construction.
    """
    stream = CampaignStream(
        provider,
        pool_ids=pool_ids,
        duration=duration,
        interval=interval,
        n_requests=n_requests,
        node_pool_size=node_pool_size,
        terminator_delay=terminator_delay,
        engine=engine,
        retain_records=retain_records,
        fault_plan=fault_plan,
        retry_policy=retry_policy,
    )
    for cyc in stream:
        if on_cycle is not None:
            on_cycle(cyc.cycle, cyc.time, cyc.s_t)
    return stream.result()
