# Tier-1 verification — identical to what CI runs.
#   make verify   : full test suite + pipeline-throughput smoke
#   make test     : test suite only
#   make bench    : full pipeline-throughput benchmark (asserts >= 50x)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: verify test bench

verify: test
	python benchmarks/pipeline_throughput.py --smoke

test:
	python -m pytest -x -q

bench:
	python benchmarks/pipeline_throughput.py
