"""Spot request lifecycle state machine — paper Fig. 1.

The structural property SnS exploits: a spot request's outcome is decided
*before* the instance reaches ``RUNNING`` (the only state that bills
compute).  The lifecycle here is shared by both ground-truth node-pool
instances (which proceed to ``RUNNING`` and may be ``INTERRUPTED``) and SnS
probes (which are ``CANCELLED`` during ``PROVISIONING`` by the event-driven
Request Terminator).

States and legal transitions::

    PENDING ──► REJECTED                      (capacity check failed)
    PENDING ──► PROVISIONING                  (capacity check passed)
    PROVISIONING ──► CANCELLED                (SnS terminator scoots)
    PROVISIONING ──► RUNNING                  (allocation completed)
    RUNNING ──► INTERRUPTED                   (provider reclaims capacity)
    RUNNING ──► TERMINATED                    (user-initiated stop)

Billing accrues only in ``RUNNING``; this is asserted throughout the test
suite and is what makes SnS "near-zero instance cost".
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional, Tuple


class RequestState(enum.Enum):
    PENDING = "pending"
    REJECTED = "rejected"
    PROVISIONING = "provisioning"
    CANCELLED = "cancelled"
    RUNNING = "running"
    INTERRUPTED = "interrupted"
    TERMINATED = "terminated"


#: state -> states reachable from it
_TRANSITIONS = {
    RequestState.PENDING: {RequestState.REJECTED, RequestState.PROVISIONING},
    RequestState.REJECTED: set(),
    RequestState.PROVISIONING: {RequestState.CANCELLED, RequestState.RUNNING},
    RequestState.CANCELLED: set(),
    RequestState.RUNNING: {RequestState.INTERRUPTED, RequestState.TERMINATED},
    RequestState.INTERRUPTED: set(),
    RequestState.TERMINATED: set(),
}

TERMINAL_STATES = frozenset(s for s, nxt in _TRANSITIONS.items() if not nxt)

_request_counter = itertools.count()


class IllegalTransition(RuntimeError):
    pass


@dataclasses.dataclass
class SpotRequest:
    """One spot instance request and its lifecycle history."""

    pool_id: str
    submit_time: float
    request_id: int = dataclasses.field(default_factory=lambda: next(_request_counter))
    state: RequestState = RequestState.PENDING
    history: List[Tuple[float, RequestState]] = dataclasses.field(default_factory=list)
    run_started: Optional[float] = None
    run_ended: Optional[float] = None

    def __post_init__(self):
        self.history.append((self.submit_time, RequestState.PENDING))

    def transition(self, new_state: RequestState, time: float) -> None:
        if new_state not in _TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"request {self.request_id}: {self.state.value} -> {new_state.value}"
            )
        self.state = new_state
        self.history.append((time, new_state))
        if new_state is RequestState.RUNNING:
            self.run_started = time
        elif new_state in (RequestState.INTERRUPTED, RequestState.TERMINATED):
            self.run_ended = time

    # -- billing ---------------------------------------------------------
    def billed_seconds(self, now: Optional[float] = None) -> float:
        """Compute-billed time: only the RUNNING interval counts."""
        if self.run_started is None:
            return 0.0
        end = self.run_ended if self.run_ended is not None else now
        if end is None:
            raise ValueError("request still running; pass `now`")
        return max(0.0, end - self.run_started)

    @property
    def is_terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def time_in_state(self, state: RequestState) -> float:
        """Total time spent in `state` (for terminal analysis/debugging)."""
        total = 0.0
        for (t0, s0), (t1, _) in zip(self.history, self.history[1:]):
            if s0 is state:
                total += t1 - t0
        return total
