"""Selective-scan (Mamba-1) Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of warp-level
parallelism, the channel axis is tiled over the grid (each program owns a
``block_d`` slab of channels) and the **SSM state stays resident in VMEM
scratch across sequence chunks** — the grid's innermost axis walks chunks
sequentially, so the (block_d × N) state never round-trips to HBM.  This
is exactly the fusion the XLA chunked-`associative_scan` path cannot
express (it materialises (B, S, D, N) discretisation tensors in HBM;
~17 GB/device at falcon-mamba's train_4k shape).

Within a chunk the recurrence runs as a `fori_loop` over timesteps on the
VPU; all loads/stores are (chunk × block_d) and (block_d × N) tiles.

grid = (B, D/block_d, S/chunk)   [chunk axis innermost/sequential]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
    y_ref, hout_ref,
    h_scr,
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)       # (chunk, bd)
    dt = dt_ref[...].astype(jnp.float32)     # (chunk, bd)
    a = a_ref[...].astype(jnp.float32)       # (bd, N)
    b = b_ref[...].astype(jnp.float32)       # (chunk, N)
    c = c_ref[...].astype(jnp.float32)       # (chunk, N)

    def step(t, carry):
        h, ys = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, axis=0)[0]   # (bd,)
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, axis=0)[0]     # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(c, t, 1, axis=0)[0]
        decay = jnp.exp(dt_t[:, None] * a)                         # (bd, N)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = (h * c_t[None, :]).sum(axis=1)                       # (bd,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_t[None], t, axis=0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros_like(x)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = h
    y_ref[...] = ys.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _final():
        hout_ref[...] = h.astype(hout_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_d", "chunk", "interpret")
)
def selective_scan(
    x: jnp.ndarray,      # (B, S, D)
    dt: jnp.ndarray,     # (B, S, D)
    a: jnp.ndarray,      # (D, N)
    b: jnp.ndarray,      # (B, S, N)
    c: jnp.ndarray,      # (B, S, N)
    h0: jnp.ndarray,     # (B, D, N)
    *,
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = False,
):
    bsz, s, d = x.shape
    n = a.shape[1]
    block_d = min(block_d, d)
    chunk = min(chunk, s)
    assert d % block_d == 0 and s % chunk == 0
    n_chunks = s // chunk
    grid = (bsz, d // block_d, n_chunks)

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)

    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((None, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((block_d, n), lambda ib, id_, ic: (id_, 0)),
            pl.BlockSpec((None, chunk, n), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((None, chunk, n), lambda ib, id_, ic: (ib, ic, 0)),
            pl.BlockSpec((None, block_d, n), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, block_d), lambda ib, id_, ic: (ib, ic, id_)),
            pl.BlockSpec((None, block_d, n), lambda ib, id_, ic: (ib, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, h0)
    return y, h_out
