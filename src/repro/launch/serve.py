"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched prefill + decode on a (reduced) config; demonstrates the public
serving API end to end on CPU.
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import arch_names, get_config
from repro.models import api
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=arch_names())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled_down()
    params = api.init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32,
    )}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32,
        )
    t0 = time.time()
    out = generate(cfg, params, batch, max_new_tokens=args.max_new_tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {out.shape} in {dt:.1f}s")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
