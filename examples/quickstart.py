"""Quickstart: the full SnS pipeline in one page.

1. simulate a spot fleet and probe it with SnS (near-zero probe cost),
2. compute SR/UR/CUT features incrementally (Algorithm 1),
3. train the XGBoost-style predictor, evaluate F1-macro at two horizons,
4. take a few training steps of a small LM with the production train step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    SimulatedProvider,
    build_dataset,
    default_fleet,
    evaluate,
    fit_predictor,
    run_campaign,
)
from repro.models import api
from repro.train import OptConfig, init_opt_state, make_train_step, synthetic_batch


def main(pools=16, hours=12.0, train_steps=5):
    # -- 1. probe a simulated spot fleet ---------------------------------
    fleet = default_fleet(pools, seed=1)
    provider = SimulatedProvider(fleet, seed=2)
    campaign = run_campaign(provider, duration=hours * 3600.0)
    print(f"probed {len(campaign.pool_ids)} pools x {campaign.s.shape[1]} cycles "
          f"({campaign.api_calls} requests)")
    print(f"probe compute cost: ${campaign.probe_compute_cost:.2f} "
          f"(node pools would cost ${campaign.node_pool_cost:.2f})")

    # -- 2 & 3. features -> predictor ------------------------------------
    for horizon in (0, 30):
        ds = build_dataset(campaign, window_minutes=240, horizon_minutes=horizon)
        model = fit_predictor("xgb", ds)
        rep = evaluate(model, ds)
        print(f"horizon {horizon:2d} min: F1-macro {rep['f1_macro']:.3f} "
              f"(unavailable-class F1 {rep['f1_unavailable']:.3f})")

    # -- 4. a few LM training steps --------------------------------------
    cfg = get_config("gemma3-1b").scaled_down()
    params = api.init_params(cfg, seed=0)
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3), remat="none"))
    batch = synthetic_batch(cfg, batch=4, seq=64, seed=0)
    for i in range(train_steps):
        params, opt_state, metrics = step(params, opt_state, batch)
        print(f"step {i}: loss {float(metrics['loss']):.3f} "
              f"grad_norm {float(metrics['grad_norm']):.2f}")


if __name__ == "__main__":
    main()
