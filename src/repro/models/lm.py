"""Causal LM assembly: embed → scanned layer stack → norm → logits.

Handles every decoder-only family in the zoo through a *block pattern*:
the per-layer structure sequence ``(mixer, is_moe)`` is folded to its
smallest period ``p`` and the stack runs as ``lax.scan`` over
``n_layers / p`` super-blocks, each super-block unrolling ``p``
structurally distinct positions (dense archs: p = 1; jamba: p = 8).
Scalar-only heterogeneity (gemma's 5:1 local:global window) rides along
as a scanned per-layer array, keeping the traced HLO to one super-block.

Three entry points per the assignment's shape grid:

* :func:`train_loss`    — full forward + causal LM cross-entropy (train_*).
* :func:`prefill`       — forward that also returns KV/SSM caches and the
  last position's logits (prefill_*).
* :func:`decode_step`   — one-token step against sequence-sharded caches
  (decode_* / long_*), flash-decoding across the `model` mesh axis.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from .blocks import init_layer, layer_forward, layer_kinds
from .common import (
    GLOBAL_WINDOW, ModelConfig, apply_norm, init_dense, make_norm_params,
    shard_map,
)

__all__ = [
    "block_pattern",
    "init_params",
    "forward",
    "logits_from_hidden",
    "train_loss",
    "prefill",
    "init_cache",
    "decode_step",
]


# --------------------------------------------------------------------------
# Block pattern
# --------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> Tuple[List[Tuple[str, bool]], int]:
    """Smallest repeating (mixer, moe) pattern and its repeat count."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            return kinds[:p], n // p
    return kinds, 1  # fully heterogeneous: one "repeat" of everything


def _shard(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _norm_axes(data_axes):
    """() / None -> None (replicated batch, e.g. long_500k's B=1)."""
    return tuple(data_axes) if data_axes else None


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict:
    key = jax.random.PRNGKey(seed)
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    pattern, repeats = block_pattern(cfg)

    params: Dict = {
        "embedding": init_dense(
            k_embed, (cfg.vocab_size, cfg.d_model), cfg.pdtype, fan_in=cfg.d_model
        ),
        "final_norm": make_norm_params(cfg, (cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.pdtype, fan_in=cfg.d_model
        )

    blocks = []
    for pos, (mixer, moe, _window) in enumerate(pattern):
        def one(rep_key):
            return init_layer(rep_key, cfg, mixer=mixer, use_moe=moe)

        keys = jax.random.split(jax.random.fold_in(k_layers, pos), repeats)
        blocks.append(jax.vmap(one)(keys))
    params["layers"] = blocks
    return params


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _stack_forward(
    cfg: ModelConfig,
    blocks,
    x: jnp.ndarray,
    *,
    mesh=None,
    data_axes=("data",),
    q_chunk=1024,
    mamba_chunk=64,
    remat: str = "none",
):
    pattern, repeats = block_pattern(cfg)
    dp_spec = P(data_axes, None, None)

    def one_layer(p_slice, h, pos):
        mixer, moe, window = pattern[pos]
        return layer_forward(
            cfg, p_slice, h,
            mixer=mixer, use_moe=moe, window=window,
            mesh=mesh, data_axes=data_axes,
            q_chunk=q_chunk, mamba_chunk=mamba_chunk,
        )

    # remat granularity is PER LAYER, not per super-block: long unrolled
    # patterns (gemma's 26 distinct positions) would otherwise hold every
    # layer's recomputed activations live at once in the backward pass
    if remat == "full":
        layer_fn = jax.checkpoint(one_layer, prevent_cse=False,
                                  static_argnums=(2,))
    elif remat == "dots":
        layer_fn = jax.checkpoint(
            one_layer,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False, static_argnums=(2,),
        )
    else:
        layer_fn = one_layer

    def body(h, block_slices):
        for pos in range(len(pattern)):
            h = layer_fn(block_slices[pos], h, pos)
        h = _shard(h, mesh, dp_spec)
        return h, None

    x, _ = jax.lax.scan(body, x, blocks)
    return x


def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: jnp.ndarray,            # (B, S) int32
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
    remat: str = "none",
) -> jnp.ndarray:
    """Token ids → final hidden states (B, S, d)."""
    data_axes = _norm_axes(data_axes)
    x = params["embedding"][tokens].astype(cfg.adtype)
    x = _shard(x, mesh, P(data_axes, None, None))
    x = _stack_forward(
        cfg, params["layers"], x,
        mesh=mesh, data_axes=data_axes,
        q_chunk=q_chunk, mamba_chunk=mamba_chunk, remat=remat,
    )
    return apply_norm(cfg, params["final_norm"], x)


def logits_from_hidden(cfg: ModelConfig, params: Dict, h: jnp.ndarray) -> jnp.ndarray:
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head.astype(h.dtype)


def train_loss(
    cfg: ModelConfig,
    params: Dict,
    tokens: jnp.ndarray,            # (B, S)
    labels: jnp.ndarray,            # (B, S) — next-token targets
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    remat: str = "dots",
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
) -> jnp.ndarray:
    data_axes = _norm_axes(data_axes)
    h = forward(
        cfg, params, tokens,
        mesh=mesh, data_axes=data_axes, remat=remat,
        q_chunk=q_chunk, mamba_chunk=mamba_chunk,
    )
    return chunked_cross_entropy(cfg, params, h, labels, mesh=mesh,
                                 data_axes=data_axes)


def chunked_cross_entropy(cfg, params, h, labels, *, mesh=None,
                          data_axes=None, seq_chunk: int = 512):
    """Sequence-chunked CE: full (S, V) f32 logits never materialise.

    Each chunk's logits are computed, reduced to (logsumexp, gold) and
    dropped; the chunk body is rematerialised in the backward pass.  At
    gemma's 262k vocab this removes ~0.8 TB/device/step of logits traffic
    versus whole-sequence CE (§Perf hillclimb record)."""
    b, s, d = h.shape
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    n_chunks = max(1, s // seq_chunk)
    csz = s // n_chunks
    assert s % n_chunks == 0

    def one_chunk(i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * csz, csz, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * csz, csz, axis=1)
        logits = (hc @ head.astype(hc.dtype)).astype(jnp.float32)
        if mesh is not None:
            logits = _shard(logits, mesh, P(data_axes, None, "model"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    if n_chunks == 1:
        total = one_chunk(0)
    else:
        totals = jax.lax.map(
            jax.checkpoint(one_chunk, prevent_cse=False), jnp.arange(n_chunks)
        )
        total = totals.sum()
    return total / (b * s)


# --------------------------------------------------------------------------
# Serving: prefill + decode
# --------------------------------------------------------------------------

def _cache_spec(cfg, data_axes):
    """PartitionSpec templates for one pattern position's cache slice."""
    return {
        "attn": {
            "k": P(None, data_axes, "model", None, None),  # (R, B, S, K, hd)
            "v": P(None, data_axes, "model", None, None),
        },
        "mamba": {
            "ssm": P(None, data_axes, "model", None),      # (R, B, din, n)
            "conv": P(None, data_axes, None, "model"),     # (R, B, dc-1, din)
        },
    }


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Dict:
    """Empty caches, one entry per pattern position, stacked over repeats.

    Attention caches are sharded (batch→data, seq→model): sequence-sharding
    is what lets 32k/500k caches fit (flash-decoding combines shards).
    """
    data_axes = _norm_axes(data_axes)
    pattern, repeats = block_pattern(cfg)
    k, hd, dc = cfg.n_kv_heads, cfg.hd, cfg.d_conv
    entries = []
    for mixer, _moe, _w in pattern:
        if mixer == "attn":
            shape = (repeats, batch, max_seq, k, hd)
            entry = {
                "k": jnp.zeros(shape, cfg.adtype),
                "v": jnp.zeros(shape, cfg.adtype),
            }
            spec = _cache_spec(cfg, data_axes)["attn"]
        else:
            entry = {
                "ssm": jnp.zeros((repeats, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((repeats, batch, dc - 1, cfg.d_inner), cfg.adtype),
            }
            spec = _cache_spec(cfg, data_axes)["mamba"]
        if mesh is not None:
            entry = {
                kk: _shard(vv, mesh, spec[kk]) for kk, vv in entry.items()
            }
        entries.append(entry)
    return {"layers": entries, "len": jnp.zeros((), jnp.int32)}


def prefill(
    cfg: ModelConfig,
    params: Dict,
    tokens: jnp.ndarray,            # (B, S)
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
    max_seq: Optional[int] = None,
    q_chunk: int = 1024,
    mamba_chunk: int = 64,
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward that also fills the caches.

    Returns (last-token logits (B, V), cache).
    """
    data_axes = _norm_axes(data_axes)
    b, s = tokens.shape
    max_seq = max_seq or s
    pattern, repeats = block_pattern(cfg)
    x = params["embedding"][tokens].astype(cfg.adtype)
    x = _shard(x, mesh, P(data_axes, None, None))

    def body(h, block_slices):
        new_entries = []
        for pos, (mixer, moe, window) in enumerate(pattern):
            p = block_slices[pos]
            hn = apply_norm(cfg, p["norm1"], h)
            if mixer == "attn":
                mixed, (k_new, v_new) = attn_mod.attention(
                    cfg, p["attn"], hn, window=window, q_chunk=q_chunk,
                    mesh=mesh, data_axes=data_axes,
                )
                if max_seq > s:
                    pad = ((0, 0), (0, max_seq - s), (0, 0), (0, 0))
                    k_new = jnp.pad(k_new, pad)
                    v_new = jnp.pad(v_new, pad)
                entry = {"k": k_new.astype(cfg.adtype), "v": v_new.astype(cfg.adtype)}
            else:
                mixed, state = mamba_mod.mamba_block(
                    cfg, p["mamba"], hn, chunk=mamba_chunk, return_state=True
                )
                entry = state
            h = h + mixed
            if cfg.family != "ssm":
                hn = apply_norm(cfg, p["norm2"], h)
                if moe:
                    y = moe_mod.moe_ffn(cfg, p["moe"], hn, mesh=mesh, data_axes=data_axes)
                    if cfg.dense_residual:
                        y = y + mlp_mod.mlp(cfg, p["residual_mlp"], hn)
                else:
                    y = mlp_mod.mlp(cfg, p["mlp"], hn)
                h = h + y
            new_entries.append(entry)
        h = _shard(h, mesh, P(data_axes, None, None))
        return h, tuple(new_entries)

    x, stacked = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    last = logits_from_hidden(cfg, params, x[:, -1:, :])[:, 0]

    specs = _cache_spec(cfg, data_axes)
    entries = []
    for pos, (mixer, _moe, _w) in enumerate(pattern):
        e = dict(stacked[pos])
        if mesh is not None:
            e = {kk: _shard(vv, mesh, specs[mixer][kk]) for kk, vv in e.items()}
        entries.append(e)
    cache = {"layers": entries, "len": jnp.asarray(s, jnp.int32)}
    return last, cache


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    token: jnp.ndarray,             # (B,) int32 — most recent token
    *,
    mesh: Optional[Mesh] = None,
    data_axes: Tuple[str, ...] = ("data",),
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: logits for the next token + updated caches."""
    data_axes = _norm_axes(data_axes)
    pattern, repeats = block_pattern(cfg)
    new_len = cache["len"] + 1

    x = params["embedding"][token[:, None]].astype(cfg.adtype)  # (B, 1, d)
    x = _shard(x, mesh, P(data_axes, None, None))
    specs = _cache_spec(cfg, data_axes)

    def attn_decode(p, h, entry, window):
        q = attn_mod.decode_project_q(cfg, p["attn"], h, new_len)
        k_new, v_new = attn_mod.decode_project_kv(cfg, p["attn"], h, new_len)

        if mesh is None:
            out, k_c, v_c = attn_mod.flash_decode(
                q, entry["k"], entry["v"], k_new, v_new, new_len,
                window=window, model_axis=None,
            )
        else:
            def body(q_, kc_, vc_, kn_, vn_):
                return attn_mod.flash_decode(
                    q_, kc_, vc_, kn_, vn_, new_len,
                    window=window, model_axis="model",
                )

            out, k_c, v_c = shard_map(
                body, mesh=mesh,
                in_specs=(
                    P(data_axes, None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, None, None, None),
                    P(data_axes, None, None, None),
                ),
                out_specs=(
                    P(data_axes, None, None),
                    P(data_axes, "model", None, None),
                    P(data_axes, "model", None, None),
                ),
                check_vma=False,
            )(q, entry["k"], entry["v"], k_new, v_new)
        y = jnp.einsum("bhk,hkd->bd", out.astype(h.dtype), p["attn"]["wo"])[:, None, :]
        return y, {"k": k_c, "v": v_c}

    def body(h, xs):
        block_slices, cache_slices = xs
        new_slices = []
        for pos, (mixer, moe, window) in enumerate(pattern):
            p = block_slices[pos]
            hn = apply_norm(cfg, p["norm1"], h)
            if mixer == "attn":
                mixed, new_entry = attn_decode(p, hn, cache_slices[pos], window)
            else:
                mixed, new_entry = mamba_mod.mamba_decode_step(
                    cfg, p["mamba"], hn, cache_slices[pos]
                )
            h = h + mixed
            if cfg.family != "ssm":
                hn = apply_norm(cfg, p["norm2"], h)
                if moe:
                    y = moe_mod.moe_ffn(cfg, p["moe"], hn, mesh=mesh, data_axes=data_axes)
                    if cfg.dense_residual:
                        y = y + mlp_mod.mlp(cfg, p["residual_mlp"], hn)
                else:
                    y = mlp_mod.mlp(cfg, p["mlp"], hn)
                h = h + y
            new_slices.append(new_entry)
        return h, tuple(new_slices)

    x, stacked = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x)[:, 0]

    entries = []
    for pos, (mixer, _moe, _w) in enumerate(pattern):
        e = dict(stacked[pos])
        if mesh is not None:
            e = {kk: _shard(vv, mesh, specs[mixer][kk]) for kk, vv in e.items()}
        entries.append(e)
    return logits, {"layers": entries, "len": new_len}
