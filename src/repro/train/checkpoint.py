"""Fault-tolerant checkpointing.

Requirements this meets for preemptible fleets:

* **atomic** — writes land in a temp directory that is `os.rename`d into
  place; a preemption mid-write can never corrupt the latest checkpoint;
* **self-describing** — a manifest records step, flattened leaf paths,
  shapes/dtypes and a content checksum, verified on load;
* **resumable onto a different mesh** — arrays are saved unsharded
  (gathered) and re-sharded by the caller's `device_put` on restore, so a
  checkpoint taken on a 2-pod mesh restores onto the surviving single-pod
  mesh (elastic scale-down) and vice versa;
* **retention** — keep the last K checkpoints, pruned oldest-first.

Format: one ``.npz`` per checkpoint + JSON manifest (no external deps).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "list_steps"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def _checksum(flat: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes()[:4096])  # prefix hash
    return h.hexdigest()[:16]


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    params,
    opt_state=None,
    *,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Atomically write checkpoint for `step`; returns its directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})

    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        np.savez(os.path.join(tmp, _ARRAYS), **flat)
        manifest = {
            "step": int(step),
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "checksum": _checksum(flat),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)   # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.startswith(".tmp"):
            # only completed (renamed) checkpoints count
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(
    ckpt_dir: str,
    params_template,
    opt_template=None,
    *,
    step: Optional[int] = None,
) -> Tuple[Any, Any, int]:
    """Restore (params, opt_state, step); templates supply tree structure
    and target dtypes (arrays are cast back, e.g. to bf16 params)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, _ARRAYS))
    flat = {k: data[k] for k in data.files}
    if manifest["checksum"] != _checksum(flat):
        raise IOError(f"checkpoint {path} failed checksum verification")

    def rebuild(template, prefix):
        leaves_p, tree = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for pth, leaf in leaves_p:
            key = prefix + "/".join(_path_str(p) for p in pth)
            arr = flat[key]
            out.append(jnp.asarray(arr, dtype=jnp.asarray(leaf).dtype))
        return jax.tree_util.tree_unflatten(tree, out)

    params = rebuild(params_template, "params/")
    opt = rebuild(opt_template, "opt/") if opt_template is not None else None
    return params, opt, manifest["step"]
