"""Spot request lifecycle state machine (paper Fig. 1)."""

import pytest

from repro.core.lifecycle import IllegalTransition, RequestState, SpotRequest


def test_happy_path_probe():
    req = SpotRequest(pool_id="p", submit_time=0.0)
    req.transition(RequestState.PROVISIONING, 1.0)
    req.transition(RequestState.CANCELLED, 1.5)
    assert req.is_terminal
    assert req.billed_seconds(now=100.0) == 0.0  # never reached RUNNING


def test_running_bills_only_running_interval():
    req = SpotRequest(pool_id="p", submit_time=0.0)
    req.transition(RequestState.PROVISIONING, 1.0)
    req.transition(RequestState.RUNNING, 10.0)
    req.transition(RequestState.INTERRUPTED, 70.0)
    assert req.billed_seconds() == 60.0


def test_rejected_is_terminal():
    req = SpotRequest(pool_id="p", submit_time=0.0)
    req.transition(RequestState.REJECTED, 0.1)
    with pytest.raises(IllegalTransition):
        req.transition(RequestState.PROVISIONING, 0.2)


@pytest.mark.parametrize(
    "path",
    [
        [RequestState.RUNNING],                      # skip provisioning
        [RequestState.CANCELLED],                    # cancel before accept
        [RequestState.PROVISIONING, RequestState.TERMINATED],
        [RequestState.PROVISIONING, RequestState.REJECTED],
    ],
)
def test_illegal_paths(path):
    req = SpotRequest(pool_id="p", submit_time=0.0)
    with pytest.raises(IllegalTransition):
        for s in path:
            req.transition(s, 1.0)


def test_history_is_ordered():
    req = SpotRequest(pool_id="p", submit_time=0.0)
    req.transition(RequestState.PROVISIONING, 1.0)
    req.transition(RequestState.RUNNING, 2.0)
    req.transition(RequestState.TERMINATED, 3.0)
    states = [s for _, s in req.history]
    assert states == [
        RequestState.PENDING,
        RequestState.PROVISIONING,
        RequestState.RUNNING,
        RequestState.TERMINATED,
    ]
    times = [t for t, _ in req.history]
    assert times == sorted(times)
