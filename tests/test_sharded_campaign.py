"""Mesh-sharded campaign engine: bit-identity with the fleet engine,
padding/masking, mesh plumbing, pipeline glue, and scope guards."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    PoolConfig,
    ShardedProvider,
    SimulatedProvider,
    compute_features,
    default_fleet,
    run_campaign,
    run_campaign_pipeline,
    run_sharded_campaign,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fresh(n_pools=10, seed=11, **kw):
    return SimulatedProvider(default_fleet(n_pools, seed=seed), seed=seed + 1, **kw)


def assert_campaigns_identical(ca, cb):
    np.testing.assert_array_equal(ca.s, cb.s)
    np.testing.assert_array_equal(ca.running, cb.running)
    np.testing.assert_array_equal(ca.times, cb.times)
    assert ca.interruptions == cb.interruptions
    assert ca.api_calls == cb.api_calls
    assert ca.probe_compute_cost == cb.probe_compute_cost
    assert ca.node_pool_cost == cb.node_pool_cost


class TestShardedParity:
    """The acceptance anchor: engine='sharded' ≡ engine='fleet' bit for
    bit — S_t, running_t, interruption logs, and cost accounting."""

    @pytest.fixture(scope="class")
    def pair(self):
        ca = run_campaign(fresh(), duration=6 * 3600.0, engine="fleet")
        cb = run_campaign(fresh(), duration=6 * 3600.0, engine="sharded")
        return ca, cb

    def test_bit_identical(self, pair):
        ca, cb = pair
        assert len(ca.interruptions) > 0  # the comparison must have teeth
        assert_campaigns_identical(ca, cb)
        assert cb.engine == "sharded"

    def test_seed_sweep(self):
        for seed in (0, 1, 2):
            ca = run_campaign(fresh(7, seed), duration=2 * 3600.0, engine="fleet")
            cb = run_campaign(fresh(7, seed), duration=2 * 3600.0, engine="sharded")
            assert_campaigns_identical(ca, cb)

    def test_pool_padding_is_invisible(self):
        # pad the pool axis well past the fleet size: padded pools must
        # not perturb a single bit of any real pool's row
        ca = run_campaign(fresh(10, 3), duration=3 * 3600.0, engine="fleet")
        cb = run_sharded_campaign(fresh(10, 3), duration=3 * 3600.0, pad_multiple=7)
        assert_campaigns_identical(ca, cb)

    def test_subset_pool_campaign(self):
        pa, pb = fresh(6, 5), fresh(6, 5)
        sub = pa.pool_ids[1:4]
        ca = run_campaign(pa, pool_ids=sub, duration=2 * 3600.0, engine="fleet")
        cb = run_campaign(pb, pool_ids=sub, duration=2 * 3600.0, engine="sharded")
        assert_campaigns_identical(ca, cb)

    def test_rate_limited_parity(self):
        fleet = [
            PoolConfig(instance_type=f"t{i}", region="r", base_capacity=30.0)
            for i in range(8)
        ]
        pa = SimulatedProvider(fleet, seed=5, requests_per_minute_per_region=30)
        pb = SimulatedProvider(fleet, seed=5, requests_per_minute_per_region=30)
        ca = run_campaign(pa, duration=2 * 3600.0, engine="fleet")
        cb = run_campaign(pb, duration=2 * 3600.0, engine="sharded")
        assert (ca.s.sum(axis=1) == 0).any(), "expected starved pools"
        assert_campaigns_identical(ca, cb)

    def test_fractional_tick_intervals(self):
        # interval not a multiple of the tick exercises the fractional
        # settle; interval < tick exercises zero-tick cycles
        for interval in (150.0, 45.0):
            ca = run_campaign(
                fresh(5, 9), duration=1800.0, interval=interval, engine="fleet"
            )
            cb = run_campaign(
                fresh(5, 9), duration=1800.0, interval=interval, engine="sharded"
            )
            assert_campaigns_identical(ca, cb)


class TestShardedPipelineGlue:
    def test_campaign_pipeline_features_identical(self):
        outs = {}
        for engine in ("fleet", "sharded"):
            result, proc = run_campaign_pipeline(
                fresh(6, 17),
                duration=4 * 3600.0,
                engine=engine,
                predict_fn=lambda x: x[:, 0],
                window_minutes=30.0,
            )
            t = result.s.shape[1]
            assert proc.update_ops == t
            assert proc.predict_calls == t
            outs[engine] = (result, proc)
        ra, pa = outs["fleet"]
        rb, pb = outs["sharded"]
        np.testing.assert_array_equal(ra.s, rb.s)
        np.testing.assert_array_equal(pa.table.features, pb.table.features)
        np.testing.assert_array_equal(pa.table.predictions, pb.table.predictions)
        # streamed features == offline replay of the campaign's S matrix
        expect = compute_features(rb.s, rb.n, 30.0, rb.interval / 60.0)
        w = pb.window_cycles
        np.testing.assert_array_equal(
            pb.table.features[:, pb.table._order()], expect[:, rb.s.shape[1] - w:, :]
        )


class TestShardedTerminatorDelay:
    """Slow-terminator probe cohorts on the sharded engine: device-resident
    (pools,) cohort slots + the host leaked-uid ledger, bit-identical to
    the fleet engine's hold -> advance -> cancel sequence."""

    def leak_pair(self, delay, seed=21, hours=2):
        kw = dict(
            duration=hours * 3600.0,
            n_requests=10,
            terminator_delay=delay,
        )
        mk = lambda: fresh(6, seed, provisioning_duration=8.0)
        ca = run_campaign(mk(), engine="fleet", **kw)
        cb = run_campaign(mk(), engine="sharded", **kw)
        return ca, cb

    def test_leaking_delay_bit_identical(self):
        # delay > provisioning_duration: probes leak into RUNNING, bill,
        # and get reclaimed alongside node-pool instances
        ca, cb = self.leak_pair(30.0)
        assert ca.probe_compute_cost > 0
        assert_campaigns_identical(ca, cb)

    def test_non_leaking_delay_bit_identical(self):
        # 0 < delay < provisioning_duration: cohorts are cancelled while
        # still provisioning — the hold/cancel path with zero leaks
        ca, cb = self.leak_pair(5.0)
        assert ca.probe_compute_cost == 0.0 == cb.probe_compute_cost
        assert_campaigns_identical(ca, cb)

    def test_multi_tick_delay_bit_identical(self):
        # delay spanning multiple dynamics ticks: leaked probes live
        # through reclamation sweeps inside the delay window
        ca, cb = self.leak_pair(120.0, seed=33)
        assert ca.probe_compute_cost > 0
        assert_campaigns_identical(ca, cb)

    def test_probe_ledger_rows_match_fleet(self):
        kw = dict(duration=2 * 3600.0, n_requests=10, terminator_delay=30.0)
        pa = fresh(6, 21, provisioning_duration=8.0)
        pb = fresh(6, 21, provisioning_duration=8.0)
        from repro.core import CampaignStream

        sa = CampaignStream(pa, engine="fleet", **kw)
        sb = CampaignStream(pb, engine="sharded", **kw)
        for _ in sa:
            pass
        for _ in sb:
            pass
        assert sa.result().probe_compute_cost == sb.result().probe_compute_cost
        assert pa.probe_ledger_len() == sb.provider.probe_ledger_len() > 0
        # disjoint cursor segments must sum to the whole on both engines
        mid = pa.probe_ledger_len() // 2
        for prov in (pa, sb.provider):
            whole = prov.probe_instance_cost()
            split = prov.probe_instance_cost(
                until=mid
            ) + prov.probe_instance_cost(since=mid)
            assert whole == pytest.approx(split, rel=1e-12)


class TestBatchedSweepDelays:
    def test_batch_matches_scalar_sweeps(self):
        from repro.core.provider import (
            reclaim_sweep_delays,
            reclaim_sweep_delays_batch,
        )

        pools = np.array([3, 0, 7, 3], dtype=np.int64)
        ticks = np.array([11, 11, 29, 54], dtype=np.int64)
        ks = np.array([4, 1, 9, 2], dtype=np.int64)
        got = reclaim_sweep_delays_batch(123, pools, ticks, ks)
        want = np.concatenate(
            [
                reclaim_sweep_delays(123, int(p), int(t), int(k))
                for p, t, k in zip(pools, ticks, ks)
            ]
        )
        np.testing.assert_array_equal(got, want)

    def test_empty_batch(self):
        from repro.core.provider import reclaim_sweep_delays_batch

        out = reclaim_sweep_delays_batch(1, [], [], [])
        assert out.shape == (0,)


class TestShardedScope:
    def test_used_provider_rejected(self):
        prov = fresh()
        prov.advance(600.0)  # mid-flight ledgers are not shardable
        with pytest.raises(ValueError):
            run_campaign(prov, duration=3600.0, engine="sharded")

    def test_slow_provisioning_rejected(self):
        prov = fresh(4, provisioning_duration=120.0)  # > tick
        with pytest.raises(NotImplementedError):
            ShardedProvider(prov)

    def test_node_pools_frozen_after_start(self):
        sp = ShardedProvider(fresh(4))
        sp.set_node_pools(sp.pool_ids, 5)
        sp.advance(60.0)
        with pytest.raises(RuntimeError):
            sp.set_node_pools(sp.pool_ids, 7)

    def test_unknown_engine_still_rejected(self):
        with pytest.raises(ValueError):
            run_campaign(fresh(2), duration=3600.0, engine="warp")


class TestShardedMultiDevice:
    """Real pool-axis sharding: 4 host-platform devices in a subprocess
    (the main process must keep its single CPU device)."""

    def test_four_way_mesh_parity(self):
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        from repro.core import SimulatedProvider, default_fleet, run_campaign

        assert len(jax.devices()) == 4
        def fresh():
            return SimulatedProvider(default_fleet(10, seed=7), seed=8)
        ca = run_campaign(fresh(), duration=4 * 3600.0, engine="fleet")
        cb = run_campaign(fresh(), duration=4 * 3600.0, engine="sharded")
        np.testing.assert_array_equal(ca.s, cb.s)
        np.testing.assert_array_equal(ca.running, cb.running)
        assert ca.interruptions == cb.interruptions
        assert ca.api_calls == cb.api_calls
        print("SHARDED_CAMPAIGN_OK")
        """
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert "SHARDED_CAMPAIGN_OK" in r.stdout, r.stdout + r.stderr

    def test_four_way_mesh_terminator_leak_accounting(self):
        # probe cohorts + leaked-uid accounting across a real 4-device
        # pool mesh: bit-identical matrices, logs, and probe cost
        code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        import jax
        from repro.core import SimulatedProvider, default_fleet, run_campaign

        assert len(jax.devices()) == 4
        def fresh():
            return SimulatedProvider(
                default_fleet(10, seed=21), seed=22, provisioning_duration=8.0
            )
        kw = dict(duration=2 * 3600.0, n_requests=10, terminator_delay=30.0)
        ca = run_campaign(fresh(), engine="fleet", **kw)
        cb = run_campaign(fresh(), engine="sharded", **kw)
        assert ca.probe_compute_cost > 0
        assert ca.probe_compute_cost == cb.probe_compute_cost
        np.testing.assert_array_equal(ca.s, cb.s)
        np.testing.assert_array_equal(ca.running, cb.running)
        np.testing.assert_array_equal(ca.times, cb.times)
        assert ca.interruptions == cb.interruptions
        assert ca.api_calls == cb.api_calls
        print("SHARDED_LEAK_OK")
        """
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert "SHARDED_LEAK_OK" in r.stdout, r.stdout + r.stderr
