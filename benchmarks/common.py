"""Shared campaign fixtures for the paper-table benchmarks.

One "paper-scale" campaign (68 pools, 24 h, 3-min cadence, 10-node pools —
the §III-B setup) is generated once per process and reused by every
benchmark module; a second provider split mimics the AWS/Azure halves.
"""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import SimulatedProvider, default_fleet, run_campaign


@functools.lru_cache(maxsize=None)
def paper_campaign(seed: int = 0, n_pools: int = 68, hours: float = 24.0):
    fleet = default_fleet(n_pools, seed=seed)
    provider = SimulatedProvider(fleet, seed=seed + 1)
    return run_campaign(provider, duration=hours * 3600.0)


@functools.lru_cache(maxsize=None)
def provider_split_campaigns(seed: int = 0):
    """(aws-like, azure-like) campaigns — Table I is reported per provider."""
    aws = default_fleet(47, seed=seed, providers=("aws",))
    azure = default_fleet(21, seed=seed + 10, providers=("azure",))
    c_aws = run_campaign(SimulatedProvider(aws, seed=seed + 1), duration=24 * 3600.0)
    c_az = run_campaign(SimulatedProvider(azure, seed=seed + 2), duration=24 * 3600.0)
    return c_aws, c_az


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
