"""Preemption-aware training runner + lost-work accounting.

Replays a pod availability trace against a (real or simulated) training
job and accounts lost computation under a checkpoint policy — the
training-side analogue of the paper's §VI-E query simulation:

* between checkpoints, completed steps are *at risk*: a preemption rolls
  the job back to the last checkpoint (work since then is lost);
* each checkpoint costs ``ckpt_cost`` seconds of training time;
* after a preemption the job waits for the pool to recover, restores, and
  continues (restore cost accounted);
* the **SnSHazard** policy additionally consumes the per-cycle SnS
  features through a trained predictor to adapt cadence / force panic
  checkpoints.

``run_replay`` is pure accounting (fast, used by benchmarks and tests);
``train_with_preemptions`` drives an actual JAX training loop through the
same logic (used by examples/elastic_training.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from .ckpt_policy import FixedInterval, SnSHazard
from .events import PodTrace

__all__ = ["ReplayResult", "run_replay"]


@dataclasses.dataclass
class ReplayResult:
    policy: str
    steps_completed: int
    steps_lost: int
    checkpoints: int
    ckpt_overhead_s: float
    lost_work_s: float
    unavailable_s: float

    @property
    def goodput(self) -> float:
        total = (
            self.steps_completed + self.steps_lost
        )
        return self.steps_completed / total if total else 0.0


def run_replay(
    trace: PodTrace,
    *,
    policy,
    step_time: float = 2.0,            # seconds per training step
    ckpt_cost: float = 30.0,           # seconds per checkpoint write
    restore_cost: float = 60.0,        # seconds to restore after preemption
    predictor: Optional[Callable[[np.ndarray], float]] = None,
    policy_name: str = "",
) -> ReplayResult:
    """Replay one pod's availability trace under a checkpoint policy.

    `predictor(features) -> P(pool survives the horizon)` feeds SnSHazard.
    """
    avail = trace.available.astype(bool)
    dt = trace.dt
    t_cycles = len(avail)

    steps_done = 0
    steps_since_ckpt = 0
    steps_lost = 0
    ckpts = 0
    ckpt_overhead = 0.0
    unavailable = 0.0
    t_last_ckpt = 0.0
    restoring = 0.0

    for c in range(t_cycles):
        now = c * dt
        if not avail[c]:
            # preemption: everything since the last checkpoint is lost
            if steps_since_ckpt:
                steps_lost += steps_since_ckpt
                steps_since_ckpt = 0
            unavailable += dt
            restoring = restore_cost
            continue

        p_survive = None
        if predictor is not None:
            p_survive = float(predictor(trace.features[c]))

        budget = dt
        if restoring > 0.0:
            used = min(budget, restoring)
            restoring -= used
            budget -= used

        while budget >= step_time:
            if policy.should_checkpoint(now + (dt - budget), t_last_ckpt, p_survive):
                if steps_since_ckpt == 0 and ckpts:
                    # nothing new to save; skip redundant write
                    t_last_ckpt = now + (dt - budget)
                else:
                    cost = min(ckpt_cost, budget)
                    budget -= cost
                    ckpt_overhead += cost
                    ckpts += 1
                    t_last_ckpt = now + (dt - budget)
                    steps_since_ckpt = 0
                    continue
            budget -= step_time
            steps_done += 1
            steps_since_ckpt += 1

    return ReplayResult(
        policy=policy_name or type(policy).__name__,
        steps_completed=steps_done,
        steps_lost=steps_lost,
        checkpoints=ckpts,
        ckpt_overhead_s=ckpt_overhead,
        lost_work_s=steps_lost * step_time,
        unavailable_s=unavailable,
    )
