"""Campaign engine throughput — pool-cycles/sec, scalar vs fleet vs sharded.

Measures a full measure→record campaign (`repro.core.run_campaign`:
regime dynamics + node pools + SnS probing) through the three collector
engines on the same fleet:

1. ``scalar``  — the paper-faithful per-pool path: one
   ``submit_spot_request`` per pool per cycle, per-request
   ``SpotRequest`` objects, per-probe Data-Lake rows (hot-path record
   retention off, the fair configuration at this scale);
2. ``fleet``   — the batched numpy engine: one ``submit_spot_requests``
   admission call per cycle for the whole fleet, matrices in place of
   objects;
3. ``sharded`` — the mesh-sharded JAX engine (`repro.core.sharded`):
   pool state device-resident and device-sharded over a 1-D
   ``("pools",)`` mesh, one donated ``shard_map``-ped jitted step per
   cycle with a single stacked host fetch; interruption events and
   probe costs are materialised in batches at campaign boundaries.
   Measured after a short warm-up campaign so the one-time XLA compile
   (cached process-wide across campaigns) is excluded — the
   steady-state rate is what a long campaign sees.

Because all engines ride the provider's counter-based per-pool RNG
streams, the benchmark also *asserts* the parity anchor: identical
``S_t`` / ``running_t`` matrices and interruption event logs from all
three engines.

Usage:
    PYTHONPATH=src python benchmarks/campaign_throughput.py [--smoke]
        [--pools 4096] [--cycles 16] [--engine all|scalar|fleet|sharded]
        [--pools-large 65536] [--multidev]

The full run asserts (16 cycles on CPU) that the fleet engine clears
>= 20x the scalar engine at the top pool count, and that the sharded
engine's best measured size clears >= 1x the fleet engine on a single
device (device-resident stepping removed the per-cycle host round-trips;
the crossover sits near ~1k pools on one CPU core — below it the jitted
step beats numpy's per-cycle Python overhead, above it numpy's masked
sparse updates win on a single device and the sharded payoff is the
device axis; the top-size ratio keeps a 0.5x regression guard), and
appends a perf record (with the device count, so multi-device
trajectories accumulate in the same file) to ``BENCH_campaign.json``.
``--multidev`` additionally records a ``sharded_scaling`` curve — the
sharded engine re-benched in subprocesses at 1/2/4 virtual host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set
before jax first initialises).  Virtual devices share the same physical
cores, so the curve measures sharding overhead and mesh plumbing, not
parallel speedup; it is recorded, never asserted.  ``--smoke`` only
checks plumbing + parity.

The full run additionally guards the chaos substrate's faults-off cost:
the fleet rate must clear 97% of the minimum fleet rate over recent
recorded non-smoke runs at the same pool count
(``faults_off_vs_floor``), and a ``chaos_fleet`` entry records the fleet
rate with an active FaultPlan + retry policy (recorded, not asserted).

The full run also records a ``large_fleet`` scaling entry at
``--pools-large`` (default 65536) pools on the fleet engine: throughput,
``host_mem_mb`` (peak-RSS delta over the campaign), end-of-campaign
columnar-ledger bytes, and a ledger-flatness check (host ledgers bounded
by the live fleet, not by pools x cycles) — the bounded-memory payoff of
the struct-of-arrays provider ledgers.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

N_REQ = 10
INTERVAL = 180.0
REQUIRED_SPEEDUP = 20.0           # fleet vs scalar
# sharded vs fleet, 1-device CPU floors.  Device-resident stepping
# (donated buffers, one stacked fetch per cycle, batched event
# materialisation) restored sharded >= fleet in its dispatch-bound
# regime (<= ~1k pools on one core: measured 1.1-1.3x at 256-512
# pools); wider single-device fleets stay numpy-favorable (masked
# sparse regime/replenish updates vs the step's dense draws), so the
# top size keeps a regression guard while the best measured size must
# clear parity.  Records carry `devices` so multi-device trajectories
# accumulate in the same file.
REQUIRED_SHARDED_SPEEDUP = 1.0        # best measured size
MIN_SHARDED_SPEEDUP_AT_SCALE = 0.5    # top (largest) measured size
ENGINES = ("scalar", "fleet", "sharded")


def _provider(pools: int, seed: int = 0):
    from repro.core import SimulatedProvider, default_fleet

    # rate limits sized for the paper's 68-pool campaign would starve a
    # SpotLake-class fleet; lift them so all engines probe every pool
    return SimulatedProvider(
        default_fleet(pools, seed=seed),
        seed=seed + 1,
        requests_per_minute_per_region=10**9,
    )


def bench_engine(engine: str, pools: int, cycles: int) -> float:
    """pool-cycles/sec for one engine (fresh provider, same seed).

    The vectorized engines take the best of three runs — their campaigns
    are sub-second, so noise on a small shared container would otherwise
    dominate the sharded-vs-fleet ratios the floors assert; the scalar
    engine is orders of magnitude slower and runs once.
    """
    from repro.core import run_campaign

    if engine == "sharded":
        # warm the process-wide compiled-step cache (one short campaign);
        # steady-state throughput is the quantity that scales with fleets
        run_campaign(
            _provider(pools),
            duration=2 * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine=engine,
        )
    best = float("inf")
    for _ in range(1 if engine == "scalar" else 3):
        provider = _provider(pools)
        t0 = time.perf_counter()
        run_campaign(
            provider,
            duration=cycles * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine=engine,
            retain_records=False,
        )
        best = min(best, time.perf_counter() - t0)
    return pools * cycles / best


def bench_multidev_curve(
    pools: int, cycles: int, devices=(1, 2, 4)
) -> dict:
    """Sharded-engine pool-cycles/sec at 1/2/4 virtual host devices.

    Each point runs in a subprocess because the XLA virtual-device flag
    must be set before jax first initialises.  The child is this same
    script with ``--sharded-rate-only``, which prints one number (the
    warmed steady-state rate from :func:`bench_engine`).
    """
    src = str(Path(__file__).resolve().parent.parent / "src")
    curve = {}
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [
                sys.executable, os.path.abspath(__file__),
                "--sharded-rate-only",
                "--pools", str(pools), "--cycles", str(cycles),
            ],
            capture_output=True, text=True, timeout=1800, env=env,
        )
        assert proc.returncode == 0, proc.stderr
        curve[str(n)] = round(float(proc.stdout.strip().splitlines()[-1]))
    return {
        "pools": pools,
        "cycles": cycles,
        "pool_cycles_per_sec": curve,
    }


def bench_large_fleet(pools: int, cycles: int) -> dict:
    """One long fleet campaign at scale: throughput + host-memory payoff.

    Drives the campaign cycle-at-a-time so the columnar-ledger footprint
    can be checkpointed mid-flight; reports the peak-RSS delta
    (``host_mem_mb``), the end-of-campaign ledger bytes, and whether the
    ledgers stayed flat across the campaign's second half (bounded by the
    live fleet, not by pools x cycles).
    """
    import resource

    from repro.core import CampaignStream

    stream = CampaignStream(
        _provider(pools, seed=5),
        duration=cycles * INTERVAL,
        interval=INTERVAL,
        n_requests=N_REQ,
        engine="fleet",
        retain_records=False,
    )
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    t0 = time.perf_counter()
    mid_bytes = 0
    for cyc in stream:
        if cyc.cycle + 1 == max(cycles // 2, 1):
            mid_bytes = stream.provider.ledger_stats().nbytes
    elapsed = time.perf_counter() - t0
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    stats = stream.provider.ledger_stats()
    stream.result()
    return {
        "pools": pools,
        "cycles": cycles,
        "pool_cycles_per_sec": round(pools * cycles / elapsed),
        "host_mem_mb": round((rss1 - rss0) / 1024.0, 1),  # linux ru_maxrss: KiB
        "ledger_mb": round(stats.nbytes / 1e6, 2),
        "live_instances": stats.instance_live,
        "ledger_flat_in_cycles": bool(stats.nbytes <= 2 * mid_bytes),
    }


def faults_off_floor_ratio(fleet_rate: float, pools: int):
    """Faults-off throughput vs the recorded historical floor.

    The chaos substrate (fault hooks, retry control plane, outcome
    matrices) must be free when disabled: the ``fault_plan=None`` path is
    compiled/evaluated without any fault work.  Guarded by comparing this
    run's fleet rate against the *minimum* fleet rate over the last
    non-smoke ``BENCH_campaign.json`` records at the same pool count —
    the recorded throughput floor (min-of-history absorbs run-to-run
    container noise; a real chaos-plumbing regression drops below the
    floor of every prior run).  Returns the ratio, or None with no
    history.
    """
    path = Path.cwd() / "BENCH_campaign.json"
    if not path.exists():
        return None
    floors = []
    for line in path.read_text().splitlines():
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if rec.get("smoke"):
            continue
        rate = (
            rec.get("per_pools", {})
            .get(str(pools), {})
            .get("pool_cycles_per_sec", {})
            .get("fleet")
        )
        if rate:
            floors.append(rate)
    if not floors:
        return None
    return fleet_rate / min(floors[-8:])


def bench_chaos_overhead(pools: int, cycles: int) -> dict:
    """Fleet rate with an active FaultPlan + retry policy (recorded, not
    asserted — chaos campaigns pay for fault evaluation by design)."""
    from repro.core import (
        FaultPlan,
        RetryPolicy,
        ThrottleBursts,
        run_campaign,
    )

    plan = FaultPlan(
        seed=11,
        throttle=ThrottleBursts(p=0.2, epoch=1800.0, mean_duration=300.0),
        request_error_p=0.02,
        timeout_p=0.02,
    )
    best = float("inf")
    for _ in range(3):
        provider = _provider(pools)
        t0 = time.perf_counter()
        run_campaign(
            provider,
            duration=cycles * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine="fleet",
            retain_records=False,
            fault_plan=plan,
            retry_policy=RetryPolicy(seed=5),
        )
        best = min(best, time.perf_counter() - t0)
    return {"pools": pools, "pool_cycles_per_sec": round(pools * cycles / best)}


def check_parity(pools: int = 256, cycles: int = 8) -> bool:
    """All engines bit-for-bit identical on shared RNG streams."""
    from repro.core import run_campaign

    results = {}
    for engine in ENGINES:
        results[engine] = run_campaign(
            _provider(pools, seed=3),
            duration=cycles * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine=engine,
            retain_records=False,
        )
    ref = results["scalar"]
    for engine in ("fleet", "sharded"):
        got = results[engine]
        np.testing.assert_array_equal(ref.s, got.s)
        np.testing.assert_array_equal(ref.running, got.running)
        assert ref.interruptions == got.interruptions, (
            f"interruption logs diverged: scalar vs {engine}"
        )
        assert ref.api_calls == got.api_calls
    return True


def run(
    pools: int = 4096,
    cycles: int = 16,
    smoke: bool = False,
    engine: str = "all",
    pools_large: int = 65536,
    multidev: bool = False,
) -> dict:
    import jax

    engines = ENGINES if engine == "all" else (engine,)
    if smoke:
        pools, cycles = min(pools, 256), min(cycles, 8)
        pools_large = min(pools_large, 512)
    # 512 is the dispatch-bound size the sharded >= 1x fleet floor pins;
    # the top size tracks the at-scale trajectory
    sizes = sorted({min(512, pools), min(1024, pools), pools})

    per_size = {}
    for p in sizes:
        rates = {e: bench_engine(e, p, cycles) for e in engines}
        entry = {"pool_cycles_per_sec": {e: round(r) for e, r in rates.items()}}
        if "scalar" in rates and "fleet" in rates:
            entry["speedup"] = round(rates["fleet"] / rates["scalar"], 1)
        if "fleet" in rates and "sharded" in rates:
            entry["speedup_sharded_vs_fleet"] = round(
                rates["sharded"] / rates["fleet"], 2
            )
        per_size[p] = entry

    result = {
        "cycles": cycles,
        "devices": len(jax.devices()),
        "per_pools": per_size,
        "parity_identical": check_parity(
            pools=min(pools, 256), cycles=min(cycles, 8)
        ),
        "smoke": smoke,
    }
    result["large_fleet"] = bench_large_fleet(
        pools_large, min(cycles, 16) if not smoke else 4
    )
    if "fleet" in engines:
        ratio = faults_off_floor_ratio(
            per_size[pools]["pool_cycles_per_sec"]["fleet"], pools
        )
        if ratio is not None:
            result["faults_off_vs_floor"] = round(ratio, 3)
        result["chaos_fleet"] = bench_chaos_overhead(
            min(pools, 1024), cycles
        )
    if multidev:
        result["sharded_scaling"] = bench_multidev_curve(pools, cycles)
    top = per_size[pools]
    if "speedup" in top:
        result["speedup"] = top["speedup"]
    sharded_ratios = [
        e["speedup_sharded_vs_fleet"]
        for e in per_size.values()
        if "speedup_sharded_vs_fleet" in e
    ]
    if "speedup_sharded_vs_fleet" in top:
        result["speedup_sharded_vs_fleet"] = top["speedup_sharded_vs_fleet"]
    if sharded_ratios:
        result["speedup_sharded_vs_fleet_best"] = max(sharded_ratios)
    if not smoke:
        if "speedup" in result:
            assert result["speedup"] >= REQUIRED_SPEEDUP, result
        if sharded_ratios:
            assert (
                result["speedup_sharded_vs_fleet_best"]
                >= REQUIRED_SHARDED_SPEEDUP
            ), result
        if "speedup_sharded_vs_fleet" in result:
            assert (
                result["speedup_sharded_vs_fleet"]
                >= MIN_SHARDED_SPEEDUP_AT_SCALE
            ), result
        assert result["large_fleet"]["ledger_flat_in_cycles"], result
        if "faults_off_vs_floor" in result:
            # chaos substrate must be free when disabled: >= 97% of the
            # recorded pre-chaos throughput floor
            assert result["faults_off_vs_floor"] >= 0.97, result
        rec = dict(result, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
        with open(Path.cwd() / "BENCH_campaign.json", "a") as f:
            f.write(json.dumps(rec) + "\n")
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=16)
    ap.add_argument("--engine", choices=("all",) + ENGINES, default="all",
                    help="bench one engine only (parity always checks all)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; skip the speedup assertions")
    ap.add_argument("--pools-large", type=int, default=65536,
                    help="fleet size for the large_fleet scaling entry")
    ap.add_argument("--multidev", action="store_true",
                    help="also record the 1/2/4-virtual-device sharded "
                         "scaling curve (spawns subprocesses)")
    ap.add_argument("--sharded-rate-only", action="store_true",
                    help=argparse.SUPPRESS)  # bench_multidev_curve child
    args = ap.parse_args()
    if args.sharded_rate_only:
        print(bench_engine("sharded", args.pools, args.cycles))
        return
    result = run(
        pools=args.pools, cycles=args.cycles, smoke=args.smoke,
        engine=args.engine, pools_large=args.pools_large,
        multidev=args.multidev,
    )
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
