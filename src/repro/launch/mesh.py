"""Production mesh construction + JAX version-compat helpers.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so tests/benches keep seeing the single
real CPU device; only the dry-run subprocess sets the 512-placeholder-
device XLA flag before first jax init).

``make_explicit_mesh`` / ``use_mesh`` paper over the mesh-API churn across
JAX releases: ``jax.sharding.AxisType`` and ``jax.set_mesh`` only exist in
newer versions, while older ones spell the same things as a plain
``jax.make_mesh`` plus the ``Mesh`` context manager.  All repo code (and
the subprocess snippets in ``tests/test_distribution.py``) goes through
these two helpers instead of the raw APIs.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax

__all__ = [
    "make_explicit_mesh",
    "use_mesh",
    "make_production_mesh",
    "make_pool_mesh",
    "make_trace_mesh",
    "data_axes_of",
    "mesh_axis_sizes",
]


def make_explicit_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    Newer JAX requires ``axis_types`` to opt out of explicit-sharding mode;
    older JAX (no ``jax.sharding.AxisType``) has exactly that behaviour by
    default and rejects the keyword.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
        )
    return jax.make_mesh(shape, axis_names)


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` when it exists,
    else ``jax.sharding.use_mesh``, else the legacy ``Mesh`` context."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    sharding_use_mesh = getattr(jax.sharding, "use_mesh", None)
    if sharding_use_mesh is not None:
        return sharding_use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod mesh, or 2×16×16 across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_explicit_mesh(shape, axes)


def make_pool_mesh(shards: int = 0):
    """1-D ``("pools",)`` mesh for the sharded campaign engine
    (``repro.core.sharded``): the pool axis split across ``shards``
    devices (default: all visible devices).  Per-pool campaign state is
    elementwise along this axis, so the mesh needs no second dimension."""
    n = int(shards) if shards else len(jax.devices())
    return make_explicit_mesh((n,), ("pools",))


def make_trace_mesh(shards: int = 0):
    """1-D ``("traces",)`` mesh for the mesh-sharded replay scan
    (``repro.kernels.replay_scan.ops``): the trace/row axis split across
    ``shards`` devices (default: all visible devices).  Replay rows are
    independent, so the scan needs no cross-device collectives."""
    n = int(shards) if shards else len(jax.devices())
    return make_explicit_mesh((n,), ("traces",))


def data_axes_of(mesh) -> Tuple[str, ...]:
    """The batch/data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_axis_sizes(mesh) -> dict:
    return {name: size for name, size in zip(mesh.axis_names, mesh.devices.shape)}
