"""SnS Collector — paper §V, Fig. 4 (left module).

Three components, mirrored from the paper's serverless deployment as an
in-process event-driven system with identical responsibilities:

* **RequestInvoker** — owns the target-pool list and the collection
  schedule (EventBridge analogue): triggers one collection cycle every
  ``interval`` seconds.
* **ParallelSpotRequester** — submits ``N`` concurrent spot requests per
  pool per cycle and appends one :class:`ProbeRecord` per request to the
  :class:`DataLake`.
* **RequestTerminator** — subscribes to provisioning lifecycle events and
  cancels accepted requests *immediately and independently of the
  requester* (the event-driven design in §V that keeps the provisioning
  window, and therefore cost, minimal).  A configurable ``terminator_delay``
  models a slow/polling terminator; with delay ≥ the provider's
  provisioning duration, probes leak into RUNNING and start billing — the
  failure mode the paper's design eliminates (covered by tests).

:func:`run_campaign` drives a full measurement campaign: ground-truth node
pools (``set_node_pool``) plus probing, producing time-aligned ``S_t`` /
``running_t`` matrices, the interruption event log, and cost accounting.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .lifecycle import RequestState, SpotRequest
from .provider import RateLimitError, SimulatedProvider

__all__ = ["ProbeRecord", "DataLake", "SnSCollector", "CampaignResult", "run_campaign"]


@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    """Outcome of one SnS probe, as stored in the Data Lake (§V)."""

    time: float
    pool_id: str
    accepted: bool
    cycle: int


class DataLake:
    """Append-only store of probe outcomes with per-pool aggregation."""

    def __init__(self):
        self.records: List[ProbeRecord] = []

    def append(self, rec: ProbeRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def success_counts(self, pool_ids: Sequence[str], n_cycles: int) -> np.ndarray:
        """Aggregate to ``S[pool, cycle]`` success-count matrix."""
        index = {p: i for i, p in enumerate(pool_ids)}
        s = np.zeros((len(pool_ids), n_cycles), dtype=np.int64)
        for rec in self.records:
            if rec.accepted and rec.cycle < n_cycles and rec.pool_id in index:
                s[index[rec.pool_id], rec.cycle] += 1
        return s


class SnSCollector:
    """Invoker + parallel requester + event-driven terminator."""

    def __init__(
        self,
        provider: SimulatedProvider,
        pool_ids: Sequence[str],
        *,
        n_requests: int = 10,
        interval: float = 180.0,
        terminator_delay: float = 0.0,
    ):
        self.provider = provider
        self.pool_ids = list(pool_ids)
        self.n = int(n_requests)
        self.interval = float(interval)
        self.terminator_delay = float(terminator_delay)
        self.lake = DataLake()
        self.probe_requests: List[SpotRequest] = []
        self._pending_cancel: List[SpotRequest] = []
        self._probing = False  # True only while the requester is submitting
        # Event-driven terminator: reacts to the provisioning lifecycle
        # event itself, independent of the requester control flow (§V).
        provider.on_provisioning(self._on_provisioning_event)

    # -- RequestTerminator -------------------------------------------------

    def _on_provisioning_event(self, req: SpotRequest) -> None:
        if not self._probing:
            return  # node-pool replenishment etc. — not ours to cancel
        if self.terminator_delay <= 0.0:
            self.provider.cancel(req)  # scoot immediately
        else:
            self._pending_cancel.append(req)  # slow-terminator model

    def _flush_delayed_cancels(self) -> None:
        for req in self._pending_cancel:
            self.provider.cancel(req)  # no-op if it already reached RUNNING
        self._pending_cancel.clear()

    # -- ParallelSpotRequester ----------------------------------------------

    def probe_pool(self, pool_id: str, cycle: int) -> int:
        """Submit N concurrent requests to one pool; return S_t."""
        successes = 0
        self._probing = True
        try:
            reqs = self.provider.submit_spot_request(pool_id, n=self.n)
        except RateLimitError:
            reqs = []  # rate-limited cycle records total failure
        finally:
            self._probing = False
        for req in reqs:
            accepted = req.state is not RequestState.REJECTED
            if accepted:
                successes += 1
            self.lake.append(ProbeRecord(self.provider.now, pool_id, accepted, cycle))
            self.probe_requests.append(req)
        return successes

    # -- RequestInvoker -----------------------------------------------------

    def run_cycle(self, cycle: int) -> np.ndarray:
        """One collection cycle across all pools; returns S_t per pool."""
        s = np.zeros(len(self.pool_ids), dtype=np.int64)
        for i, pool_id in enumerate(self.pool_ids):
            s[i] = self.probe_pool(pool_id, cycle)
        if self.terminator_delay > 0.0:
            # slow terminator: cancels land only after the delay has passed
            self.provider.advance(self.provider.now + self.terminator_delay)
            self._flush_delayed_cancels()
        return s

    # -- accounting ----------------------------------------------------------

    def probe_compute_cost(self) -> float:
        """Total compute dollars billed to probe requests (≈ 0 by design)."""
        total = 0.0
        for req in self.probe_requests:
            if req.run_started is not None:
                price = self.provider.pool_config(req.pool_id).price_per_hour
                total += req.billed_seconds(self.provider.now) * price / 3600.0
        return total


@dataclasses.dataclass
class CampaignResult:
    pool_ids: List[str]
    times: np.ndarray          # (T,) cycle timestamps (seconds)
    s: np.ndarray              # (pools, T) SnS success counts
    running: np.ndarray        # (pools, T) actual running node counts
    n: int                     # requests per measurement point
    interval: float            # collection interval (seconds)
    interruptions: list        # InterruptionEvent list
    probe_compute_cost: float  # $ billed to probes (≈ 0 by design)
    node_pool_cost: float      # $ billed to ground-truth running nodes
    api_calls: int


def run_campaign(
    provider: SimulatedProvider,
    *,
    pool_ids: Optional[Sequence[str]] = None,
    duration: float = 24 * 3600.0,
    interval: float = 180.0,
    n_requests: int = 10,
    node_pool_size: int = 10,
    terminator_delay: float = 0.0,
) -> CampaignResult:
    """Run a §III-B style campaign: node pools + SnS probing side by side."""
    pool_ids = list(pool_ids) if pool_ids is not None else provider.pool_ids
    collector = SnSCollector(
        provider,
        pool_ids,
        n_requests=n_requests,
        interval=interval,
        terminator_delay=terminator_delay,
    )
    for pid in pool_ids:
        provider.set_node_pool(pid, node_pool_size)
    # Let pools acquire their initial nodes before the first measurement.
    provider.advance(provider.now + 3 * provider.tick)

    n_cycles = int(duration // interval)
    times = np.zeros(n_cycles)
    s = np.zeros((len(pool_ids), n_cycles), dtype=np.int64)
    running = np.zeros_like(s)
    t0 = provider.now
    for c in range(n_cycles):
        provider.advance(t0 + c * interval)
        times[c] = provider.now
        s[:, c] = collector.run_cycle(c)
        for i, pid in enumerate(pool_ids):
            running[i, c] = provider.running_count(pid)

    # node-pool compute cost: integrate running counts over the campaign
    node_cost = 0.0
    for i, pid in enumerate(pool_ids):
        price = provider.pool_config(pid).price_per_hour
        node_cost += float(running[i].sum()) * interval / 3600.0 * price

    return CampaignResult(
        pool_ids=pool_ids,
        times=times,
        s=s,
        running=running,
        n=n_requests,
        interval=interval,
        interruptions=list(provider.interruptions),
        probe_compute_cost=collector.probe_compute_cost(),
        node_pool_cost=node_cost,
        api_calls=provider.api_calls,
    )
